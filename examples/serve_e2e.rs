//! END-TO-END VALIDATION DRIVER (EXPERIMENTS.md §E2E).
//!
//! Exercises the full three-layer stack on a real small workload:
//! the byte-level LM *trained at artifact-build time* (loss curve in
//! artifacts/loss_curve.json) is served through the Rust coordinator
//! (continuous batching, slot KV cache) executing the AOT PJRT artifacts —
//! once in BF16 and once in FP8 (static per-tensor) — and reports the
//! latency/throughput comparison plus sample generations.
//!
//! ```text
//! make artifacts && cargo run --release --example serve_e2e
//! ```

use std::path::Path;

use gaudi_fp8::coordinator::{Engine, EngineConfig};
use gaudi_fp8::server::workload::{WorkloadConfig, WorkloadGen};
use gaudi_fp8::util::json::Json;
use gaudi_fp8::util::render_table;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    if !dir.join("meta.json").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(1);
    }

    // Training evidence: the served model is real (trained), not random.
    if let Ok(text) = std::fs::read_to_string(dir.join("loss_curve.json")) {
        if let Ok(j) = Json::parse(&text) {
            let loss = j.get("loss").and_then(Json::as_f32_vec).unwrap_or_default();
            if loss.len() >= 2 {
                println!(
                    "byte-LM training: loss {:.3} → {:.3} over {} logged steps\n",
                    loss[0],
                    loss[loss.len() - 1],
                    loss.len()
                );
            }
        }
    }

    let wl = WorkloadConfig {
        requests: 24,
        prompt_len_min: 8,
        prompt_len_max: 48,
        max_new_min: 12,
        max_new_max: 28,
        seed: 42,
    };

    let mut rows = Vec::new();
    let mut samples: Vec<(String, String)> = Vec::new();
    for variant in ["bf16", "fp8_pt", "fp8_pc"] {
        let mut engine = Engine::new(EngineConfig::new(dir, variant))?;
        let tw = std::time::Instant::now();
        engine.warmup()?; // compile artifacts outside the timed window
        println!("[{variant}] warmup (XLA compile) {:.1}s", tw.elapsed().as_secs_f64());
        let t0 = std::time::Instant::now();
        engine.metrics = gaudi_fp8::coordinator::ServeMetrics::new();
        let reqs = WorkloadGen::new(wl.clone()).generate_all();
        for r in reqs {
            engine.submit(r);
        }
        let outs = engine.run_to_completion()?;
        let wall = t0.elapsed().as_secs_f64();
        let m = &engine.metrics;
        rows.push(vec![
            variant.to_string(),
            outs.len().to_string(),
            format!("{:.0}", m.generated_tokens as f64 / wall),
            format!("{:.1}", m.ttft.mean_s() * 1e3),
            format!("{:.1}", m.ttft.p95_s() * 1e3),
            format!("{:.2}", m.tpot.mean_s() * 1e3),
            format!("{:.2}", m.mean_decode_batch()),
            format!("{:.1}s", wall),
        ]);
        if variant != "fp8_pc" {
            let o = outs.iter().find(|o| o.id == 0).unwrap();
            let text: String = o.tokens.iter().map(|t| *t as u8 as char).collect();
            samples.push((variant.to_string(), text));
        }
    }
    println!(
        "{}",
        render_table(
            "E2E serving — trained byte-LM, 24 batched requests, full stack",
            &[
                "variant",
                "done",
                "tok/s",
                "ttft ms",
                "ttft p95",
                "tpot ms",
                "mean batch",
                "wall"
            ],
            &rows
        )
    );
    println!("\nsample generations (request 0):");
    for (v, text) in &samples {
        println!("  {v:<8} {text:?}");
    }
    println!("\nNOTE: on this CPU testbed FP8 is *emulated* (decode+mul per element),");
    println!("so fp8 variants trade accuracy only; the throughput win is the Gaudi");
    println!("story — see `cargo bench` Tables 1/5/6 for the modelled speedups.");
    Ok(())
}
