//! Fleet serving demo: four simulated Gaudi 2 engines behind the router.
//!
//! Shows the full L4 story in one run:
//!   1. a Poisson open-loop workload routed by least-outstanding-tokens;
//!   2. per-replica and fleet-merged TTFT/TPOT percentiles;
//!   3. draining a replica (rolling restart) — traffic routes around it;
//!   4. typed rejections when a request can never fit (fleet-wide KV OOM).
//!
//! Run: cargo run --example fleet_serve

use gaudi_fp8::coordinator::Request;
use gaudi_fp8::router::{
    FleetConfig, FleetRouter, RoutePolicy, SimReplica, SimReplicaConfig, TimedRequest,
};
use gaudi_fp8::server::workload::{ArrivalPattern, OpenLoopConfig, WorkloadConfig};

fn fleet(replicas: usize, policy: RoutePolicy) -> FleetRouter {
    let mut router = FleetRouter::new(FleetConfig {
        policy,
        queue_capacity: 1024,
    });
    for i in 0..replicas {
        router.add_replica(Box::new(
            SimReplica::new(&format!("gaudi2-sim{i}"), SimReplicaConfig::synthetic_tiny())
                .expect("sim replica"),
        ));
    }
    router
}

fn main() -> anyhow::Result<()> {
    println!("== fleet of 4 simulated Gaudi 2 engines, least-outstanding-tokens ==");
    let mut router = fleet(4, RoutePolicy::LeastOutstandingTokens);
    let open = OpenLoopConfig {
        workload: WorkloadConfig {
            requests: 64,
            prompt_len_min: 16,
            prompt_len_max: 256,
            max_new_min: 8,
            max_new_max: 24,
            seed: 7,
        },
        pattern: ArrivalPattern::Poisson { rate_per_s: 128.0 },
    };
    let report = router.run_open_loop(open.generate())?;
    println!("{}", report.metrics.report());

    println!("\n== rolling restart: replica 0 drained, traffic routes around it ==");
    let mut router = fleet(4, RoutePolicy::LeastOutstandingTokens);
    router.drain_replica(0);
    let report = router.run_open_loop(open.generate())?;
    println!("{}", report.metrics.report());
    println!(
        "replica 0 dispatched {} (drained), others {:?}",
        router.registry.dispatched(0),
        (1..4).map(|i| router.registry.dispatched(i)).collect::<Vec<_>>()
    );

    println!("\n== typed rejection: a request no replica's KV could ever hold ==");
    // Shrink the replicas' KV to 8 blocks × 16 tokens for the demo.
    let mut tiny = SimReplicaConfig::synthetic_tiny();
    tiny.kv_blocks_override = Some(8);
    let mut router_small = FleetRouter::new(FleetConfig::default());
    for i in 0..2 {
        router_small.add_replica(Box::new(SimReplica::new(&format!("small{i}"), tiny.clone())?));
    }
    let mut arrivals: Vec<TimedRequest> = (0..4u64)
        .map(|i| TimedRequest::new(Request::new(i, vec![1; 32], 8), 0.0))
        .collect();
    arrivals.push(TimedRequest::new(Request::new(99, vec![1; 120], 64), 0.0));
    let report = router_small.run_open_loop(arrivals)?;
    println!("completed: {}", report.outputs.len());
    for r in &report.rejected {
        println!("rejected req {}: {:?}", r.id, r.reason);
    }
    Ok(())
}
