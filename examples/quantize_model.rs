//! The paper's §3.3 quantization recipe, end to end, on a synthetic model:
//!
//!   1. pick the accuracy metric + degradation threshold;
//!   2. measure the high-precision baseline;
//!   3. calibrate on a separate split;
//!   4. quantize all linears under each scaling method;
//!   5. skip edge layers (embedding / lm-head);
//!   6. select the scheme that meets the threshold with the highest
//!      modelled throughput.
//!
//! ```text
//! cargo run --release --example quantize_model [mistral|llama2|...]
//! ```

use gaudi_fp8::eval::suite::{evaluate_model, EvalConfig};
use gaudi_fp8::fp8::Fp8Format;
use gaudi_fp8::gaudisim::{gemm_time_s, Device, GemmConfig, ScalingKind};
use gaudi_fp8::model::config::{ModelConfig, ModelFamily};
use gaudi_fp8::quant::QuantScheme;

fn main() {
    let family = match std::env::args().nth(1).as_deref() {
        Some("mistral") => ModelFamily::Mistral,
        Some("mixtral") => ModelFamily::Mixtral,
        Some("llama3") => ModelFamily::Llama3,
        _ => ModelFamily::Llama2,
    };
    let cfg = ModelConfig::synthetic_small(family);
    println!("recipe target: {} ({family:?} statistics)", cfg.name);

    // Step 1: metric = commonsense-proxy accuracy; threshold = -1% (the
    // paper's typical budget). Throughput metric = modelled Gaudi-2 GEMM
    // TFLOPS for the layer shapes.
    let threshold = -1.0;
    let fmt = Fp8Format::E4M3Gaudi2;

    // Candidate schemes, fastest first (Table 1's ordering).
    let candidates = vec![
        (
            "Per Tensor (HW pow2)".to_string(),
            QuantScheme::per_tensor_hw(fmt),
            ScalingKind::PerTensorHwPow2,
        ),
        (
            "Per Tensor Scaling".to_string(),
            QuantScheme::per_tensor(fmt),
            ScalingKind::PerTensorSw,
        ),
        (
            "Per Channel Scaling".to_string(),
            QuantScheme::per_channel(fmt),
            ScalingKind::PerChannel,
        ),
        (
            "SmoothQuant".to_string(),
            QuantScheme::smoothquant(fmt, 0.5),
            ScalingKind::PerChannel,
        ),
    ];

    // Steps 2–5 happen inside evaluate_model (baseline + calibration on a
    // disjoint split + per-scheme eval; edge layers are never quantized).
    let schemes: Vec<(String, QuantScheme)> = candidates
        .iter()
        .map(|(n, s, _)| (n.clone(), *s))
        .collect();
    let rows = evaluate_model(&cfg, &schemes, &EvalConfig::default());
    println!("\nbaseline PPL {:.3}\n", rows[0].ppl);

    let dev = Device::gaudi2();
    let tput = |kind: ScalingKind| {
        gemm_time_s(
            &GemmConfig {
                m: 4096,
                k: cfg.hidden,
                n: cfg.hidden,
                scaling: kind,
            },
            &dev,
        )
        .tflops
    };

    println!(
        "{:<24} {:>9} {:>10} {:>12}  verdict",
        "scheme", "ΔCS(%)", "ΔPPL(%)", "model TFLOPS"
    );
    let mut selected: Option<(&str, f64)> = None;
    for (row, (name, _, kind)) in rows[1..].iter().zip(&candidates) {
        let t = tput(*kind);
        let pass = row.commonsense_delta_pct >= threshold;
        println!(
            "{:<24} {:>9.2} {:>10.2} {:>12.1}  {}",
            name,
            row.commonsense_delta_pct,
            row.ppl_delta_pct,
            t,
            if pass { "PASS" } else { "fail" }
        );
        if pass && selected.is_none() {
            selected = Some((name, t));
        }
    }
    match selected {
        Some((name, t)) => println!(
            "\nselected: {name} — meets the {threshold}% budget at the highest throughput ({t:.0} TFLOPS)"
        ),
        None => println!("\nno scheme met the budget; consider SmoothQuant α sweep or BF16"),
    }
}
