//! Capacity planner: "will this model fit, and how fast will it run?" —
//! the Table 5/6 workflow as a tool. For each model of the paper's zoo on
//! Gaudi 2 and Gaudi 3: weight footprint under FP8-linears, max batch per
//! context length, prefill and decode throughput.
//!
//! ```text
//! cargo run --release --example capacity_planner
//! ```

use gaudi_fp8::gaudisim::{
    decode_step_tflops, prefill_tflops, Device, E2eConfig, MemoryModel, ScalingKind,
};
use gaudi_fp8::model::config::ModelConfig;
use gaudi_fp8::util::render_table;

fn main() {
    let models = [
        ModelConfig::llama2_7b(),
        ModelConfig::llama2_13b(),
        ModelConfig::llama3_8b(),
        ModelConfig::mistral_7b(),
        ModelConfig::mixtral_8x7b(),
        ModelConfig::llama31_70b(),
    ];
    for dev in [Device::gaudi2(), Device::gaudi3()] {
        let mut rows = Vec::new();
        for m in &models {
            let mm = MemoryModel::new(dev, m.clone());
            let fits_bf16 = mm.fits_bf16(1, 2048);
            let max_b_2k = mm.max_batch_pow2(2048);
            let max_b_8k = mm.max_batch_pow2(8192);
            let e2e = E2eConfig {
                model: m.clone(),
                device: dev,
                scaling: ScalingKind::PerTensorHwPow2,
                lm_head_bf16: true,
            };
            let pf = prefill_tflops(&e2e, 2048);
            let dc = decode_step_tflops(&e2e, max_b_2k.unwrap_or(1), 2048);
            rows.push(vec![
                m.name.clone(),
                format!("{:.1} GB", mm.weight_bytes_fp8() / 1e9),
                if fits_bf16 { "yes" } else { "NO" }.into(),
                max_b_2k.map_or("-".into(), |b| b.to_string()),
                max_b_8k.map_or("-".into(), |b| b.to_string()),
                format!("{:.0}", pf.tflops),
                format!("{:.0}", dc.tflops),
            ]);
        }
        println!(
            "{}",
            render_table(
                &format!(
                    "Capacity plan — {:?} ({} GB HBM, {} TFLOPS FP8)",
                    dev.generation, dev.hbm_capacity_gb, dev.peak_fp8_tflops
                ),
                &[
                    "model",
                    "fp8 weights",
                    "bf16 fits?",
                    "maxB@2k",
                    "maxB@8k",
                    "prefill TF@2k",
                    "decode TF@2k"
                ],
                &rows
            )
        );
    }
    println!("Note the paper's §4.2.4 observation: Llama-70B fits a single Gaudi 2 only in FP8.");
}
