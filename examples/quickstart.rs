//! Quickstart: a ten-minute tour of the library.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Covers: the FP8 formats and the Gaudi2/Gaudi3 range difference, the
//! scaled FP8 GEMM (Eq. 2), calibration + scheme comparison, and a Gaudi
//! roofline query. No artifacts required.

use gaudi_fp8::calib::ActObserver;
use gaudi_fp8::fp8::{decode, encode_rne, CastMode, Fp8Format};
use gaudi_fp8::gaudisim::{gemm_time_s, Device, GemmConfig, ScalingKind};
use gaudi_fp8::quant::{QuantScheme, QuantizedLinear};
use gaudi_fp8::tensor::Tensor2;
use gaudi_fp8::util::rng::XorShiftRng;

fn main() {
    // 1. FP8 formats (paper §2.4): the same value on Gaudi 2 vs Gaudi 3.
    println!("== formats ==");
    for (fmt, label) in [
        (Fp8Format::E4M3Gaudi2, "E4M3 (Gaudi 2, ±240)"),
        (Fp8Format::E4M3, "E4M3 (Gaudi 3/OCP, ±448)"),
        (Fp8Format::E5M2, "E5M2 (±57344)"),
    ] {
        let x = 300.0f32;
        let q = decode(encode_rne(x, fmt, CastMode::SatFinite), fmt);
        println!("  {label:<28} Q(300.0) = {q}");
    }

    // 2. A quantized linear layer under different schemes.
    println!("\n== quantized linear (Eq. 2) ==");
    let mut rng = XorShiftRng::new(1);
    let w = Tensor2::randn(64, 256, 0.05, &mut rng);
    let x = Tensor2::randn_outlier_cols(32, 256, 1.0, 0.05, 300.0, &mut rng);
    let mut obs = ActObserver::new(256);
    obs.observe(&x);
    let stats = obs.finalize();
    println!("  calibrated r_x = {:.1} (Eq. 8a)", stats.r_x);
    for scheme in [
        QuantScheme::unit_scale(Fp8Format::E4M3Gaudi2),
        QuantScheme::per_tensor(Fp8Format::E4M3Gaudi2),
        QuantScheme::per_tensor_hw(Fp8Format::E4M3Gaudi2),
        QuantScheme::per_channel(Fp8Format::E4M3Gaudi2),
        QuantScheme::smoothquant(Fp8Format::E4M3Gaudi2, 0.5),
    ] {
        let q = QuantizedLinear::prepare(&w, Some(&stats), scheme);
        println!(
            "  {:<22} relative error {:.4}",
            scheme.label(),
            q.relative_error(&w, &x)
        );
    }

    // 3. What does this buy on hardware? Roofline query (Table 1).
    println!("\n== Gaudi 2 roofline (M=K=N=8192) ==");
    for scaling in [
        ScalingKind::PerTensorHwPow2,
        ScalingKind::PerTensorSw,
        ScalingKind::PerChannel,
        ScalingKind::Bf16,
    ] {
        let r = gemm_time_s(
            &GemmConfig {
                m: 8192,
                k: 8192,
                n: 8192,
                scaling,
            },
            &Device::gaudi2(),
        );
        println!(
            "  {:<28} {:>6.1} TFLOPS  (MFU {:>5.1}%)",
            scaling.label(),
            r.tflops,
            r.mfu * 100.0
        );
    }
    println!("\nNext: `make artifacts` then `cargo run --release --example serve_e2e`.");
}
