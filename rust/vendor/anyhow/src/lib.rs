//! Offline stand-in for the `anyhow` crate.
//!
//! The sandboxed build cannot reach crates.io, so this path crate provides
//! the subset of the anyhow 1.x API the workspace uses: [`Error`],
//! [`Result`], the [`anyhow!`] / [`bail!`] / [`ensure!`] macros, and the
//! [`Context`] extension trait for `Result<T, E: std::error::Error>` and
//! `Option<T>`.
//!
//! Differences from real anyhow (deliberate, to stay tiny):
//! * the error is a flattened message chain (no backtraces, no downcasting);
//! * `Context` is not implemented for `Result<T, anyhow::Error>` — call
//!   `.map_err(|e| e.context(...))` for that case.

use std::error::Error as StdError;
use std::fmt;

/// A flattened error chain. `chain[0]` is the outermost (most recently
/// attached) context; the last entry is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }

    /// Iterate the chain from outermost context to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the whole chain, like anyhow.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors, like anyhow's `Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($t)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing thing"));
    }

    #[test]
    fn context_wraps_outermost_first() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| "opening config").unwrap_err();
        assert_eq!(e.to_string(), "opening config");
        assert!(format!("{e:#}").contains("missing thing"));
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn option_context_and_macros() {
        let n: Option<u32> = None;
        assert!(n.context("empty").is_err());
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x == 0 {
                bail!("zero not allowed (got {})", x);
            }
            Ok(x)
        }
        assert!(f(5).is_ok());
        assert!(f(-1).unwrap_err().to_string().contains("negative"));
        assert!(f(0).unwrap_err().to_string().contains("zero"));
        let e = anyhow!("plain {}", 42);
        assert_eq!(e.to_string(), "plain 42");
    }
}
