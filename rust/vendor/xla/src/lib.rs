//! Offline stub of the `xla` (PJRT) bindings.
//!
//! The real crate links the xla_extension C++ library, which the sandboxed
//! build cannot download. This stub keeps the [`Literal`] host-tensor type
//! fully functional (so code that builds literals compiles and runs), while
//! HLO parsing / compilation / execution return a clear "unavailable"
//! error. The artifact-dependent integration tests skip themselves when
//! `artifacts/` is absent, so the unavailable paths are never hit in CI.

use std::fmt;

/// Error type mirroring `xla::Error` closely enough for `?` + anyhow.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} unavailable: offline stub backend (the real xla_extension \
         runtime is not bundled in this build)"
    ))
}

/// Element types the runtime layer inspects.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    U8,
    F32,
    F64,
    Bf16,
}

impl ElementType {
    /// The real bindings distinguish `ElementType` from the proto-level
    /// `PrimitiveType`; here they coincide.
    pub fn primitive_type(self) -> ElementType {
        self
    }
}

/// Array shape: dims + element type.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

#[derive(Clone, Debug)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host literal: a dense array (f32 or i32) or a tuple of literals.
#[derive(Clone, Debug)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

/// Types storable in a [`Literal`].
pub trait NativeType: Copy {
    fn element_type() -> ElementType;
    fn store(data: Vec<Self>) -> Data;
    fn extract(data: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn element_type() -> ElementType {
        ElementType::F32
    }
    fn store(data: Vec<Self>) -> Data {
        Data::F32(data)
    }
    fn extract(data: &Data) -> Option<Vec<Self>> {
        match data {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn element_type() -> ElementType {
        ElementType::S32
    }
    fn store(data: Vec<Self>) -> Data {
        Data::I32(data)
    }
    fn extract(data: &Data) -> Option<Vec<Self>> {
        match data {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            data: T::store(data.to_vec()),
        }
    }

    /// Reshape (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        let have = match &self.data {
            Data::F32(v) => v.len() as i64,
            Data::I32(v) => v.len() as i64,
            Data::Tuple(_) => return Err(unavailable("reshape of tuple literal")),
        };
        if n != have {
            return Err(Error(format!("reshape {dims:?} wants {n} elems, literal has {have}")));
        }
        Ok(Literal {
            dims: dims.to_vec(),
            data: self.data.clone(),
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        let ty = match &self.data {
            Data::F32(_) => ElementType::F32,
            Data::I32(_) => ElementType::S32,
            Data::Tuple(_) => return Err(Error("tuple literal has no array shape".into())),
        };
        Ok(ArrayShape {
            dims: self.dims.clone(),
            ty,
        })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(&self.data)
            .ok_or_else(|| Error("literal element type mismatch in to_vec".into()))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.data {
            Data::Tuple(parts) => Ok(parts.clone()),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }

    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal {
            dims: Vec::new(),
            data: Data::Tuple(parts),
        }
    }

    /// Element-type conversion (stub supports f32 ↔ i32).
    pub fn convert(&self, ty: ElementType) -> Result<Literal> {
        let data = match (&self.data, ty) {
            (Data::F32(v), ElementType::F32) => Data::F32(v.clone()),
            (Data::I32(v), ElementType::S32) => Data::I32(v.clone()),
            (Data::I32(v), ElementType::F32) => Data::F32(v.iter().map(|x| *x as f32).collect()),
            (Data::F32(v), ElementType::S32) => Data::I32(v.iter().map(|x| *x as i32).collect()),
            _ => return Err(unavailable("literal conversion for this type pair")),
        };
        Ok(Literal {
            dims: self.dims.clone(),
            data,
        })
    }
}

impl From<i32> for Literal {
    fn from(v: i32) -> Self {
        Literal {
            dims: Vec::new(),
            data: Data::I32(vec![v]),
        }
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

/// Parsed HLO module (stub: never constructed — parsing is unavailable).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(unavailable(&format!("parsing HLO text {path:?}")))
    }
}

/// XLA computation wrapper.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client. The stub "CPU client" constructs fine (so registries and
/// engines can be built and report errors lazily) but cannot compile.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("XLA compilation"))
    }
}

/// Device buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("device-to-host transfer"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T: AsRef<Literal>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("XLA execution"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        let shape = r.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 2]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn scalar_and_convert() {
        let s = Literal::from(7i32);
        assert_eq!(s.to_vec::<i32>().unwrap(), vec![7]);
        let f = s.convert(ElementType::F32.primitive_type()).unwrap();
        assert_eq!(f.to_vec::<f32>().unwrap(), vec![7.0]);
    }

    #[test]
    fn tuple_and_unavailable_paths() {
        let t = Literal::tuple(vec![Literal::from(1), Literal::from(2)]);
        assert_eq!(t.to_tuple().unwrap().len(), 2);
        assert!(Literal::from(1).to_tuple().is_err());
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "stub-cpu");
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let exe = PjRtLoadedExecutable { _private: () };
        assert!(exe.execute::<Literal>(&[]).is_err());
    }
}
