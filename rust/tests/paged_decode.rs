//! Block-table-native decode acceptance suite (ISSUE 5).
//!
//! * **Zero dense materialization**: a decode step's reads, instrumented
//!   at the pool, equal the sum over the group of each slot's live block
//!   bytes — no bucket padding, no window padding — for every KV dtype.
//! * **Read parity**: per-slot paged reads (block-tile dequant through
//!   [`PagedAttentionView`]) are bit-identical to the dense reference
//!   gather for f32/bf16 *and* fp8 (same codes, same scales, same decode
//!   arithmetic), and the online-softmax paged attention readout matches
//!   a two-pass dense-reference softmax to f32 roundoff.
//! * **Write parity**: the paged `append_token` stays within PR 2's
//!   half-ulp bound (per block-level scale group) of the dense
//!   gather→poke→scatter reference for fp8, bit-identical for f32/bf16.
//! * **Beam fork** (satellite): a width-2 beam over `fork_slot` shares
//!   history refcounts and isolates branch writes.
//! * **Append edge cases** (satellite): block-boundary append, append
//!   into a shared last block (forces payload-copying CoW against a
//!   prefix-cache owner), and append past capacity keeps returning the
//!   "sequence full" signal the engine's `maybe_finish` retires on.

use gaudi_fp8::coordinator::{
    AppendOutcome, AttendOptions, Dequant, KvStore, PrefixCache, PrefixCacheConfig,
};
use gaudi_fp8::quant::{KvDtype, KvLayout};
use gaudi_fp8::util::pool::Parallelism;
use gaudi_fp8::util::rng::XorShiftRng;

const LAYERS: usize = 2;
const KVH: usize = 2;
const HD: usize = 4;
const ROW: usize = KVH * HD;
const T: usize = 48;
const BT: usize = 8;

fn store(dtype: KvDtype, slots: usize, extra_blocks: usize) -> KvStore {
    KvStore::with_block_tokens(LAYERS, slots, T, KVH, HD, dtype, BT, extra_blocks)
}

fn randn(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = XorShiftRng::new(seed);
    (0..n).map(|_| rng.normal()).collect()
}

const ALL_DTYPES: [KvDtype; 3] = [KvDtype::F32, KvDtype::Bf16, KvDtype::FP8_DEFAULT];

#[test]
fn decode_step_reads_exactly_the_groups_live_block_bytes() {
    // The acceptance criterion verbatim: bytes read per step == Σ over the
    // group of each slot's live block bytes, ragged lengths included.
    for dtype in ALL_DTYPES {
        let mut s = store(dtype, 4, 0);
        let n = LAYERS * T * ROW;
        let (k, v) = (randn(n, 1), randn(n, 2));
        let lens = [3usize, 8, 21, 48];
        let mut group = Vec::new();
        for &len in &lens {
            let slot = s.alloc_slot().unwrap();
            s.write_slot(slot, &k, &v, len);
            group.push(slot);
        }
        s.pool().reset_bytes_read();
        let _ = s.decode_attention_probe(&group, 99);
        let layout = KvLayout::new(dtype, LAYERS, KVH, HD);
        let expect: usize = lens
            .iter()
            .map(|&l| l.div_ceil(BT) * layout.block_bytes(BT))
            .sum();
        assert_eq!(s.pool().bytes_read(), expect as u64, "{dtype:?}");
        let view = s.paged_view(&group);
        assert_eq!(view.live_block_bytes(), expect, "{dtype:?}");
        // No bucket padding: a dense step would charge 4 full windows.
        let dense = 4 * T.div_ceil(BT) * layout.block_bytes(BT);
        assert!(expect < dense, "{dtype:?}");
    }
}

#[test]
fn paged_reads_are_bit_identical_to_the_dense_reference_gather() {
    // Same codes, same scales, same dequant arithmetic: assembling the
    // valid positions from block-tile reads must reproduce the dense
    // gather bit-for-bit — for fp8 too, since dequant-on-read shares the
    // per-block scale refs with the gather path.
    for dtype in ALL_DTYPES {
        let mut s = store(dtype, 1, 0);
        let n = LAYERS * T * ROW;
        let (kin, vin) = (randn(n, 3), randn(n, 4));
        let slot = s.alloc_slot().unwrap();
        let len = 21usize; // partial tail block
        s.write_slot(slot, &kin, &vin, len);
        let (kg, vg, _) = s.gather_batch(&[slot]);
        let view = s.paged_view(&[slot]);
        let mut k_tile = vec![0.0f32; BT * HD];
        let mut v_tile = vec![0.0f32; BT * HD];
        for l in 0..LAYERS {
            for h in 0..KVH {
                for (bi, &id) in view.slot(0).blocks.iter().enumerate() {
                    view.pool().read_block_head(id, l, h, &mut k_tile, &mut v_tile);
                    let tok0 = bi * BT;
                    for ti in 0..BT.min(len - tok0.min(len)) {
                        let p = tok0 + ti;
                        if p >= len {
                            break;
                        }
                        for d in 0..HD {
                            let dense_i = (l * T + p) * ROW + h * HD + d;
                            let tile_i = ti * HD + d;
                            assert_eq!(
                                k_tile[tile_i].to_bits(),
                                kg[dense_i].to_bits(),
                                "{dtype:?} K at (l {l}, h {h}, p {p}, d {d})"
                            );
                            assert_eq!(v_tile[tile_i].to_bits(), vg[dense_i].to_bits());
                        }
                    }
                }
            }
        }
        // FP8 exposes its per-block scale refs through the view.
        match dtype {
            KvDtype::Fp8(_) => {
                let (ks, vs) = view.block_scales(0, 0, 0).expect("fp8 scales");
                assert_eq!(ks.len(), KVH);
                assert!(ks.iter().chain(vs.iter()).all(|x| *x > 0.0));
            }
            _ => assert!(view.block_scales(0, 0, 0).is_none()),
        }
    }
}

#[test]
fn paged_attention_readout_matches_two_pass_dense_reference() {
    // The online softmax over block tiles vs a two-pass softmax over the
    // dense gather: identical math, different accumulation order — agree
    // to f32 roundoff.
    let mut s = store(KvDtype::F32, 1, 0);
    let n = LAYERS * T * ROW;
    let (kin, vin) = (randn(n, 7), randn(n, 8));
    let slot = s.alloc_slot().unwrap();
    let len = 37usize;
    s.write_slot(slot, &kin, &vin, len);
    let (kg, vg, _) = s.gather_batch(&[slot]);
    let view = s.paged_view(&[slot]);
    let q = randn(HD, 9);
    for l in 0..LAYERS {
        for h in 0..KVH {
            let paged = view.attend(0, l, h, &q);
            // Dense two-pass reference.
            let mut scores = Vec::with_capacity(len);
            for p in 0..len {
                let off = (l * T + p) * ROW + h * HD;
                let mut sdot = 0.0f32;
                for d in 0..HD {
                    sdot += q[d] * kg[off + d];
                }
                scores.push(sdot / (HD as f32).sqrt());
            }
            let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let ws: Vec<f32> = scores.iter().map(|x| (x - m).exp()).collect();
            let z: f32 = ws.iter().sum();
            for d in 0..HD {
                let mut acc = 0.0f32;
                for (p, w) in ws.iter().enumerate() {
                    let off = (l * T + p) * ROW + h * HD;
                    acc += w * vg[off + d];
                }
                acc /= z;
                assert!(
                    (acc - paged[d]).abs() <= 1e-4 * (1.0 + acc.abs()),
                    "(l {l}, h {h}, d {d}): dense {acc} vs paged {}",
                    paged[d]
                );
            }
        }
    }
}

#[test]
fn fp8_append_stays_within_half_ulp_of_the_dense_write_reference() {
    // Two fp8 stores take the same logical tokens through the two write
    // paths; reads must agree within PR 2's half-ulp bound per
    // (block, layer, kv-head) scale group. (In practice they are
    // bit-identical — append re-encodes from the same dequantized history
    // — but the contract we pin is the half-ulp bound.)
    let dtype = KvDtype::FP8_DEFAULT;
    let half_ulp_rel = (2.0f32).powi(-4); // E4M3: 3 mantissa bits → 2^-(3+1)
    let mut a = store(dtype, 1, 0);
    let mut b = store(dtype, 1, 0);
    let sa = a.alloc_slot().unwrap();
    let sb = b.alloc_slot().unwrap();
    let n = LAYERS * T * ROW;
    let (k0, v0) = (randn(n, 21), randn(n, 22));
    let base_len = 14usize;
    a.write_slot(sa, &k0, &v0, base_len);
    b.write_slot(sb, &k0, &v0, base_len);
    let mut rng = XorShiftRng::new(23);
    for _ in 0..6 {
        let kr: Vec<f32> = (0..LAYERS * ROW).map(|_| rng.normal()).collect();
        let vr: Vec<f32> = (0..LAYERS * ROW).map(|_| rng.normal()).collect();
        assert_ne!(a.append_token(sa, &kr, &vr), AppendOutcome::AtCapacity);
        let (mut kg, mut vg, _) = b.gather_batch(&[sb]);
        let len = b.len(sb).unwrap();
        for l in 0..LAYERS {
            let at = (l * T + len) * ROW;
            kg[at..at + ROW].copy_from_slice(&kr[l * ROW..(l + 1) * ROW]);
            vg[at..at + ROW].copy_from_slice(&vr[l * ROW..(l + 1) * ROW]);
        }
        b.scatter_batch(&[sb], &kg, &vg);
    }
    let (ka, va, la) = a.gather_batch(&[sa]);
    let (kb, vb, lb) = b.gather_batch(&[sb]);
    assert_eq!(la, lb);
    let len = la[0] as usize;
    for (x, y, name) in [(&ka, &kb, "K"), (&va, &vb, "V")] {
        for blk in 0..len.div_ceil(BT) {
            let tok0 = blk * BT;
            let tokn = BT.min(len - tok0);
            for l in 0..LAYERS {
                for h in 0..KVH {
                    let mut maxabs = 0.0f32;
                    for p in tok0..tok0 + tokn {
                        for d in 0..HD {
                            let i = (l * T + p) * ROW + h * HD + d;
                            maxabs = maxabs.max(y[i].abs());
                        }
                    }
                    let bound = maxabs * half_ulp_rel * 1.001 + 1e-30;
                    for p in tok0..tok0 + tokn {
                        for d in 0..HD {
                            let i = (l * T + p) * ROW + h * HD + d;
                            assert!(
                                (x[i] - y[i]).abs() <= bound,
                                "{name}[blk {blk}, l {l}, h {h}, p {p}]: \
                                 append {} vs dense {}",
                                x[i],
                                y[i]
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn width_2_beam_forks_share_history_and_write_privately() {
    // The beam-fork smoke test: one prompt, two beams, a few divergent
    // decode steps — shared-history refcounts stay balanced and each
    // beam reads only its own continuation.
    let mut s = store(KvDtype::F32, 3, 0);
    let n = LAYERS * T * ROW;
    let root = s.alloc_slot().unwrap();
    let mut prompt = vec![0.0f32; n];
    for (i, x) in prompt.iter_mut().enumerate() {
        *x = (i % 13) as f32 * 0.5;
    }
    let plen = 2 * BT + 3; // two full shared blocks + a partial hot block
    s.write_slot(root, &prompt, &prompt, plen);
    let beam = s.fork_slot(root).expect("beam slot");
    let shared = s.slot_blocks(root);
    assert_eq!(shared.len(), 3);
    for &id in &shared {
        assert_eq!(s.pool().ref_count(id), 2, "both beams read block {id}");
    }
    // Diverge for several steps, crossing a block boundary on the way.
    for step in 0..BT {
        let a = vec![1000.0 + step as f32; LAYERS * ROW];
        let b = vec![2000.0 + step as f32; LAYERS * ROW];
        assert_eq!(s.append_token(root, &a, &a), AppendOutcome::Appended);
        assert_eq!(s.append_token(beam, &b, &b), AppendOutcome::Appended);
    }
    // Shared prompt blocks keep both readers; the diverged tail is private.
    assert_eq!(s.pool().ref_count(shared[0]), 2);
    assert_eq!(s.pool().ref_count(shared[1]), 2);
    let (rb, bb) = (s.slot_blocks(root), s.slot_blocks(beam));
    assert_ne!(rb[2], bb[2], "hot block CoW'd at the fork point");
    for blocks in [&rb, &bb] {
        for &id in &blocks[2..] {
            assert_eq!(s.pool().ref_count(id), 1, "beam tail must be private");
        }
    }
    // Each beam reads the shared prompt plus exactly its own tokens.
    let (kr, _, _) = s.gather_batch(&[root]);
    let (kb, _, _) = s.gather_batch(&[beam]);
    for p in 0..plen {
        for e in 0..ROW {
            let i = p * ROW + e;
            assert_eq!(kr[i], prompt[i], "root prompt intact");
            assert_eq!(kb[i], prompt[i], "beam prompt intact");
        }
    }
    for step in 0..BT {
        let i = (plen + step) * ROW;
        assert!(kr[i..i + ROW].iter().all(|x| *x == 1000.0 + step as f32));
        assert!(kb[i..i + ROW].iter().all(|x| *x == 2000.0 + step as f32));
    }
    // Releasing one beam returns only its private tail.
    let used_before = s.pool().used_blocks();
    s.free_slot(beam);
    assert_eq!(s.pool().ref_count(shared[0]), 1);
    assert!(s.pool().used_blocks() < used_before);
    s.free_slot(root);
    assert_eq!(s.pool().used_blocks(), 0, "no leaked beam blocks");
}

#[test]
fn append_into_a_shared_last_block_cows_away_from_the_prefix_cache() {
    // The engine's full-hit bootstrap shape: a cached prefix is mapped
    // with the write position *inside* the last shared block (owned by
    // the prefix cache); the paged append must clone that block's valid
    // history before writing, leaving the cached original untouched.
    let mut s = store(KvDtype::F32, 2, 8);
    let mut pc = PrefixCache::new(PrefixCacheConfig {
        block_tokens: BT,
        max_blocks: 8,
        layout: KvLayout::new(KvDtype::F32, LAYERS, KVH, HD),
    });
    let n = LAYERS * T * ROW;
    let writer = s.alloc_slot().unwrap();
    let mut kp = vec![0.0f32; n];
    for (i, x) in kp.iter_mut().enumerate() {
        *x = 5.0 + (i % 17) as f32;
    }
    let plen = 2 * BT; // block-aligned: fully cacheable
    let prompt: Vec<i32> = (0..plen as i32).collect();
    s.write_slot(writer, &kp, &kp, plen);
    let blocks = s.slot_blocks(writer);
    pc.insert_shared(&prompt, &blocks, s.pool_mut());
    s.free_slot(writer);
    assert_eq!(s.pool().used_blocks(), 2, "cache owns the prompt blocks");

    // Warm start at len = plen − 1: the bootstrap append lands inside the
    // last *cached* block.
    let reader = s.alloc_slot().unwrap();
    let ids = pc.mapped_blocks(&prompt, plen).expect("physical hit");
    s.map_shared_prefix(reader, &ids, plen - 1);
    assert_eq!(s.pool().ref_count(ids[1]), 2, "cache + reader");
    let kr = vec![777.0f32; LAYERS * ROW];
    assert_eq!(s.append_token(reader, &kr, &kr), AppendOutcome::Appended);
    let rb = s.slot_blocks(reader);
    assert_eq!(rb[0], ids[0], "cold shared block still mapped");
    assert_ne!(rb[1], ids[1], "hot block must be cloned away from the cache");
    assert_eq!(s.pool().ref_count(ids[1]), 1, "cache keeps its original");
    // The clone carried the valid history; position plen−1 is the write.
    let (kg, _, _) = s.gather_batch(&[reader]);
    for p in 0..plen - 1 {
        for e in 0..ROW {
            assert_eq!(kg[p * ROW + e], kp[p * ROW + e], "cloned history at {p}");
        }
    }
    let at = (plen - 1) * ROW;
    assert!(kg[at..at + ROW].iter().all(|x| *x == 777.0));
    // The cached original still holds the writer's bytes: map it fresh.
    let check = s.alloc_slot().unwrap();
    let ids2 = pc.mapped_blocks(&prompt, plen).expect("still cached");
    s.map_shared_prefix(check, &ids2, plen);
    let (kc, _, _) = s.gather_batch(&[check]);
    for p in 0..plen {
        for e in 0..ROW {
            assert_eq!(kc[p * ROW + e], kp[p * ROW + e], "cache corrupted at {p}");
        }
    }
}

#[test]
fn append_past_capacity_keeps_signalling_sequence_full() {
    // The retirement contract `maybe_finish` relies on: reaching t reports
    // Full, every further attempt reports AtCapacity, and nothing ever
    // writes past the window.
    let mut s = store(KvDtype::F32, 1, 0);
    let slot = s.alloc_slot().unwrap();
    let n = LAYERS * T * ROW;
    s.write_slot(slot, &vec![1.0; n], &vec![1.0; n], T - 1);
    let kr = vec![9.0f32; LAYERS * ROW];
    assert_eq!(s.append_token(slot, &kr, &kr), AppendOutcome::Full);
    assert_eq!(s.len(slot), Some(T));
    assert!(s.is_full(slot));
    let (before, _, _) = s.gather_batch(&[slot]);
    for _ in 0..3 {
        assert_eq!(s.append_token(slot, &kr, &kr), AppendOutcome::AtCapacity);
    }
    assert_eq!(s.len(slot), Some(T));
    let (after, _, _) = s.gather_batch(&[slot]);
    assert_eq!(before, after, "at-capacity appends must not write");
}

/// Build a ragged-length multi-slot store and return (store, group) —
/// the shape the worker-count axis has to keep deterministic.
fn ragged_store(dtype: KvDtype, seed: u64) -> (KvStore, Vec<usize>) {
    let lens = [3usize, 8, 21, 48, 1, 30];
    let mut s = store(dtype, lens.len(), 0);
    let n = LAYERS * T * ROW;
    let mut group = Vec::new();
    for (i, &len) in lens.iter().enumerate() {
        let slot = s.alloc_slot().unwrap();
        let (k, v) = (randn(n, seed + 2 * i as u64), randn(n, seed + 2 * i as u64 + 1));
        s.write_slot(slot, &k, &v, len);
        group.push(slot);
    }
    (s, group)
}

#[test]
fn attend_output_and_bytes_are_identical_for_every_worker_count() {
    // ISSUE 8 determinism contract: the data-parallel single-entry read
    // path must be bit-identical to the serial path at any worker count —
    // tiles reduce per task in block order regardless of which worker runs
    // the task — and `bytes_read` must stay byte-exact (relaxed atomic
    // adds of per-call constants are order-independent).
    let seed = std::env::var("PAGED_KV_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0xB10C_5EED);
    let ncpu = std::thread::available_parallelism().map_or(4, |n| n.get());
    for dtype in ALL_DTYPES {
        let (s, group) = ragged_store(dtype, seed);
        s.pool().reset_bytes_read();
        let serial = s.decode_attention_probe_opts(
            &group,
            seed ^ 0x5EED,
            &AttendOptions::sequential(),
        );
        let serial_bytes = s.pool().bytes_read();
        assert!(serial_bytes > 0, "{dtype:?}: probe must read blocks");
        for workers in [1usize, 2, 7, ncpu] {
            let opts = AttendOptions {
                parallelism: Parallelism::Fixed(workers),
                dequant: Dequant::default(),
            };
            s.pool().reset_bytes_read();
            let out = s.decode_attention_probe_opts(&group, seed ^ 0x5EED, &opts);
            assert_eq!(out.len(), serial.len());
            for (i, (a, r)) in out.iter().zip(&serial).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    r.to_bits(),
                    "{dtype:?}: output diverged at {i} with {workers} workers"
                );
            }
            assert_eq!(
                s.pool().bytes_read(),
                serial_bytes,
                "{dtype:?}: bytes_read drifted at {workers} workers"
            );
        }
    }
}

#[test]
fn lut_and_scalar_dequant_read_bit_identically() {
    // The shared 256-entry decode table holds exactly `decode(code) * 1.0`
    // per code, and the pre-scaled tile LUT multiplies the same two f32
    // operands the scalar path does — so Lut vs Scalar attend outputs are
    // bit-identical, not merely close.
    let (s, group) = ragged_store(KvDtype::FP8_DEFAULT, 0xD0_D0);
    let lut = s.decode_attention_probe_opts(
        &group,
        77,
        &AttendOptions {
            parallelism: Parallelism::Sequential,
            dequant: Dequant::Lut,
        },
    );
    let scalar = s.decode_attention_probe_opts(
        &group,
        77,
        &AttendOptions {
            parallelism: Parallelism::Sequential,
            dequant: Dequant::Scalar,
        },
    );
    assert_eq!(lut.len(), scalar.len());
    for (i, (a, b)) in lut.iter().zip(&scalar).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "Lut vs Scalar diverged at {i}");
    }
    // And the raw tile reads agree too, not just the softmax readout.
    let view = s.paged_view(&group);
    let id = view.slot(0).blocks[0];
    let (mut kl, mut vl) = (vec![0.0f32; BT * HD], vec![0.0f32; BT * HD]);
    let (mut ks, mut vs) = (vec![0.0f32; BT * HD], vec![0.0f32; BT * HD]);
    view.pool()
        .read_block_head_with(id, 0, 0, &mut kl, &mut vl, Dequant::Lut);
    view.pool()
        .read_block_head_with(id, 0, 0, &mut ks, &mut vs, Dequant::Scalar);
    for i in 0..BT * HD {
        assert_eq!(kl[i].to_bits(), ks[i].to_bits(), "K tile at {i}");
        assert_eq!(vl[i].to_bits(), vs[i].to_bits(), "V tile at {i}");
    }
}
