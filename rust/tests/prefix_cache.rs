//! Integration: the radix-tree prefix KV cache and chunked prefill
//! (ISSUE 3 acceptance).
//!
//! * Interleaved insert/acquire/release/evict never dangles a block
//!   refcount, and eviction never frees a block an active sequence pins.
//! * A full-hit prompt produces a zero-tail prefill plan.
//! * Two requests sharing a 512-token prefix are resident *concurrently*
//!   under a byte budget that forces strict serialization without the
//!   cache (the capacity-per-dollar mechanism at admission level).
//! * The fleet serves shared-prefix traffic end to end with hits counted
//!   in the merged metrics.

use gaudi_fp8::coordinator::{
    chunk_spans, AdmissionQueue, KvStore, PrefixCache, PrefixCacheConfig, Request, SchedulePolicy,
    Scheduler,
};
use gaudi_fp8::quant::{KvDtype, KvLayout};
use gaudi_fp8::router::{
    FleetConfig, FleetRouter, ReplicaHandle, RoutePolicy, SimReplica, SimReplicaConfig,
    TimedRequest,
};
use gaudi_fp8::util::rng::XorShiftRng;

fn tiny_layout() -> KvLayout {
    KvLayout::new(KvDtype::FP8_DEFAULT, 4, 2, 32)
}

fn cache(block_tokens: usize, max_blocks: usize) -> PrefixCache {
    PrefixCache::new(PrefixCacheConfig {
        block_tokens,
        max_blocks,
        layout: tiny_layout(),
    })
}

#[test]
fn full_hit_prompt_produces_zero_tail_plan() {
    let sched = Scheduler::new(
        SchedulePolicy::PrefillFirst,
        vec![16, 32, 64, 128, 256],
        vec![1, 2, 4],
    );
    let prompt = vec![42i32; 128];
    let mut pc = cache(16, 64);
    pc.insert(&prompt);

    let mut q = AdmissionQueue::new(8);
    q.push(Request::new(1, prompt.clone(), 8)).unwrap();
    let mut kv = KvStore::new(4, 2, 256, 2, 32);
    let plan = sched.plan_with_prefix(&q, &mut kv, Some(&pc), 32, true);
    let pp = plan.prefill.expect("full hit admits");
    assert_eq!(pp.cached_tokens, 128);
    assert!(pp.chunks.is_empty(), "full hit ⇒ zero-tail prefill plan");
    // The same prompt one token longer has a one-token tail.
    let mut longer = prompt.clone();
    longer.push(7);
    let mut q2 = AdmissionQueue::new(8);
    q2.push(Request::new(2, longer, 8)).unwrap();
    let mut kv2 = KvStore::new(4, 2, 256, 2, 32);
    let plan = sched.plan_with_prefix(&q2, &mut kv2, Some(&pc), 32, true);
    let pp = plan.prefill.expect("partial hit admits");
    assert_eq!(pp.cached_tokens, 128);
    assert_eq!(pp.chunks, vec![(128, 1)]);
    assert_eq!(chunk_spans(129, 128, 32), vec![(128, 1)]);
}

/// Random interleave of every cache operation over a prefix-sharing prompt
/// family: per-block refcounts must balance exactly, eviction must never
/// free a pinned block, and draining all pins must leave the cache fully
/// evictable.
#[test]
fn interleaved_ops_never_dangle_refcounts_or_free_pinned_blocks() {
    let bt = 16usize;
    let mut pc = cache(bt, 48);
    let mut rng = XorShiftRng::new(0x5EED);
    // 12 prompts: 4 roots × 3 extensions, sharing 2–6 blocks.
    let family: Vec<Vec<i32>> = (0..12)
        .map(|i| {
            let root = (i / 3) as i32;
            let ext = (i % 3) as i32;
            let mut p = vec![root; bt * 2];
            p.extend(vec![100 + root * 8 + ext; bt * (1 + ext as usize)]);
            p.extend(vec![200 + i as i32; bt]);
            p
        })
        .collect();
    let mut live: Vec<(usize, usize)> = Vec::new();
    for step in 0..4000 {
        match rng.below(5) {
            0 | 1 => {
                let i = rng.below(family.len());
                let got = pc.acquire(&family[i]);
                live.push((i, got));
            }
            2 => {
                if !live.is_empty() {
                    let (i, got) = live.swap_remove(rng.below(live.len()));
                    pc.release(&family[i], got);
                }
            }
            3 => {
                let i = rng.below(family.len());
                pc.insert(&family[i]);
            }
            _ => {
                pc.evict_blocks(1 + rng.below(8));
            }
        }
        let expected: u64 = live.iter().map(|(_, t)| (t / bt) as u64).sum();
        assert_eq!(pc.total_refs(), expected, "refcount drift at step {step}");
        assert!(pc.referenced_blocks() <= pc.cached_blocks());
        assert!(pc.cached_blocks() <= pc.max_blocks());
        for (i, t) in &live {
            assert!(
                pc.lookup(&family[*i]) >= *t,
                "step {step}: eviction freed a pinned path"
            );
        }
    }
    for (i, got) in live.drain(..) {
        pc.release(&family[i], got);
    }
    assert_eq!(pc.total_refs(), 0, "all pins must drain");
    pc.evict_blocks(usize::MAX);
    assert_eq!(pc.cached_blocks(), 0, "unpinned cache must drain fully");
}

#[test]
fn eviction_never_frees_blocks_referenced_by_an_active_sequence() {
    let mut pc = cache(16, 64);
    let hot = vec![1i32; 64];
    let cold = vec![2i32; 64];
    pc.insert(&hot);
    pc.insert(&cold);
    let pinned = pc.acquire(&hot);
    assert_eq!(pinned, 64);
    // Demand far exceeds what is evictable; only the cold path may go.
    let freed = pc.evict_blocks(usize::MAX);
    assert_eq!(freed, 4, "only the 4 unpinned blocks are evictable");
    assert_eq!(pc.lookup(&hot), 64, "pinned prefix must survive");
    assert_eq!(pc.lookup(&cold), 0);
    pc.release(&hot, pinned);
    assert_eq!(pc.evict_blocks(usize::MAX), 4);
}

/// ISSUE 3 acceptance: two requests sharing a 512-token prefix are both
/// resident under a KV *byte* budget that admits only one at a time
/// without the cache. 48 blocks × 16 tokens × 512 B/token; each request
/// needs blocks_for(512 + 16) = 33 blocks dedicated, but only 1 private
/// block once the shared prefix (32 blocks) is pool-charged to the cache.
#[test]
fn shared_512_prefix_admits_concurrently_under_byte_budget() {
    let budget_bytes = 48.0 * 16.0 * 512.0; // 48 blocks at the tiny fp8 rate
    let mk = |prefix_cache: bool| {
        let mut cfg = SimReplicaConfig::synthetic_tiny();
        cfg.prefix_cache = prefix_cache;
        cfg.kv_bytes_budget_override = Some(budget_bytes);
        SimReplica::new("budget", cfg).unwrap()
    };
    let prompt = vec![9i32; 512];

    // Without the cache: the byte budget serializes — every decode step
    // runs at batch 1 and the second request waits for the first retire.
    let mut r = mk(false);
    assert_eq!(r.allocator().total_blocks, 48);
    r.submit(Request::new(0, prompt.clone(), 16), 0.0);
    r.submit(Request::new(1, prompt.clone(), 16), 0.0);
    let mut peak_active = 0;
    while r.has_work() {
        r.step().unwrap();
        peak_active = peak_active.max(r.active());
    }
    assert_eq!(r.metrics().requests_completed, 2);
    assert_eq!(peak_active, 1, "without sharing the budget must serialize");
    assert_eq!(r.metrics().mean_decode_batch(), 1.0);
    let serial_makespan = r.clock_s();

    // With the cache: the prefix is charged once, both admit, decode
    // batches, and the makespan shrinks.
    let mut r = mk(true);
    r.submit(Request::new(0, prompt.clone(), 16), 0.0);
    r.submit(Request::new(1, prompt.clone(), 16), 0.0);
    let mut peak_active = 0;
    while r.has_work() {
        r.step().unwrap();
        peak_active = peak_active.max(r.active());
    }
    assert_eq!(r.metrics().requests_completed, 2);
    assert_eq!(peak_active, 2, "shared prefix must admit concurrently");
    assert!(r.metrics().mean_decode_batch() > 1.0);
    assert_eq!(r.metrics().prefix_hits, 1);
    assert_eq!(r.metrics().prefix_hit_tokens, 512);
    assert!(r.clock_s() < serial_makespan);
    // Exact pool accounting at the end: free + cache-held = total.
    let held = r.prefix_cache().unwrap().cached_blocks();
    assert_eq!(r.allocator().free_blocks() + held, r.allocator().total_blocks);
    assert_eq!(r.prefix_cache().unwrap().total_refs(), 0);
}

#[test]
fn fleet_serves_shared_prefix_traffic_with_hits_in_merged_metrics() {
    let mut cfg = SimReplicaConfig::synthetic_tiny();
    cfg.prefix_cache = true;
    let mut router = FleetRouter::new(FleetConfig {
        policy: RoutePolicy::LeastOutstandingTokens,
        queue_capacity: 256,
    });
    for i in 0..2 {
        router.add_replica(Box::new(SimReplica::new(&format!("p{i}"), cfg.clone()).unwrap()));
    }
    let prompt = vec![5i32; 256];
    let arrivals: Vec<TimedRequest> = (0..8)
        .map(|i| TimedRequest::new(Request::new(i, prompt.clone(), 8), 0.0))
        .collect();
    let report = router.run_open_loop(arrivals).unwrap();
    assert_eq!(report.outputs.len(), 8);
    assert!(report.rejected.is_empty());
    let m = &report.metrics.merged;
    assert_eq!(m.prefix_hits + m.prefix_misses, 8);
    assert!(m.prefix_hits >= 1, "shared prompts must hit: {}", m.prefix_hits);
    assert!(m.prefix_hit_tokens >= 256);
    // The warmth signal surfaces through the replica handles and the row.
    let warm: usize = (0..2)
        .map(|id| router.registry.handle(id).cached_prefix_tokens(&prompt))
        .max()
        .unwrap();
    assert_eq!(warm, 256);
    let row = report.metrics.json_row(2, "least_outstanding", 8);
    assert!(row.contains("\"prefix_hits\""), "{row}");
}
