//! Integration + property tests for the quantized KV-cache subsystem
//! (ISSUE 2): FP8 roundtrip error bounds for every format, the
//! freed-slot-zeroing guarantee under code+scale storage, and the shared
//! `KvLayout` accounting contract across `BlockAllocator`, `MemoryModel`,
//! and `SimReplica`.

use gaudi_fp8::coordinator::{BlockAllocator, KvStore};
use gaudi_fp8::fp8::Fp8Format;
use gaudi_fp8::gaudisim::{Device, MemoryModel};
use gaudi_fp8::model::config::ModelConfig;
use gaudi_fp8::quant::KvDtype;
use gaudi_fp8::router::{SimReplica, SimReplicaConfig};
use gaudi_fp8::util::prop::forall_msg;
use gaudi_fp8::util::rng::XorShiftRng;

/// Random KV geometry + data whose per-(layer, kv-head) groups span ~12
/// decades of magnitude (each group gets its own power-of-two level).
#[derive(Clone, Debug)]
struct KvCase {
    layers: usize,
    t: usize,
    kv_heads: usize,
    head_dim: usize,
    k: Vec<f32>,
    v: Vec<f32>,
}

fn gen_case(rng: &mut XorShiftRng) -> KvCase {
    let layers = 1 + rng.below(3);
    let t = 1 + rng.below(8);
    let kv_heads = 1 + rng.below(3);
    let head_dim = 1 + rng.below(6);
    let n = layers * t * kv_heads * head_dim;
    let mut k = vec![0.0f32; n];
    let mut v = vec![0.0f32; n];
    for buf in [&mut k, &mut v] {
        for l in 0..layers {
            for h in 0..kv_heads {
                // Group magnitude level; occasionally an all-zero group.
                let level = if rng.below(8) == 0 {
                    0.0
                } else {
                    (2.0f32).powi(rng.below(41) as i32 - 20)
                };
                for ti in 0..t {
                    for d in 0..head_dim {
                        let idx = l * (t * kv_heads * head_dim)
                            + (ti * kv_heads + h) * head_dim
                            + d;
                        buf[idx] = rng.normal() * level;
                    }
                }
            }
        }
    }
    KvCase {
        layers,
        t,
        kv_heads,
        head_dim,
        k,
        v,
    }
}

/// Max-abs of one (layer, kv-head) group in a (L, T, Hkv, D) buffer.
fn group_maxabs(buf: &[f32], c: &KvCase, l: usize, h: usize) -> f32 {
    let mut m = 0.0f32;
    for ti in 0..c.t {
        for d in 0..c.head_dim {
            let idx =
                l * (c.t * c.kv_heads * c.head_dim) + (ti * c.kv_heads + h) * c.head_dim + d;
            m = m.max(buf[idx].abs());
        }
    }
    m
}

/// Roundtrip error of every element stays within half an ulp *at the scale
/// group's max-abs*: with s = maxabs / r_q, the scaled grid's largest ulp
/// is ≤ maxabs·2^-man_bits, so |deq - x| ≤ maxabs·2^-(man_bits+1) (plus a
/// hair of f32 divide/multiply noise).
#[test]
fn fp8_kv_roundtrip_error_within_half_ulp_of_group_maxabs() {
    for format in Fp8Format::ALL {
        let half_ulp_rel = (2.0f32).powi(-(format.params().man_bits as i32 + 1));
        forall_msg(0xC0FE + format as u64, 120, gen_case, |c| {
            let mut store = KvStore::with_dtype(
                c.layers,
                2,
                c.t,
                c.kv_heads,
                c.head_dim,
                KvDtype::Fp8(format),
            );
            let slot = store.alloc_slot().expect("slot");
            store.write_slot(slot, &c.k, &c.v, c.t);
            let (k, v, _) = store.gather_batch(&[slot]);
            for (orig, deq, name) in [(&c.k, &k, "K"), (&c.v, &v, "V")] {
                for l in 0..c.layers {
                    for h in 0..c.kv_heads {
                        let maxabs = group_maxabs(orig, c, l, h);
                        let bound = maxabs * half_ulp_rel * 1.001 + 1e-30;
                        for ti in 0..c.t {
                            for d in 0..c.head_dim {
                                let idx = l * (c.t * c.kv_heads * c.head_dim)
                                    + (ti * c.kv_heads + h) * c.head_dim
                                    + d;
                                let err = (deq[idx] - orig[idx]).abs();
                                if !(err <= bound) {
                                    return Err(format!(
                                        "{format:?} {name}[{idx}] (l={l} h={h}): \
                                         |{} - {}| = {err:e} > {bound:e} (maxabs {maxabs:e})",
                                        deq[idx], orig[idx]
                                    ));
                                }
                            }
                        }
                    }
                }
            }
            Ok(())
        });
    }
}

/// The freed-slot guarantee for code+scale storage: after free + realloc,
/// gathers return exact zeros AND the stale scales are gone — a subsequent
/// small-magnitude write must roundtrip within its own (small) bound, not
/// the previous occupant's coarse grid.
#[test]
fn freed_slot_zeroing_resets_codes_and_scales() {
    for format in Fp8Format::ALL {
        let half_ulp_rel = (2.0f32).powi(-(format.params().man_bits as i32 + 1));
        forall_msg(0xDEAD + format as u64, 60, gen_case, |c| {
            let mut store = KvStore::with_dtype(
                c.layers,
                1,
                c.t,
                c.kv_heads,
                c.head_dim,
                KvDtype::Fp8(format),
            );
            let slot = store.alloc_slot().expect("slot");
            // First occupant: huge magnitudes force coarse scales.
            let big: Vec<f32> = c.k.iter().map(|x| x * 1e6 + 1e6).collect();
            store.write_slot(slot, &big, &big, c.t);
            store.free_slot(slot);
            let slot = store.alloc_slot().expect("slot");
            let (k0, v0, lens) = store.gather_batch(&[slot]);
            if !k0.iter().all(|x| *x == 0.0) || !v0.iter().all(|x| *x == 0.0) {
                return Err(format!("{format:?}: stale KV after free"));
            }
            if lens != vec![0] {
                return Err(format!("{format:?}: stale len {lens:?}"));
            }
            // Second occupant: small magnitudes must get fresh scales.
            store.write_slot(slot, &c.k, &c.v, c.t);
            let (k1, _, _) = store.gather_batch(&[slot]);
            for l in 0..c.layers {
                for h in 0..c.kv_heads {
                    let maxabs = group_maxabs(&c.k, c, l, h);
                    let bound = maxabs * half_ulp_rel * 1.001 + 1e-30;
                    for ti in 0..c.t {
                        for d in 0..c.head_dim {
                            let idx = l * (c.t * c.kv_heads * c.head_dim)
                                + (ti * c.kv_heads + h) * c.head_dim
                                + d;
                            let err = (k1[idx] - c.k[idx]).abs();
                            if !(err <= bound) {
                                return Err(format!(
                                    "{format:?}: stale scale leaked — err {err:e} > {bound:e}"
                                ));
                            }
                        }
                    }
                }
            }
            Ok(())
        });
    }
}

/// BF16 KV roundtrips within BF16's relative error (2^-8) — no scales
/// involved.
#[test]
fn bf16_kv_roundtrip_error_bounded() {
    forall_msg(0xBF16, 80, gen_case, |c| {
        let mut store =
            KvStore::with_dtype(c.layers, 1, c.t, c.kv_heads, c.head_dim, KvDtype::Bf16);
        let slot = store.alloc_slot().expect("slot");
        store.write_slot(slot, &c.k, &c.v, c.t);
        let (k, _, _) = store.gather_batch(&[slot]);
        for (i, (a, b)) in c.k.iter().zip(&k).enumerate() {
            let tol = a.abs() * (2.0f32).powi(-8) + 1e-38;
            if (a - b).abs() > tol {
                return Err(format!("K[{i}]: {a} vs {b}"));
            }
        }
        Ok(())
    });
}

/// Acceptance: `BlockAllocator`, `MemoryModel`, and `SimReplica` all charge
/// bytes/token from the one shared `KvLayout` — no more three-way
/// disagreement about what a token costs.
#[test]
fn accounting_is_shared_across_components() {
    let budget = 64.0 * 1024.0 * 1024.0;
    let block_tokens = 16;
    for dtype in [KvDtype::F32, KvDtype::Bf16, KvDtype::FP8_DEFAULT] {
        // The capacity model's layout…
        let cfg = SimReplicaConfig::synthetic_tiny();
        let model = cfg.e2e.model.clone();
        let mm = MemoryModel::new(Device::gaudi2(), model.clone()).with_kv_dtype(dtype);
        let layout = mm.kv_layout();
        assert_eq!(layout, model.kv_layout(dtype));
        assert_eq!(mm.kv_bytes(1, 1), layout.bytes_per_token() as f64);
        // …sizes the admission allocator…
        let alloc = BlockAllocator::from_layout(budget, &layout, block_tokens).unwrap();
        let expect_blocks =
            (budget / (layout.bytes_per_token() * block_tokens) as f64) as usize;
        assert_eq!(alloc.total_blocks, expect_blocks, "{dtype:?}");
        // …and the fleet replica's pool is the same computation.
        let mut rcfg = cfg.clone();
        rcfg.kv_dtype = dtype;
        rcfg.kv_bytes_budget_override = Some(budget);
        let replica = SimReplica::new("contract", rcfg).unwrap();
        assert_eq!(replica.allocator().total_blocks, expect_blocks, "{dtype:?}");
        // …while the host store provisions exactly block_bytes per pool
        // block (paged: per-slot arenas became 16-token physical blocks
        // with block-granular FP8 scale metadata).
        let store = KvStore::with_dtype(
            model.layers,
            2,
            32,
            model.kv_heads,
            model.head_dim(),
            dtype,
        );
        let bt = store.block_tokens();
        let blocks_per_seq = 32usize.div_ceil(bt);
        assert_eq!(
            store.kv_bytes(),
            2 * blocks_per_seq * layout.block_bytes(bt),
            "{dtype:?}"
        );
        // The payload rate is still the shared bytes/token contract.
        assert_eq!(
            store.kv_bytes() - 2 * blocks_per_seq * layout.scale_bytes_per_block(),
            2 * 32 * layout.bytes_per_token(),
            "{dtype:?}"
        );
    }
}

/// The Table 6 frontier is a property of the FP8 layout: swapping the
/// capacity model to f32 KV collapses the paper's headline cell.
#[test]
fn table6_headline_cell_requires_fp8_layout() {
    let fp8 = MemoryModel::new(Device::gaudi2(), ModelConfig::llama31_70b());
    assert_eq!(fp8.kv_layout().bytes_per_token(), 163_840);
    assert!(fp8.fits(16, 8192));
    let f32m = MemoryModel::new(Device::gaudi2(), ModelConfig::llama31_70b())
        .with_kv_dtype(KvDtype::F32);
    assert!(!f32m.fits(16, 8192));
}
