//! Cross-module pipeline tests: calibration → scales → quantized GEMM →
//! eval, exercising the §3.3 recipe end to end on the Rust side.

use gaudi_fp8::calib::{ActObserver, MeasurementStore};
use gaudi_fp8::fp8::Fp8Format;
use gaudi_fp8::gaudisim::{Device, Generation};
use gaudi_fp8::model::config::{ModelConfig, ModelFamily};
use gaudi_fp8::model::layers::enumerate_linears;
use gaudi_fp8::quant::{KvDtype, QuantScheme, QuantizedLinear, ScaleSet, WeightScaling};
use gaudi_fp8::tensor::Tensor2;
use gaudi_fp8::util::rng::XorShiftRng;

/// The full §3.3 recipe: calibrate on one split, quantize, evaluate on a
/// disjoint split, pick the fastest scheme within the accuracy budget.
#[test]
fn recipe_selects_scheme_within_budget() {
    let mut rng = XorShiftRng::new(99);
    let c = 256;
    let w = Tensor2::randn(128, c, 0.04, &mut rng);
    let x_cal = Tensor2::randn(64, c, 1.0, &mut rng);
    let x_eval = Tensor2::randn(64, c, 1.0, &mut rng);

    let mut obs = ActObserver::new(c);
    obs.observe(&x_cal);
    let stats = obs.finalize();

    let fmt = Fp8Format::E4M3Gaudi2;
    // Schemes ordered by descending modelled throughput (Table 1 ordering:
    // HW pow2 > per-tensor SW > per-channel).
    let candidates = [
        ("hw_pow2", QuantScheme::per_tensor_hw(fmt)),
        ("per_tensor", QuantScheme::per_tensor(fmt)),
        ("per_channel", QuantScheme::per_channel(fmt)),
    ];
    let budget = 0.06; // relative error budget (the paper's "-1%" analogue)
    let mut selected = None;
    for (name, scheme) in candidates {
        let q = QuantizedLinear::prepare(&w, Some(&stats), scheme);
        let err = q.relative_error(&w, &x_eval);
        if err < budget {
            selected = Some((name, err));
            break;
        }
    }
    let (name, err) = selected.expect("no scheme met the budget");
    // With well-behaved activations the FASTEST scheme already passes —
    // exactly the paper's conclusion that simple per-tensor (HW) scaling
    // suffices.
    assert_eq!(name, "hw_pow2", "expected the fastest scheme, got {name} ({err})");
}

/// Measurement files round-trip through JSON and feed scale computation.
#[test]
fn measurement_store_to_scales() {
    let mut rng = XorShiftRng::new(5);
    let cfg = ModelConfig::synthetic_tiny(ModelFamily::Llama2);
    let mut store = MeasurementStore::new();
    for op in enumerate_linears(&cfg) {
        if op.kind.is_edge() {
            continue;
        }
        let x = Tensor2::randn(16, op.in_features, 1.0, &mut rng);
        let mut obs = ActObserver::new(op.in_features);
        obs.observe(&x);
        store.insert(&op.qualified_name(), obs.finalize());
    }
    let dir = std::env::temp_dir().join("gaudi_fp8_pipeline");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("meas.json");
    store.save(&path).unwrap();
    let loaded = MeasurementStore::load(&path).unwrap();
    assert_eq!(store, loaded);
    // Every entry produces a usable per-tensor scale.
    for (_, st) in &loaded.entries {
        let s = gaudi_fp8::quant::act_scale_per_tensor(st.r_x, 1.0, Fp8Format::E4M3Gaudi2);
        assert!(s.is_finite() && s > 0.0);
    }
}

/// Gaudi2 vs Gaudi3 format difference visible through the whole pipeline:
/// activations beyond ±240 clip on Gaudi 2's E4M3 but not Gaudi 3's.
#[test]
fn gaudi3_range_advantage_end_to_end() {
    let mut rng = XorShiftRng::new(17);
    let c = 128;
    let w = Tensor2::randn(32, c, 0.05, &mut rng);
    // Activations with max ≈ 3.5σ·100 ≈ 350: inside E4M3's ±448, outside
    // E4M3-Gaudi2's ±240.
    let x = Tensor2::randn(32, c, 1.0, &mut rng).map(|v| v * 100.0);
    let mut obs = ActObserver::new(c);
    obs.observe(&x);
    let stats = obs.finalize();

    // UNIT scale (no rescaling): Gaudi2 clips hard, Gaudi3 less.
    let g2 = QuantizedLinear::prepare(&w, Some(&stats), QuantScheme::unit_scale(Fp8Format::E4M3Gaudi2));
    let g3 = QuantizedLinear::prepare(&w, Some(&stats), QuantScheme::unit_scale(Fp8Format::E4M3));
    let (e2, e3) = (g2.relative_error(&w, &x), g3.relative_error(&w, &x));
    assert!(e3 < e2, "gaudi3 {e3} should beat gaudi2 {e2} on 300-range acts");
    // With calibrated per-tensor scaling both recover.
    let g2s = QuantizedLinear::prepare(&w, Some(&stats), QuantScheme::per_tensor(Fp8Format::E4M3Gaudi2));
    assert!(g2s.relative_error(&w, &x) < e2 / 2.0);
}

/// MSE scale search constrained to the HW-accelerated sets (§2.4): Gaudi 3's
/// denser pow2 grid can only help.
#[test]
fn hw_scale_sets_gaudi3_at_least_as_good() {
    let mut rng = XorShiftRng::new(23);
    let w = Tensor2::randn(64, 256, 0.007, &mut rng); // small weights
    let x = Tensor2::randn(32, 256, 1.0, &mut rng);
    let mut obs = ActObserver::new(256);
    obs.observe(&x);
    let stats = obs.finalize();
    let fmt = Fp8Format::E4M3Gaudi2;
    let mk = |gen| QuantScheme {
        weight: WeightScaling::MsePerTensor(ScaleSet::HwAccelerated(gen)),
        ..QuantScheme::per_tensor(fmt)
    };
    let g2 = QuantizedLinear::prepare(&w, Some(&stats), mk(Generation::Gaudi2));
    let g3 = QuantizedLinear::prepare(&w, Some(&stats), mk(Generation::Gaudi3));
    let (e2, e3) = (g2.relative_error(&w, &x), g3.relative_error(&w, &x));
    assert!(
        e3 <= e2 * 1.001,
        "gaudi3 HW set {e3} should be ≤ gaudi2 HW set {e2}"
    );
}

/// Capacity + roofline agree with the serving layer's block accounting.
#[test]
fn capacity_model_consistent_with_block_allocator() {
    use gaudi_fp8::coordinator::BlockAllocator;
    use gaudi_fp8::gaudisim::MemoryModel;
    let cfg = ModelConfig::llama31_70b();
    let mm = MemoryModel::new(Device::gaudi2(), cfg.clone());
    let kv_budget = mm.capacity_bytes() - mm.weight_bytes_fp8() - 0.5e9;
    // Both sides of the check now charge the one shared KvLayout rate.
    let alloc =
        BlockAllocator::from_layout(kv_budget, &cfg.kv_layout(KvDtype::FP8_DEFAULT), 16).unwrap();
    assert_eq!(mm.kv_layout(), cfg.kv_layout(KvDtype::FP8_DEFAULT));
    // Table 6 frontier: batch 16 × seq 8192 fits, batch 32 × 8192 does not.
    let mut a = alloc.clone();
    for _ in 0..16 {
        a.allocate(8192).unwrap();
    }
    let mut b = alloc.clone();
    let mut ok = 0;
    for _ in 0..32 {
        if b.allocate(8192).is_ok() {
            ok += 1;
        }
    }
    assert!(ok < 32, "32×8192 must exceed the KV budget (got {ok})");
}
