//! Integration: the fleet router over simulated Gaudi replicas.
//!
//! Acceptance (ISSUE 1):
//! * a 4-replica fleet drains a 64-request open-loop workload to completion
//!   under each routing policy with zero lost requests;
//! * least-outstanding-tokens achieves p99 TTFT ≤ round-robin's on a skewed
//!   bursty arrival trace;
//! * total fleet throughput scales ≥ 3× from 1 → 4 replicas on the
//!   synthetic model.
//!
//! Acceptance (ISSUE 2):
//! * at an equal KV byte budget, FP8 KV admits ≥ 1.8× the concurrent batch
//!   of f32 KV, with decode readout MSE vs f32 KV below 1e-2;
//! * a 4-replica FP8-KV fleet serves a workload the same fleet under f32
//!   KV must reject as `KvExhausted`.

use gaudi_fp8::coordinator::{KvStore, LatencyStat, Request, RequestOutput};
use gaudi_fp8::quant::KvDtype;
use gaudi_fp8::router::{
    FleetConfig, FleetRouter, RejectReason, ReplicaHandle, ReplicaState, RoutePolicy, SimReplica,
    SimReplicaConfig, TimedRequest,
};
use gaudi_fp8::server::workload::{ArrivalPattern, OpenLoopConfig, WorkloadConfig};
use gaudi_fp8::util::rng::XorShiftRng;

fn make_fleet(replicas: usize, policy: RoutePolicy) -> FleetRouter {
    let mut router = FleetRouter::new(FleetConfig {
        policy,
        queue_capacity: 4096,
    });
    for i in 0..replicas {
        router.add_replica(Box::new(
            SimReplica::new(&format!("sim{i}"), SimReplicaConfig::synthetic_tiny()).unwrap(),
        ));
    }
    router
}

fn open_loop_64(pattern: ArrivalPattern) -> Vec<TimedRequest> {
    OpenLoopConfig {
        workload: WorkloadConfig {
            requests: 64,
            prompt_len_min: 16,
            prompt_len_max: 128,
            max_new_min: 8,
            max_new_max: 16,
            seed: 11,
        },
        pattern,
    }
    .generate()
}

fn all_policies() -> [RoutePolicy; 3] {
    [
        RoutePolicy::RoundRobin,
        RoutePolicy::LeastOutstandingTokens,
        RoutePolicy::SessionAffinity { prefix_tokens: 16 },
    ]
}

#[test]
fn four_replica_fleet_drains_64_requests_under_each_policy() {
    for policy in all_policies() {
        for pattern in [
            ArrivalPattern::Burst,
            ArrivalPattern::Poisson { rate_per_s: 256.0 },
        ] {
            let mut router = make_fleet(4, policy);
            let report = router.run_open_loop(open_loop_64(pattern.clone())).unwrap();
            assert!(
                report.rejected.is_empty(),
                "{policy:?}/{pattern:?}: rejected {:?}",
                report.rejected
            );
            assert_eq!(
                report.outputs.len(),
                64,
                "{policy:?}/{pattern:?}: lost requests"
            );
            // Every request id exactly once — nothing lost or duplicated.
            let mut ids: Vec<u64> = report.outputs.iter().map(|o| o.id).collect();
            ids.sort_unstable();
            assert_eq!(ids, (0..64).collect::<Vec<u64>>());
            // Every output actually generated tokens.
            assert!(report.outputs.iter().all(|o| !o.tokens.is_empty()));
            assert_eq!(report.metrics.merged.requests_completed, 64);
        }
    }
}

#[test]
fn round_robin_dispatches_evenly_on_uniform_burst() {
    let mut router = make_fleet(4, RoutePolicy::RoundRobin);
    let report = router.run_open_loop(open_loop_64(ArrivalPattern::Burst)).unwrap();
    for r in &report.metrics.replicas {
        assert_eq!(r.dispatched, 16, "round-robin must spread 64 over 4 evenly");
    }
}

/// Skewed bursty trace: every 4th request is heavy — a 512-token prompt
/// *and* a 64-token generation budget (8× the light requests' work, in both
/// the time model and the outstanding-tokens load signal). Round-robin's
/// blind rotation pins every heavy request onto one replica;
/// least-outstanding-tokens routes around the hot spot.
fn skewed_bursty_trace() -> Vec<TimedRequest> {
    let mut out = Vec::new();
    for i in 0..64u64 {
        let (prompt_len, max_new) = if i % 4 == 0 { (512, 64) } else { (16, 8) };
        let burst = i / 8;
        let arrival_s = burst as f64 * 0.05;
        out.push(TimedRequest::new(
            Request::new(i, vec![((i % 26) as u8 + b'a') as i32; prompt_len], max_new),
            arrival_s,
        ));
    }
    out
}

fn p99_ttft(outputs: &[RequestOutput]) -> f64 {
    let mut stat = LatencyStat::new();
    for o in outputs {
        stat.record(o.ttft_s);
    }
    stat.p99_s()
}

#[test]
fn least_outstanding_beats_round_robin_p99_ttft_on_skewed_trace() {
    let mut rr = make_fleet(4, RoutePolicy::RoundRobin);
    let rr_report = rr.run_open_loop(skewed_bursty_trace()).unwrap();
    assert_eq!(rr_report.outputs.len(), 64);

    let mut lot = make_fleet(4, RoutePolicy::LeastOutstandingTokens);
    let lot_report = lot.run_open_loop(skewed_bursty_trace()).unwrap();
    assert_eq!(lot_report.outputs.len(), 64);

    let rr_p99 = p99_ttft(&rr_report.outputs);
    let lot_p99 = p99_ttft(&lot_report.outputs);
    assert!(
        lot_p99 <= rr_p99 + 1e-9,
        "least-outstanding p99 TTFT {lot_p99:.4}s must not exceed round-robin's {rr_p99:.4}s"
    );
}

fn saturating_burst(n: u64) -> Vec<TimedRequest> {
    (0..n)
        .map(|i| TimedRequest::new(Request::new(i, vec![7; 64], 16), 0.0))
        .collect()
}

#[test]
fn fleet_throughput_scales_3x_from_1_to_4_replicas() {
    let mut tput = Vec::new();
    for replicas in [1usize, 4] {
        let mut router = make_fleet(replicas, RoutePolicy::LeastOutstandingTokens);
        let report = router.run_open_loop(saturating_burst(64)).unwrap();
        assert_eq!(report.outputs.len(), 64);
        tput.push(report.metrics.throughput_tok_s());
    }
    assert!(
        tput[1] >= 3.0 * tput[0],
        "1→4 replicas must scale ≥3×: {:.1} → {:.1} tok/s",
        tput[0],
        tput[1]
    );
}

#[test]
fn session_affinity_keeps_multi_turn_sessions_on_one_replica() {
    let mut router = make_fleet(4, RoutePolicy::SessionAffinity { prefix_tokens: 16 });
    // 8 sessions × 4 turns, interleaved arrival order.
    let mut arrivals = Vec::new();
    let mut id = 0u64;
    for turn in 0..4 {
        for session in 0..8u64 {
            arrivals.push(TimedRequest::new(
                Request::new(id, vec![session as i32; 24], 8).with_session(session),
                turn as f64 * 0.2,
            ));
            id += 1;
        }
    }
    let report = router.run_open_loop(arrivals).unwrap();
    assert_eq!(report.outputs.len(), 32);
    assert!(report.rejected.is_empty());
    // With 8 sessions pinned over 4 replicas, dispatch totals per replica
    // must be whole sessions (multiples of 4 turns).
    for r in &report.metrics.replicas {
        assert_eq!(
            r.dispatched % 4,
            0,
            "session split across replicas: {:?}",
            report.metrics.replicas
        );
    }
}

#[test]
fn kv_and_prompt_rejections_carry_reasons_and_nothing_is_lost() {
    let mut cfg = SimReplicaConfig::synthetic_tiny();
    cfg.kv_blocks_override = Some(8); // 8 × 16 = 128 KV tokens per replica
    let mut router = FleetRouter::new(FleetConfig {
        policy: RoutePolicy::LeastOutstandingTokens,
        queue_capacity: 64,
    });
    for i in 0..2 {
        router.add_replica(Box::new(SimReplica::new(&format!("s{i}"), cfg.clone()).unwrap()));
    }
    let mut arrivals = Vec::new();
    // 6 servable requests.
    for i in 0..6u64 {
        arrivals.push(TimedRequest::new(Request::new(i, vec![1; 32], 8), 0.0));
    }
    // One whose KV footprint exceeds every replica's whole cache.
    arrivals.push(TimedRequest::new(Request::new(100, vec![1; 120], 64), 0.0));
    // One whose prompt exceeds every compiled bucket.
    arrivals.push(TimedRequest::new(Request::new(101, vec![1; 5000], 8), 0.0));
    let submitted = arrivals.len();
    let report = router.run_open_loop(arrivals).unwrap();
    assert_eq!(
        report.outputs.len() + report.rejected.len(),
        submitted,
        "every request must be answered or rejected"
    );
    let kv = report.rejected.iter().find(|r| r.id == 100).unwrap();
    assert_eq!(kv.reason, RejectReason::KvExhausted { needed_tokens: 184 });
    let long = report.rejected.iter().find(|r| r.id == 101).unwrap();
    assert_eq!(long.reason, RejectReason::PromptTooLong { prompt_len: 5000 });
    assert_eq!(report.outputs.len(), 6);
}

/// At the same KV byte budget, FP8 KV (1 B/elem) must admit ≥ 1.8× the
/// concurrent batch of f32 KV (4 B/elem) — with the shared `KvLayout`
/// rate it is exactly 4× minus block rounding — and the quantization must
/// cost < 1e-2 decode readout MSE on the synthetic model's KV.
#[test]
fn fp8_kv_admits_double_the_batch_of_f32_at_equal_budget() {
    let budget = 48.0 * 1024.0 * 1024.0;
    let seq_tokens = 272; // 256-token prompt + 16 generated
    let admitted = |dtype: KvDtype| -> usize {
        let mut cfg = SimReplicaConfig::synthetic_tiny();
        cfg.kv_dtype = dtype;
        cfg.kv_bytes_budget_override = Some(budget);
        let replica = SimReplica::new("cap", cfg).unwrap();
        let mut alloc = replica.allocator().clone();
        let mut batch = 0;
        while alloc.allocate(seq_tokens).is_ok() {
            batch += 1;
        }
        batch
    };
    let f32_batch = admitted(KvDtype::F32);
    let fp8_batch = admitted(KvDtype::FP8_DEFAULT);
    assert!(f32_batch > 0);
    assert!(
        fp8_batch as f64 >= 1.8 * f32_batch as f64,
        "fp8 KV must admit ≥1.8× f32's batch: {f32_batch} → {fp8_batch}"
    );

    // Fidelity half of the trade: same K/V data through an f32 and an fp8
    // store, single-step attention readout per (slot, layer, head).
    let (layers, t, kv_heads, head_dim) = (4, 64, 2, 32);
    let n = layers * t * kv_heads * head_dim;
    let mut rng = XorShiftRng::new(2024);
    let k: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
    let v: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
    let readout = |dtype: KvDtype| -> Vec<f32> {
        let mut store = KvStore::with_dtype(layers, 1, t, kv_heads, head_dim, dtype);
        let slot = store.alloc_slot().unwrap();
        store.write_slot(slot, &k, &v, t);
        store.decode_attention_probe(&[slot], 555)
    };
    let exact = readout(KvDtype::F32);
    let quant = readout(KvDtype::FP8_DEFAULT);
    let mse: f64 = exact
        .iter()
        .zip(&quant)
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        / exact.len() as f64;
    assert!(mse < 1e-2, "decode readout MSE vs f32 KV: {mse}");
}

/// End to end through the 4-replica fleet: a workload whose per-request KV
/// footprint exceeds every f32-KV replica's whole cache (typed
/// `KvExhausted` rejects) is served to completion once the same fleet
/// stores KV in FP8 — the "Llama 70B fits only with FP8 KV" mechanism at
/// fleet scale.
#[test]
fn fleet_serves_under_fp8_kv_what_f32_kv_rejects() {
    let budget = 600.0 * 1024.0; // per replica: 288 f32 KV tokens vs 1200 fp8
    let workload = || -> Vec<TimedRequest> {
        (0..8u64)
            .map(|i| TimedRequest::new(Request::new(i, vec![1; 384], 16), 0.0))
            .collect()
    };
    let fleet = |dtype: KvDtype| -> FleetRouter {
        let mut cfg = SimReplicaConfig::synthetic_tiny();
        cfg.kv_dtype = dtype;
        cfg.kv_bytes_budget_override = Some(budget);
        let mut router = FleetRouter::new(FleetConfig {
            policy: RoutePolicy::LeastOutstandingTokens,
            queue_capacity: 64,
        });
        for i in 0..4 {
            router.add_replica(Box::new(
                SimReplica::new(&format!("kv{i}"), cfg.clone()).unwrap(),
            ));
        }
        router
    };

    let report = fleet(KvDtype::F32).run_open_loop(workload()).unwrap();
    assert!(report.outputs.is_empty(), "f32 KV cannot hold a 400-token request");
    assert_eq!(report.rejected.len(), 8);
    assert!(report
        .rejected
        .iter()
        .all(|r| matches!(r.reason, RejectReason::KvExhausted { needed_tokens: 400 })));

    let report = fleet(KvDtype::FP8_DEFAULT).run_open_loop(workload()).unwrap();
    assert!(report.rejected.is_empty(), "{:?}", report.rejected);
    assert_eq!(report.outputs.len(), 8);
    assert!(report.outputs.iter().all(|o| o.tokens.len() == 16));
}

#[test]
fn drained_replica_finishes_without_new_work() {
    let mut router = make_fleet(2, RoutePolicy::RoundRobin);
    router.drain_replica(0);
    let report = router.run_open_loop(open_loop_64(ArrivalPattern::Burst)).unwrap();
    assert_eq!(report.outputs.len(), 64);
    assert_eq!(router.registry.dispatched(0), 0);
    assert_eq!(router.registry.dispatched(1), 64);
    assert_eq!(router.registry.state(0), ReplicaState::Draining);
}

/// ISSUE 4: prefix-aware fleet admission. A prompt longer than every
/// compiled prefill bucket used to be screened *cold* by
/// `could_ever_admit` and rejected `PromptTooLong` — even when a replica
/// held its prefix and would happily serve the tail through the chunked
/// decode path. A cold fleet must still reject it; a warm fleet must admit
/// and complete it.
#[test]
fn warm_prompt_rejected_cold_is_admitted_when_prefix_is_resident() {
    let mut cfg = SimReplicaConfig::synthetic_tiny();
    cfg.prefix_cache = true;
    cfg.prefill_seqs = vec![16, 32, 64, 128]; // 160-token prompt fits no bucket
    let long_prompt = vec![4i32; 160];

    // Cold: typed PromptTooLong reject at the router.
    let mut router = FleetRouter::new(FleetConfig {
        policy: RoutePolicy::LeastOutstandingTokens,
        queue_capacity: 64,
    });
    router.add_replica(Box::new(SimReplica::new("cold", cfg.clone()).unwrap()));
    let report = router
        .run_open_loop(vec![TimedRequest::new(
            Request::new(0, long_prompt.clone(), 8),
            0.0,
        )])
        .unwrap();
    assert!(report.outputs.is_empty());
    assert_eq!(report.rejected.len(), 1);
    assert!(
        matches!(
            report.rejected[0].reason,
            RejectReason::PromptTooLong { prompt_len: 160 }
        ),
        "{:?}",
        report.rejected[0].reason
    );

    // Warm: first serve the 128-token prefix (fits a bucket) so the cache
    // holds it, then the same long prompt routes, admits warm, and
    // completes via the chunked tail.
    let mut router = FleetRouter::new(FleetConfig {
        policy: RoutePolicy::LeastOutstandingTokens,
        queue_capacity: 64,
    });
    router.add_replica(Box::new(SimReplica::new("warm", cfg).unwrap()));
    let arrivals = vec![
        TimedRequest::new(Request::new(0, long_prompt[..128].to_vec(), 8), 0.0),
        // Arrives long after the warmer finished: the cache is resident
        // when the router screens it.
        TimedRequest::new(Request::new(1, long_prompt.clone(), 8), 1000.0),
    ];
    let report = router.run_open_loop(arrivals).unwrap();
    assert!(report.rejected.is_empty(), "{:?}", report.rejected);
    assert_eq!(report.outputs.len(), 2);
    let long = report.outputs.iter().find(|o| o.id == 1).unwrap();
    assert_eq!(long.prompt_len, 160);
    assert_eq!(long.tokens.len(), 8, "warm admission must serve fully");
    assert!(report.metrics.merged.prefix_hits >= 1);
    // Serving the long prompt published its own tail too: the replica's
    // warmth signal now covers the whole prompt.
    assert!(router.registry.handle(0).cached_prefix_tokens(&long_prompt) >= 128);
}
