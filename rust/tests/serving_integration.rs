//! Integration: the full serving stack (coordinator → runtime → AOT
//! artifacts) on the trained byte-LM. Requires `make artifacts`.

use std::path::{Path, PathBuf};

use gaudi_fp8::coordinator::{Engine, EngineConfig, Request, SchedulePolicy};
use gaudi_fp8::quant::KvDtype;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("meta.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        None
    }
}

fn prompt(text: &str) -> Vec<i32> {
    text.bytes().map(|b| b as i32).collect()
}

#[test]
fn single_request_generates_tokens() {
    let Some(dir) = artifacts_dir() else { return };
    let mut eng = Engine::new(EngineConfig::new(&dir, "fp8_pt")).unwrap();
    let mut req = Request::new(1, prompt("the quick "), 8);
    req.stop_token = None;
    eng.submit(req);
    let outs = eng.run_to_completion().unwrap();
    assert_eq!(outs.len(), 1);
    assert_eq!(outs[0].tokens.len(), 8);
    assert!(outs[0].ttft_s > 0.0);
    // Byte-LM over ASCII: generated tokens must be valid vocab entries.
    assert!(outs[0].tokens.iter().all(|t| (0..256).contains(t)));
}

#[test]
fn batched_requests_all_complete() {
    let Some(dir) = artifacts_dir() else { return };
    let mut eng = Engine::new(EngineConfig::new(&dir, "fp8_pt")).unwrap();
    for i in 0..6 {
        eng.submit(Request::new(i, prompt("hello world "), 6 + i as usize % 3));
    }
    let outs = eng.run_to_completion().unwrap();
    assert_eq!(outs.len(), 6);
    let mut ids: Vec<u64> = outs.iter().map(|o| o.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
    for o in &outs {
        assert!(!o.tokens.is_empty());
    }
    // Continuous batching actually batched: with 6 concurrent requests the
    // mean decode batch must exceed 1.
    assert!(
        eng.metrics.mean_decode_batch() > 1.5,
        "mean decode batch {}",
        eng.metrics.mean_decode_batch()
    );
}

#[test]
fn batched_generation_matches_solo_generation() {
    // The KV slot management must not leak state between requests: a
    // request decoded inside a busy batch must produce exactly the tokens
    // it produces alone.
    let Some(dir) = artifacts_dir() else { return };
    let p = prompt("and the ");

    let mut solo = Engine::new(EngineConfig::new(&dir, "fp8_pt")).unwrap();
    solo.submit(Request::new(0, p.clone(), 6));
    let solo_tokens = solo.run_to_completion().unwrap()[0].tokens.clone();

    let mut busy = Engine::new(EngineConfig::new(&dir, "fp8_pt")).unwrap();
    busy.submit(Request::new(10, prompt("a completely different one "), 9));
    busy.submit(Request::new(11, p.clone(), 6));
    busy.submit(Request::new(12, prompt("xyzzy "), 7));
    let outs = busy.run_to_completion().unwrap();
    let batched_tokens = outs.iter().find(|o| o.id == 11).unwrap().tokens.clone();
    assert_eq!(
        solo_tokens, batched_tokens,
        "batching changed generation: {solo_tokens:?} vs {batched_tokens:?}"
    );
}

#[test]
fn trained_byte_lm_produces_plausible_text() {
    // The e2e mandate: the served model is a REAL (trained) model. The
    // synthetic corpus is lowercase words + spaces/periods, so greedy
    // completions should be mostly such bytes.
    let Some(dir) = artifacts_dir() else { return };
    let mut eng = Engine::new(EngineConfig::new(&dir, "fp8_pt")).unwrap();
    eng.submit(Request::new(1, prompt("the ma"), 24));
    let outs = eng.run_to_completion().unwrap();
    let text: String = outs[0]
        .tokens
        .iter()
        .map(|t| *t as u8 as char)
        .collect();
    let plausible = text
        .chars()
        .filter(|c| c.is_ascii_lowercase() || *c == ' ' || *c == '.' || c.is_ascii_uppercase())
        .count();
    assert!(
        plausible as f64 >= 0.9 * text.len() as f64,
        "generated implausible bytes: {text:?}"
    );
}

#[test]
fn decode_past_cache_t_finishes_request_at_capacity() {
    // ISSUE 2 satellite: a generation budget beyond the KV window must end
    // at cache capacity via the scatter "sequence full" signal — not pin
    // the length and overwrite the last position forever.
    let Some(dir) = artifacts_dir() else { return };
    let mut eng = Engine::new(EngineConfig::new(&dir, "fp8_pt")).unwrap();
    let cache_t = eng.meta.cache_t;
    let p = prompt("the ");
    let mut req = Request::new(1, p.clone(), cache_t + 64);
    req.stop_token = None;
    eng.submit(req);
    let outs = eng.run_to_completion().unwrap();
    assert_eq!(outs.len(), 1);
    // Prefill leaves len = prompt; each decode appends one position; the
    // request retires exactly when len reaches cache_t.
    assert_eq!(
        outs[0].tokens.len(),
        cache_t - p.len() + 1,
        "must stop exactly at cache capacity"
    );
}

#[test]
fn fp8_kv_engine_serves_and_agrees_with_f32_kv() {
    let Some(dir) = artifacts_dir() else { return };
    let mut cfg = EngineConfig::new(&dir, "fp8_pt");
    cfg.kv_dtype = KvDtype::FP8_DEFAULT;
    let mut fp8 = Engine::new(cfg).unwrap();
    assert_eq!(fp8.kv_layout().dtype, KvDtype::FP8_DEFAULT);
    let mut f32e = Engine::new(EngineConfig::new(&dir, "fp8_pt")).unwrap();
    // 4× byte saving on the host store at identical geometry.
    assert!(fp8.kv_layout().bytes_per_token() * 4 == f32e.kv_layout().bytes_per_token());
    for eng in [&mut fp8, &mut f32e] {
        for i in 0..4 {
            eng.submit(Request::new(i, prompt("hello world "), 8));
        }
    }
    let a = fp8.run_to_completion().unwrap();
    let b = f32e.run_to_completion().unwrap();
    assert_eq!(a.len(), 4);
    assert!(a.iter().all(|o| !o.tokens.is_empty()));
    // The first token comes from prefill logits (before any KV dequant) and
    // must agree bit-for-bit with the f32-KV engine.
    for x in &a {
        let y = b.iter().find(|o| o.id == x.id).unwrap();
        assert_eq!(x.tokens[0], y.tokens[0]);
    }
}

#[test]
fn decode_first_policy_protects_running_batch() {
    let Some(dir) = artifacts_dir() else { return };
    let mut cfg = EngineConfig::new(&dir, "bf16");
    cfg.policy = SchedulePolicy::DecodeFirst { min_decode: 2 };
    let mut eng = Engine::new(cfg).unwrap();
    for i in 0..4 {
        eng.submit(Request::new(i, prompt("abc "), 4));
    }
    let outs = eng.run_to_completion().unwrap();
    assert_eq!(outs.len(), 4);
}

#[test]
fn fp8_and_bf16_generations_agree_mostly() {
    // The paper's <1% degradation claim, e2e: greedy decode paths may
    // diverge after a few tokens, but the FIRST token (argmax of a full
    // prefill) should agree between bf16 and fp8 for typical prompts.
    let Some(dir) = artifacts_dir() else { return };
    let prompts = ["the ", "and so ", "with a ", "of the "];
    let mut agree = 0;
    for (i, p) in prompts.iter().enumerate() {
        let mut bf = Engine::new(EngineConfig::new(&dir, "bf16")).unwrap();
        bf.submit(Request::new(i as u64, prompt(p), 1));
        let t_bf = bf.run_to_completion().unwrap()[0].tokens[0];
        let mut f8 = Engine::new(EngineConfig::new(&dir, "fp8_pt")).unwrap();
        f8.submit(Request::new(i as u64, prompt(p), 1));
        let t_f8 = f8.run_to_completion().unwrap()[0].tokens[0];
        if t_bf == t_f8 {
            agree += 1;
        }
    }
    assert!(agree >= 3, "first-token agreement {agree}/4");
}

/// ISSUE 5 roundtrip: the block-table-native decode path must generate the
/// same tokens as the pre-paged dense reference (`dense-decode-ref`
/// feature). Both engines read identical dequantized KV — the paged
/// artifact gathers the exported pool blocks, the dense one takes the
/// gathered batch — and both write through paths proven byte-identical at
/// the store level, so greedy decode must not diverge.
#[cfg(feature = "dense-decode-ref")]
#[test]
fn dense_reference_engine_matches_paged_tokens() {
    let Some(dir) = artifacts_dir() else { return };
    let run = |dense: bool| {
        let mut cfg = EngineConfig::new(&dir, "fp8_pt");
        cfg.use_dense_decode = dense;
        let mut eng = Engine::new(cfg).unwrap();
        let mut req = Request::new(1, prompt("the quick "), 12);
        req.stop_token = None;
        eng.submit(req);
        eng.run_to_completion().unwrap()[0].tokens.clone()
    };
    let paged = run(false);
    let dense = run(true);
    assert_eq!(
        paged, dense,
        "paged and dense-reference decode diverged: {paged:?} vs {dense:?}"
    );
}

/// ISSUE 10: speculative draft-verify decoding under the greedy
/// accept-prefix rule must be **bit-identical** to plain token-by-token
/// greedy decode, for every KV dtype: a draft token stands iff it equals
/// the target's argmax, the first mismatch is replaced by the target's
/// own token, and rejected KV rolls back via block truncation.
#[test]
fn speculative_decode_is_bit_identical_to_plain_greedy() {
    let Some(dir) = artifacts_dir() else { return };
    for dtype in [KvDtype::F32, KvDtype::Bf16, KvDtype::FP8_DEFAULT] {
        let run = |gamma: usize| {
            let mut cfg = EngineConfig::new(&dir, "fp8_pt");
            cfg.kv_dtype = dtype;
            cfg.spec_gamma = gamma;
            let mut eng = Engine::new(cfg).unwrap();
            let mut req = Request::new(1, prompt("the quick "), 16);
            req.stop_token = None;
            eng.submit(req);
            let tokens = eng.run_to_completion().unwrap()[0].tokens.clone();
            (tokens, eng.metrics.clone())
        };
        let (plain, base) = run(0);
        let (spec, m) = run(3);
        assert_eq!(base.spec_rounds, 0);
        assert_eq!(
            plain, spec,
            "speculation changed greedy output under {dtype:?}: {plain:?} vs {spec:?}"
        );
        assert!(m.spec_rounds > 0, "single-stream decode must speculate");
        // Every round verifies exactly γ draft tokens.
        assert_eq!(
            m.spec_accepted_tokens + m.spec_rejected_tokens,
            3 * m.spec_rounds,
            "round accounting must balance under {dtype:?}"
        );
    }
}

/// ISSUE 10: a width-k beam request decodes k co-resident CoW branches
/// but retires as exactly ONE output with fork/prune accounting
/// balanced; width 1 is plain greedy with zero forks.
#[test]
fn beam_group_emits_one_output_with_balanced_forks() {
    let Some(dir) = artifacts_dir() else { return };
    let run = |k: usize| {
        let mut cfg = EngineConfig::new(&dir, "fp8_pt");
        cfg.beam_width = k;
        let mut eng = Engine::new(cfg).unwrap();
        let mut req = Request::new(1, prompt("the quick "), 8);
        req.stop_token = None;
        eng.submit(req);
        let outs = eng.run_to_completion().unwrap();
        assert_eq!(outs.len(), 1, "beam width {k} must emit one output");
        assert_eq!(outs[0].tokens.len(), 8, "beam width {k} token budget");
        (outs[0].tokens.clone(), eng.metrics.clone())
    };
    let (_, m1) = run(1);
    assert_eq!(m1.beam_forks, 0);
    assert_eq!(m1.beam_prunes, 0);
    let (_, m3) = run(3);
    assert_eq!(m3.beam_forks, 2, "width 3 forks two branches");
    assert_eq!(m3.beam_prunes, 2, "every forked branch is pruned at retire");
    // Branches decode as one co-scheduled group.
    assert!(
        m3.mean_decode_batch() > 1.0,
        "beam branches must batch together, got {}",
        m3.mean_decode_batch()
    );
}
