//! Integration: AOT artifacts (python/jax/pallas) executed from Rust must
//! reproduce the python-computed expectations (artifacts/selfcheck.json)
//! and agree with the native Rust scaled-GEMM implementation.
//!
//! Requires `make artifacts`; tests no-op (with a notice) when absent.

use std::path::{Path, PathBuf};

use gaudi_fp8::fp8::Fp8Format;
use gaudi_fp8::gemm::{quantize_matrix, scaled_gemm, DiagScale, QuantRounding};
use gaudi_fp8::quant::{act_scale_per_tensor, weight_scale_per_channel, weight_scale_per_tensor};
use gaudi_fp8::runtime::{Artifact, Runtime, TensorIn};
use gaudi_fp8::tensor::Tensor2;
use gaudi_fp8::util::json::Json;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("meta.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        None
    }
}

fn load_f32(path: &Path) -> Vec<f32> {
    let bytes = std::fs::read(path).unwrap();
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

fn selfcheck(dir: &Path) -> Json {
    Json::parse(&std::fs::read_to_string(dir.join("selfcheck.json")).unwrap()).unwrap()
}

fn gemm_shape(dir: &Path) -> (usize, usize, usize) {
    let meta = Json::parse(&std::fs::read_to_string(dir.join("meta.json")).unwrap()).unwrap();
    let v: Vec<usize> = meta
        .get("gemm_shape")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as usize)
        .collect();
    (v[0], v[1], v[2])
}

#[test]
fn gemm_artifacts_match_python_selfcheck() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let (m, k, n) = gemm_shape(&dir);
    let x = load_f32(&dir.join("gemm_x.f32"));
    let w = load_f32(&dir.join("gemm_w.f32"));
    let check = selfcheck(&dir);
    for variant in ["bf16", "fp8_pt", "fp8_pc", "unit"] {
        let art = Artifact::load(
            &rt,
            variant,
            &dir.join(format!("gemm_{variant}.hlo.txt")),
        )
        .unwrap();
        let outs = art
            .run(&[
                TensorIn::f32(&[m, k], x.clone()),
                TensorIn::f32(&[n, k], w.clone()),
            ])
            .unwrap();
        let expect = check.get("gemm").unwrap().get(variant).unwrap();
        let first16 = expect.get("first16").unwrap().as_f32_vec().unwrap();
        let l2 = expect.get("l2").unwrap().as_f64().unwrap();
        let got = &outs[0].data;
        for (i, (a, b)) in got.iter().zip(&first16).enumerate() {
            assert!(
                (a - b).abs() <= 1e-4 * b.abs().max(1.0),
                "{variant}[{i}]: rust {a} vs python {b}"
            );
        }
        let got_l2 = (got.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>()).sqrt();
        assert!(
            (got_l2 - l2).abs() / l2 < 1e-5,
            "{variant}: l2 {got_l2} vs {l2}"
        );
    }
}

#[test]
fn gemm_fp8_artifact_matches_native_rust_gemm() {
    // The same Eq. 2 computed two completely independent ways: the Pallas
    // kernel lowered to HLO and executed by PJRT, and the native Rust
    // gemm crate. Per-tensor dynamic scales on both sides.
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let (m, k, n) = gemm_shape(&dir);
    let x = load_f32(&dir.join("gemm_x.f32"));
    let w = load_f32(&dir.join("gemm_w.f32"));
    let fmt = Fp8Format::E4M3Gaudi2;

    let xt = Tensor2::from_vec(m, k, x.clone());
    let wt = Tensor2::from_vec(n, k, w.clone());
    let s_x = act_scale_per_tensor(gaudi_fp8::tensor::abs_max(&xt), 1.0, fmt);
    // per-tensor weights
    let s_w = weight_scale_per_tensor(gaudi_fp8::tensor::abs_max(&wt), fmt);
    let xq = quantize_matrix(&xt, &[s_x], &[], fmt, QuantRounding::Nearest);
    let wq = quantize_matrix(&wt, &[s_w], &[], fmt, QuantRounding::Nearest);
    let native = scaled_gemm(
        &xq,
        &wq,
        &DiagScale::Scalar(s_x),
        &DiagScale::Scalar(s_w),
        false,
    );

    let art = Artifact::load(&rt, "gemm_fp8_pt", &dir.join("gemm_fp8_pt.hlo.txt")).unwrap();
    let outs = art
        .run(&[TensorIn::f32(&[m, k], x), TensorIn::f32(&[n, k], w)])
        .unwrap();
    let mut max_rel = 0.0f64;
    let scale = native
        .data
        .iter()
        .fold(0.0f32, |a, b| a.max(b.abs()))
        .max(1e-6) as f64;
    for (a, b) in outs[0].data.iter().zip(&native.data) {
        max_rel = max_rel.max(((a - b).abs() as f64) / scale);
    }
    // Same math, different accumulation tiling → tiny float divergence.
    assert!(max_rel < 1e-5, "pallas-vs-rust max rel diff {max_rel}");

    // Per-channel variant against native per-channel.
    let s_wc = weight_scale_per_channel(&gaudi_fp8::tensor::row_abs_max(&wt), fmt);
    let wqc = quantize_matrix(&wt, &s_wc, &[], fmt, QuantRounding::Nearest);
    let native_pc = scaled_gemm(
        &xq,
        &wqc,
        &DiagScale::Scalar(s_x),
        &DiagScale::Vector(s_wc),
        false,
    );
    let art = Artifact::load(&rt, "gemm_fp8_pc", &dir.join("gemm_fp8_pc.hlo.txt")).unwrap();
    let x2 = load_f32(&dir.join("gemm_x.f32"));
    let w2 = load_f32(&dir.join("gemm_w.f32"));
    let outs = art
        .run(&[TensorIn::f32(&[m, k], x2), TensorIn::f32(&[n, k], w2)])
        .unwrap();
    let mut max_rel = 0.0f64;
    for (a, b) in outs[0].data.iter().zip(&native_pc.data) {
        max_rel = max_rel.max(((a - b).abs() as f64) / scale);
    }
    assert!(max_rel < 1e-5, "pc pallas-vs-rust max rel diff {max_rel}");
}

#[test]
fn prefill_artifacts_match_python_selfcheck() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let check = selfcheck(&dir);
    let tokens: Vec<i32> = check
        .get("tokens")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as i32)
        .collect();
    let params = gaudi_fp8::runtime::load_params_bin(&dir.join("weights_tiny.bin")).unwrap();
    let param_ins: Vec<TensorIn> = params
        .iter()
        .map(|p| TensorIn::f32(&p.dims, p.data.clone()))
        .collect();

    for variant in ["bf16", "unit", "fp8_pt", "fp8_pc", "fp8_dyn"] {
        let art = Artifact::load(
            &rt,
            variant,
            &dir.join(format!("prefill_{variant}_b1_s16.hlo.txt")),
        )
        .unwrap();
        let mut ins = param_ins.clone();
        ins.push(TensorIn::i32(&[1, tokens.len()], tokens.clone()));
        let outs = art.run(&ins).unwrap();
        let expect = check.get("prefill").unwrap().get(variant).unwrap();
        let first16 = expect.get("first16").unwrap().as_f32_vec().unwrap();
        for (i, (a, b)) in outs[0].data.iter().zip(&first16).enumerate() {
            assert!(
                (a - b).abs() <= 2e-4 * b.abs().max(1.0),
                "{variant} logits[{i}]: rust {a} vs python {b}"
            );
        }
        let l2 = expect.get("l2").unwrap().as_f64().unwrap();
        let got_l2 = outs[0]
            .data
            .iter()
            .map(|v| (*v as f64) * (*v as f64))
            .sum::<f64>()
            .sqrt();
        assert!(
            (got_l2 - l2).abs() / l2 < 1e-4,
            "{variant}: l2 {got_l2} vs python {l2}"
        );
    }
}

#[test]
fn quantized_variants_stay_close_to_bf16_reference() {
    // End-to-end accuracy sanity on the REAL trained model: fp8 logits
    // should track the bf16 logits (the paper's <1% degradation regime).
    let Some(dir) = artifacts_dir() else { return };
    let check = selfcheck(&dir);
    let pre = check.get("prefill").unwrap();
    let bf16 = pre.get("bf16").unwrap().get("l2").unwrap().as_f64().unwrap();
    for variant in ["fp8_pt", "fp8_pc", "fp8_dyn"] {
        let l2 = pre.get(variant).unwrap().get("l2").unwrap().as_f64().unwrap();
        let rel = (l2 - bf16).abs() / bf16;
        assert!(rel < 0.2, "{variant}: l2 {l2} vs bf16 {bf16} ({rel:.3})");
    }
}
