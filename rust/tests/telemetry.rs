//! Integration: the serving telemetry layer (ISSUE 6).
//!
//! Acceptance:
//! * a traced fleet run exports Chrome trace-event JSON that parses, keeps
//!   per-(pid, tid) timestamps monotonic, and whose synthesized
//!   whole-request / ttft spans reproduce every `RequestOutput`'s measured
//!   TTFT and total latency within 1%;
//! * the Prometheus `repro_mfu` summary matches an offline aggregation of
//!   the per-step MFU values the gaudisim device model emitted into the
//!   trace;
//! * merging N per-replica latency reservoirs is order-independent and
//!   percentile-bounded (property test);
//! * an undersized trace ring buffer surfaces its drop count in the fleet
//!   metrics and the human report.

use gaudi_fp8::coordinator::{LatencyStat, Request};
use gaudi_fp8::router::{
    FleetConfig, FleetRouter, RoutePolicy, SimReplica, SimReplicaConfig, TimedRequest,
};
use gaudi_fp8::server::workload::{ArrivalPattern, OpenLoopConfig, WorkloadConfig};
use gaudi_fp8::util::json::Json;
use gaudi_fp8::util::prop::forall_msg;

fn traced_fleet(replicas: usize, capacity: usize) -> FleetRouter {
    let mut router = FleetRouter::new(FleetConfig {
        policy: RoutePolicy::LeastOutstandingTokens,
        queue_capacity: 4096,
    });
    for i in 0..replicas {
        router.add_replica(Box::new(
            SimReplica::new(&format!("sim{i}"), SimReplicaConfig::synthetic_tiny()).unwrap(),
        ));
    }
    router.enable_tracing(capacity);
    router
}

fn workload(requests: usize) -> Vec<TimedRequest> {
    OpenLoopConfig {
        workload: WorkloadConfig {
            requests,
            prompt_len_min: 16,
            prompt_len_max: 128,
            max_new_min: 8,
            max_new_max: 16,
            seed: 77,
        },
        pattern: ArrivalPattern::Poisson { rate_per_s: 128.0 },
    }
    .generate()
}

/// Non-metadata trace events from a parsed export.
fn data_events(trace: &Json) -> Vec<&Json> {
    trace
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array")
        .iter()
        .filter(|e| e.get("ph").and_then(Json::as_str) != Some("M"))
        .collect()
}

#[test]
fn traced_fleet_export_parses_monotonic_and_reproduces_latencies() {
    let mut router = traced_fleet(2, 65_536);
    let report = router.run_open_loop(workload(24)).unwrap();
    assert_eq!(report.outputs.len(), 24);
    assert_eq!(
        report.metrics.merged.trace_events_dropped, 0,
        "ring buffer must be ample for this workload"
    );

    let out = router.chrome_trace();
    let trace = Json::parse(&out).expect("chrome trace must be valid JSON");
    let events = data_events(&trace);
    assert!(!events.is_empty(), "traced run must emit events");

    // Perfetto sanity: every track's timestamps are non-decreasing.
    let mut last: std::collections::HashMap<(u64, u64), f64> = std::collections::HashMap::new();
    for e in &events {
        let pid = e.get("pid").and_then(Json::as_f64).unwrap() as u64;
        let tid = e.get("tid").and_then(Json::as_f64).unwrap() as u64;
        let ts = e.get("ts").and_then(Json::as_f64).unwrap();
        assert!(ts >= 0.0);
        let prev = last.entry((pid, tid)).or_insert(f64::NEG_INFINITY);
        assert!(*prev <= ts, "track ({pid},{tid}) went backwards");
        *prev = ts;
    }

    // Span fidelity: each request's synthesized spans reproduce its
    // measured latencies within 1% (the export rounds at 0.001 us).
    let span_dur_us = |name: &str, tid: u64| -> f64 {
        events
            .iter()
            .find(|e| {
                e.get("name").and_then(Json::as_str) == Some(name)
                    && e.get("tid").and_then(Json::as_f64) == Some(tid as f64)
            })
            .unwrap_or_else(|| panic!("missing {name} span on tid {tid}"))
            .get("dur")
            .and_then(Json::as_f64)
            .unwrap()
    };
    for o in &report.outputs {
        let tid = o.id + 1;
        let total_us = o.total_s * 1e6;
        let ttft_us = o.ttft_s * 1e6;
        let req_dur = span_dur_us("request", tid);
        let ttft_dur = span_dur_us("ttft", tid);
        assert!(
            (req_dur - total_us).abs() <= 0.01 * total_us + 0.01,
            "request {}: span {req_dur}us vs measured {total_us}us",
            o.id
        );
        assert!(
            (ttft_dur - ttft_us).abs() <= 0.01 * ttft_us + 0.01,
            "request {}: ttft span {ttft_dur}us vs measured {ttft_us}us",
            o.id
        );
    }
}

/// The Prometheus `repro_mfu` summary and the trace agree because both are
/// fed by the same gaudisim per-step reports; re-aggregating the trace's
/// per-step MFU offline must land on the exported mean.
#[test]
fn prometheus_mfu_matches_offline_trace_aggregation() {
    let mut router = traced_fleet(1, 65_536);
    let report = router.run_open_loop(workload(16)).unwrap();
    assert_eq!(report.outputs.len(), 16);
    assert_eq!(report.metrics.merged.trace_events_dropped, 0);

    // Offline aggregation: mean of every per-step mfu in the trace.
    let out = router.chrome_trace();
    let trace = Json::parse(&out).unwrap();
    let mut sum = 0.0;
    let mut count = 0u64;
    for e in data_events(&trace) {
        let name = e.get("name").and_then(Json::as_str).unwrap_or("");
        if name == "prefill_chunk" || name == "decode_step" {
            let mfu = e
                .get("args")
                .and_then(|a| a.get("mfu"))
                .and_then(Json::as_f64)
                .expect("step events carry mfu");
            assert!((0.0..=1.0).contains(&mfu), "mfu {mfu} out of range");
            sum += mfu;
            count += 1;
        }
    }
    assert!(count > 0, "no step events in trace");
    let offline_mean = sum / count as f64;

    // Exported summary side.
    let prom = report.metrics.render_prometheus();
    let scrape = |needle: &str| -> f64 {
        prom.lines()
            .find(|l| l.starts_with(needle) && !l.starts_with('#'))
            .and_then(|l| l.split_whitespace().last())
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| panic!("missing {needle} in:\n{prom}"))
    };
    let prom_sum = scrape("repro_mfu_sum");
    let prom_count = scrape("repro_mfu_count");
    assert_eq!(prom_count as u64, count, "one summary sample per step event");
    let prom_mean = prom_sum / prom_count;
    // Trace args round mfu at 1e-6; anything past that is a real mismatch.
    assert!(
        (prom_mean - offline_mean).abs() < 1e-4,
        "prometheus mean {prom_mean} vs offline trace mean {offline_mean}"
    );
    assert!(prom_mean > 0.0, "simulated steps must report nonzero MFU");
}

/// Merging N per-replica reservoirs: any merge order yields identical
/// percentiles, and every percentile stays within the global sample range.
#[test]
fn latency_merge_is_order_independent_and_percentile_bounded() {
    forall_msg(
        0x7e1e_5eed_u64,
        40,
        |rng| {
            let replicas = 1 + rng.below(5);
            (0..replicas)
                .map(|_| {
                    // Up to 1500 samples per replica: some cases push the
                    // combined reservoir past the retention cap, exercising
                    // the sort-then-downsample path.
                    (0..rng.below(1500))
                        .map(|_| rng.next_f64() * 4.0 + 1e-4)
                        .collect::<Vec<f64>>()
                })
                .collect::<Vec<Vec<f64>>>()
        },
        |samples| {
            let stats: Vec<LatencyStat> = samples
                .iter()
                .map(|s| {
                    let mut st = LatencyStat::new();
                    for &v in s {
                        st.record(v);
                    }
                    st
                })
                .collect();
            let forward = LatencyStat::merge_many(stats.iter());
            let backward = LatencyStat::merge_many(stats.iter().rev());
            for q in [0.5, 0.95, 0.99] {
                let (f, b) = (forward.percentile_s(q), backward.percentile_s(q));
                if (f - b).abs() > 1e-12 {
                    return Err(format!("p{q}: order-dependent merge {f} vs {b}"));
                }
            }
            let all: Vec<f64> = samples.iter().flatten().copied().collect();
            if all.is_empty() {
                return Ok(());
            }
            let lo = all.iter().copied().fold(f64::INFINITY, f64::min);
            let hi = all.iter().copied().fold(f64::NEG_INFINITY, f64::max);
            for q in [0.5, 0.95, 0.99] {
                let p = forward.percentile_s(q);
                if !(lo..=hi).contains(&p) {
                    return Err(format!("p{q}={p} outside sample range [{lo}, {hi}]"));
                }
            }
            if forward.count != all.len() as u64 {
                return Err(format!("count {} != {}", forward.count, all.len()));
            }
            Ok(())
        },
    );
}

#[test]
fn undersized_ring_buffer_surfaces_drop_accounting() {
    let mut router = traced_fleet(1, 4);
    let arrivals: Vec<TimedRequest> = (0..16u64)
        .map(|i| TimedRequest::new(Request::new(i, vec![3; 64], 8), 0.0))
        .collect();
    let report = router.run_open_loop(arrivals).unwrap();
    assert_eq!(report.outputs.len(), 16);
    assert!(
        report.metrics.merged.trace_events_dropped > 0,
        "capacity-4 recorder must drop events over 16 requests"
    );
    assert!(
        report.metrics.report().contains("warning: trace ring buffer dropped"),
        "drop warning missing:\n{}",
        report.metrics.report()
    );
    // The surviving buffer still exports valid JSON.
    assert!(Json::parse(&router.chrome_trace()).is_ok());
}
