//! Exhaustive and adversarial FP8 format tests — the numeric foundation
//! everything else rests on.

use gaudi_fp8::fp8::{
    decode, encode_nearest_oracle, encode_rne, rescale_pow2, CastMode, DecodeTable, Fp8Format,
};
use gaudi_fp8::util::prop::interesting_f32;
use gaudi_fp8::util::rng::XorShiftRng;

/// Every f32 that is exactly half way between two representable values,
/// plus epsilon above/below, for every format — the encoder's hardest
/// inputs, enumerated exhaustively.
#[test]
fn all_neighbour_midpoints_and_offsets() {
    for f in Fp8Format::ALL {
        let t = DecodeTable::new(f);
        let sp = t.sorted_positive();
        for w in sp.windows(2) {
            let (lo, hi) = (w[0].0, w[1].0);
            if lo == hi {
                continue;
            }
            let mid = lo + (hi - lo) / 2.0;
            for (x, _label) in [
                (mid, "mid"),
                (f32::from_bits(mid.to_bits() - 1), "below"),
                (f32::from_bits(mid.to_bits() + 1), "above"),
            ] {
                let fast = encode_rne(x, f, CastMode::SatFinite);
                let slow = encode_nearest_oracle(x, &t, CastMode::SatFinite);
                let (vf, vs) = (t.get(fast), t.get(slow));
                assert!(
                    vf == vs,
                    "format {f:?} x={x} ({}): fast {vf} vs oracle {vs}",
                    _label
                );
                // And negated.
                let fast = encode_rne(-x, f, CastMode::SatFinite);
                let slow = encode_nearest_oracle(-x, &t, CastMode::SatFinite);
                assert_eq!(t.get(fast), t.get(slow), "format {f:?} x={}", -x);
            }
        }
    }
}

/// One million random floats per format: bit-manip encoder ≡ oracle.
/// (Scaled down in debug builds so plain `cargo test` stays fast.)
#[test]
fn encoder_fuzz_1m() {
    let iters: u32 = if cfg!(debug_assertions) { 50_000 } else { 1_000_000 };
    for f in Fp8Format::ALL {
        let t = DecodeTable::new(f);
        let mut rng = XorShiftRng::new(0xF0F0 + f as u64);
        let scale = f.params().max_normal / 3.0;
        for i in 0..iters {
            let x = interesting_f32(&mut rng, scale);
            for mode in [CastMode::SatFinite, CastMode::Ieee] {
                let fast = encode_rne(x, f, mode);
                let slow = encode_nearest_oracle(x, &t, mode);
                let (vf, vs) = (t.get(fast), t.get(slow));
                let same = (vf.is_nan() && vs.is_nan()) || vf == vs;
                assert!(same, "format {f:?} mode {mode:?} i={i} x={x}: {vf} vs {vs}");
            }
        }
    }
}

/// rescale_pow2 over the full (code × k) grid for all formats.
#[test]
fn rescale_pow2_full_grid() {
    let ks: Vec<i32> = if cfg!(debug_assertions) {
        vec![-40, -9, -4, -1, 0, 1, 4, 6, 40]
    } else {
        (-40..=40).collect()
    };
    for f in Fp8Format::ALL {
        for &k in &ks {
            for c in 0u16..=255 {
                let c = c as u8;
                let v = decode(c, f);
                let fast = rescale_pow2(c, k, f);
                if v.is_nan() {
                    assert!(decode(fast, f).is_nan());
                    continue;
                }
                if v.is_infinite() {
                    assert_eq!(fast, c);
                    continue;
                }
                let slow = encode_rne(v * (2.0f64.powi(k) as f32), f, CastMode::SatFinite);
                let (vf, vs) = (decode(fast, f), decode(slow, f));
                assert!(
                    vf == vs,
                    "format {f:?} k={k} code {c:#04x} ({v}): {vf} vs {vs}"
                );
            }
        }
    }
}

/// Monotonicity: x ≤ y ⇒ decode(encode(x)) ≤ decode(encode(y)).
/// Rounding must never invert order — a property quantized comparisons
/// (e.g. argmax over quantized logits) depend on.
#[test]
fn encode_is_monotone() {
    for f in Fp8Format::ALL {
        let t = DecodeTable::new(f);
        let mut rng = XorShiftRng::new(0xACE);
        let scale = f.params().max_normal / 2.0;
        for _ in 0..100_000 {
            let a = interesting_f32(&mut rng, scale);
            let b = interesting_f32(&mut rng, scale);
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            let vlo = t.get(encode_rne(lo, f, CastMode::SatFinite));
            let vhi = t.get(encode_rne(hi, f, CastMode::SatFinite));
            assert!(vlo <= vhi, "format {f:?}: {lo} → {vlo}, {hi} → {vhi}");
        }
    }
}

/// The three formats' ranges nest as the paper describes.
#[test]
fn format_range_nesting() {
    let g2 = Fp8Format::E4M3Gaudi2.params().max_normal;
    let g3 = Fp8Format::E4M3.params().max_normal;
    let e5 = Fp8Format::E5M2.params().max_normal;
    assert_eq!(g2, 240.0);
    assert_eq!(g3, 448.0);
    assert_eq!(e5, 57344.0);
    assert!(g2 < g3 && g3 < e5);
    // Precision ordering is the inverse: E4M3 resolves 1.0's neighbourhood
    // finer than E5M2.
    let t4 = DecodeTable::new(Fp8Format::E4M3);
    let t5 = DecodeTable::new(Fp8Format::E5M2);
    let next4 = t4.get(encode_rne(1.0, Fp8Format::E4M3, CastMode::SatFinite) + 1);
    let next5 = t5.get(encode_rne(1.0, Fp8Format::E5M2, CastMode::SatFinite) + 1);
    assert!(next4 - 1.0 < next5 - 1.0);
}
