//! Property suite for the paged KV subsystem (ISSUE 4).
//!
//! * **Randomized interleavings** of alloc / warm-map / CoW-append /
//!   publish / free / evict / swap-out / swap-in (the ISSUE 9 host-tier
//!   preemption cycle) / truncate (the ISSUE 10 speculative rollback,
//!   including cuts landing inside shared blocks) / fork (the beam
//!   branch primitive; prune = Finish of a branch) over a prefix-sharing
//!   prompt family, asserting after every op:
//!   (a) pool refcount balance — each block's refcount equals the number
//!       of live block tables mapping it, plus one if the prefix cache
//!       owns it, plus one per swap record pinning it resident;
//!   (b) the capacity partition — free-listed blocks plus the distinct
//!       union of mapped, prefix-owned, and swap-pinned blocks always
//!       equals pool capacity (moved blocks live on the host, off-pool);
//!   (c) write isolation — after a copy-on-write append, the written block
//!       is reachable from exactly one sequence, and every sequence still
//!       reads exactly its own expected values (shared prefixes included).
//!   Swapped-in sequences must read back every value bit-identically (the
//!   value check covers them the moment they rejoin `live`), and swap
//!   traffic is metered by `SwappedSlot::swapped_bytes` at the `KvLayout`
//!   rate — never by `BlockPool::bytes_read`, which stays byte-exact for
//!   HBM reads alone.
//!   The schedule is seeded (`PAGED_KV_SEED` overrides) and failures are
//!   shrunk to a minimal op subsequence before reporting.
//! * **Dtype-parametrized roundtrips**: gather→scatter through block
//!   tables matches the old contiguous path bit-for-bit for f32/bf16 and
//!   stays within the PR 2 half-ulp bound (per block-level scale group)
//!   for fp8 — including slots whose tail block is partially filled.
//! * **The capacity acceptance claim**: N sequences sharing a P-token
//!   prefix hold P-worth of blocks once plus N private tails, verified by
//!   reading pool occupancy, versus N·P under private copies.

use gaudi_fp8::coordinator::{
    AppendOutcome, BlockId, ForkError, KvStore, PrefixCache, PrefixCacheConfig, SwappedSlot,
};
use gaudi_fp8::fp8::bf16::{bf16_to_f32, f32_to_bf16};
use gaudi_fp8::fp8::Fp8Format;
use gaudi_fp8::quant::{KvDtype, KvLayout};
use gaudi_fp8::util::rng::XorShiftRng;
use std::collections::HashMap;

// ---------------------------------------------------------------------------
// Randomized interleaving harness
// ---------------------------------------------------------------------------

const LAYERS: usize = 2;
const KV_HEADS: usize = 1;
const HEAD_DIM: usize = 2;
const ROW: usize = KV_HEADS * HEAD_DIM;
const BT: usize = 4;
const T: usize = 24;
const SLOTS: usize = 4;
const CACHE_BLOCKS: usize = 8;

#[derive(Clone, Debug)]
enum Op {
    /// Admit a sequence for prompt family `i`: warm-map if the prefix is
    /// cached (full hits bootstrap at `len - 1`, the engine shape that
    /// forces CoW), cold-write otherwise.
    Start(usize),
    /// Append one uniquely-valued token to live sequence `i % live`.
    /// Even-uid sequences use the paged hot path (`append_token`: one
    /// (L, Hkv, D) row, payload-copying CoW); odd-uid sequences use the
    /// dense reference (gather → poke → scatter) — both write paths must
    /// uphold every invariant, interleaved in one world.
    Append(usize),
    /// Share a cold sequence's block-aligned prompt into the cache
    /// (`insert_shared` — block adoption, no copies).
    Publish(usize),
    /// Retire live sequence `i % live`: free the slot, release pins.
    Finish(usize),
    /// Evict up to `n` refcount-0 cached blocks back into the pool.
    Evict(usize),
    /// Preempt live sequence `i % live` to the host tier
    /// (`swap_out_slot`): exclusive blocks move off-device, shared ones
    /// stay pinned resident inside the record, the slot frees.
    SwapOut(usize),
    /// Resume swapped sequence `i % swapped` (`swap_in_slot`) if a slot
    /// and pool headroom exist right now; otherwise the record is kept
    /// for a later retry (the call must not mutate anything on refusal).
    SwapIn(usize),
    /// Roll live sequence `i % live` back to `n % len` tokens
    /// (`truncate_slot`, the speculative-reject path): blocks wholly past
    /// the cut are released (shared ones by refcount drop), a cut inside
    /// a shared block keeps it shared, and the model truncates with it.
    Truncate(usize, usize),
    /// Fork live sequence `i % live` into a fresh slot sharing its whole
    /// history (`fork_slot`, the beam primitive). The typed refusal must
    /// name the genuinely missing resource.
    Fork(usize),
}

struct Seq {
    uid: usize,
    slot: usize,
    fam: usize,
    /// Tokens pinned in the prefix cache (released on Finish).
    pinned: usize,
    /// Expected value per valid position (each position is written with
    /// one value replicated across layers/heads/dims).
    vals: Vec<f32>,
    /// Started cold (owns true prompt KV) — only these may Publish,
    /// mirroring the engine, where warm tails are never inserted.
    cold: bool,
}

/// A preempted sequence parked in the host tier: its model state rides
/// along so the value check can verify a bit-identical restore the moment
/// it swaps back in.
struct Swapped {
    seq: Seq,
    record: SwappedSlot,
    /// Blocks that stayed device-resident under the record's pin
    /// (refcount > 1 at swap-out time) — the census charges the record
    /// one reference for each.
    resident_ids: Vec<BlockId>,
}

/// Prompts sharing prefixes at block and sub-block depths; all ≤ 16
/// tokens so sequences can append well past their prompt inside T = 24.
fn family() -> Vec<Vec<i32>> {
    let mut fams = Vec::new();
    for root in 0..3i32 {
        for ext in 0..3usize {
            let mut p = vec![root + 1; 2 * BT]; // shared 2-block root
            p.extend(vec![100 + root * 8 + ext as i32; BT]);
            if ext == 2 {
                p.extend(vec![50 + root; 2]); // non-block-aligned tail
            }
            fams.push(p);
        }
    }
    fams
}

/// The value every sequence must read at prompt position `p` — a function
/// of the token only, so physically shared blocks are coherent across all
/// sequences of a prefix family.
fn prompt_val(prompt: &[i32], p: usize) -> f32 {
    (prompt[p] * 100 + p as i32) as f32
}

/// The value sequence `uid` appends at position `p` — unique per
/// sequence, so any cross-sequence leak through a shared or CoW'd block
/// is caught by the value check.
fn append_val(uid: usize, p: usize) -> f32 {
    (200_000 + uid * 64 + p) as f32
}

/// Fill position `p` of an (L, 1, T, Hkv, D) buffer pair with `val`.
fn poke(k: &mut [f32], v: &mut [f32], p: usize, val: f32) {
    for l in 0..LAYERS {
        let base = (l * T + p) * ROW;
        k[base..base + ROW].fill(val);
        v[base..base + ROW].fill(val);
    }
}

fn check_invariants(
    kv: &KvStore,
    pc: &PrefixCache,
    live: &[Seq],
    swapped: &[Swapped],
) -> Result<(), String> {
    let pool = kv.pool();
    // Ownership census: block table references + cache ownership + swap
    // records' resident pins.
    let mut owners: HashMap<BlockId, u32> = HashMap::new();
    for s in live {
        for id in kv.slot_blocks(s.slot) {
            *owners.entry(id).or_insert(0) += 1;
        }
    }
    for sw in swapped {
        for &id in &sw.resident_ids {
            *owners.entry(id).or_insert(0) += 1;
        }
    }
    let cache_ids = pc.owned_blocks();
    {
        let mut seen = std::collections::HashSet::new();
        for &id in &cache_ids {
            if !seen.insert(id) {
                return Err(format!("cache owns block {id} twice"));
            }
            *owners.entry(id).or_insert(0) += 1;
        }
    }
    // (a) refcount balance, per block.
    for id in 0..pool.total_blocks() {
        let expect = owners.get(&id).copied().unwrap_or(0);
        if pool.ref_count(id) != expect {
            return Err(format!(
                "block {id}: pool refcount {} but {} owners (tables + cache)",
                pool.ref_count(id),
                expect
            ));
        }
    }
    // (b) the capacity partition: free + |mapped ∪ cache-owned| = total.
    if pool.free_blocks() + owners.len() != pool.total_blocks() {
        return Err(format!(
            "capacity partition broken: {} free + {} owned != {} total",
            pool.free_blocks(),
            owners.len(),
            pool.total_blocks()
        ));
    }
    if pc.cached_blocks() != cache_ids.len() {
        return Err(format!(
            "cache accounting drift: cached_blocks {} vs {} owned IDs",
            pc.cached_blocks(),
            cache_ids.len()
        ));
    }
    // Prefix pin balance: swapped sequences keep their prompt pinned in
    // the cache for the whole preemption round trip.
    let expect_pins: u64 = live
        .iter()
        .map(|s| (s.pinned / BT) as u64)
        .chain(swapped.iter().map(|sw| (sw.seq.pinned / BT) as u64))
        .sum();
    if pc.total_refs() != expect_pins {
        return Err(format!(
            "pin imbalance: cache holds {} refs, sequences hold {expect_pins}",
            pc.total_refs()
        ));
    }
    if pc.referenced_blocks() > pc.cached_blocks() {
        return Err("referenced > cached".into());
    }
    // (c) every sequence reads exactly its own values.
    for s in live {
        let (k, v, lens) = kv.gather_batch(&[s.slot]);
        if lens[0] as usize != s.vals.len() {
            return Err(format!(
                "seq {}: store len {} vs model len {}",
                s.uid,
                lens[0],
                s.vals.len()
            ));
        }
        for (p, want) in s.vals.iter().enumerate() {
            for l in 0..LAYERS {
                let base = (l * T + p) * ROW;
                for e in 0..ROW {
                    if k[base + e] != *want || v[base + e] != *want {
                        return Err(format!(
                            "seq {} pos {p}: read {} expected {want} \
                             (cross-sequence leak through a shared/CoW block?)",
                            s.uid,
                            k[base + e]
                        ));
                    }
                }
            }
        }
        for l in 0..LAYERS {
            let start = (l * T + s.vals.len()) * ROW;
            let end = (l + 1) * T * ROW;
            if k[start..end].iter().any(|x| *x != 0.0) {
                return Err(format!("seq {}: nonzero past len", s.uid));
            }
        }
    }
    Ok(())
}

/// Execute `ops` against a fresh world, checking every invariant after
/// every op. Err = the failure message (the shrinker minimizes on it).
fn run_ops(ops: &[Op]) -> Result<(), String> {
    let fams = family();
    let mut kv = KvStore::with_block_tokens(
        LAYERS,
        SLOTS,
        T,
        KV_HEADS,
        HEAD_DIM,
        KvDtype::F32,
        BT,
        CACHE_BLOCKS,
    );
    let mut pc = PrefixCache::new(PrefixCacheConfig {
        block_tokens: BT,
        max_blocks: CACHE_BLOCKS,
        layout: KvLayout::new(KvDtype::F32, LAYERS, KV_HEADS, HEAD_DIM),
    });
    let mut live: Vec<Seq> = Vec::new();
    let mut swapped: Vec<Swapped> = Vec::new();
    let mut next_uid = 0usize;

    for op in ops {
        match op {
            Op::Start(f) => {
                if live.len() == SLOTS {
                    continue;
                }
                let fam = f % fams.len();
                let prompt = &fams[fam];
                let slot = kv
                    .alloc_slot()
                    .ok_or_else(|| String::from("no free slot with live < SLOTS"))?;
                let cached = pc.acquire(prompt).min(prompt.len());
                let mapped = if cached > 0 {
                    pc.mapped_blocks(prompt, cached)
                } else {
                    None
                };
                let (vals, pinned, cold) = match mapped {
                    Some(ids) => {
                        // Warm: full hits bootstrap one position early —
                        // the engine shape whose append lands inside the
                        // last shared block and must CoW.
                        let start = if cached == prompt.len() {
                            cached - 1
                        } else {
                            cached
                        };
                        kv.map_shared_prefix(slot, &ids, start);
                        let vals: Vec<f32> =
                            (0..start).map(|p| prompt_val(prompt, p)).collect();
                        (vals, cached, false)
                    }
                    None => {
                        if cached > 0 {
                            pc.release(prompt, cached);
                        }
                        let n = LAYERS * T * ROW;
                        let (mut k, mut v) = (vec![0.0f32; n], vec![0.0f32; n]);
                        for p in 0..prompt.len() {
                            poke(&mut k, &mut v, p, prompt_val(prompt, p));
                        }
                        kv.write_slot(slot, &k, &v, prompt.len());
                        let vals: Vec<f32> =
                            (0..prompt.len()).map(|p| prompt_val(prompt, p)).collect();
                        (vals, 0, true)
                    }
                };
                live.push(Seq {
                    uid: next_uid,
                    slot,
                    fam,
                    pinned,
                    vals,
                    cold,
                });
                next_uid += 1;
            }
            Op::Append(i) => {
                if live.is_empty() {
                    continue;
                }
                let idx = i % live.len();
                let slot = live[idx].slot;
                let len = live[idx].vals.len();
                if len >= T {
                    continue;
                }
                let val = append_val(live[idx].uid, len);
                if live[idx].uid % 2 == 0 {
                    // Paged hot path.
                    let row = vec![val; LAYERS * ROW];
                    let out = kv.append_token(slot, &row, &row);
                    if out == AppendOutcome::AtCapacity {
                        return Err(format!(
                            "append_token refused seq {} at len {len} < T",
                            live[idx].uid
                        ));
                    }
                } else {
                    // Dense reference path.
                    let (mut k, mut v, _) = kv.gather_batch(&[slot]);
                    poke(&mut k, &mut v, len, val);
                    kv.scatter_batch(&[slot], &k, &v);
                }
                live[idx].vals.push(val);
                // (c) the written (hot) block must now be private.
                let blocks = kv.slot_blocks(slot);
                let hot = blocks[len / BT];
                if kv.pool().ref_count(hot) != 1 {
                    return Err(format!(
                        "append by seq {} wrote block {hot} with refcount {} — \
                         reachable from another sequence or the cache after a write",
                        live[idx].uid,
                        kv.pool().ref_count(hot)
                    ));
                }
            }
            Op::Publish(i) => {
                if live.is_empty() {
                    continue;
                }
                let idx = i % live.len();
                if !live[idx].cold {
                    continue; // engine parity: warm tails are never inserted
                }
                let (slot, fam, old_pins) = (live[idx].slot, live[idx].fam, live[idx].pinned);
                let prompt = fams[fam].clone();
                let blocks = kv.slot_blocks(slot);
                pc.insert_shared(&prompt, &blocks, kv.pool_mut());
                if old_pins > 0 {
                    pc.release(&prompt, old_pins);
                }
                live[idx].pinned = pc.acquire(&prompt);
            }
            Op::Finish(i) => {
                if live.is_empty() {
                    continue;
                }
                let s = live.remove(i % live.len());
                kv.free_slot(s.slot);
                if s.pinned > 0 {
                    pc.release(&fams[s.fam], s.pinned);
                }
            }
            Op::Evict(n) => {
                pc.evict_blocks_pooled(n.max(1), kv.pool_mut());
            }
            Op::SwapOut(i) => {
                if live.is_empty() {
                    continue;
                }
                let s = live.remove(i % live.len());
                let table = kv.slot_blocks(s.slot);
                // Predict the resident/moved split from pre-swap refcounts:
                // shared blocks (refs > 1) must stay pinned on device.
                let resident_ids: Vec<BlockId> = table
                    .iter()
                    .copied()
                    .filter(|&id| kv.pool().ref_count(id) > 1)
                    .collect();
                let hbm_reads = kv.pool().bytes_read();
                let record = kv.swap_out_slot(s.slot);
                if kv.pool().bytes_read() != hbm_reads {
                    return Err("swap-out charged the HBM read meter".into());
                }
                if record.len() != s.vals.len() {
                    return Err(format!(
                        "swap record len {} vs model len {} for seq {}",
                        record.len(),
                        s.vals.len(),
                        s.uid
                    ));
                }
                if record.resident_blocks() != resident_ids.len()
                    || record.moved_blocks() + record.resident_blocks() != table.len()
                {
                    return Err(format!(
                        "swap split drift for seq {}: record says {} moved + {} resident, \
                         refcounts said {} resident of {} total",
                        s.uid,
                        record.moved_blocks(),
                        record.resident_blocks(),
                        resident_ids.len(),
                        table.len()
                    ));
                }
                // Byte-exact host-link accounting at the layout rate:
                // moved blocks only, codes and scales charged together.
                let rate = kv.layout().block_bytes(BT);
                if record.swapped_bytes(&kv.layout(), BT) != record.moved_blocks() * rate {
                    return Err(format!(
                        "swapped_bytes {} != {} moved blocks × {rate} B/block",
                        record.swapped_bytes(&kv.layout(), BT),
                        record.moved_blocks()
                    ));
                }
                swapped.push(Swapped {
                    seq: s,
                    record,
                    resident_ids,
                });
            }
            Op::SwapIn(i) => {
                if swapped.is_empty() {
                    continue;
                }
                let idx = i % swapped.len();
                if !kv.can_swap_in(&swapped[idx].record) {
                    // Pool or slot pressure: the record waits. Nothing may
                    // have been mutated, which the per-op census verifies.
                    continue;
                }
                let sw = swapped.remove(idx);
                let hbm_reads = kv.pool().bytes_read();
                match kv.swap_in_slot(sw.record) {
                    Ok(slot) => {
                        if kv.pool().bytes_read() != hbm_reads {
                            return Err("swap-in charged the HBM read meter".into());
                        }
                        let mut seq = sw.seq;
                        seq.slot = slot;
                        // The value check now re-verifies every position of
                        // this sequence — a bit-identical restore or bust.
                        live.push(seq);
                    }
                    Err(_) => {
                        return Err(format!(
                            "swap_in_slot refused seq {} after can_swap_in approved",
                            sw.seq.uid
                        ));
                    }
                }
            }
            Op::Truncate(i, n) => {
                if live.is_empty() {
                    continue;
                }
                let idx = i % live.len();
                let len = live[idx].vals.len();
                if len == 0 {
                    continue;
                }
                // Strict shrink (0..len-1): the speculative-reject shape.
                // Cuts landing mid-block leave that block shared if it was;
                // the value check only reads the kept span, and gather
                // zero-fills past len, so stale positions must be invisible.
                let new_len = n % len;
                kv.truncate_slot(live[idx].slot, new_len);
                live[idx].vals.truncate(new_len);
                // A cut can reach into the prompt prefix; appends after it
                // rewrite positions Publish would claim as prompt content,
                // so a truncated sequence is never inserted into the cache.
                live[idx].cold = false;
            }
            Op::Fork(i) => {
                if live.is_empty() {
                    continue;
                }
                let idx = i % live.len();
                let free_slots = live.len() < SLOTS;
                let free_blocks = kv.pool().free_blocks();
                match kv.fork_slot(live[idx].slot) {
                    Ok(slot) => {
                        // Zero-copy branch: shares every block; the census
                        // now expects +1 refs on each, and the value check
                        // re-reads the whole history through the new slot.
                        let vals = live[idx].vals.clone();
                        let fam = live[idx].fam;
                        live.push(Seq {
                            uid: next_uid,
                            slot,
                            fam,
                            pinned: 0,
                            vals,
                            cold: false,
                        });
                        next_uid += 1;
                    }
                    Err(ForkError::NoFreeBlocks) => {
                        if free_blocks != 0 {
                            return Err(format!(
                                "fork said NoFreeBlocks with {free_blocks} blocks free"
                            ));
                        }
                    }
                    Err(ForkError::NoFreeSlot) => {
                        if free_slots {
                            return Err("fork said NoFreeSlot with a slot free".into());
                        }
                        if free_blocks == 0 {
                            return Err("NoFreeBlocks must win when both are exhausted".into());
                        }
                    }
                    Err(ForkError::InactiveSource) => {
                        return Err(format!(
                            "fork of live seq {} said InactiveSource",
                            live[idx].uid
                        ));
                    }
                }
            }
        }
        check_invariants(&kv, &pc, &live, &swapped)?;
    }
    // Drain: everything must come home. Swap records end in
    // discard_swapped (the abort path), which must release their
    // resident pins for the leak checks below to balance.
    while let Some(s) = live.pop() {
        kv.free_slot(s.slot);
        if s.pinned > 0 {
            pc.release(&fams[s.fam], s.pinned);
        }
    }
    while let Some(sw) = swapped.pop() {
        kv.discard_swapped(sw.record);
        if sw.seq.pinned > 0 {
            pc.release(&fams[sw.seq.fam], sw.seq.pinned);
        }
    }
    if pc.total_refs() != 0 {
        return Err(format!("{} pins leaked after drain", pc.total_refs()));
    }
    pc.evict_blocks_pooled(usize::MAX, kv.pool_mut());
    if pc.cached_blocks() != 0 {
        return Err("unpinned cache failed to drain".into());
    }
    if kv.pool().used_blocks() != 0 {
        return Err(format!(
            "{} blocks leaked after full drain",
            kv.pool().used_blocks()
        ));
    }
    Ok(())
}

fn gen_ops(rng: &mut XorShiftRng, n: usize) -> Vec<Op> {
    (0..n)
        .map(|_| match rng.below(12) {
            0 | 1 => Op::Start(rng.below(64)),
            2 | 3 | 4 => Op::Append(rng.below(64)),
            5 => Op::Publish(rng.below(64)),
            6 => Op::Finish(rng.below(64)),
            7 => Op::Evict(1 + rng.below(4)),
            8 => Op::SwapOut(rng.below(64)),
            9 => Op::SwapIn(rng.below(64)),
            10 => Op::Truncate(rng.below(64), rng.below(24)),
            _ => Op::Fork(rng.below(64)),
        })
        .collect()
}

/// Greedy delta-shrink: repeatedly drop any op whose removal still fails,
/// until no single removal reproduces. Deterministic (`run_ops` is pure in
/// its input), so the minimal schedule is replayable as printed.
fn shrink_failing(mut ops: Vec<Op>, mut msg: String) -> (Vec<Op>, String) {
    loop {
        let mut improved = false;
        let mut i = 0;
        while i < ops.len() {
            let mut cand = ops.clone();
            cand.remove(i);
            if let Err(m) = run_ops(&cand) {
                ops = cand;
                msg = m;
                improved = true;
            } else {
                i += 1;
            }
        }
        if !improved {
            return (ops, msg);
        }
    }
}

#[test]
fn randomized_interleavings_preserve_pool_invariants() {
    let seed = std::env::var("PAGED_KV_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0xB10C_5EED);
    let mut rng = XorShiftRng::new(seed);
    for case in 0..60 {
        let ops = gen_ops(&mut rng, 80);
        if let Err(msg) = run_ops(&ops) {
            let (min_ops, min_msg) = shrink_failing(ops, msg);
            panic!(
                "paged KV property failed (seed {seed:#x}, case {case}): {min_msg}\n\
                 minimal repro ({} ops): {min_ops:?}",
                min_ops.len()
            );
        }
    }
}

/// A speculative rollback whose cut lands *inside* a block another branch
/// still reads must keep that block shared (no clone, no zeroing): the
/// sibling reads every original value bit-identically, blocks wholly past
/// the cut return to the pool, and the branch's next append CoWs its own
/// copy before writing anything.
#[test]
fn truncation_inside_a_shared_block_preserves_the_sibling() {
    let mut kv = KvStore::with_block_tokens(LAYERS, 2, T, KV_HEADS, HEAD_DIM, KvDtype::F32, BT, 0);
    let root = kv.alloc_slot().unwrap();
    let n = LAYERS * T * ROW;
    let (mut k, mut v) = (vec![0.0f32; n], vec![0.0f32; n]);
    for p in 0..6 {
        poke(&mut k, &mut v, p, (10 + p) as f32);
    }
    kv.write_slot(root, &k, &v, 6); // one full block + a half block
    let branch = kv.fork_slot(root).unwrap();
    assert_eq!(kv.pool().used_blocks(), 2, "fork copies no blocks");
    // The branch speculates one token (CoW of the hot block), then the
    // verifier rejects back to 3 tokens — a cut inside the first block,
    // which the root still reads.
    let row = vec![99.0f32; LAYERS * ROW];
    kv.append_token(branch, &row, &row);
    assert_eq!(kv.pool().used_blocks(), 3, "append CoW'd the hot block");
    kv.truncate_slot(branch, 3);
    assert_eq!(kv.len(branch), Some(3));
    assert_eq!(
        kv.pool().used_blocks(),
        2,
        "the branch's private hot-block copy returned to the pool"
    );
    let shared = kv.slot_blocks(branch)[0];
    assert_eq!(kv.slot_blocks(root)[0], shared, "kept block stays shared");
    assert_eq!(kv.pool().ref_count(shared), 2);
    let (kr, _, lens) = kv.gather_batch(&[root]);
    assert_eq!(lens, vec![6]);
    for p in 0..6 {
        assert_eq!(kr[p * ROW], (10 + p) as f32, "sibling value at {p}");
    }
    // Writing after the rollback goes through CoW again — the rejected
    // positions never leak into the sibling's block.
    let row2 = vec![7.0f32; LAYERS * ROW];
    kv.append_token(branch, &row2, &row2);
    assert_eq!(kv.pool().ref_count(shared), 1, "root's block went private");
    let (kb, _, lens) = kv.gather_batch(&[branch]);
    assert_eq!(lens, vec![4]);
    assert_eq!(kb[3 * ROW], 7.0);
    for p in 0..3 {
        assert_eq!(kb[p * ROW], (10 + p) as f32, "kept value at {p}");
    }
    kv.free_slot(root);
    kv.free_slot(branch);
    assert_eq!(kv.pool().used_blocks(), 0);
}

// ---------------------------------------------------------------------------
// Dtype-parametrized roundtrips through block tables
// ---------------------------------------------------------------------------

/// Geometry with a partially filled tail block: len 18 over 4-token
/// blocks = 4 full blocks + 2 tokens.
const RT_LAYERS: usize = 2;
const RT_KVH: usize = 2;
const RT_HD: usize = 3;
const RT_ROW: usize = RT_KVH * RT_HD;
const RT_T: usize = 20;
const RT_BT: usize = 4;
const RT_LEN: usize = 18;

fn rt_source(seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = XorShiftRng::new(seed);
    let n = RT_LAYERS * RT_T * RT_ROW;
    let k = (0..n).map(|_| rng.normal()).collect();
    let v = (0..n).map(|_| rng.normal() * 2.0).collect();
    (k, v)
}

fn rt_store(dtype: KvDtype) -> KvStore {
    KvStore::with_block_tokens(RT_LAYERS, 2, RT_T, RT_KVH, RT_HD, dtype, RT_BT, 0)
}

/// What the pre-paged contiguous store returned for a valid position:
/// identity for f32, an independent per-element BF16 roundtrip for bf16.
fn reference(dtype: KvDtype, x: f32) -> f32 {
    match dtype {
        KvDtype::F32 => x,
        KvDtype::Bf16 => bf16_to_f32(f32_to_bf16(x)),
        KvDtype::Fp8(_) => unreachable!("fp8 is bound-checked, not bitwise"),
    }
}

#[test]
fn paged_roundtrip_matches_contiguous_reference_bitwise_for_f32_and_bf16() {
    for dtype in [KvDtype::F32, KvDtype::Bf16] {
        let (ks, vs) = rt_source(41);
        let mut store = rt_store(dtype);
        let slot = store.alloc_slot().unwrap();
        store.write_slot(slot, &ks, &vs, RT_LEN);
        let (k, v, lens) = store.gather_batch(&[slot]);
        assert_eq!(lens, vec![RT_LEN as i32]);
        for l in 0..RT_LAYERS {
            for p in 0..RT_LEN {
                for e in 0..RT_ROW {
                    let i = (l * RT_T + p) * RT_ROW + e;
                    assert_eq!(
                        k[i].to_bits(),
                        reference(dtype, ks[i]).to_bits(),
                        "{dtype:?} K mismatch at layer {l} pos {p} elem {e}"
                    );
                    assert_eq!(v[i].to_bits(), reference(dtype, vs[i]).to_bits());
                }
            }
            // Positions past len (including the partial tail block's own
            // tail) come back as exact zeros.
            let start = (l * RT_T + RT_LEN) * RT_ROW;
            let end = (l + 1) * RT_T * RT_ROW;
            assert!(k[start..end].iter().all(|x| *x == 0.0));
        }
        // Scatter appends into the partial tail block; history must not
        // move a bit and the appended position must store exactly.
        let (mut k2, v2) = (k.clone(), v.clone());
        let newv = 0.8125f32; // exactly representable in bf16
        for l in 0..RT_LAYERS {
            let base = (l * RT_T + RT_LEN) * RT_ROW;
            k2[base..base + RT_ROW].fill(newv);
        }
        store.scatter_batch(&[slot], &k2, &v2);
        let (k3, _, lens) = store.gather_batch(&[slot]);
        assert_eq!(lens, vec![RT_LEN as i32 + 1]);
        for l in 0..RT_LAYERS {
            for p in 0..RT_LEN {
                for e in 0..RT_ROW {
                    let i = (l * RT_T + p) * RT_ROW + e;
                    assert_eq!(k3[i].to_bits(), k[i].to_bits(), "{dtype:?}: history moved");
                }
            }
            let base = (l * RT_T + RT_LEN) * RT_ROW;
            assert!(k3[base..base + RT_ROW].iter().all(|x| *x == newv));
        }
    }
}

#[test]
fn paged_fp8_roundtrip_within_half_ulp_of_block_group_maxabs() {
    for format in Fp8Format::ALL {
        let half_ulp_rel = (2.0f32).powi(-(format.params().man_bits as i32 + 1));
        let (ks, vs) = rt_source(0xF8 + format as u64);
        let mut store = rt_store(KvDtype::Fp8(format));
        let slot = store.alloc_slot().unwrap();
        store.write_slot(slot, &ks, &vs, RT_LEN);
        let (k, v, _) = store.gather_batch(&[slot]);
        // PR 2's half-ulp property at the paged store's (finer) scale
        // granularity: the group is (block, layer, kv-head), its max-abs
        // taken over the block's *valid* tokens only — the partially
        // filled tail block included.
        for (src, deq, name) in [(&ks, &k, "K"), (&vs, &v, "V")] {
            for b in 0..RT_LEN.div_ceil(RT_BT) {
                let tok0 = b * RT_BT;
                let tokn = RT_BT.min(RT_LEN - tok0);
                for l in 0..RT_LAYERS {
                    for h in 0..RT_KVH {
                        let mut maxabs = 0.0f32;
                        for p in tok0..tok0 + tokn {
                            for d in 0..RT_HD {
                                let i = (l * RT_T + p) * RT_ROW + h * RT_HD + d;
                                maxabs = maxabs.max(src[i].abs());
                            }
                        }
                        let bound = maxabs * half_ulp_rel * 1.001 + 1e-30;
                        for p in tok0..tok0 + tokn {
                            for d in 0..RT_HD {
                                let i = (l * RT_T + p) * RT_ROW + h * RT_HD + d;
                                let err = (deq[i] - src[i]).abs();
                                assert!(
                                    err <= bound,
                                    "{format:?} {name}[block {b}, l {l}, h {h}, p {p}]: \
                                     |{} - {}| = {err:e} > {bound:e}",
                                    deq[i],
                                    src[i]
                                );
                            }
                        }
                    }
                }
            }
        }
        // Gather→scatter→gather: appending a token re-encodes only the
        // hot block. Cold blocks are bit-stable (their bytes never move);
        // the hot block's history stays within the half-ulp bound of its
        // *recomputed* scale group (the appended token joins the group, so
        // the grid may legitimately shift by one scale step).
        let (k0, v0, _) = store.gather_batch(&[slot]);
        let mut k1 = k0.clone();
        for l in 0..RT_LAYERS {
            let base = (l * RT_T + RT_LEN) * RT_ROW;
            k1[base..base + RT_ROW].fill(0.25);
        }
        store.scatter_batch(&[slot], &k1, &v0);
        let (k2, _, _) = store.gather_batch(&[slot]);
        let hot0 = (RT_LEN / RT_BT) * RT_BT;
        for l in 0..RT_LAYERS {
            for p in 0..hot0 {
                for e in 0..RT_ROW {
                    let i = (l * RT_T + p) * RT_ROW + e;
                    assert_eq!(k2[i].to_bits(), k0[i].to_bits(), "{format:?}: cold block drift");
                }
            }
            for h in 0..RT_KVH {
                // New scale group: the hot block's tokens [hot0, len+1).
                let mut maxabs = 0.0f32;
                for p in hot0..RT_LEN + 1 {
                    for d in 0..RT_HD {
                        let i = (l * RT_T + p) * RT_ROW + h * RT_HD + d;
                        maxabs = maxabs.max(k1[i].abs());
                    }
                }
                let bound = maxabs * half_ulp_rel * 1.001 + 1e-30;
                for p in hot0..RT_LEN {
                    for d in 0..RT_HD {
                        let i = (l * RT_T + p) * RT_ROW + h * RT_HD + d;
                        assert!(
                            (k2[i] - k0[i]).abs() <= 2.0 * bound,
                            "{format:?}: hot-block history drifted past one grid step: \
                             {} vs {}",
                            k2[i],
                            k0[i]
                        );
                    }
                }
            }
        }
    }
}

/// ISSUE 9: a swap-out/swap-in round trip through the host tier must be
/// lossless **by construction** — raw stored codes plus (under FP8) the
/// per-(block, layer, kv-head) scales move together, so the restored
/// sequence dequantizes to exactly the same bits with no re-quantization
/// step. Also pins down the metering split: host-link traffic is
/// `swapped_bytes` at the `KvLayout` block rate, and the HBM read meter
/// (`BlockPool::bytes_read`) never moves for swap traffic.
#[test]
fn swap_roundtrip_restores_codes_and_scales_bit_identically() {
    let mut dtypes = vec![KvDtype::F32, KvDtype::Bf16];
    dtypes.extend(Fp8Format::ALL.iter().map(|&f| KvDtype::Fp8(f)));
    for dtype in dtypes {
        let (ks, vs) = rt_source(0x5A);
        let mut store = rt_store(dtype);
        let slot = store.alloc_slot().unwrap();
        store.write_slot(slot, &ks, &vs, RT_LEN);
        let (k0, v0, lens0) = store.gather_batch(&[slot]);
        let used0 = store.pool().used_blocks();
        let hbm_reads = store.pool().bytes_read();

        let record = store.swap_out_slot(slot);
        assert_eq!(record.len(), RT_LEN);
        let blocks = RT_LEN.div_ceil(RT_BT);
        assert_eq!(
            record.moved_blocks(),
            blocks,
            "{dtype:?}: every block is exclusive here, so every block moves"
        );
        assert_eq!(record.resident_blocks(), 0);
        assert_eq!(
            record.swapped_bytes(&store.layout(), RT_BT),
            blocks * store.layout().block_bytes(RT_BT),
            "{dtype:?}: host-link bytes at the declared layout rate, scales included"
        );
        assert_eq!(
            store.pool().used_blocks(),
            0,
            "{dtype:?}: moved blocks return to the device free list"
        );

        let slot2 = store
            .swap_in_slot(record)
            .unwrap_or_else(|_| panic!("{dtype:?}: swap-in must fit an empty pool"));
        assert_eq!(store.pool().used_blocks(), used0);
        assert_eq!(
            store.pool().bytes_read(),
            hbm_reads,
            "{dtype:?}: swap traffic must never charge the HBM read meter"
        );
        let (k1, v1, lens1) = store.gather_batch(&[slot2]);
        assert_eq!(lens1, lens0);
        for (i, (a, b)) in k0.iter().zip(&k1).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{dtype:?}: K drift at {i}");
        }
        for (i, (a, b)) in v0.iter().zip(&v1).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{dtype:?}: V drift at {i}");
        }
    }
}

// ---------------------------------------------------------------------------
// The capacity acceptance claim, read off pool occupancy
// ---------------------------------------------------------------------------

#[test]
fn n_sequences_sharing_a_prefix_hold_it_once_plus_private_tails() {
    let (layers, kvh, hd, bt, t) = (2usize, 2usize, 4usize, 16usize, 128usize);
    let row = kvh * hd;
    let n_req = 4usize;
    let prefix_tokens = 64usize; // 4 blocks
    let tail_tokens = 8usize; // 1 block each
    let prompt = vec![5i32; prefix_tokens];
    let layout = KvLayout::new(KvDtype::FP8_DEFAULT, layers, kvh, hd);

    let n = layers * t * row;
    let mut kbuf = vec![0.0f32; n];
    let vbuf = vec![0.0f32; n];
    for p in 0..prefix_tokens {
        let x = 0.5 + 0.01 * p as f32;
        for l in 0..layers {
            let base = (l * t + p) * row;
            kbuf[base..base + row].fill(x);
        }
    }

    // Paged: one cold writer publishes the prefix; the rest map it.
    let mut kv = KvStore::with_block_tokens(
        layers,
        n_req,
        t,
        kvh,
        hd,
        KvDtype::FP8_DEFAULT,
        bt,
        prefix_tokens / bt,
    );
    let mut pc = PrefixCache::new(PrefixCacheConfig {
        block_tokens: bt,
        max_blocks: prefix_tokens / bt,
        layout,
    });
    let append = |kv: &mut KvStore, slot: usize, count: usize| {
        let (mut k, v, _) = kv.gather_batch(&[slot]);
        for _ in 0..count {
            let len = kv.len(slot).unwrap();
            for l in 0..layers {
                let base = (l * t + len) * row;
                k[base..base + row].fill(0.125);
            }
            kv.scatter_batch(&[slot], &k, &v);
        }
    };
    let writer = kv.alloc_slot().unwrap();
    kv.write_slot(writer, &kbuf, &vbuf, prefix_tokens);
    let blocks = kv.slot_blocks(writer);
    pc.insert_shared(&prompt, &blocks, kv.pool_mut());
    append(&mut kv, writer, tail_tokens);
    for _ in 1..n_req {
        let slot = kv.alloc_slot().unwrap();
        let ids = pc.mapped_blocks(&prompt, prefix_tokens).expect("physical hit");
        kv.map_shared_prefix(slot, &ids, prefix_tokens);
        append(&mut kv, slot, tail_tokens);
    }
    let prefix_blocks = prefix_tokens / bt;
    let tail_blocks = tail_tokens.div_ceil(bt);
    assert_eq!(
        kv.pool().used_blocks(),
        prefix_blocks + n_req * tail_blocks,
        "paged residency must be prefix-once + N private tails"
    );
    let paged_resident = kv.resident_bytes();

    // Copy baseline: every request holds the prefix privately.
    let mut copy =
        KvStore::with_block_tokens(layers, n_req, t, kvh, hd, KvDtype::FP8_DEFAULT, bt, 0);
    for _ in 0..n_req {
        let slot = copy.alloc_slot().unwrap();
        copy.write_slot(slot, &kbuf, &vbuf, prefix_tokens);
        append(&mut copy, slot, tail_tokens);
    }
    assert_eq!(
        copy.pool().used_blocks(),
        n_req * (prefix_blocks + tail_blocks),
        "copy residency is N × (prefix + tail)"
    );
    let copy_resident = copy.resident_bytes();
    assert!(
        paged_resident * 2 < copy_resident,
        "sharing must at least halve residency at N = {n_req}: {paged_resident} vs {copy_resident}"
    );
    // ~P·bytes + N·tail vs ~N·P, exactly, at the block-byte rate.
    assert_eq!(
        paged_resident,
        (prefix_blocks + n_req * tail_blocks) * layout.block_bytes(bt)
    );
}
