//! Figure A: operator-level throughput sweep — GEMM TFLOPS vs shape for
//! every scaling configuration, including the non-square shapes of real
//! LLM layers (Llama-70B projections at several prefill lengths) and
//! BF16 for the 2× FP8 speedup context. Emitted as CSV series + an ASCII
//! plot, the figure-regeneration format of this repo.

use gaudi_fp8::gaudisim::{gemm_time_s, Device, GemmConfig, ScalingKind};

fn main() {
    let dev = Device::gaudi2();
    let scalings = [
        ScalingKind::PerTensorHwPow2,
        ScalingKind::PerTensorHalfHw,
        ScalingKind::PerTensorSw,
        ScalingKind::PerChannel,
        ScalingKind::Bf16,
    ];

    println!("# Figure A data (CSV): square GEMM sweep");
    println!("m,scaling,tflops,mfu");
    let sizes = [512usize, 1024, 2048, 4096, 6144, 8192, 12288, 16384];
    for &m in &sizes {
        for s in scalings {
            let r = gemm_time_s(
                &GemmConfig {
                    m,
                    k: m,
                    n: m,
                    scaling: s,
                },
                &dev,
            );
            println!("{m},{},{:.1},{:.3}", s.label(), r.tflops, r.mfu);
        }
    }

    println!("\n# LLM-layer shapes (Llama-70B, prefill M=4096)");
    println!("layer,m,k,n,tflops_fp8_hw,tflops_bf16,speedup");
    let shapes = [
        ("q_proj", 4096usize, 8192usize, 8192usize),
        ("kv_proj", 4096, 8192, 1024),
        ("o_proj", 4096, 8192, 8192),
        ("gate/up", 4096, 8192, 28672),
        ("down", 4096, 28672, 8192),
    ];
    for (name, m, k, n) in shapes {
        let f8 = gemm_time_s(
            &GemmConfig {
                m,
                k,
                n,
                scaling: ScalingKind::PerTensorHwPow2,
            },
            &dev,
        );
        let bf = gemm_time_s(
            &GemmConfig {
                m,
                k,
                n,
                scaling: ScalingKind::Bf16,
            },
            &dev,
        );
        println!(
            "{name},{m},{k},{n},{:.1},{:.1},{:.2}",
            f8.tflops,
            bf.tflops,
            bf.time_s / f8.time_s
        );
    }

    // ASCII plot: MFU vs size for the HW pow2 path.
    println!("\n# MFU vs M (per-tensor HW pow2)");
    for &m in &sizes {
        let r = gemm_time_s(
            &GemmConfig {
                m,
                k: m,
                n: m,
                scaling: ScalingKind::PerTensorHwPow2,
            },
            &dev,
        );
        let bars = (r.mfu * 60.0) as usize;
        println!("{m:>6} | {:<60} {:.1}%", "#".repeat(bars), r.mfu * 100.0);
    }
}
