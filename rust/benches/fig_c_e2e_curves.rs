//! Figure C: end-to-end throughput curves — prefill TFLOPS vs sequence
//! length (finer sweep than Table 5) and decode TFLOPS vs batch at several
//! context lengths (finer than Table 6), with BF16-peak and FP8-peak
//! reference lines; plus the Gaudi 2 vs Gaudi 3 projection.
//!
//! Decode rows price the block-table-native path (ISSUE 5):
//! [`decode_step_tflops`] charges each row's live 16-token blocks plus a
//! per-block launch floor. Figure C4 sets that against the dense-copy
//! reference (every bucket row padded to the full window) — the cost of
//! the per-step densify the paged engine deleted.

use gaudi_fp8::gaudisim::{
    attn_time_s_dense_copy, attn_time_s_paged, decode_step_tflops, decode_step_tflops_dense,
    prefill_tflops, Device, E2eConfig, MemoryModel,
};
use gaudi_fp8::model::config::ModelConfig;

fn main() {
    let cfg = E2eConfig::llama31_70b_paper();
    println!("# Figure C1 (CSV): prefill TFLOPS vs seq (Llama3.1-70B, Gaudi2)");
    println!("seq,tflops,mfu");
    let mut seq = 256usize;
    while seq <= 32768 {
        let r = prefill_tflops(&cfg, seq);
        println!("{seq},{:.1},{:.3}", r.tflops, r.mfu);
        seq *= 2;
    }
    println!("ref,bf16_peak,432");
    println!("ref,fp8_peak,865");

    println!("\n# Figure C2 (CSV): decode TFLOPS vs batch at context lengths");
    println!("context,batch,tflops,fits");
    let mm = MemoryModel::new(Device::gaudi2(), ModelConfig::llama31_70b());
    for context in [512usize, 2048, 8192] {
        for batch in [1usize, 2, 4, 8, 16, 32, 64, 128, 256] {
            let fits = mm.fits(batch, context);
            let r = decode_step_tflops(&cfg, batch, context);
            println!("{context},{batch},{:.1},{}", r.tflops, fits);
        }
    }

    println!("\n# Figure C4 (CSV): paged vs dense-copy decode at an 8192 window");
    println!("context,batch,paged_tflops,dense_tflops,paged_attn_ms,dense_attn_ms");
    for context in [512usize, 2048, 8192] {
        for batch in [8usize, 32, 128] {
            let p = decode_step_tflops(&cfg, batch, context);
            let d = decode_step_tflops_dense(&cfg, batch, context, 8192);
            let pa = attn_time_s_paged(&cfg, &vec![context; batch]) * 1e3;
            let da = attn_time_s_dense_copy(&cfg, batch, 8192) * 1e3;
            println!(
                "{context},{batch},{:.1},{:.1},{pa:.3},{da:.3}",
                p.tflops, d.tflops
            );
        }
    }

    println!("\n# Figure C3: Gaudi 3 projection (same model)");
    println!("seq,g2_tflops,g3_tflops,ratio");
    let g3 = E2eConfig {
        device: Device::gaudi3(),
        ..E2eConfig::llama31_70b_paper()
    };
    for seq in [1024usize, 4096, 16384] {
        let a = prefill_tflops(&cfg, seq).tflops;
        let b = prefill_tflops(&g3, seq).tflops;
        println!("{seq},{a:.1},{b:.1},{:.2}", b / a);
    }

    // ASCII curve of C1.
    println!("\n# prefill TFLOPS vs seq (ASCII)");
    let mut seq = 256usize;
    while seq <= 32768 {
        let r = prefill_tflops(&cfg, seq);
        println!(
            "{seq:>6} | {:<56} {:.0}",
            "#".repeat((r.tflops / 12.0) as usize),
            r.tflops
        );
        seq *= 2;
    }
}
