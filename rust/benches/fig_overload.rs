//! Fig OVERLOAD (beyond the paper): graceful degradation under offered
//! load past capacity — scheduler preemption with the host KV tier
//! (ISSUE 9) versus a reject-only baseline.
//!
//! One simulated Gaudi 2 replica with a deliberately tight block pool is
//! driven open-loop at a sweep of arrival rates anchored to its measured
//! capacity (a burst calibration run fixes `capacity_rps`, so the sweep
//! stays under/over-loaded regardless of how the synthetic model's step
//! times evolve). Four modes per rate:
//!
//!   reject_only — host tier off, tiny fleet + replica queues: overload
//!                 sheds requests (`QueueFull`), the lost-work baseline;
//!   swap        — preempt to the host tier, resume via PCIe swap-in;
//!   recompute   — preempt by dropping blocks, resume via chunked
//!                 re-prefill;
//!   auto        — price swap vs recompute per victim, take the cheaper.
//!
//! Hard assertions (the ISSUE 9 acceptance bars):
//!   * every preempting mode completes all requests with zero rejections
//!     at every rate — overload degrades latency, never loses work;
//!   * p99 TTFT under `auto` is monotone non-decreasing in offered load
//!     (small tolerance for reservoir discretization) — no cliff;
//!   * the reject-only baseline row is emitted at every rate for
//!     comparison.
//!
//! Emits one JSON row per (mode, rate) cell — the shared
//! `FleetMetrics::json_row_fig` emitter plus the bench-local sweep axes
//! (`rate_rps`, `offered_x`) — then SHAPE lines (suppressed under
//! `BENCH_SMOKE=1`, where stdout must stay pure JSON).

use gaudi_fp8::coordinator::PreemptPolicy;
use gaudi_fp8::router::{
    FleetConfig, FleetRouter, FleetRunReport, RoutePolicy, SimReplica, SimReplicaConfig,
};
use gaudi_fp8::server::workload::{ArrivalPattern, OpenLoopConfig, WorkloadConfig};

/// Tight-pool replica: 24 blocks of 16 tokens. The largest request
/// (256-token prompt + 16 generated = 17 blocks) fits alone, but two
/// large requests cannot coexist — so overload genuinely exhausts the
/// pool instead of just queueing, and the preemption path is exercised.
fn replica_cfg(mode: &str) -> SimReplicaConfig {
    let mut cfg = SimReplicaConfig::synthetic_tiny();
    cfg.kv_blocks_override = Some(24);
    if mode == "reject_only" {
        // No host tier and almost no local buffering: pressure surfaces
        // as fleet-queue rejections instead of preemption.
        cfg.queue_capacity = 4;
    } else {
        cfg.host_kv_bytes = 1e9;
        cfg.preempt_policy = PreemptPolicy::parse(mode).expect("mode is a preempt policy");
    }
    cfg
}

fn workload(requests: usize) -> WorkloadConfig {
    WorkloadConfig {
        requests,
        prompt_len_min: 64,
        prompt_len_max: 256,
        max_new_min: 16,
        max_new_max: 16,
        seed: 7,
    }
}

fn run_mode(mode: &str, pattern: ArrivalPattern, requests: usize) -> FleetRunReport {
    let mut router = FleetRouter::new(FleetConfig {
        policy: RoutePolicy::RoundRobin,
        queue_capacity: if mode == "reject_only" { 8 } else { 4096 },
    });
    router.add_replica(Box::new(
        SimReplica::new(&format!("gaudi2-{mode}"), replica_cfg(mode)).expect("sim replica"),
    ));
    let open = OpenLoopConfig {
        workload: workload(requests),
        pattern,
    };
    let report = router.run_open_loop(open.generate()).expect("fleet run");
    assert_eq!(
        report.outputs.len() + report.rejected.len(),
        requests,
        "request accounting must balance in mode={mode}"
    );
    report
}

/// Measure this replica's saturated service rate: burst all requests at
/// t=0 with the tier on and divide by the makespan.
fn calibrate_capacity_rps(requests: usize) -> f64 {
    let report = run_mode("auto", ArrivalPattern::Burst, requests);
    let makespan = report.metrics.makespan_s;
    assert!(makespan > 0.0, "calibration run must take virtual time");
    requests as f64 / makespan
}

fn main() {
    let smoke = matches!(std::env::var("BENCH_SMOKE").as_deref(), Ok("1"));
    let requests = if smoke { 24 } else { 96 };
    let multipliers: &[f64] = if smoke {
        &[0.5, 2.0, 4.0]
    } else {
        &[0.25, 0.5, 1.0, 2.0, 4.0]
    };

    let capacity_rps = calibrate_capacity_rps(requests);
    let mut auto_p99_s: Vec<f64> = Vec::new();
    let mut baseline_rejects_at_peak = 0usize;
    let mut total_preemptions = 0u64;

    for &mult in multipliers {
        let rate = capacity_rps * mult;
        for mode in ["reject_only", "swap", "recompute", "auto"] {
            let pattern = ArrivalPattern::Uniform { rate_per_s: rate };
            let report = run_mode(mode, pattern, requests);
            if mode != "reject_only" {
                // The acceptance bar: overload never loses work when the
                // scheduler can preempt to the host tier.
                assert_eq!(
                    report.rejected.len(),
                    0,
                    "mode={mode} must reject nothing at {mult}x capacity"
                );
                assert_eq!(
                    report.outputs.len(),
                    requests,
                    "mode={mode} must complete everything at {mult}x capacity"
                );
                total_preemptions += report.metrics.merged.preemptions;
            } else if (mult - multipliers[multipliers.len() - 1]).abs() < f64::EPSILON {
                baseline_rejects_at_peak = report.rejected.len();
            }
            if mode == "auto" {
                auto_p99_s.push(report.metrics.merged.ttft.p99_s());
            }
            // The shared fleet-row emitter, plus the sweep axes this bench
            // adds locally (benches are outside the rust/src schema lint).
            let mut row = report.metrics.json_row_fig("fig_overload", 1, mode, requests);
            row.pop();
            row.push_str(&format!(
                ",\"rate_rps\":{rate:.3},\"offered_x\":{mult:.2}}}"
            ));
            println!("{row}");
        }
    }

    // No cliff: p99 TTFT degrades monotonically with offered load (10%
    // slack absorbs percentile-reservoir discretization at light load).
    for (i, w) in auto_p99_s.windows(2).enumerate() {
        assert!(
            w[1] >= w[0] * 0.9 - 1e-9,
            "auto p99 TTFT must not improve under heavier load: \
             {:.4}s at {}x -> {:.4}s at {}x",
            w[0],
            multipliers[i],
            w[1],
            multipliers[i + 1]
        );
    }
    let first = auto_p99_s.first().copied().unwrap_or(0.0);
    let last = auto_p99_s.last().copied().unwrap_or(0.0);
    assert!(
        last >= first,
        "auto p99 TTFT must degrade from {first:.4}s to at least itself, got {last:.4}s"
    );

    if !smoke {
        let ratio = if first > 0.0 { last / first } else { 0.0 };
        println!(
            "SHAPE: capacity {capacity_rps:.1} req/s; auto p99 TTFT degrades smoothly \
             {:.2}ms -> {:.2}ms ({ratio:.2}x) from {}x to {}x offered load, zero lost ✓",
            first * 1e3,
            last * 1e3,
            multipliers[0],
            multipliers[multipliers.len() - 1]
        );
        println!(
            "SHAPE: preemptions across preempting modes = {total_preemptions}; \
             reject-only baseline sheds {baseline_rejects_at_peak} requests at peak load"
        );
    }
}
