//! Table 5: Llama v3.1 70B prefill throughput vs sequence length on a
//! single Gaudi 2 (HW-accelerated static per-tensor FP8; attention and LM
//! head excluded from FP8 — hence "understated" MFU).

use gaudi_fp8::gaudisim::{prefill_tflops, E2eConfig};
use gaudi_fp8::util::render_table;

fn main() {
    let cfg = E2eConfig::llama31_70b_paper();
    let paper = [
        (1024usize, 649.1, 75.4),
        (2048, 671.0, 77.6),
        (4096, 602.8, 69.7),
        (8192, 513.7, 59.4),
        (16384, 390.1, 45.1),
    ];
    let mut rows = Vec::new();
    for &(seq, p_tf, p_mfu) in &paper {
        let r = prefill_tflops(&cfg, seq);
        rows.push(vec![
            seq.to_string(),
            format!("{p_tf:.1}"),
            format!("{:.1}", r.tflops),
            format!("{p_mfu:.1}%"),
            format!("{:.1}%", r.mfu * 100.0),
            format!("{:.0} ms", r.time_s * 1e3),
        ]);
    }
    println!(
        "{}",
        render_table(
            "Table 5 — Llama v3.1 70B prefill, single Gaudi 2 (paper vs model)",
            &["seq", "paper TF", "model TF", "paper MFU", "model MFU", "model time"],
            &rows
        )
    );
    let t2048 = prefill_tflops(&cfg, 2048).tflops;
    let t8192 = prefill_tflops(&cfg, 8192).tflops;
    println!("SHAPE: peak at 2048 ({t2048:.0} TF); 8192 still above peak BF16 432 TF ({t8192:.0} TF) ✓");
}
