//! Table 2: Llama2-family accuracy under quantization — synthetic-scale
//! analogue (tiny≈7B-class, small≈13B-class, base≈70B-class stand-ins).
//! Paper Δ values printed alongside for shape comparison.

use gaudi_fp8::eval::suite::{evaluate_model, paper_schemes, EvalConfig};
use gaudi_fp8::eval::tables::render_accuracy_table;
use gaudi_fp8::fp8::Fp8Format;
use gaudi_fp8::model::config::{ModelConfig, ModelFamily};

fn main() {
    let ec = EvalConfig::default();
    let schemes = paper_schemes(Fp8Format::E4M3Gaudi2);
    // (model, paper ΔPPL% for unit/pt/pc, paper ΔCS, paper ΔMMLU)
    let paper = [
        ("Llama2-7B", [8.24, 3.20, 3.15], [-0.42, -0.42, -0.12], [-1.40, -6.23, -6.29]),
        ("Llama2-13B", [2.38, 1.74, 1.78], [0.13, 0.21, 0.20], [-1.13, -1.48, -0.91]),
        ("Llama2-70B", [9.34, 2.08, 2.07], [-1.19, -0.42, -0.48], [-3.44, -0.21, -0.53]),
    ];
    for (i, cfg) in [
        ModelConfig::synthetic_tiny(ModelFamily::Llama2),
        ModelConfig::synthetic_small(ModelFamily::Llama2),
        ModelConfig::synthetic_base(ModelFamily::Llama2),
    ]
    .iter()
    .enumerate()
    {
        let rows = evaluate_model(cfg, &schemes, &ec);
        println!(
            "{}",
            render_accuracy_table(&format!("{} (analogue of {})", cfg.name, paper[i].0), &rows)
        );
        println!(
            "paper ΔPPL% (unit/pt/pc): {:?}   paper ΔCS: {:?}   paper ΔMMLU: {:?}\n",
            paper[i].1, paper[i].2, paper[i].3
        );
    }
    println!("shape checks: unit worst on PPL; pt≈pc; commonsense Δ small; MMLU Δ larger.");
}
