//! Table 4: Mistral-7B / Mixtral-8x7B — the activation-outlier families
//! where Unit Scale collapses (+136% / +725% PPL in the paper) while
//! calibrated per-tensor / per-channel scaling stays within ~1%.

use gaudi_fp8::eval::suite::{evaluate_model, paper_schemes, EvalConfig};
use gaudi_fp8::eval::tables::render_accuracy_table;
use gaudi_fp8::fp8::Fp8Format;
use gaudi_fp8::model::config::{ModelConfig, ModelFamily};

fn main() {
    let ec = EvalConfig::default();
    let schemes = paper_schemes(Fp8Format::E4M3Gaudi2);
    let paper = [
        ("Mistral-7B", [136.3, 4.84, 4.81], [-45.09, -0.17, -0.36], [-27.26, -3.55, -4.03]),
        ("Mixtral-8x7B", [725.0, 1.13, 1.06], [-21.21, 0.48, -0.01], [-22.02, -0.50, -0.64]),
    ];
    for (i, cfg) in [
        ModelConfig::synthetic_small(ModelFamily::Mistral),
        ModelConfig::synthetic_base(ModelFamily::Mixtral),
    ]
    .iter()
    .enumerate()
    {
        let rows = evaluate_model(cfg, &schemes, &ec);
        println!(
            "{}",
            render_accuracy_table(&format!("{} (analogue of {})", cfg.name, paper[i].0), &rows)
        );
        println!(
            "paper ΔPPL% (unit/pt/pc): {:?}   paper ΔCS: {:?}   paper ΔMMLU: {:?}\n",
            paper[i].1, paper[i].2, paper[i].3
        );
        // Headline shape assertion, printed loudly.
        let unit = &rows[1];
        let pt = &rows[2];
        println!(
            "SHAPE: unit ΔPPL {:.1}% vs per-tensor {:.1}% → ratio {:.0}× (paper: {:.0}×)\n",
            unit.ppl_delta_pct,
            pt.ppl_delta_pct,
            unit.ppl_delta_pct / pt.ppl_delta_pct.max(0.01),
            paper[i].1[0] / paper[i].1[1]
        );
    }
}
