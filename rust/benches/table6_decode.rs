//! Table 6: Llama v3.1 70B decode TFLOPS (batch × target sequence length)
//! with the OOM frontier, single Gaudi 2, FP8 linears + FP8 KV.
//!
//! Cells re-derive under the block-table-native pricing (ISSUE 5):
//! [`decode_step_tflops`] charges each row's live 16-token blocks plus a
//! per-block launch floor, which reproduces the paper's flat-factor
//! numbers at these block-aligned geometries — the in-repo Table 6
//! asserts hold unchanged. A footer quantifies what the dense-copy
//! engine path (bucket rows padded to the full window) would cost.

use gaudi_fp8::gaudisim::{
    decode_step_tflops, decode_step_tflops_dense, Device, E2eConfig, MemoryModel,
};
use gaudi_fp8::model::config::ModelConfig;
use gaudi_fp8::util::render_table;

fn main() {
    let cfg = E2eConfig::llama31_70b_paper();
    let mm = MemoryModel::new(Device::gaudi2(), ModelConfig::llama31_70b());
    let paper: &[(usize, [Option<f64>; 5])] = &[
        (8, [Some(32.8), Some(32.4), Some(30.8), Some(30.2), Some(23.4)]),
        (16, [Some(63.2), Some(61.5), Some(55.8), Some(51.4), Some(39.6)]),
        (32, [Some(120.1), Some(112.0), Some(94.1), Some(79.5), None]),
        (64, [Some(224.1), Some(198.8), Some(152.3), None, None]),
        (128, [Some(387.1), Some(312.8), None, None, None]),
    ];
    let seqs = [512usize, 1024, 2048, 4096, 8192];
    let mut rows = Vec::new();
    for (batch, prow) in paper {
        let mut cells = vec![batch.to_string()];
        for (i, &seq) in seqs.iter().enumerate() {
            let fits = mm.fits(*batch, seq);
            let cell = if fits {
                let r = decode_step_tflops(&cfg, *batch, seq);
                match prow[i] {
                    Some(p) => format!("{:.1} ({p})", r.tflops),
                    None => format!("{:.1} (paper: OOM!)", r.tflops),
                }
            } else {
                match prow[i] {
                    None => "OOM (OOM)".to_string(),
                    Some(p) => format!("OOM! (paper {p})"),
                }
            };
            cells.push(cell);
        }
        rows.push(cells);
    }
    println!(
        "{}",
        render_table(
            "Table 6 — decode TFLOPS, model (paper) — Llama v3.1 70B, Gaudi 2",
            &["batch", "512", "1024", "2048", "4096", "8192"],
            &rows
        )
    );
    println!("OOM frontier reproduced exactly: FP8 weights (~72.6 GB) + FP8 KV vs 96 GB HBM.");
    // What the pre-paged dense-copy decode would pay at a live context far
    // below the window — the bandwidth the block-table-native path saves.
    let (b, ctx, window) = (16usize, 512usize, 8192usize);
    let paged = decode_step_tflops(&cfg, b, ctx);
    let dense = decode_step_tflops_dense(&cfg, b, ctx, window);
    println!(
        "Paged reads at (batch {b}, ctx {ctx}): {:.1} TF vs {:.1} TF for the \
         dense copy padded to the {window} window ({:.2}x step time).",
        paged.tflops,
        dense.tflops,
        dense.time_s / paged.time_s
    );
}
