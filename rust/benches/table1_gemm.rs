//! Table 1: scaled FP8 GEMM throughput on Gaudi 2 — per-tensor HW pow2 vs
//! per-tensor SW vs per-channel, M=K=N ∈ {4096, 6144, 8192}.
//!
//! Two parts:
//!  1. the analytical Gaudi 2 model, paper numbers alongside;
//!  2. *measured* relative ordering on the CPU emulation: pow2 exponent-bias
//!     rescaling (the §2.4 integer trick) vs per-element scaling vs
//!     per-channel scaling, on the emulated scaled-GEMM hot path.

use gaudi_fp8::fp8::{rescale_pow2, Fp8Format};
use gaudi_fp8::gaudisim::{gemm_time_s, Device, GemmConfig, ScalingKind};
use gaudi_fp8::gemm::{quantize_matrix, scaled_gemm, DiagScale, QMatrix, QuantRounding};
use gaudi_fp8::tensor::Tensor2;
use gaudi_fp8::util::rng::XorShiftRng;
use gaudi_fp8::util::{render_table, Bencher};

fn main() {
    analytical();
    measured_emulation();
}

fn analytical() {
    let dev = Device::gaudi2();
    let paper: &[(usize, ScalingKind, f64, f64)] = &[
        (4096, ScalingKind::PerTensorHwPow2, 803.8, 92.9),
        (4096, ScalingKind::PerTensorSw, 771.4, 89.2),
        (4096, ScalingKind::PerChannel, 746.5, 86.3),
        (6144, ScalingKind::PerTensorHwPow2, 849.1, 98.2),
        (6144, ScalingKind::PerTensorSw, 837.5, 96.8),
        (6144, ScalingKind::PerChannel, 831.5, 96.1),
        (8192, ScalingKind::PerTensorHwPow2, 851.2, 98.4),
        (8192, ScalingKind::PerTensorSw, 800.8, 92.6),
        (8192, ScalingKind::PerChannel, 760.4, 87.9),
    ];
    let mut rows = Vec::new();
    for &(m, scaling, p_tflops, p_mfu) in paper {
        let r = gemm_time_s(
            &GemmConfig {
                m,
                k: m,
                n: m,
                scaling,
            },
            &dev,
        );
        rows.push(vec![
            m.to_string(),
            scaling.label().to_string(),
            format!("{p_tflops:.1}"),
            format!("{:.1}", r.tflops),
            format!("{p_mfu:.1}%"),
            format!("{:.1}%", r.mfu * 100.0),
        ]);
    }
    println!(
        "{}",
        render_table(
            "Table 1 — FP8 GEMM throughput, Gaudi 2 (paper vs model)",
            &[
                "M=K=N",
                "scaling",
                "paper TF",
                "model TF",
                "paper MFU",
                "model MFU"
            ],
            &rows
        )
    );
}

fn measured_emulation() {
    println!("\n## Measured CPU-emulation ordering (512x512x512)\n");
    let mut rng = XorShiftRng::new(1);
    let n = 512;
    let fmt = Fp8Format::E4M3Gaudi2;
    let x = Tensor2::randn(n, n, 1.0, &mut rng);
    let w = Tensor2::randn(n, n, 0.05, &mut rng);
    let xq = quantize_matrix(&x, &[0.0125], &[], fmt, QuantRounding::Nearest);
    let wq = quantize_matrix(&w, &[0.001], &[], fmt, QuantRounding::Nearest);
    let flops = 2.0 * (n as f64).powi(3);

    let mut b = Bencher::new("table1_emulated");
    // HW pow2 path: scale folded into the codes by the integer exponent
    // rescale; descale degenerates to unit.
    b.bench_throughput("per_tensor_hw_pow2", flops, "GFLOP/s", || {
        let xq2 = QMatrix {
            rows: xq.rows,
            cols: xq.cols,
            codes: xq.codes.iter().map(|c| rescale_pow2(*c, 0, fmt)).collect(),
            format: fmt,
        };
        let out = scaled_gemm(
            &xq2,
            &wq,
            &DiagScale::Scalar(1.0),
            &DiagScale::Scalar(1.0),
            false,
        );
        std::hint::black_box(out);
    });
    b.bench_throughput("per_tensor_sw", flops, "GFLOP/s", || {
        let out = scaled_gemm(
            &xq,
            &wq,
            &DiagScale::Scalar(0.0137),
            &DiagScale::Scalar(0.0011),
            false,
        );
        std::hint::black_box(out);
    });
    let s_w: Vec<f32> = (0..n).map(|i| 0.001 + i as f32 * 1e-6).collect();
    b.bench_throughput("per_channel", flops, "GFLOP/s", || {
        let out = scaled_gemm(
            &xq,
            &wq,
            &DiagScale::Scalar(0.0137),
            &DiagScale::Vector(s_w.clone()),
            false,
        );
        std::hint::black_box(out);
    });
}
