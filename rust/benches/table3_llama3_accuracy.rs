//! Table 3: Llama3-family accuracy under quantization — synthetic-scale
//! analogue (GQA geometry; tiny≈8B-class, base≈70B-class).

use gaudi_fp8::eval::suite::{evaluate_model, paper_schemes, EvalConfig};
use gaudi_fp8::eval::tables::render_accuracy_table;
use gaudi_fp8::fp8::Fp8Format;
use gaudi_fp8::model::config::{ModelConfig, ModelFamily};

fn main() {
    let ec = EvalConfig::default();
    let schemes = paper_schemes(Fp8Format::E4M3Gaudi2);
    let paper = [
        ("Llama3-8B", [6.58, 3.10, 3.14], [-0.95, -0.48, -0.32], [-3.26, -2.05, -1.82]),
        ("Llama3-70B", [7.52, 3.43, 3.52], [-0.89, -0.22, -0.39], [-1.03, 0.19, -0.37]),
    ];
    for (i, cfg) in [
        ModelConfig::synthetic_tiny(ModelFamily::Llama3),
        ModelConfig::synthetic_base(ModelFamily::Llama3),
    ]
    .iter()
    .enumerate()
    {
        let rows = evaluate_model(cfg, &schemes, &ec);
        println!(
            "{}",
            render_accuracy_table(&format!("{} (analogue of {})", cfg.name, paper[i].0), &rows)
        );
        println!(
            "paper ΔPPL% (unit/pt/pc): {:?}   paper ΔCS: {:?}   paper ΔMMLU: {:?}\n",
            paper[i].1, paper[i].2, paper[i].3
        );
    }
    println!("shape checks: larger (wider) analogue less degraded — §4.2.1.");
}
