//! Fig prefix-cache (beyond the paper's tables, the serving lever its
//! FP8 wins compound with): what a radix-tree shared-prefix KV cache buys
//! when a fleet's traffic shares a long system prompt.
//!
//! Three row families, one JSON object per line:
//! * `kind:"serve"` — a paper-geometry `SimReplica` (Llama v3.1 70B on
//!   Gaudi 2, FP8 KV) serving N requests that share a system prompt of
//!   `shared_prefix` tokens (+32 unique tail each), with the cache on vs
//!   off: hit rate, mean/p95 TTFT, makespan, cached bytes, and the KV
//!   bytes the cache saved (hit tokens × the shared `KvLayout` rate).
//! * `kind:"chunk"` — a long-uncached-tail workload at several
//!   `--prefill-chunk` granularities (chunked tails interleave with
//!   decode; tiny chunks pay the per-GEMM launch floor).
//! * `kind:"capacity"` — the `MemoryModel` Table 6 budget with the batch
//!   sharing a prefix stored once: bytes saved and the OOM frontier shift.
//!
//! SHAPE checks (suppressed under `BENCH_SMOKE=1`, where stdout must be
//! pure JSON): at a 1024-token shared prefix the cache improves mean TTFT
//! ≥ 2× and saves measurable KV bytes.

use gaudi_fp8::coordinator::{LatencyStat, Request};
use gaudi_fp8::gaudisim::{Device, MemoryModel};
use gaudi_fp8::model::config::ModelConfig;
use gaudi_fp8::router::{ReplicaHandle, SimReplica, SimReplicaConfig};

struct ServeCell {
    hit_rate: f64,
    hit_tokens: u64,
    chunks: u64,
    ttft_mean_s: f64,
    ttft_p95_s: f64,
    makespan_s: f64,
    cached_bytes: usize,
    saved_bytes: u64,
}

/// Serve `requests` prompts of `shared_prefix` shared + `tail` unique
/// tokens on one paper-geometry replica; all arrive at t = 0.
fn run_cell(requests: usize, shared_prefix: usize, tail: usize, cache: bool, chunk: usize) -> ServeCell {
    let mut cfg = SimReplicaConfig::gaudi2_llama31_70b();
    cfg.prefix_cache = cache;
    cfg.prefill_chunk = chunk;
    let rate = cfg.e2e.model.kv_layout(cfg.kv_dtype).bytes_per_token() as u64;
    let mut replica = SimReplica::new("prefix-bench", cfg).expect("replica");
    for i in 0..requests {
        let mut prompt = vec![7i32; shared_prefix];
        prompt.extend((0..tail).map(|j| 1000 + (i * 9173 + j) as i32));
        assert!(replica.submit(Request::new(i as u64, prompt, 16), 0.0));
    }
    let mut ttft = LatencyStat::new();
    let mut done = 0usize;
    while replica.has_work() {
        replica.step().expect("sim step");
        for o in replica.take_finished() {
            assert_eq!(o.tokens.len(), 16, "request must complete fully");
            ttft.record(o.ttft_s);
            done += 1;
        }
    }
    assert_eq!(done, requests);
    let m = replica.metrics();
    ServeCell {
        hit_rate: m.prefix_hit_rate(),
        hit_tokens: m.prefix_hit_tokens,
        chunks: m.prefill_chunks,
        ttft_mean_s: ttft.mean_s(),
        ttft_p95_s: ttft.p95_s(),
        makespan_s: replica.clock_s(),
        cached_bytes: replica.cached_prefix_bytes(),
        saved_bytes: m.prefix_hit_tokens * rate,
    }
}

fn serve_row(requests: usize, shared_prefix: usize, cache: bool, c: &ServeCell) {
    println!(
        "{{\"fig\":\"fig_prefix_cache\",\"kind\":\"serve\",\"requests\":{requests},\
         \"shared_prefix\":{shared_prefix},\"prefix_cache\":{cache},\
         \"hit_rate\":{:.4},\"hit_tokens\":{},\
         \"ttft_mean_ms\":{:.3},\"ttft_p95_ms\":{:.3},\"makespan_s\":{:.4},\
         \"cached_prefix_bytes\":{},\"kv_bytes_saved\":{}}}",
        c.hit_rate,
        c.hit_tokens,
        c.ttft_mean_s * 1e3,
        c.ttft_p95_s * 1e3,
        c.makespan_s,
        c.cached_bytes,
        c.saved_bytes,
    );
}

fn main() {
    let smoke = matches!(std::env::var("BENCH_SMOKE").as_deref(), Ok("1"));
    let requests = if smoke { 8 } else { 64 };
    let prefixes: &[usize] = if smoke { &[256, 1024] } else { &[256, 512, 1024, 2048] };

    // Hit rate + TTFT vs shared-prefix length, cache on vs off.
    let mut gain_at_1024 = 0.0f64;
    let mut saved_at_1024 = 0u64;
    for &p in prefixes {
        let off = run_cell(requests, p, 32, false, 0);
        let on = run_cell(requests, p, 32, true, 0);
        serve_row(requests, p, false, &off);
        serve_row(requests, p, true, &on);
        if p == 1024 {
            gain_at_1024 = off.ttft_mean_s / on.ttft_mean_s.max(1e-12);
            saved_at_1024 = on.saved_bytes;
        }
    }

    // Chunk-granularity sensitivity: a 1024-token shared prefix with a
    // 1024-token *uncached* tail, recomputed in chunks.
    let chunk_requests = if smoke { 4 } else { 16 };
    for chunk in [0usize, 512, 128] {
        let c = run_cell(chunk_requests, 1024, 1024, true, chunk);
        println!(
            "{{\"fig\":\"fig_prefix_cache\",\"kind\":\"chunk\",\"requests\":{chunk_requests},\
             \"shared_prefix\":1024,\"tail\":1024,\"prefill_chunk\":{chunk},\
             \"prefill_chunks\":{},\"ttft_mean_ms\":{:.3},\"makespan_s\":{:.4}}}",
            c.chunks,
            c.ttft_mean_s * 1e3,
            c.makespan_s,
        );
    }

    // Capacity: the Table 6 budget with a shared prefix stored once.
    let mm = MemoryModel::new(Device::gaudi2(), ModelConfig::llama31_70b());
    for (batch, seq, shared) in [(16usize, 8192usize, 1024usize), (32, 8192, 6144)] {
        let dedicated = mm.kv_bytes(batch, seq);
        let shared_bytes = mm.kv_bytes_shared(batch, seq, shared);
        println!(
            "{{\"fig\":\"fig_prefix_cache\",\"kind\":\"capacity\",\"batch\":{batch},\
             \"seq\":{seq},\"shared_prefix\":{shared},\"kv_bytes\":{:.0},\
             \"kv_bytes_shared\":{:.0},\"kv_bytes_saved\":{:.0},\
             \"fits\":{},\"fits_shared\":{}}}",
            dedicated,
            shared_bytes,
            dedicated - shared_bytes,
            mm.fits(batch, seq),
            mm.fits_shared(batch, seq, shared),
        );
    }

    if smoke {
        return;
    }
    println!(
        "SHAPE: prefix cache cuts mean TTFT {gain_at_1024:.2}x at a 1024-token shared \
         prefix ({requests} requests) {}",
        if gain_at_1024 >= 2.0 { "✓" } else { "✗ (expected ≥2x)" }
    );
    println!(
        "SHAPE: {saved_at_1024} KV bytes saved by prefix sharing {}",
        if saved_at_1024 > 0 { "✓" } else { "✗ (expected >0)" }
    );
}
