//! Fig prefix-cache (beyond the paper's tables, the serving lever its
//! FP8 wins compound with): what a radix-tree shared-prefix KV cache buys
//! when a fleet's traffic shares a long system prompt.
//!
//! Three row families, one JSON object per line:
//! * `kind:"serve"` — a paper-geometry `SimReplica` (Llama v3.1 70B on
//!   Gaudi 2, FP8 KV) serving N requests that share a system prompt of
//!   `shared_prefix` tokens (+32 unique tail each), with the cache on vs
//!   off: hit rate, mean/p95 TTFT, makespan, cached bytes, and the KV
//!   bytes the cache saved (hit tokens × the shared `KvLayout` rate).
//! * `kind:"chunk"` — a long-uncached-tail workload at several
//!   `--prefill-chunk` granularities (chunked tails interleave with
//!   decode; tiny chunks pay the per-GEMM launch floor).
//! * `kind:"capacity"` — the `MemoryModel` Table 6 budget with the batch
//!   sharing a prefix stored once: bytes saved and the OOM frontier shift.
//! * `kind:"paged"` — the *host store* made real: N concurrent requests
//!   sharing a 1024-token prefix in the paged `KvStore`, physical HBM
//!   bytes resident with block sharing (paged) vs per-request copies
//!   (copy). Asserts paged residency ≈ prefix-once + N tails (≲ 1/N of
//!   copy for short tails).
//!
//! SHAPE checks (suppressed under `BENCH_SMOKE=1`, where stdout must be
//! pure JSON): at a 1024-token shared prefix the cache improves mean TTFT
//! ≥ 2× and saves measurable KV bytes.

use gaudi_fp8::coordinator::{
    AppendOutcome, KvStore, LatencyStat, PrefixCache, PrefixCacheConfig, Request,
};
use gaudi_fp8::gaudisim::{Device, MemoryModel};
use gaudi_fp8::model::config::ModelConfig;
use gaudi_fp8::quant::{KvDtype, KvLayout, KV_BLOCK_TOKENS};
use gaudi_fp8::router::{ReplicaHandle, SimReplica, SimReplicaConfig};

struct ServeCell {
    hit_rate: f64,
    hit_tokens: u64,
    chunks: u64,
    ttft_mean_s: f64,
    ttft_p95_s: f64,
    makespan_s: f64,
    cached_bytes: usize,
    saved_bytes: u64,
}

/// Serve `requests` prompts of `shared_prefix` shared + `tail` unique
/// tokens on one paper-geometry replica; all arrive at t = 0.
fn run_cell(requests: usize, shared_prefix: usize, tail: usize, cache: bool, chunk: usize) -> ServeCell {
    let mut cfg = SimReplicaConfig::gaudi2_llama31_70b();
    cfg.prefix_cache = cache;
    cfg.prefill_chunk = chunk;
    let rate = cfg.e2e.model.kv_layout(cfg.kv_dtype).bytes_per_token() as u64;
    let mut replica = SimReplica::new("prefix-bench", cfg).expect("replica");
    for i in 0..requests {
        let mut prompt = vec![7i32; shared_prefix];
        prompt.extend((0..tail).map(|j| 1000 + (i * 9173 + j) as i32));
        assert!(replica.submit(Request::new(i as u64, prompt, 16), 0.0));
    }
    let mut ttft = LatencyStat::new();
    let mut done = 0usize;
    while replica.has_work() {
        replica.step().expect("sim step");
        for o in replica.take_finished() {
            assert_eq!(o.tokens.len(), 16, "request must complete fully");
            ttft.record(o.ttft_s);
            done += 1;
        }
    }
    assert_eq!(done, requests);
    let m = replica.metrics();
    ServeCell {
        hit_rate: m.prefix_hit_rate(),
        hit_tokens: m.prefix_hit_tokens,
        chunks: m.prefill_chunks,
        ttft_mean_s: ttft.mean_s(),
        ttft_p95_s: ttft.p95_s(),
        makespan_s: replica.clock_s(),
        cached_bytes: replica.cached_prefix_bytes(),
        saved_bytes: m.prefix_hit_tokens * rate,
    }
}

fn serve_row(requests: usize, shared_prefix: usize, cache: bool, c: &ServeCell) {
    println!(
        "{{\"fig\":\"fig_prefix_cache\",\"kind\":\"serve\",\"requests\":{requests},\
         \"shared_prefix\":{shared_prefix},\"prefix_cache\":{cache},\
         \"hit_rate\":{:.4},\"hit_tokens\":{},\
         \"ttft_mean_ms\":{:.3},\"ttft_p95_ms\":{:.3},\"makespan_s\":{:.4},\
         \"cached_prefix_bytes\":{},\"kv_bytes_saved\":{}}}",
        c.hit_rate,
        c.hit_tokens,
        c.ttft_mean_s * 1e3,
        c.ttft_p95_s * 1e3,
        c.makespan_s,
        c.cached_bytes,
        c.saved_bytes,
    );
}

/// Physical residency in the paged host store: `requests` sequences share
/// a `shared`-token prefix (+`tail` appended tokens each) on a small
/// synthetic geometry (the byte *ratio* is geometry-independent). Returns
/// (paged resident bytes, copy resident bytes).
fn paged_residency(requests: usize, shared: usize, tail: usize) -> (usize, usize) {
    let (layers, kv_heads, head_dim) = (2usize, 2usize, 8usize);
    let row = kv_heads * head_dim;
    let bt = KV_BLOCK_TOKENS;
    let t = shared + tail + bt;
    let dtype = KvDtype::FP8_DEFAULT;
    let layout = KvLayout::new(dtype, layers, kv_heads, head_dim);
    let n = layers * t * row;
    let mut kbuf = vec![0.0f32; n];
    for (i, x) in kbuf.iter_mut().enumerate() {
        *x = ((i % 97) as f32 - 48.0) * 0.01;
    }
    let vbuf = kbuf.clone();
    // Tail tokens land one at a time through the paged write path (the
    // dense scatter_batch staging is feature-gated out of the default
    // surface); values are irrelevant here — only block residency counts.
    let tail_row = vec![0.01f32; layers * row];
    let append = |kv: &mut KvStore, slot: usize, count: usize| {
        for _ in 0..count {
            assert_ne!(
                kv.append_token(slot, &tail_row, &tail_row),
                AppendOutcome::AtCapacity,
                "tail append must fit the slot window"
            );
        }
    };

    // Paged: request 0 prefills cold and publishes; the rest map blocks.
    let cache_blocks = shared / bt;
    let mut kv = KvStore::with_block_tokens(
        layers,
        requests,
        t,
        kv_heads,
        head_dim,
        dtype,
        bt,
        cache_blocks,
    );
    let mut pc = PrefixCache::new(PrefixCacheConfig {
        block_tokens: bt,
        max_blocks: cache_blocks,
        layout,
    });
    let prompt = vec![7i32; shared];
    let writer = kv.alloc_slot().expect("slot");
    kv.write_slot(writer, &kbuf, &vbuf, shared);
    let blocks = kv.slot_blocks(writer);
    pc.insert_shared(&prompt, &blocks, kv.pool_mut());
    append(&mut kv, writer, tail);
    for _ in 1..requests {
        let slot = kv.alloc_slot().expect("slot");
        let ids = pc.mapped_blocks(&prompt, shared).expect("physical hit");
        kv.map_shared_prefix(slot, &ids, shared);
        append(&mut kv, slot, tail);
    }
    let paged = kv.resident_bytes();
    // Exactly prefix-once + N private tails, read off pool occupancy.
    let tail_blocks = tail.div_ceil(bt);
    assert_eq!(
        kv.pool().used_blocks(),
        shared / bt + requests * tail_blocks,
        "paged residency must be prefix-once + N tails"
    );

    // Copy: every request prefills privately (the pre-paged engine path).
    let mut copy =
        KvStore::with_block_tokens(layers, requests, t, kv_heads, head_dim, dtype, bt, 0);
    for _ in 0..requests {
        let slot = copy.alloc_slot().expect("slot");
        copy.write_slot(slot, &kbuf, &vbuf, shared);
        append(&mut copy, slot, tail);
    }
    let copied = copy.resident_bytes();
    // ≈ 1/N of the copy path (tails add a small constant).
    let ratio = paged as f64 / copied as f64;
    let ideal = 1.0 / requests as f64;
    assert!(
        ratio <= ideal * 1.6,
        "paged/copy residency {ratio:.4} must approach 1/N = {ideal:.4}"
    );
    (paged, copied)
}

fn main() {
    let smoke = matches!(std::env::var("BENCH_SMOKE").as_deref(), Ok("1"));
    let requests = if smoke { 8 } else { 64 };
    let prefixes: &[usize] = if smoke { &[256, 1024] } else { &[256, 512, 1024, 2048] };

    // Hit rate + TTFT vs shared-prefix length, cache on vs off.
    let mut gain_at_1024 = 0.0f64;
    let mut saved_at_1024 = 0u64;
    for &p in prefixes {
        let off = run_cell(requests, p, 32, false, 0);
        let on = run_cell(requests, p, 32, true, 0);
        serve_row(requests, p, false, &off);
        serve_row(requests, p, true, &on);
        if p == 1024 {
            gain_at_1024 = off.ttft_mean_s / on.ttft_mean_s.max(1e-12);
            saved_at_1024 = on.saved_bytes;
        }
    }

    // Chunk-granularity sensitivity: a 1024-token shared prefix with a
    // 1024-token *uncached* tail, recomputed in chunks.
    let chunk_requests = if smoke { 4 } else { 16 };
    for chunk in [0usize, 512, 128] {
        let c = run_cell(chunk_requests, 1024, 1024, true, chunk);
        println!(
            "{{\"fig\":\"fig_prefix_cache\",\"kind\":\"chunk\",\"requests\":{chunk_requests},\
             \"shared_prefix\":1024,\"tail\":1024,\"prefill_chunk\":{chunk},\
             \"prefill_chunks\":{},\"ttft_mean_ms\":{:.3},\"makespan_s\":{:.4}}}",
            c.chunks,
            c.ttft_mean_s * 1e3,
            c.makespan_s,
        );
    }

    // Physical host-store residency: paged block sharing vs per-request
    // copies at N concurrent requests over a 1024-token shared prefix.
    for &n in if smoke { &[4usize, 8][..] } else { &[4usize, 8, 16][..] } {
        let (paged, copied) = paged_residency(n, 1024, 32);
        println!(
            "{{\"fig\":\"fig_prefix_cache\",\"kind\":\"paged\",\"requests\":{n},\
             \"shared_prefix\":1024,\"tail\":32,\"paged_resident_bytes\":{paged},\
             \"copy_resident_bytes\":{copied},\"residency_ratio\":{:.4}}}",
            paged as f64 / copied as f64,
        );
    }

    // Capacity: the Table 6 budget with a shared prefix stored once.
    let mm = MemoryModel::new(Device::gaudi2(), ModelConfig::llama31_70b());
    for (batch, seq, shared) in [(16usize, 8192usize, 1024usize), (32, 8192, 6144)] {
        let dedicated = mm.kv_bytes(batch, seq);
        let shared_bytes = mm.kv_bytes_shared(batch, seq, shared);
        println!(
            "{{\"fig\":\"fig_prefix_cache\",\"kind\":\"capacity\",\"batch\":{batch},\
             \"seq\":{seq},\"shared_prefix\":{shared},\"kv_bytes\":{:.0},\
             \"kv_bytes_shared\":{:.0},\"kv_bytes_saved\":{:.0},\
             \"fits\":{},\"fits_shared\":{}}}",
            dedicated,
            shared_bytes,
            dedicated - shared_bytes,
            mm.fits(batch, seq),
            mm.fits_shared(batch, seq, shared),
        );
    }

    if smoke {
        return;
    }
    println!(
        "SHAPE: prefix cache cuts mean TTFT {gain_at_1024:.2}x at a 1024-token shared \
         prefix ({requests} requests) {}",
        if gain_at_1024 >= 2.0 { "✓" } else { "✗ (expected ≥2x)" }
    );
    println!(
        "SHAPE: {saved_at_1024} KV bytes saved by prefix sharing {}",
        if saved_at_1024 > 0 { "✓" } else { "✗ (expected >0)" }
    );
}
