//! Fig D (beyond the paper): fleet scaling — aggregate throughput and
//! TTFT/TPOT percentiles for 1→8 simulated Gaudi 2 replicas under each
//! routing policy, on a fixed open-loop workload per replica count.
//!
//! Emits one JSON row per (replicas, policy) cell, then a SHAPE check:
//! total fleet throughput must scale ≥3× from 1 → 4 replicas.

use gaudi_fp8::router::{FleetConfig, FleetRouter, RoutePolicy, SimReplica, SimReplicaConfig};
use gaudi_fp8::server::workload::{ArrivalPattern, OpenLoopConfig, WorkloadConfig};

fn run(replicas: usize, policy: RoutePolicy, requests: usize) -> (f64, String) {
    let mut router = FleetRouter::new(FleetConfig {
        policy,
        queue_capacity: 4096,
    });
    for i in 0..replicas {
        router.add_replica(Box::new(
            SimReplica::new(&format!("gaudi2-sim{i}"), SimReplicaConfig::synthetic_tiny())
                .expect("sim replica"),
        ));
    }
    let open = OpenLoopConfig {
        workload: WorkloadConfig {
            requests,
            prompt_len_min: 16,
            prompt_len_max: 256,
            max_new_min: 16,
            max_new_max: 16,
            seed: 7,
        },
        pattern: ArrivalPattern::Burst,
    };
    let report = router.run_open_loop(open.generate()).expect("fleet run");
    assert_eq!(
        report.outputs.len(),
        requests,
        "lost requests at replicas={replicas} policy={}",
        policy.label()
    );
    (
        report.metrics.throughput_tok_s(),
        report.metrics.json_row(replicas, policy.label(), requests),
    )
}

fn main() {
    const REQUESTS: usize = 128;
    let policies = [
        RoutePolicy::RoundRobin,
        RoutePolicy::LeastOutstandingTokens,
        RoutePolicy::SessionAffinity { prefix_tokens: 16 },
    ];
    let mut scale_1 = 0.0f64;
    let mut scale_4 = 0.0f64;
    for replicas in [1usize, 2, 4, 8] {
        for policy in policies {
            let (tput, row) = run(replicas, policy, REQUESTS);
            println!("{row}");
            if policy == RoutePolicy::LeastOutstandingTokens {
                if replicas == 1 {
                    scale_1 = tput;
                }
                if replicas == 4 {
                    scale_4 = tput;
                }
            }
        }
    }
    let ratio = if scale_1 > 0.0 { scale_4 / scale_1 } else { 0.0 };
    println!(
        "SHAPE: least-outstanding throughput 1→4 replicas scales {ratio:.2}x \
         ({scale_1:.0} → {scale_4:.0} tok/s) {}",
        if ratio >= 3.0 { "✓" } else { "✗ (expected ≥3x)" }
    );
}
