//! Fig SPEC (beyond the paper): single-stream speculative draft-verify
//! decoding on the Gaudi 2 performance model (ISSUE 10).
//!
//! Token-by-token decode at batch 1 (Table 6) is weight-streaming-bound:
//! the FP8 MME sits idle while ~35 GB of weights cross HBM per emitted
//! token. A draft-verify round moves the same weights once but scores
//! `γ + 1` positions in a single chunked multi-token target step — the
//! Table 5 vs Table 6 utilization gap converted into a latency win, priced
//! entirely from the existing gaudisim primitives
//! (`speculative_round_time_s` = γ tiny-draft decode steps + one
//! `chunked_prefill_time_s` verify chunk; nothing in the Table 5/6 pricing
//! changes).
//!
//! The sweep runs γ ∈ {2, 4, 8} × an acceptance grid × paper contexts and
//! emits one JSON row per cell. Hard assertions (the ISSUE 10 acceptance
//! bars):
//!
//!   * speedup ≥ 1.5× at the reference point γ = 4, α = 0.8, at every
//!     context in the sweep;
//!   * speedup is monotone non-decreasing in acceptance for fixed (γ,
//!     context) — more agreement never hurts;
//!   * bounded α → 0 loss: the verify chunk costs at most 2× one plain
//!     decode step, so the worst case degrades to plain decode plus the
//!     draft overhead and one extra step — never a cliff.
//!
//! SHAPE lines are suppressed under `BENCH_SMOKE=1` (stdout must stay
//! pure JSON for the CI validator).

use gaudi_fp8::gaudisim::{
    decode_group_time_s_paged, speculative_expected_tokens_per_round, speculative_round_time_s,
    speculative_tpot_s, E2eConfig,
};

fn main() {
    let smoke = matches!(std::env::var("BENCH_SMOKE").as_deref(), Ok("1"));
    let target = E2eConfig::llama31_70b_paper();
    let draft = E2eConfig::synthetic_tiny_draft();
    let contexts: &[usize] = if smoke {
        &[1024]
    } else {
        &[1024, 4096, 16384]
    };
    let alphas: &[f64] = if smoke {
        &[0.0, 0.4, 0.8]
    } else {
        &[0.0, 0.2, 0.4, 0.6, 0.8, 0.9]
    };

    let mut headline_speedups: Vec<(usize, f64)> = Vec::new();
    for &context in contexts {
        let baseline = decode_group_time_s_paged(&target, &[context]);
        assert!(baseline > 0.0, "baseline decode step must take time");
        for gamma in [2usize, 4, 8] {
            let draft_s: f64 = (0..gamma)
                .map(|i| decode_group_time_s_paged(&draft, &[context + i]))
                .sum();
            let round = speculative_round_time_s(&target, &draft, context, gamma);
            let verify = round - draft_s;
            // Bounded loss at α → 0: a fully-rejected round still emits one
            // token at cost draft + verify, and the verify chunk streams the
            // weights once — within 2× a plain step even with the extra
            // attention rows. So speculation never degrades beyond draft
            // overhead plus one step, at any acceptance.
            assert!(
                verify <= 2.0 * baseline,
                "verify chunk (γ={gamma}, ctx={context}) costs {:.2}ms > 2x the \
                 {:.2}ms plain decode step — the α→0 bound is broken",
                verify * 1e3,
                baseline * 1e3
            );
            let mut prev_speedup = 0.0f64;
            for &alpha in alphas {
                let expected = speculative_expected_tokens_per_round(gamma, alpha);
                let tpot = speculative_tpot_s(&target, &draft, context, gamma, alpha);
                let speedup = baseline / tpot;
                assert!(
                    speedup >= prev_speedup - 1e-12,
                    "speedup must be monotone in acceptance at γ={gamma}, ctx={context}: \
                     {prev_speedup:.3}x then {speedup:.3}x at α={alpha}"
                );
                prev_speedup = speedup;
                if gamma == 4 && (alpha - 0.8).abs() < 1e-9 {
                    // The ISSUE 10 headline bar.
                    assert!(
                        speedup > 1.5,
                        "γ=4 at 80% acceptance must beat token-by-token by 1.5x \
                         at ctx={context}, got {speedup:.3}x"
                    );
                    headline_speedups.push((context, speedup));
                }
                println!(
                    "{{\"bench\":\"fig_speculative\",\"context\":{context},\"gamma\":{gamma},\
                     \"acceptance\":{alpha:.2},\"baseline_tpot_ms\":{:.4},\
                     \"draft_ms\":{:.4},\"verify_ms\":{:.4},\"round_ms\":{:.4},\
                     \"expected_tokens\":{expected:.4},\"spec_tpot_ms\":{:.4},\
                     \"speedup\":{speedup:.4}}}",
                    baseline * 1e3,
                    draft_s * 1e3,
                    verify * 1e3,
                    round * 1e3,
                    tpot * 1e3,
                );
            }
        }
    }

    if !smoke {
        for (context, speedup) in &headline_speedups {
            println!(
                "SHAPE: ctx {context}: γ=4 @ 80% acceptance emits tokens {speedup:.2}x \
                 faster than token-by-token decode ✓"
            );
        }
        println!(
            "SHAPE: verify chunk stays within 2x a plain decode step at every (γ, ctx) — \
             α→0 loses only the draft overhead, never a cliff ✓"
        );
    }
}
