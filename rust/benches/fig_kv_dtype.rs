//! Fig KV-dtype (beyond the paper's tables, §4.2.4's mechanism): what the
//! KV-cache storage dtype buys at a fixed byte budget.
//!
//! For each dtype (f32, bf16, fp8) emits one JSON row:
//! * `bytes_per_token` — the shared `KvLayout` accounting rate;
//! * `max_admitted_batch` — concurrent sequences a `SimReplica`'s block
//!   allocator admits from an equal byte budget;
//! * `decode_readout_mse_vs_f32` — single-step attention-readout MSE of a
//!   `KvStore` holding the same data, measured by `decode_attention_probe`
//!   on the synthetic-tiny geometry (the pre-LM-head decode fidelity
//!   signal; the LM head is a fixed linear map on this readout).
//!
//! SHAPE checks: fp8 admits ≥ 1.8× the f32 batch, with readout MSE < 1e-2.
//!
//! `BENCH_SMOKE=1` (the CI bench-smoke job) runs a reduced-size probe
//! (half the token window) and suppresses the human-readable SHAPE lines
//! so stdout is pure JSON, one row per line.

use gaudi_fp8::coordinator::KvStore;
use gaudi_fp8::quant::KvDtype;
use gaudi_fp8::router::{SimReplica, SimReplicaConfig};
use gaudi_fp8::util::rng::XorShiftRng;

/// Tokens one admitted request pins (prompt 256 + 16 generated).
const SEQ_TOKENS: usize = 272;
/// Equal KV byte budget for every dtype: 64 MiB.
const BUDGET_BYTES: f64 = 64.0 * 1024.0 * 1024.0;

fn max_admitted_batch(dtype: KvDtype) -> usize {
    let mut cfg = SimReplicaConfig::synthetic_tiny();
    cfg.kv_dtype = dtype;
    cfg.kv_bytes_budget_override = Some(BUDGET_BYTES);
    let replica = SimReplica::new("budget", cfg).expect("replica");
    let mut alloc = replica.allocator().clone();
    let mut batch = 0;
    while alloc.allocate(SEQ_TOKENS).is_ok() {
        batch += 1;
    }
    batch
}

/// Attention readout of a store holding `(k, v)` on synthetic-tiny
/// geometry (4 layers, 2 kv-heads, 32 head-dim, `t`-token window).
fn probe(dtype: KvDtype, t: usize, k: &[f32], v: &[f32]) -> Vec<f32> {
    let (layers, kv_heads, head_dim) = (4, 2, 32);
    let mut store = KvStore::with_dtype(layers, 1, t, kv_heads, head_dim, dtype);
    let slot = store.alloc_slot().expect("slot");
    store.write_slot(slot, k, v, t);
    store.decode_attention_probe(&[slot], 4242)
}

fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        / a.len() as f64
}

fn main() {
    let smoke = matches!(std::env::var("BENCH_SMOKE").as_deref(), Ok("1"));
    let t = if smoke { 32usize } else { 64usize };
    let (layers, kv_heads, head_dim) = (4usize, 2usize, 32usize);
    let n = layers * t * kv_heads * head_dim;
    let mut rng = XorShiftRng::new(7);
    let k: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
    let v: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
    let reference = probe(KvDtype::F32, t, &k, &v);

    let model = SimReplicaConfig::synthetic_tiny().e2e.model;
    let mut admitted = Vec::new();
    let mut mses = Vec::new();
    for dtype in [KvDtype::F32, KvDtype::Bf16, KvDtype::FP8_DEFAULT] {
        let batch = max_admitted_batch(dtype);
        let err = mse(&reference, &probe(dtype, t, &k, &v));
        admitted.push(batch);
        mses.push(err);
        println!(
            "{{\"fig\":\"fig_kv_dtype\",\"kv_dtype\":\"{}\",\"bytes_per_token\":{},\
             \"kv_budget_bytes\":{:.0},\"seq_tokens\":{},\"max_admitted_batch\":{},\
             \"decode_readout_mse_vs_f32\":{:.3e}}}",
            dtype.name(),
            model.kv_layout(dtype).bytes_per_token(),
            BUDGET_BYTES,
            SEQ_TOKENS,
            batch,
            err,
        );
    }

    if smoke {
        return;
    }
    let ratio = admitted[2] as f64 / admitted[0].max(1) as f64;
    println!(
        "SHAPE: fp8 KV admits {ratio:.2}x the f32 batch at an equal budget \
         ({} → {}) {}",
        admitted[0],
        admitted[2],
        if ratio >= 1.8 { "✓" } else { "✗ (expected ≥1.8x)" }
    );
    println!(
        "SHAPE: fp8 decode readout MSE vs f32 KV = {:.3e} {}",
        mses[2],
        if mses[2] < 1e-2 { "✓" } else { "✗ (expected <1e-2)" }
    );
}
