//! Figure B: accuracy-vs-method comparison across model families — the Δ%
//! bar-chart data underlying Tables 2–4, plus the extended method grid
//! (MSE search, SmoothQuant, dynamic per-sample, pow2/HW scales) the paper
//! describes in §3.2 but does not tabulate.

use gaudi_fp8::eval::suite::{evaluate_model, EvalConfig};
use gaudi_fp8::fp8::Fp8Format;
use gaudi_fp8::gaudisim::Generation;
use gaudi_fp8::model::config::{ModelConfig, ModelFamily};
use gaudi_fp8::quant::{ActScaling, QuantScheme, ScaleSet, WeightScaling};

fn main() {
    let fmt = Fp8Format::E4M3Gaudi2;
    let schemes: Vec<(String, QuantScheme)> = vec![
        ("Unit Scale".into(), QuantScheme::unit_scale(fmt)),
        ("Per Tensor".into(), QuantScheme::per_tensor(fmt)),
        ("Per Tensor (HW pow2)".into(), QuantScheme::per_tensor_hw(fmt)),
        ("Per Channel".into(), QuantScheme::per_channel(fmt)),
        (
            "MSE Per Tensor".into(),
            QuantScheme {
                weight: WeightScaling::MsePerTensor(ScaleSet::Arbitrary),
                ..QuantScheme::per_tensor(fmt)
            },
        ),
        (
            "MSE Per Channel (HW set)".into(),
            QuantScheme {
                weight: WeightScaling::MsePerChannel(ScaleSet::HwAccelerated(Generation::Gaudi2)),
                ..QuantScheme::per_tensor(fmt)
            },
        ),
        (
            "Dynamic Per Sample".into(),
            QuantScheme {
                act: ActScaling::PerSampleDynamic { backoff: 1.0 },
                ..QuantScheme::per_channel(fmt)
            },
        ),
        ("SmoothQuant α=0.5".into(), QuantScheme::smoothquant(fmt, 0.5)),
    ];

    let ec = EvalConfig {
        eval_samples: 384,
        ..Default::default()
    };
    println!("# Figure B data (CSV)");
    println!("family,method,ppl_delta_pct,commonsense_delta,mmlu_delta");
    for family in [
        ModelFamily::Llama2,
        ModelFamily::Llama3,
        ModelFamily::Mistral,
        ModelFamily::Mixtral,
    ] {
        let cfg = ModelConfig::synthetic_small(family);
        let rows = evaluate_model(&cfg, &schemes, &ec);
        for r in &rows[1..] {
            println!(
                "{:?},{},{:.2},{:.2},{:.2}",
                family, r.configuration, r.ppl_delta_pct, r.commonsense_delta_pct, r.mmlu_delta_pct
            );
        }
        // Bar chart of ΔPPL (log-ish clamp for the unit-scale blowups).
        println!("\n# ΔPPL% — {family:?}");
        for r in &rows[1..] {
            let v = r.ppl_delta_pct.clamp(0.0, 400.0);
            println!(
                "{:>26} | {:<40} {:.1}%",
                r.configuration,
                "#".repeat((v / 10.0) as usize),
                r.ppl_delta_pct
            );
        }
        println!();
    }
}
