//! Hot-path micro-benchmarks — the §Perf targets: FP8 encode/decode, the
//! emulated scaled GEMM, KV gather/scatter, and the batcher admission path.
//! Run before/after each optimization; results recorded in EXPERIMENTS.md.

use gaudi_fp8::coordinator::KvStore;
use gaudi_fp8::fp8::{
    decode, encode_rne, encode_stochastic, rescale_pow2, CastMode, DecodeTable, Fp8Format,
    Fp8Gemm8x8,
};
use gaudi_fp8::gemm::{quantize_matrix, scaled_gemm_with_table, DiagScale, QuantRounding};
use gaudi_fp8::quant::KvDtype;
use gaudi_fp8::tensor::{matmul_nt, Tensor2};
use gaudi_fp8::util::rng::XorShiftRng;
use gaudi_fp8::util::{bench::black_box, Bencher};

fn main() {
    let mut b = Bencher::new("hotpath");
    let fmt = Fp8Format::E4M3Gaudi2;
    let mut rng = XorShiftRng::new(9);

    // --- encode -----------------------------------------------------------
    let xs: Vec<f32> = (0..4096).map(|_| rng.normal() * 50.0).collect();
    b.bench_throughput("encode_rne_4k", 4096.0, "Gelem/s", || {
        let mut acc = 0u32;
        for &x in &xs {
            acc = acc.wrapping_add(encode_rne(x, fmt, CastMode::SatFinite) as u32);
        }
        black_box(acc);
    });
    let mut srng = XorShiftRng::new(11);
    b.bench_throughput("encode_stochastic_4k", 4096.0, "Gelem/s", || {
        let mut acc = 0u32;
        for &x in &xs {
            acc = acc.wrapping_add(encode_stochastic(x, fmt, CastMode::SatFinite, &mut srng) as u32);
        }
        black_box(acc);
    });

    // --- decode -----------------------------------------------------------
    let codes: Vec<u8> = (0..4096).map(|i| (i % 251) as u8).collect();
    let table = DecodeTable::new(fmt);
    b.bench_throughput("decode_table_4k", 4096.0, "Gelem/s", || {
        let mut acc = 0.0f32;
        for &c in &codes {
            acc += table.get(c);
        }
        black_box(acc);
    });
    b.bench_throughput("decode_scalar_4k", 4096.0, "Gelem/s", || {
        let mut acc = 0.0f32;
        for &c in &codes {
            acc += decode(c, fmt);
        }
        black_box(acc);
    });
    b.bench_throughput("rescale_pow2_4k", 4096.0, "Gelem/s", || {
        let mut acc = 0u32;
        for &c in &codes {
            acc = acc.wrapping_add(rescale_pow2(c, 2, fmt) as u32);
        }
        black_box(acc);
    });

    // --- GEMM -------------------------------------------------------------
    let n = 256;
    let x = Tensor2::randn(n, n, 1.0, &mut rng);
    let w = Tensor2::randn(n, n, 0.05, &mut rng);
    let flops = 2.0 * (n as f64).powi(3);
    b.bench_throughput("f32_gemm_256", flops, "GFLOP/s", || {
        black_box(matmul_nt(&x, &w));
    });
    let xq = quantize_matrix(&x, &[0.0125], &[], fmt, QuantRounding::Nearest);
    let wq = quantize_matrix(&w, &[0.001], &[], fmt, QuantRounding::Nearest);
    let ptable = Fp8Gemm8x8::new(fmt, fmt);
    b.bench_throughput("fp8_emulated_gemm_256", flops, "GFLOP/s", || {
        black_box(scaled_gemm_with_table(
            &xq,
            &wq,
            &DiagScale::Scalar(0.0125),
            &DiagScale::Scalar(0.001),
            false,
            &ptable,
        ));
    });
    b.bench_throughput("quantize_matrix_256", (n * n) as f64, "Gelem/s", || {
        black_box(quantize_matrix(&x, &[0.0125], &[], fmt, QuantRounding::Nearest));
    });

    // --- KV management ----------------------------------------------------
    let mut kv = KvStore::new(4, 8, 160, 2, 32);
    let ss = 160 * 2 * 32;
    let kdata = vec![0.5f32; 4 * ss];
    for _ in 0..4 {
        let s = kv.alloc_slot().unwrap();
        kv.write_slot(s, &kdata, &kdata, 100);
    }
    let slots = kv.active_slots();
    let kv_bytes = (4 * slots.len() * ss * 4 * 2) as f64;
    b.bench_throughput("kv_gather_4slots", kv_bytes, "GB/s", || {
        black_box(kv.gather_batch(&slots));
    });
    // §Perf L3: allocation-free gather into persistent scratch.
    let mut sk = vec![0.0f32; 4 * slots.len() * ss];
    let mut sv = vec![0.0f32; 4 * slots.len() * ss];
    b.bench_throughput("kv_gather_into_4slots", kv_bytes, "GB/s", || {
        black_box(kv.gather_batch_into(&slots, slots.len(), &mut sk, &mut sv));
    });
    let (gk, gv, _) = kv.gather_batch(&slots);
    // Paged scatter appends one position (hot block only): reset lengths
    // each iter so it never saturates at capacity, and account only the
    // hot-block span actually touched, derived from the store's own
    // geometry so a block-size change cannot silently skew the rows.
    let valid_in_hot_block = 100 % kv.block_tokens() + 1;
    let hot_bytes =
        (slots.len() * kv.layers * valid_in_hot_block * kv.kv_heads * kv.head_dim * 2 * 4) as f64;
    b.bench_throughput("kv_scatter_4slots", hot_bytes, "GB/s", || {
        for &s in &slots {
            kv.set_len(s, 100);
        }
        black_box(kv.scatter_batch(&slots, &gk, &gv));
    });

    // FP8 KV store (ISSUE 2): quantize-on-scatter / dequantize-on-gather.
    // Throughput is in logical f32 bytes so rows compare with the f32 store.
    let mut kv8 = KvStore::with_dtype(4, 8, 160, 2, 32, KvDtype::Fp8(fmt));
    for _ in 0..4 {
        let s = kv8.alloc_slot().unwrap();
        kv8.write_slot(s, &kdata, &kdata, 100);
    }
    let slots8 = kv8.active_slots();
    b.bench_throughput("kv_fp8_gather_4slots", kv_bytes, "GB/s", || {
        black_box(kv8.gather_batch(&slots8));
    });
    let (g8k, g8v, _) = kv8.gather_batch(&slots8);
    b.bench_throughput("kv_fp8_scatter_4slots", hot_bytes, "GB/s", || {
        for &s in &slots8 {
            kv8.set_len(s, 100);
        }
        black_box(kv8.scatter_batch(&slots8, &g8k, &g8v));
    });
}
