//! Hot-path micro-benchmarks — the §Perf targets: FP8 encode/decode, the
//! emulated scaled GEMM, KV gather/scatter, and the batcher admission path.
//! Run before/after each optimization; results recorded in EXPERIMENTS.md.
//!
//! ISSUE 5 adds `kind:"paged_decode"` JSON rows (one per line, the only
//! stdout under `BENCH_SMOKE=1`): paged append + per-slot block-table
//! reads vs the old dense gather/scatter at B ∈ {8, 32} × ctx ∈ {1k, 4k}
//! inside a 4k window. Each row's measured bytes-moved ratio is asserted
//! to match the gaudisim paged/dense pricing split
//! (`kv_read_bytes_dense / kv_read_bytes_paged`) exactly — the model and
//! the host store charge the same geometry.
//!
//! ISSUE 8 adds `kind:"paged_parallel"` rows: the data-parallel
//! single-entry read path (scoped worker pool + shared-LUT FP8 dequant)
//! vs the serial scalar-dequant baseline at (B=32, ctx=4k) on an FP8
//! store — output bit-identical and `bytes_read` byte-identical across
//! configs, wall clock reported per row.

use std::time::Instant;

use gaudi_fp8::coordinator::{AttendOptions, Dequant, KvStore};
use gaudi_fp8::fp8::{
    decode, encode_rne, encode_stochastic, rescale_pow2, CastMode, DecodeTable, Fp8Format,
    Fp8Gemm8x8,
};
use gaudi_fp8::gaudisim::{kv_read_bytes_dense, kv_read_bytes_paged};
use gaudi_fp8::gemm::{quantize_matrix, scaled_gemm_with_table, DiagScale, QuantRounding};
use gaudi_fp8::model::config::ModelConfig;
use gaudi_fp8::quant::{KvDtype, KvLayout};
use gaudi_fp8::tensor::{matmul_nt, Tensor2};
use gaudi_fp8::util::pool::{auto_workers, Parallelism};
use gaudi_fp8::util::rng::XorShiftRng;
use gaudi_fp8::util::{bench::black_box, Bencher};

fn main() {
    let smoke = matches!(std::env::var("BENCH_SMOKE").as_deref(), Ok("1"));
    if !smoke {
        timed_micro();
    }
    paged_decode_rows(smoke);
}

fn timed_micro() {
    let mut b = Bencher::new("hotpath");
    let fmt = Fp8Format::E4M3Gaudi2;
    let mut rng = XorShiftRng::new(9);

    // --- encode -----------------------------------------------------------
    let xs: Vec<f32> = (0..4096).map(|_| rng.normal() * 50.0).collect();
    b.bench_throughput("encode_rne_4k", 4096.0, "Gelem/s", || {
        let mut acc = 0u32;
        for &x in &xs {
            acc = acc.wrapping_add(encode_rne(x, fmt, CastMode::SatFinite) as u32);
        }
        black_box(acc);
    });
    let mut srng = XorShiftRng::new(11);
    b.bench_throughput("encode_stochastic_4k", 4096.0, "Gelem/s", || {
        let mut acc = 0u32;
        for &x in &xs {
            acc = acc.wrapping_add(encode_stochastic(x, fmt, CastMode::SatFinite, &mut srng) as u32);
        }
        black_box(acc);
    });

    // --- decode -----------------------------------------------------------
    let codes: Vec<u8> = (0..4096).map(|i| (i % 251) as u8).collect();
    let table = DecodeTable::new(fmt);
    b.bench_throughput("decode_table_4k", 4096.0, "Gelem/s", || {
        let mut acc = 0.0f32;
        for &c in &codes {
            acc += table.get(c);
        }
        black_box(acc);
    });
    b.bench_throughput("decode_scalar_4k", 4096.0, "Gelem/s", || {
        let mut acc = 0.0f32;
        for &c in &codes {
            acc += decode(c, fmt);
        }
        black_box(acc);
    });
    b.bench_throughput("rescale_pow2_4k", 4096.0, "Gelem/s", || {
        let mut acc = 0u32;
        for &c in &codes {
            acc = acc.wrapping_add(rescale_pow2(c, 2, fmt) as u32);
        }
        black_box(acc);
    });

    // --- GEMM -------------------------------------------------------------
    let n = 256;
    let x = Tensor2::randn(n, n, 1.0, &mut rng);
    let w = Tensor2::randn(n, n, 0.05, &mut rng);
    let flops = 2.0 * (n as f64).powi(3);
    b.bench_throughput("f32_gemm_256", flops, "GFLOP/s", || {
        black_box(matmul_nt(&x, &w));
    });
    let xq = quantize_matrix(&x, &[0.0125], &[], fmt, QuantRounding::Nearest);
    let wq = quantize_matrix(&w, &[0.001], &[], fmt, QuantRounding::Nearest);
    let ptable = Fp8Gemm8x8::new(fmt, fmt);
    b.bench_throughput("fp8_emulated_gemm_256", flops, "GFLOP/s", || {
        black_box(scaled_gemm_with_table(
            &xq,
            &wq,
            &DiagScale::Scalar(0.0125),
            &DiagScale::Scalar(0.001),
            false,
            &ptable,
        ));
    });
    b.bench_throughput("quantize_matrix_256", (n * n) as f64, "Gelem/s", || {
        black_box(quantize_matrix(&x, &[0.0125], &[], fmt, QuantRounding::Nearest));
    });

    // --- KV management ----------------------------------------------------
    let mut kv = KvStore::new(4, 8, 160, 2, 32);
    let ss = 160 * 2 * 32;
    let kdata = vec![0.5f32; 4 * ss];
    for _ in 0..4 {
        let s = kv.alloc_slot().unwrap();
        kv.write_slot(s, &kdata, &kdata, 100);
    }
    let slots = kv.active_slots();
    let kv_bytes = (4 * slots.len() * ss * 4 * 2) as f64;
    b.bench_throughput("kv_gather_4slots", kv_bytes, "GB/s", || {
        black_box(kv.gather_batch(&slots));
    });
    // §Perf L3: allocation-free gather into persistent scratch.
    let mut sk = vec![0.0f32; 4 * slots.len() * ss];
    let mut sv = vec![0.0f32; 4 * slots.len() * ss];
    b.bench_throughput("kv_gather_into_4slots", kv_bytes, "GB/s", || {
        black_box(kv.gather_batch_into(&slots, slots.len(), &mut sk, &mut sv));
    });
    let (gk, gv, _) = kv.gather_batch(&slots);
    // Paged scatter appends one position (hot block only): reset lengths
    // each iter so it never saturates at capacity, and account only the
    // hot-block span actually touched, derived from the store's own
    // geometry so a block-size change cannot silently skew the rows.
    let valid_in_hot_block = 100 % kv.block_tokens() + 1;
    let hot_bytes =
        (slots.len() * kv.layers * valid_in_hot_block * kv.kv_heads * kv.head_dim * 2 * 4) as f64;
    b.bench_throughput("kv_scatter_4slots", hot_bytes, "GB/s", || {
        for &s in &slots {
            kv.set_len(s, 100);
        }
        black_box(kv.scatter_batch(&slots, &gk, &gv));
    });

    // FP8 KV store (ISSUE 2): quantize-on-scatter / dequantize-on-gather.
    // Throughput is in logical f32 bytes so rows compare with the f32 store.
    let mut kv8 = KvStore::with_dtype(4, 8, 160, 2, 32, KvDtype::Fp8(fmt));
    for _ in 0..4 {
        let s = kv8.alloc_slot().unwrap();
        kv8.write_slot(s, &kdata, &kdata, 100);
    }
    let slots8 = kv8.active_slots();
    b.bench_throughput("kv_fp8_gather_4slots", kv_bytes, "GB/s", || {
        black_box(kv8.gather_batch(&slots8));
    });
    let (g8k, g8v, _) = kv8.gather_batch(&slots8);
    b.bench_throughput("kv_fp8_scatter_4slots", hot_bytes, "GB/s", || {
        for &s in &slots8 {
            kv8.set_len(s, 100);
        }
        black_box(kv8.scatter_batch(&slots8, &g8k, &g8v));
    });
}

/// Build a `b`-slot store of `dtype` in a `window`-token window, every
/// slot written to `ctx` valid tokens. Returns (store, active slots).
fn paged_store(
    layers: usize,
    kvh: usize,
    hd: usize,
    window: usize,
    bt: usize,
    b: usize,
    ctx: usize,
    dtype: KvDtype,
) -> (KvStore, Vec<usize>) {
    let row = kvh * hd;
    let mut kv = KvStore::with_block_tokens(layers, b, window, kvh, hd, dtype, bt, 0);
    let mut buf = vec![0.0f32; layers * window * row];
    for (i, x) in buf.iter_mut().enumerate() {
        *x = (i % 97) as f32 * 0.03125 - 1.5;
    }
    let mut group = Vec::new();
    for _ in 0..b {
        let s = kv.alloc_slot().expect("slot");
        kv.write_slot(s, &buf, &buf, ctx);
        group.push(s);
    }
    (kv, group)
}

/// ISSUE 5: paged append + per-slot block-table reads vs dense
/// gather/scatter — JSON bytes rows for every (B, ctx) cell, plus timed
/// throughput rows for the (8, 1k) cell outside smoke mode.
fn paged_decode_rows(smoke: bool) {
    let (layers, kvh, hd, window, bt) = (2usize, 2usize, 16usize, 4096usize, 16usize);
    let row = kvh * hd;
    // Any model geometry works for the pricing split: the dense/paged
    // ratio is pure (bucket·window)/(Σ live-block tokens) — rates cancel.
    let model = ModelConfig::llama31_70b();
    for &(b, ctx) in &[(8usize, 1024usize), (8, 4096), (32, 1024), (32, 4096)] {
        let (kv, group) = paged_store(layers, kvh, hd, window, bt, b, ctx, KvDtype::F32);
        // Measured paged bytes: one decode step's per-slot reads, off the
        // pool's own instrumentation.
        kv.pool().reset_bytes_read();
        black_box(kv.decode_attention_probe(&group, 11));
        let paged_bytes = kv.pool().bytes_read() as f64;
        // Dense staging bytes: the (L, B, window, Hkv·D) K+V f32 pair the
        // pre-paged engine materialized every step.
        let dense_bytes = (2 * layers * b * window * row * 4) as f64;
        let measured_ratio = dense_bytes / paged_bytes;
        let ctxs = vec![ctx; b];
        let model_ratio =
            kv_read_bytes_dense(&model, b, window) / kv_read_bytes_paged(&model, &ctxs);
        assert!(
            (measured_ratio / model_ratio - 1.0).abs() < 1e-9,
            "bytes ratio drifted from the gaudisim pricing split: \
             measured {measured_ratio} vs model {model_ratio} at (b={b}, ctx={ctx})"
        );
        println!(
            "{{\"bench\":\"hotpath_micro\",\"kind\":\"paged_decode\",\"b\":{b},\
             \"ctx\":{ctx},\"window\":{window},\"paged_bytes\":{paged_bytes:.0},\
             \"dense_bytes\":{dense_bytes:.0},\"measured_ratio\":{measured_ratio:.6},\
             \"model_ratio\":{model_ratio:.6}}}"
        );
    }
    paged_parallel_rows(smoke, &model);
    if smoke {
        return;
    }

    // Timed comparison at (8, 1k): the paged read + append hot path vs the
    // dense gather + scatter it replaced.
    let mut bench = Bencher::new("hotpath");
    let (b, ctx) = (8usize, 1024usize);
    let (mut kv, group) = paged_store(layers, kvh, hd, window, bt, b, ctx, KvDtype::F32);
    let live_bytes = (b * ctx.div_ceil(bt) * bt * 2 * layers * row * 4) as f64;
    bench.bench_throughput("kv_paged_read_8x1k", live_bytes, "GB/s", || {
        black_box(kv.decode_attention_probe(&group, 11));
    });
    let ss = window * row;
    let dense_bytes = (2 * layers * b * window * row * 4) as f64;
    let mut sk = vec![0.0f32; layers * b * ss];
    let mut sv = vec![0.0f32; layers * b * ss];
    bench.bench_throughput("kv_dense_gather_8x1k", dense_bytes, "GB/s", || {
        black_box(kv.gather_batch_into(&group, b, &mut sk, &mut sv));
    });
    let token_bytes = (b * 2 * layers * row * 4) as f64;
    let kr = vec![0.123f32; layers * row];
    bench.bench_throughput("kv_append_token_8x1k", token_bytes, "GB/s", || {
        for &s in &group {
            kv.set_len(s, ctx);
        }
        for &s in &group {
            black_box(kv.append_token(s, &kr, &kr));
        }
    });
    let (gk, gv, _) = kv.gather_batch(&group);
    let hot_bytes = (b * 2 * layers * row * 4) as f64; // ctx % bt == 0 → 1 valid token
    bench.bench_throughput("kv_dense_scatter_8x1k", hot_bytes, "GB/s", || {
        for &s in &group {
            kv.set_len(s, ctx);
        }
        black_box(kv.scatter_batch(&group, &gk, &gv));
    });
}

/// ISSUE 8: the data-parallel single-entry read path at the largest
/// paged_decode cell (B=32, ctx=4k), on an FP8 store so the dequant
/// kernel axis is real. Two `kind:"paged_parallel"` rows: the serial
/// scalar-dequant baseline (workers=1) vs the scoped pool + shared LUT
/// (workers=auto). Asserts, for every config: output bit-identical to
/// the serial baseline, `bytes_read` byte-identical, and the dense/paged
/// bytes ratio equal to the gaudisim pricing split — parallelism and the
/// dequant kernel change wall clock only, never traffic or results.
fn paged_parallel_rows(smoke: bool, model: &ModelConfig) {
    let (layers, kvh, hd, window, bt) = (2usize, 2usize, 16usize, 4096usize, 16usize);
    let (b, ctx) = (32usize, 4096usize);
    let dtype = KvDtype::FP8_DEFAULT;
    let (kv, group) = paged_store(layers, kvh, hd, window, bt, b, ctx, dtype);
    // Same-rate dense equivalent: what a dense staging pass over the full
    // window would move *at this store's own layout rate*, so the ratio
    // reduces to pure token geometry and matches the gaudisim split.
    let layout = KvLayout::new(dtype, layers, kvh, hd);
    let dense_bytes = ((b * window / bt) * layout.block_bytes(bt)) as f64;
    let ctxs = vec![ctx; b];
    let model_ratio = kv_read_bytes_dense(model, b, window) / kv_read_bytes_paged(model, &ctxs);
    let auto = auto_workers().max(2);
    let iters = if smoke { 1 } else { 7 };
    let mut ref_out: Vec<f32> = Vec::new();
    let mut ref_bytes = 0u64;
    let mut walls = [0.0f64; 2];
    let configs = [(1usize, Dequant::Scalar, "scalar"), (auto, Dequant::Lut, "lut")];
    for (ci, &(workers, dequant, name)) in configs.iter().enumerate() {
        let opts = AttendOptions {
            parallelism: Parallelism::Fixed(workers),
            dequant,
        };
        let mut best = f64::INFINITY;
        let mut out = Vec::new();
        let mut bytes = 0u64;
        for _ in 0..iters {
            kv.pool().reset_bytes_read();
            let t0 = Instant::now();
            out = kv.decode_attention_probe_opts(&group, 11, &opts);
            best = best.min(t0.elapsed().as_secs_f64() * 1e3);
            bytes = kv.pool().bytes_read();
        }
        if ci == 0 {
            ref_out = out.clone();
            ref_bytes = bytes;
        } else {
            assert!(
                out.iter()
                    .zip(&ref_out)
                    .all(|(a, r)| a.to_bits() == r.to_bits()),
                "attend output must be bit-identical across worker counts and dequant kernels"
            );
            assert_eq!(
                bytes, ref_bytes,
                "bytes_read must not depend on worker count or dequant kernel"
            );
        }
        let paged_bytes = bytes as f64;
        let measured_ratio = dense_bytes / paged_bytes;
        assert!(
            (measured_ratio / model_ratio - 1.0).abs() < 1e-9,
            "bytes ratio drifted from the gaudisim pricing split: \
             measured {measured_ratio} vs model {model_ratio} (workers={workers})"
        );
        walls[ci] = best;
        println!(
            "{{\"bench\":\"hotpath_micro\",\"kind\":\"paged_parallel\",\"b\":{b},\
             \"ctx\":{ctx},\"window\":{window},\"workers\":{workers},\
             \"dequant\":\"{name}\",\"wall_ms\":{best:.3},\
             \"paged_bytes\":{paged_bytes:.0},\"dense_bytes\":{dense_bytes:.0},\
             \"measured_ratio\":{measured_ratio:.6},\"model_ratio\":{model_ratio:.6}}}"
        );
    }
    if smoke {
        return;
    }
    let speedup = walls[0] / walls[1].max(1e-9);
    println!(
        "SHAPE: paged attend {auto}-worker LUT vs 1-worker scalar speedup {speedup:.2}x \
         at (B={b}, ctx={ctx}) {}",
        if speedup >= 3.0 { "✓" } else { "✗ (expected ≥3x)" }
    );
}
