//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from the
//! Rust request path.
//!
//! Interchange is HLO *text* (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).

pub mod artifact;
pub mod params;
pub mod registry;

pub use artifact::{Artifact, TensorIn, TensorOut};
pub use params::{load_params_bin, ParamTensor};
pub use registry::{ArtifactKey, ArtifactRegistry};

use anyhow::Result;
use std::sync::Arc;

/// Shared PJRT CPU client (one per thread — the xla crate's client type is
/// !Send; engines created on the same thread share it).
#[derive(Clone)]
pub struct Runtime {
    pub client: Arc<xla::PjRtClient>,
}

thread_local! {
    static SHARED: std::cell::RefCell<Option<Runtime>> = const { std::cell::RefCell::new(None) };
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        if let Some(rt) = SHARED.with(|s| s.borrow().clone()) {
            return Ok(rt);
        }
        let rt = Self {
            client: Arc::new(xla::PjRtClient::cpu()?),
        };
        SHARED.with(|s| *s.borrow_mut() = Some(rt.clone()));
        Ok(rt)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}
