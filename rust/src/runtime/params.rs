//! Weights file format shared with `python/compile/params_io.py`.
//!
//! Layout (little-endian):
//! ```text
//! magic   b"GFP8PARM"
//! u32     version (1)
//! u32     tensor count
//! repeat:
//!   u16   name length, name bytes (utf-8)
//!   u8    dtype (0 = f32, 1 = bf16-as-u16)
//!   u8    ndim
//!   u32×ndim  dims
//!   data  (f32 LE or u16 LE)
//! ```

use anyhow::{bail, Context, Result};
use std::io::Read;
use std::path::Path;

use crate::fp8::bf16::bf16_to_f32;

#[derive(Clone, Debug)]
pub struct ParamTensor {
    pub name: String,
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl ParamTensor {
    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }
}

pub const MAGIC: &[u8; 8] = b"GFP8PARM";

/// Load every tensor, in file order (the artifact argument order).
pub fn load_params_bin(path: &Path) -> Result<Vec<ParamTensor>> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("bad magic in {path:?}");
    }
    let version = read_u32(&mut f)?;
    if version != 1 {
        bail!("unsupported params version {version}");
    }
    let count = read_u32(&mut f)? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = read_u16(&mut f)? as usize;
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("tensor name utf-8")?;
        let mut hdr = [0u8; 2];
        f.read_exact(&mut hdr)?;
        let (dtype, ndim) = (hdr[0], hdr[1] as usize);
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(read_u32(&mut f)? as usize);
        }
        let numel: usize = dims.iter().product();
        let data = match dtype {
            0 => {
                let mut buf = vec![0u8; numel * 4];
                f.read_exact(&mut buf)?;
                buf.chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect()
            }
            1 => {
                let mut buf = vec![0u8; numel * 2];
                f.read_exact(&mut buf)?;
                buf.chunks_exact(2)
                    .map(|c| bf16_to_f32(u16::from_le_bytes([c[0], c[1]])))
                    .collect()
            }
            d => bail!("unknown dtype tag {d}"),
        };
        out.push(ParamTensor { name, dims, data });
    }
    Ok(out)
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u16<R: Read>(r: &mut R) -> Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_test_file(path: &Path) {
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(MAGIC).unwrap();
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        // tensor "a": f32 [2,2]
        f.write_all(&1u16.to_le_bytes()).unwrap();
        f.write_all(b"a").unwrap();
        f.write_all(&[0u8, 2u8]).unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        for v in [1.0f32, -2.0, 3.5, 0.0] {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
        // tensor "b": bf16 [3]
        f.write_all(&1u16.to_le_bytes()).unwrap();
        f.write_all(b"b").unwrap();
        f.write_all(&[1u8, 1u8]).unwrap();
        f.write_all(&3u32.to_le_bytes()).unwrap();
        for v in [1.0f32, 0.5, -2.0] {
            let b = crate::fp8::bf16::f32_to_bf16(v);
            f.write_all(&b.to_le_bytes()).unwrap();
        }
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("gaudi_fp8_params_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("w.bin");
        write_test_file(&p);
        let tensors = load_params_bin(&p).unwrap();
        assert_eq!(tensors.len(), 2);
        assert_eq!(tensors[0].name, "a");
        assert_eq!(tensors[0].dims, vec![2, 2]);
        assert_eq!(tensors[0].data, vec![1.0, -2.0, 3.5, 0.0]);
        assert_eq!(tensors[1].name, "b");
        assert_eq!(tensors[1].data, vec![1.0, 0.5, -2.0]);
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("gaudi_fp8_params_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, b"NOTMAGIC....").unwrap();
        assert!(load_params_bin(&p).is_err());
    }
}
