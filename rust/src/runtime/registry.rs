//! Artifact registry: lazily compile and cache executables keyed by
//! (kind, variant, batch, seq). One compiled executable per model variant
//! and shape bucket, as the three-layer architecture prescribes.

use anyhow::{bail, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use super::artifact::Artifact;
use super::Runtime;

/// Identifies one artifact file.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ArtifactKey {
    /// "prefill" | "decode" | "gemm" | custom.
    pub kind: String,
    /// Quantization variant ("bf16", "fp8_pt", ...).
    pub variant: String,
    pub batch: usize,
    /// Sequence length (prefill) or cache capacity (decode); 0 if n/a.
    pub seq: usize,
}

impl ArtifactKey {
    pub fn prefill(variant: &str, batch: usize, seq: usize) -> Self {
        Self {
            kind: "prefill".into(),
            variant: variant.into(),
            batch,
            seq,
        }
    }

    pub fn decode(variant: &str, batch: usize) -> Self {
        Self {
            kind: "decode".into(),
            variant: variant.into(),
            batch,
            seq: 0,
        }
    }

    /// Block-table-native decode (ISSUE 5): the artifact takes the KV
    /// block pool plus per-row block tables and lengths, walks the tables
    /// in place, and returns only the appended token's KV.
    pub fn decode_paged(variant: &str, batch: usize) -> Self {
        Self {
            kind: "decode_paged".into(),
            variant: variant.into(),
            batch,
            seq: 0,
        }
    }

    /// Filename convention shared with aot.py.
    pub fn filename(&self) -> String {
        match self.kind.as_str() {
            "prefill" => format!(
                "prefill_{}_b{}_s{}.hlo.txt",
                self.variant, self.batch, self.seq
            ),
            "decode" => format!("decode_{}_b{}.hlo.txt", self.variant, self.batch),
            "decode_paged" => format!("decode_paged_{}_b{}.hlo.txt", self.variant, self.batch),
            k => format!("{}_{}.hlo.txt", k, self.variant),
        }
    }
}

// Thread-wide compiled-artifact cache: XLA compilation of the larger FP8
// artifacts takes tens of seconds, and engines/tests routinely reopen the
// same files — key by absolute path, compile once per thread. (The xla
// crate's client/executable types are !Send, so process-wide sharing is
// not sound; engines created on the same thread — the common case — share.)
thread_local! {
    static THREAD_CACHE: std::cell::RefCell<HashMap<PathBuf, std::sync::Arc<Artifact>>> =
        std::cell::RefCell::new(HashMap::new());
}

/// Lazy-compiling artifact cache.
pub struct ArtifactRegistry {
    rt: Runtime,
    dir: PathBuf,
    cache: Mutex<HashMap<ArtifactKey, std::sync::Arc<Artifact>>>,
}

impl ArtifactRegistry {
    pub fn new(rt: Runtime, dir: &Path) -> Self {
        Self {
            rt,
            dir: dir.to_path_buf(),
            cache: Mutex::new(HashMap::new()),
        }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Paths available on disk (for listing / diagnostics).
    pub fn available(&self) -> Vec<String> {
        std::fs::read_dir(&self.dir)
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .map(|e| e.file_name().to_string_lossy().into_owned())
                    .filter(|n| n.ends_with(".hlo.txt"))
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Get (compiling on first use) the artifact for `key`.
    pub fn get(&self, key: &ArtifactKey) -> Result<std::sync::Arc<Artifact>> {
        // A poisoned artifact cache only means another thread panicked
        // mid-insert; the map itself stays valid, so keep serving.
        if let Some(a) = self
            .cache
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .get(key)
        {
            return Ok(a.clone());
        }
        let path = self.dir.join(key.filename());
        if !path.exists() {
            bail!(
                "artifact {:?} not found at {path:?} — run `make artifacts`",
                key
            );
        }
        let canonical = path.canonicalize().unwrap_or_else(|_| path.clone());
        let cached = THREAD_CACHE.with(|c| c.borrow().get(&canonical).cloned());
        let art = match cached {
            Some(a) => a,
            None => {
                let a =
                    std::sync::Arc::new(Artifact::load(&self.rt, &key.filename(), &path)?);
                THREAD_CACHE.with(|c| c.borrow_mut().insert(canonical, a.clone()));
                a
            }
        };
        self.cache
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .insert(key.clone(), art.clone());
        Ok(art)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn filename_convention() {
        assert_eq!(
            ArtifactKey::prefill("fp8_pt", 1, 64).filename(),
            "prefill_fp8_pt_b1_s64.hlo.txt"
        );
        assert_eq!(
            ArtifactKey::decode("bf16", 4).filename(),
            "decode_bf16_b4.hlo.txt"
        );
        assert_eq!(
            ArtifactKey::decode_paged("fp8_pt", 8).filename(),
            "decode_paged_fp8_pt_b8.hlo.txt"
        );
    }

    #[test]
    fn missing_artifact_is_error() {
        let rt = Runtime::cpu().unwrap();
        let reg = ArtifactRegistry::new(rt, Path::new("/nonexistent"));
        assert!(reg.get(&ArtifactKey::decode("bf16", 1)).is_err());
        assert!(reg.available().is_empty());
    }
}
