//! One compiled artifact: HLO text → PJRT executable, with typed I/O.

use anyhow::{anyhow, Context, Result};
use std::path::Path;

use super::Runtime;

/// Host-side input tensor (f32 or i32), row-major.
#[derive(Clone, Debug)]
pub enum TensorIn {
    F32 { dims: Vec<usize>, data: Vec<f32> },
    I32 { dims: Vec<usize>, data: Vec<i32> },
    /// Rank-0 i32 (e.g. the decode position).
    ScalarI32(i32),
}

impl TensorIn {
    pub fn f32(dims: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        TensorIn::F32 {
            dims: dims.to_vec(),
            data,
        }
    }

    pub fn i32(dims: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        TensorIn::I32 {
            dims: dims.to_vec(),
            data,
        }
    }

    /// Build the PJRT literal (host copy happens here — hot paths build
    /// long-lived literals once, e.g. model weights).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            TensorIn::F32 { dims, data } => {
                let d: Vec<i64> = dims.iter().map(|x| *x as i64).collect();
                xla::Literal::vec1(data).reshape(&d)?
            }
            TensorIn::I32 { dims, data } => {
                let d: Vec<i64> = dims.iter().map(|x| *x as i64).collect();
                xla::Literal::vec1(data).reshape(&d)?
            }
            TensorIn::ScalarI32(v) => xla::Literal::from(*v),
        })
    }
}

/// Host-side output tensor.
#[derive(Clone, Debug)]
pub struct TensorOut {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

/// A compiled HLO artifact ready to execute.
pub struct Artifact {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Load HLO text from `path` and compile it on `rt`'s client.
    pub fn load(rt: &Runtime, name: &str, path: &Path) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = rt
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        Ok(Self {
            name: name.to_string(),
            exe,
        })
    }

    /// Execute with the given inputs; returns the flattened tuple outputs as
    /// f32 tensors (i32/u8 outputs are converted).
    pub fn run(&self, inputs: &[TensorIn]) -> Result<Vec<TensorOut>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        self.run_literals(&literals)
    }

    /// Execute with pre-built literals — the hot path keeps the (large)
    /// weight literals alive across calls and only rebuilds the small
    /// per-step inputs.
    pub fn run_literals(&self, literals: &[xla::Literal]) -> Result<Vec<TensorOut>> {
        let result = self.exe.execute::<xla::Literal>(literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True.
        let parts = result.to_tuple()?;
        let mut outs = Vec::with_capacity(parts.len());
        for lit in parts {
            outs.push(literal_to_f32(&lit)?);
        }
        Ok(outs)
    }
}

fn literal_to_f32(lit: &xla::Literal) -> Result<TensorOut> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|d| *d as usize).collect();
    let data = match shape.ty() {
        xla::ElementType::F32 => lit.to_vec::<f32>()?,
        xla::ElementType::S32 => lit
            .to_vec::<i32>()?
            .into_iter()
            .map(|v| v as f32)
            .collect(),
        _ => {
            // bf16 / u8 / pred / f64 ... — convert on the client side.
            let conv = lit.convert(xla::ElementType::F32.primitive_type())?;
            return literal_to_f32(&conv);
        }
    };
    Ok(TensorOut { dims, data })
}

#[cfg(test)]
mod tests {
    // Compilation-dependent tests live in rust/tests/runtime_integration.rs
    // (they need `make artifacts` to have run).
    use super::*;

    #[test]
    fn tensor_in_shape_checked() {
        let t = TensorIn::f32(&[2, 3], vec![0.0; 6]);
        matches!(t, TensorIn::F32 { .. });
    }

    #[test]
    #[should_panic]
    fn tensor_in_shape_mismatch_panics() {
        TensorIn::f32(&[2, 3], vec![0.0; 5]);
    }
}
