//! Request types flowing through the coordinator.

use crate::obs::Clock;

pub type RequestId = u64;

/// An inference request: prompt tokens + generation budget.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Stop generation at this token (e.g. b'.' for the byte-LM demo).
    pub stop_token: Option<i32>,
    /// Arrival clock, anchored when the request was constructed:
    /// `arrival.now_s()` is the request's age in seconds. An
    /// [`obs::Clock`](crate::obs::Clock) rather than a raw `Instant` so
    /// queueing/TTFT accounting works identically under wall and virtual
    /// (simulated) time.
    pub arrival: Clock,
    /// Multi-turn conversation id: the fleet router's session-affinity
    /// policy keeps every turn of a session on the replica that already
    /// holds its KV history.
    pub session: Option<u64>,
    /// Per-request beam width override: `Some(k)` forks `k` branches off
    /// the prompt KV at the first token and emits the best-scoring one;
    /// `None` inherits the engine's configured default.
    pub beam_width: Option<usize>,
}

impl Request {
    pub fn new(id: RequestId, prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        Self {
            id,
            prompt,
            max_new_tokens,
            stop_token: None,
            arrival: Clock::wall(),
            session: None,
            beam_width: None,
        }
    }

    pub fn with_session(mut self, session: u64) -> Self {
        self.session = Some(session);
        self
    }

    pub fn with_beam_width(mut self, k: usize) -> Self {
        self.beam_width = Some(k.max(1));
        self
    }
}

/// Lifecycle of an admitted request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestState {
    Waiting,
    Prefilling,
    Decoding,
    Finished,
}

/// Completed request with latency breakdown.
#[derive(Clone, Debug)]
pub struct RequestOutput {
    pub id: RequestId,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    /// Time to first token (prefill + queueing), seconds.
    pub ttft_s: f64,
    /// Mean time per output token after the first, seconds.
    pub tpot_s: f64,
    pub total_s: f64,
}

impl RequestOutput {
    pub fn tokens_per_s(&self) -> f64 {
        if self.total_s > 0.0 {
            self.tokens.len() as f64 / self.total_s
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_construction() {
        let r = Request::new(7, vec![1, 2, 3], 16);
        assert_eq!(r.id, 7);
        assert_eq!(r.prompt.len(), 3);
        assert!(r.stop_token.is_none());
        assert!(r.session.is_none());
        assert!(r.beam_width.is_none());
        assert_eq!(Request::new(8, vec![1], 4).with_session(42).session, Some(42));
        assert_eq!(Request::new(9, vec![1], 4).with_beam_width(0).beam_width, Some(1));
        assert_eq!(Request::new(9, vec![1], 4).with_beam_width(4).beam_width, Some(4));
    }

    #[test]
    fn output_throughput() {
        let o = RequestOutput {
            id: 1,
            prompt_len: 4,
            tokens: vec![0; 10],
            ttft_s: 0.1,
            tpot_s: 0.01,
            total_s: 0.2,
        };
        assert_eq!(o.tokens_per_s(), 50.0);
    }
}
