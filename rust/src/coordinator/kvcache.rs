//! KV-cache management: a page/block accounting allocator (the admission
//! model behind Table 6's OOM frontier) and the slot-based host KV store
//! the engine streams in/out of the decode artifacts.

use anyhow::{bail, Result};

/// Page-granular KV accounting (vLLM-style). Used for admission control and
/// by the gaudisim capacity experiments; pure bookkeeping, no data.
#[derive(Clone, Debug)]
pub struct BlockAllocator {
    pub block_tokens: usize,
    pub total_blocks: usize,
    free_blocks: usize,
}

impl BlockAllocator {
    pub fn new(total_blocks: usize, block_tokens: usize) -> Self {
        Self {
            block_tokens,
            total_blocks,
            free_blocks: total_blocks,
        }
    }

    /// Capacity sized from device HBM: bytes available for KV / bytes per
    /// block. Degenerate geometry (zero-sized blocks, non-finite or
    /// too-small budgets) is an error — a 0-block allocator would silently
    /// reject every request.
    pub fn from_capacity(
        kv_bytes_budget: f64,
        bytes_per_token: usize,
        block_tokens: usize,
    ) -> Result<Self> {
        if bytes_per_token == 0 || block_tokens == 0 {
            bail!(
                "degenerate KV block geometry: bytes_per_token={bytes_per_token}, \
                 block_tokens={block_tokens} (both must be > 0)"
            );
        }
        if !kv_bytes_budget.is_finite() || kv_bytes_budget < 0.0 {
            bail!("invalid KV byte budget {kv_bytes_budget}");
        }
        let block_bytes = (bytes_per_token * block_tokens) as f64;
        let blocks = (kv_bytes_budget / block_bytes).floor() as usize;
        if blocks == 0 {
            bail!(
                "KV budget {kv_bytes_budget:.0} B below one {block_bytes:.0}-B block \
                 ({block_tokens} tokens × {bytes_per_token} B/token) — model does not fit"
            );
        }
        Ok(Self::new(blocks, block_tokens))
    }

    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    pub fn free_blocks(&self) -> usize {
        self.free_blocks
    }

    pub fn can_allocate(&self, tokens: usize) -> bool {
        self.blocks_for(tokens) <= self.free_blocks
    }

    pub fn allocate(&mut self, tokens: usize) -> Result<usize> {
        let need = self.blocks_for(tokens);
        if need > self.free_blocks {
            bail!(
                "KV OOM: need {need} blocks, {} free of {}",
                self.free_blocks,
                self.total_blocks
            );
        }
        self.free_blocks -= need;
        Ok(need)
    }

    pub fn release(&mut self, blocks: usize) {
        self.free_blocks = (self.free_blocks + blocks).min(self.total_blocks);
    }

    pub fn utilization(&self) -> f64 {
        1.0 - self.free_blocks as f64 / self.total_blocks.max(1) as f64
    }
}

/// Host-side KV storage for `slots` concurrent sequences with capacity `t`
/// tokens each, layout (L, slot, T, Hkv, D) matching the decode artifact.
pub struct KvStore {
    pub layers: usize,
    pub slots: usize,
    pub t: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Valid tokens per slot; None = slot free.
    lens: Vec<Option<usize>>,
}

impl KvStore {
    pub fn new(layers: usize, slots: usize, t: usize, kv_heads: usize, head_dim: usize) -> Self {
        let n = layers * slots * t * kv_heads * head_dim;
        Self {
            layers,
            slots,
            t,
            kv_heads,
            head_dim,
            k: vec![0.0; n],
            v: vec![0.0; n],
            lens: vec![None; slots],
        }
    }

    fn slot_stride(&self) -> usize {
        self.t * self.kv_heads * self.head_dim
    }

    fn layer_stride(&self) -> usize {
        self.slots * self.slot_stride()
    }

    pub fn alloc_slot(&mut self) -> Option<usize> {
        let idx = self.lens.iter().position(|l| l.is_none())?;
        self.lens[idx] = Some(0);
        Some(idx)
    }

    pub fn free_slot(&mut self, slot: usize) {
        self.lens[slot] = None;
        // Zero the slot so stale keys can never leak into a new request.
        let (ls, ss) = (self.layer_stride(), self.slot_stride());
        for l in 0..self.layers {
            let base = l * ls + slot * ss;
            self.k[base..base + ss].fill(0.0);
            self.v[base..base + ss].fill(0.0);
        }
    }

    pub fn len(&self, slot: usize) -> Option<usize> {
        self.lens[slot]
    }

    pub fn set_len(&mut self, slot: usize, len: usize) {
        assert!(len <= self.t);
        self.lens[slot] = Some(len);
    }

    pub fn active_slots(&self) -> Vec<usize> {
        (0..self.slots).filter(|s| self.lens[*s].is_some()).collect()
    }

    /// Write a prefill artifact's (L, 1, T, Hkv, D) output into `slot`.
    pub fn write_slot(&mut self, slot: usize, k_out: &[f32], v_out: &[f32], len: usize) {
        let ss = self.slot_stride();
        assert_eq!(k_out.len(), self.layers * ss, "prefill kv size");
        let ls = self.layer_stride();
        for l in 0..self.layers {
            let src = &k_out[l * ss..(l + 1) * ss];
            let dst = l * ls + slot * ss;
            self.k[dst..dst + ss].copy_from_slice(src);
            let src = &v_out[l * ss..(l + 1) * ss];
            self.v[dst..dst + ss].copy_from_slice(src);
        }
        self.set_len(slot, len);
    }

    /// Gather `group` slots into a contiguous (L, B, T, Hkv, D) batch
    /// buffer for the decode artifact. Returns (k, v, lens).
    pub fn gather_batch(&self, group: &[usize]) -> (Vec<f32>, Vec<f32>, Vec<i32>) {
        let b = group.len();
        let ss = self.slot_stride();
        let mut k = vec![0.0f32; self.layers * b * ss];
        let mut v = vec![0.0f32; self.layers * b * ss];
        let lens = self.gather_batch_into(group, b, &mut k, &mut v);
        (k, v, lens)
    }

    /// Allocation-free gather into caller-owned buffers sized for a batch
    /// of `bucket` rows (§Perf L3: the per-step `vec!` zero-fill dominated
    /// the gather path). Rows ≥ group.len() are left untouched — the engine
    /// zeroes padding rows only when the bucket grows.
    pub fn gather_batch_into(
        &self,
        group: &[usize],
        bucket: usize,
        k: &mut [f32],
        v: &mut [f32],
    ) -> Vec<i32> {
        let b = bucket;
        assert!(group.len() <= b);
        let ss = self.slot_stride();
        let ls = self.layer_stride();
        assert_eq!(k.len(), self.layers * b * ss, "k buffer size");
        assert_eq!(v.len(), self.layers * b * ss, "v buffer size");
        let mut lens = Vec::with_capacity(b);
        for (bi, &slot) in group.iter().enumerate() {
            lens.push(self.lens[slot].unwrap_or(0) as i32);
            for l in 0..self.layers {
                let src = l * ls + slot * ss;
                let dst = (l * b + bi) * ss;
                k[dst..dst + ss].copy_from_slice(&self.k[src..src + ss]);
                v[dst..dst + ss].copy_from_slice(&self.v[src..src + ss]);
            }
        }
        lens.resize(b, 0);
        lens
    }

    /// Scatter an updated (L, B, T, Hkv, D) batch back into the slots and
    /// bump their lengths.
    pub fn scatter_batch(&mut self, group: &[usize], k: &[f32], v: &[f32]) {
        let b = group.len();
        let ss = self.slot_stride();
        let ls = self.layer_stride();
        assert_eq!(k.len(), self.layers * b * ss);
        for (bi, &slot) in group.iter().enumerate() {
            for l in 0..self.layers {
                let dst = l * ls + slot * ss;
                let src = (l * b + bi) * ss;
                self.k[dst..dst + ss].copy_from_slice(&k[src..src + ss]);
                self.v[dst..dst + ss].copy_from_slice(&v[src..src + ss]);
            }
            if let Some(len) = self.lens[slot] {
                self.lens[slot] = Some((len + 1).min(self.t));
            }
        }
    }

    pub fn kv_bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_allocator_accounting() {
        let mut a = BlockAllocator::new(10, 16);
        assert_eq!(a.blocks_for(1), 1);
        assert_eq!(a.blocks_for(16), 1);
        assert_eq!(a.blocks_for(17), 2);
        assert!(a.can_allocate(160));
        assert!(!a.can_allocate(161));
        let got = a.allocate(33).unwrap(); // 3 blocks
        assert_eq!(got, 3);
        assert_eq!(a.free_blocks(), 7);
        assert!(a.allocate(160).is_err());
        a.release(3);
        assert_eq!(a.free_blocks(), 10);
        assert_eq!(a.utilization(), 0.0);
    }

    #[test]
    fn from_capacity_sizing() {
        // Llama3.1-70B fp8 KV: 163840 B/token; 20 GB budget, 16-token blocks.
        let a = BlockAllocator::from_capacity(20e9, 163_840, 16).unwrap();
        assert_eq!(a.total_blocks, (20e9 / (163_840.0 * 16.0)) as usize);
        // matches Table 6: batch 16 × 8192 ≈ 131k tokens needs 8192 blocks.
        assert!(a.total_blocks > 7000);
    }

    #[test]
    fn from_capacity_rejects_degenerate_geometry() {
        assert!(BlockAllocator::from_capacity(20e9, 0, 16).is_err());
        assert!(BlockAllocator::from_capacity(20e9, 163_840, 0).is_err());
        assert!(BlockAllocator::from_capacity(f64::NAN, 163_840, 16).is_err());
        assert!(BlockAllocator::from_capacity(-1.0, 163_840, 16).is_err());
        // Budget smaller than a single block: error, not a 0-block allocator.
        let e = BlockAllocator::from_capacity(1000.0, 163_840, 16).unwrap_err();
        assert!(format!("{e:#}").contains("does not fit"), "{e:#}");
    }

    #[test]
    fn slot_lifecycle() {
        let mut s = KvStore::new(2, 3, 8, 2, 4);
        let a = s.alloc_slot().unwrap();
        let b = s.alloc_slot().unwrap();
        assert_ne!(a, b);
        assert_eq!(s.active_slots(), vec![a, b]);
        s.free_slot(a);
        assert_eq!(s.active_slots(), vec![b]);
        let c = s.alloc_slot().unwrap();
        assert_eq!(c, a); // reuses freed slot
    }

    #[test]
    fn write_gather_scatter_roundtrip() {
        let (l, slots, t, kvh, hd) = (2, 4, 8, 2, 4);
        let mut s = KvStore::new(l, slots, t, kvh, hd);
        let slot = s.alloc_slot().unwrap();
        let ss = t * kvh * hd;
        let k_out: Vec<f32> = (0..l * ss).map(|i| i as f32).collect();
        let v_out: Vec<f32> = (0..l * ss).map(|i| -(i as f32)).collect();
        s.write_slot(slot, &k_out, &v_out, 5);
        assert_eq!(s.len(slot), Some(5));
        let (k, v, lens) = s.gather_batch(&[slot]);
        assert_eq!(k, k_out);
        assert_eq!(v, v_out);
        assert_eq!(lens, vec![5]);
        // scatter back modified data and check the bump.
        let k2: Vec<f32> = k.iter().map(|x| x + 1.0).collect();
        s.scatter_batch(&[slot], &k2, &v);
        assert_eq!(s.len(slot), Some(6));
        let (k3, _, _) = s.gather_batch(&[slot]);
        assert_eq!(k3, k2);
    }

    #[test]
    fn gather_multi_slot_interleaves_layers() {
        let (l, slots, t, kvh, hd) = (2, 4, 2, 1, 1);
        let mut s = KvStore::new(l, slots, t, kvh, hd);
        let a = s.alloc_slot().unwrap();
        let b = s.alloc_slot().unwrap();
        let ss = t * kvh * hd;
        s.write_slot(a, &vec![1.0; l * ss], &vec![1.5; l * ss], 1);
        s.write_slot(b, &vec![2.0; l * ss], &vec![2.5; l * ss], 2);
        let (k, _v, lens) = s.gather_batch(&[a, b]);
        // layout (L, B, T*, ...): layer0 = [a..., b...], layer1 = [a..., b...]
        assert_eq!(k, vec![1.0, 1.0, 2.0, 2.0, 1.0, 1.0, 2.0, 2.0]);
        assert_eq!(lens, vec![1, 2]);
    }

    #[test]
    fn freed_slot_is_zeroed() {
        let mut s = KvStore::new(1, 1, 2, 1, 1);
        let slot = s.alloc_slot().unwrap();
        s.write_slot(slot, &[9.0, 9.0], &[9.0, 9.0], 2);
        s.free_slot(slot);
        let slot = s.alloc_slot().unwrap();
        let (k, v, lens) = s.gather_batch(&[slot]);
        assert_eq!(k, vec![0.0, 0.0]);
        assert_eq!(v, vec![0.0, 0.0]);
        assert_eq!(lens, vec![0]);
    }
}
