//! KV-cache management: the paged physical block pool behind the host KV
//! store (vLLM-style paged attention at the byte level), the block-table
//! [`KvStore`] the engine streams in/out of the decode artifacts, and the
//! bookkeeping [`BlockAllocator`] the admission model uses (Table 6's OOM
//! frontier).
//!
//! # The paged layout
//!
//! All KV bytes live in one [`BlockPool`] of fixed 16-token blocks
//! ([`crate::quant::KV_BLOCK_TOKENS`]); a sequence is a *block table* — an
//! ordered list of physical block IDs — plus a valid length. Blocks are
//! refcounted, so two sequences (or a sequence and the radix
//! [`super::prefix::PrefixCache`]) can map the **same** physical block: a
//! shared 6144-token prefix costs its bytes once, no matter how many
//! concurrent requests read it. Writes never touch a block another reader
//! can still see — [`KvStore::append_token`] copy-on-writes the partially
//! filled tail block when it is shared.
//!
//! # The `KvLayout` accounting contract
//!
//! Every component that answers "what does a KV token cost?" derives the
//! rate from one shared [`KvLayout`] (dtype + model geometry):
//!
//! * [`BlockAllocator::from_layout`] — admission control sizes its block
//!   pool from `layout.bytes_per_token()`;
//! * `gaudisim::MemoryModel` — the Table 6 OOM frontier charges the same
//!   rate (FP8 KV by default, as in the paper), block-quantized for the
//!   shared-prefix variants;
//! * `router::SimReplica` — fleet admission budgets HBM minus FP8 weights
//!   at the same rate;
//! * [`KvStore`] — the host store's actual allocation is exactly
//!   `pool blocks × layout.block_bytes(block_tokens)`.
//!
//! FP8 KV stores one f32 max-abs scale per (block, layer, kv-head) group
//! for each of K and V. That metadata is per-*block* (< 1% of a block's
//! payload at any realistic geometry, `layout.scale_bytes_per_block()`)
//! and is charged against the fixed workspace reserve so the per-token
//! rate — and with it the Table 6 frontier — stays exact.
//!
//! # The paged read/write contract (ISSUE 5)
//!
//! The decode hot path is **block-table-native**:
//!
//! * Reads go through a [`PagedAttentionView`]: per-slot `&[BlockId]`
//!   tables plus per-block FP8 scale refs, dequantized on read at block
//!   granularity ([`BlockPool::read_block_head`] decodes one 16-token
//!   block tile — the SRAM-resident working set of a real paged kernel).
//!   There is **no** dense `(L, B, T, …)` staging, no zero-fill, and no
//!   bucket padding: a step reads exactly each slot's live block bytes,
//!   which [`BlockPool::bytes_read`] instruments so tests can assert it.
//! * Writes go through [`KvStore::append_token`]: one token is quantized
//!   into the hot block (copy-on-write first if that block is still
//!   readable elsewhere), replacing the full dense scatter.
//!
//! # The single read entry point (ISSUE 8)
//!
//! All paged reads funnel through **one** public API:
//! [`PagedAttentionView::attend_into`], which takes a batch of
//! [`AttendTask`]s (independent (slot, layer, kv-head) online-softmax
//! readouts) plus an [`AttendOptions`] selecting the worker count
//! ([`Parallelism`]) and the dequant kernel ([`Dequant`]). Tasks run
//! data-parallel on the scoped [`crate::util::pool`] workers; per-task
//! tiles reduce in block-table order, so output is bit-identical for
//! every worker count. [`PagedAttentionView::attend`] is a thin one-task
//! convenience wrapper and [`KvStore::decode_attention_probe`] is built
//! on the same entry point — future kernel variants (SIMD, PJRT) slot in
//! behind this one signature.
//!
//! The pre-paged dense staging (`gather_batch` / `gather_batch_into` /
//! `scatter_batch`) survives only behind the `dense-decode-ref` cargo
//! feature as the reference implementation for roundtrip/property tests;
//! the default public `KvStore` surface is paged-only.

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Result};

use crate::fp8::bf16::{bf16_to_f32, f32_to_bf16};
use crate::fp8::{decode, decode_table, encode_rne, CastMode, DecodeTable, Fp8Format};
use crate::quant::{
    weight_scale_per_tensor, KvDtype, KvLayout, FP8_SCALE_GROUP_BYTES, KV_BLOCK_TOKENS,
};
use crate::util::pool::{self, Parallelism};
use crate::util::rng::XorShiftRng;

/// Page-granular KV accounting (vLLM-style). Used for admission control and
/// by the gaudisim capacity experiments; pure bookkeeping, no data — the
/// data-carrying twin is [`BlockPool`].
#[derive(Clone, Debug)]
pub struct BlockAllocator {
    pub block_tokens: usize,
    pub total_blocks: usize,
    free_blocks: usize,
}

impl BlockAllocator {
    pub fn new(total_blocks: usize, block_tokens: usize) -> Self {
        Self {
            block_tokens,
            total_blocks,
            free_blocks: total_blocks,
        }
    }

    /// Capacity sized from device HBM: bytes available for KV / bytes per
    /// block. Degenerate geometry (zero-sized blocks, non-finite or
    /// too-small budgets) is an error — a 0-block allocator would silently
    /// reject every request.
    pub fn from_capacity(
        kv_bytes_budget: f64,
        bytes_per_token: usize,
        block_tokens: usize,
    ) -> Result<Self> {
        if bytes_per_token == 0 || block_tokens == 0 {
            bail!(
                "degenerate KV block geometry: bytes_per_token={bytes_per_token}, \
                 block_tokens={block_tokens} (both must be > 0)"
            );
        }
        if !kv_bytes_budget.is_finite() || kv_bytes_budget < 0.0 {
            bail!("invalid KV byte budget {kv_bytes_budget}");
        }
        let block_bytes = (bytes_per_token * block_tokens) as f64;
        let blocks = (kv_bytes_budget / block_bytes).floor() as usize;
        if blocks == 0 {
            bail!(
                "KV budget {kv_bytes_budget:.0} B below one {block_bytes:.0}-B block \
                 ({block_tokens} tokens × {bytes_per_token} B/token) — model does not fit"
            );
        }
        Ok(Self::new(blocks, block_tokens))
    }

    /// Capacity sized from the shared accounting contract: bytes/token
    /// comes from the [`KvLayout`], the single source of truth also used
    /// by `MemoryModel` and `SimReplica`.
    pub fn from_layout(
        kv_bytes_budget: f64,
        layout: &KvLayout,
        block_tokens: usize,
    ) -> Result<Self> {
        Self::from_capacity(kv_bytes_budget, layout.bytes_per_token(), block_tokens)
    }

    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    pub fn free_blocks(&self) -> usize {
        self.free_blocks
    }

    pub fn can_allocate(&self, tokens: usize) -> bool {
        self.blocks_for(tokens) <= self.free_blocks
    }

    pub fn can_allocate_blocks(&self, blocks: usize) -> bool {
        blocks <= self.free_blocks
    }

    /// Allocate an exact block count (the prefix cache shares the pool at
    /// block granularity, so token-rounding must happen exactly once, at
    /// the caller).
    pub fn allocate_blocks(&mut self, blocks: usize) -> Result<()> {
        if blocks > self.free_blocks {
            bail!(
                "KV OOM: need {blocks} blocks, {} free of {}",
                self.free_blocks,
                self.total_blocks
            );
        }
        self.free_blocks -= blocks;
        Ok(())
    }

    pub fn allocate(&mut self, tokens: usize) -> Result<usize> {
        let need = self.blocks_for(tokens);
        if need > self.free_blocks {
            bail!(
                "KV OOM: need {need} blocks, {} free of {}",
                self.free_blocks,
                self.total_blocks
            );
        }
        self.free_blocks -= need;
        Ok(need)
    }

    /// Checked release: freeing more blocks than are outstanding is a
    /// double-release accounting bug, not a condition to clamp over —
    /// clamping would hide the corruption until admission over-commits.
    pub fn release(&mut self, blocks: usize) -> Result<()> {
        if self.free_blocks + blocks > self.total_blocks {
            bail!(
                "KV block over-release: freeing {blocks} with {} free of {} \
                 (double release?)",
                self.free_blocks,
                self.total_blocks
            );
        }
        self.free_blocks += blocks;
        Ok(())
    }

    pub fn utilization(&self) -> f64 {
        1.0 - self.free_blocks as f64 / self.total_blocks.max(1) as f64
    }
}

/// Identifier of one physical block in a [`BlockPool`].
pub type BlockId = usize;

/// Dtype-specific backing storage: raw values (F32/BF16) or FP8 codes plus
/// per-(block, layer, kv-head) max-abs scales, K and V scaled
/// independently. FP8 dequant indexes the process-wide
/// [`crate::fp8::decode_table`] LUT — pools no longer carry a private
/// table copy.
enum KvData {
    F32 {
        k: Vec<f32>,
        v: Vec<f32>,
    },
    Bf16 {
        k: Vec<u16>,
        v: Vec<u16>,
    },
    Fp8 {
        format: Fp8Format,
        k: Vec<u8>,
        v: Vec<u8>,
        /// One scale per (block, layer, kv-head), row-major in that order;
        /// freed blocks reset to 1.0.
        k_scale: Vec<f32>,
        v_scale: Vec<f32>,
    },
}

/// Quantize one (T, Hkv, D) region with a fresh max-abs scale per kv-head.
/// The scale is `maxabs / r_q` (sanitized to 1.0 for all-zero groups), so
/// the group's max lands exactly on the largest representable magnitude.
///
/// Only positions `< valid_t` are scanned and encoded; the tail is zeroed.
/// Prefill artifacts hand over bucket-padded buffers whose positions past
/// the prompt hold real (pad-token) activations — attention masks them,
/// but letting them into the max-abs would coarsen the valid tokens' grid.
#[allow(clippy::too_many_arguments)]
fn encode_region_fp8(
    src: &[f32],
    dst: &mut [u8],
    scales: &mut [f32],
    valid_t: usize,
    t: usize,
    kv_heads: usize,
    head_dim: usize,
    format: Fp8Format,
) {
    for h in 0..kv_heads {
        let mut maxabs = 0.0f32;
        for ti in 0..valid_t {
            let base = (ti * kv_heads + h) * head_dim;
            for d in 0..head_dim {
                maxabs = maxabs.max(src[base + d].abs());
            }
        }
        // Clamp to the f32 normal range: a deep-subnormal group max would
        // otherwise yield a scale whose reciprocal overflows to infinity
        // and poisons the codes with NaN.
        let s = weight_scale_per_tensor(maxabs, format).max(f32::MIN_POSITIVE);
        scales[h] = s;
        let inv = 1.0 / s;
        for ti in 0..valid_t {
            let base = (ti * kv_heads + h) * head_dim;
            for d in 0..head_dim {
                dst[base + d] = encode_rne(src[base + d] * inv, format, CastMode::SatFinite);
            }
        }
        for ti in valid_t..t {
            let base = (ti * kv_heads + h) * head_dim;
            dst[base..base + head_dim].fill(0);
        }
    }
}

/// Dequantize one (T, Hkv, D) region using the per-head scales.
fn decode_region_fp8(
    src: &[u8],
    dst: &mut [f32],
    scales: &[f32],
    table: &DecodeTable,
    t: usize,
    kv_heads: usize,
    head_dim: usize,
) {
    for h in 0..kv_heads {
        let s = scales[h];
        for ti in 0..t {
            let base = (ti * kv_heads + h) * head_dim;
            for d in 0..head_dim {
                dst[base + d] = table.get(src[base + d]) * s;
            }
        }
    }
}

/// The single physical KV block pool: `total_blocks` refcounted blocks of
/// `block_tokens` tokens each, every block holding all layers' K and V for
/// its token span — layout `(block, layer, token, kv_head, head_dim)` —
/// in the pool's [`KvDtype`] (FP8 adds per-(block, layer, kv-head) scales).
///
/// The free list *is* the allocator: a block leaves it on [`Self::alloc`]
/// (refcount 1), gains readers via [`Self::retain`], and returns —
/// zeroed, scales reset — when [`Self::release`] drops the last reference.
/// Sharing a prefix is `retain`; nothing is ever copied until a writer
/// needs a block someone else can still read.
pub struct BlockPool {
    block_tokens: usize,
    layers: usize,
    kv_heads: usize,
    head_dim: usize,
    total_blocks: usize,
    data: KvData,
    refs: Vec<u32>,
    free: Vec<BlockId>,
    /// Physical bytes dequantized through the paged read path
    /// ([`Self::read_block_head`]) since the last reset — the
    /// instrumentation behind the "a decode step reads exactly the live
    /// block bytes" contract. Dense reference gathers are deliberately
    /// *not* counted: the counter measures the paged path alone.
    /// Atomic (relaxed) so the scoped attend workers can charge it
    /// concurrently: each tile read adds one exact integer, and integer
    /// addition is order-independent, so the total is byte-exact for
    /// every worker count.
    bytes_read: AtomicU64,
    /// Copy-on-write clones performed ([`Self::clone_block`]) over the
    /// pool's lifetime — the telemetry behind `CowCopy` trace events.
    cow_clones: u64,
}

impl BlockPool {
    pub fn new(
        total_blocks: usize,
        block_tokens: usize,
        layers: usize,
        kv_heads: usize,
        head_dim: usize,
        dtype: KvDtype,
    ) -> Self {
        assert!(block_tokens > 0, "degenerate block geometry");
        let n = total_blocks * layers * block_tokens * kv_heads * head_dim;
        let data = match dtype {
            KvDtype::F32 => KvData::F32 {
                k: vec![0.0; n],
                v: vec![0.0; n],
            },
            KvDtype::Bf16 => KvData::Bf16 {
                k: vec![0; n],
                v: vec![0; n],
            },
            KvDtype::Fp8(format) => KvData::Fp8 {
                format,
                k: vec![0; n],
                v: vec![0; n],
                k_scale: vec![1.0; total_blocks * layers * kv_heads],
                v_scale: vec![1.0; total_blocks * layers * kv_heads],
            },
        };
        Self {
            block_tokens,
            layers,
            kv_heads,
            head_dim,
            total_blocks,
            data,
            refs: vec![0; total_blocks],
            // Reversed so the first alloc hands out block 0 — deterministic
            // IDs make failures readable.
            free: (0..total_blocks).rev().collect(),
            bytes_read: AtomicU64::new(0),
            cow_clones: 0,
        }
    }

    pub fn dtype(&self) -> KvDtype {
        match &self.data {
            KvData::F32 { .. } => KvDtype::F32,
            KvData::Bf16 { .. } => KvDtype::Bf16,
            KvData::Fp8 { format, .. } => KvDtype::Fp8(*format),
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn total_blocks(&self) -> usize {
        self.total_blocks
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Physically resident (allocated) blocks.
    pub fn used_blocks(&self) -> usize {
        self.total_blocks - self.free.len()
    }

    /// Current reference count of `id` (0 = on the free list).
    pub fn ref_count(&self, id: BlockId) -> u32 {
        self.refs[id]
    }

    fn row(&self) -> usize {
        self.kv_heads * self.head_dim
    }

    /// Take a block off the free list with refcount 1. `None` = pool
    /// exhausted (callers that provisioned `slots + cache` blocks can
    /// never see this).
    pub fn alloc(&mut self) -> Option<BlockId> {
        let id = self.free.pop()?;
        debug_assert_eq!(self.refs[id], 0, "free-listed block with live refs");
        self.refs[id] = 1;
        self.audit();
        Some(id)
    }

    /// Add a reader to a live block (prefix sharing / block-table mapping).
    pub fn retain(&mut self, id: BlockId) {
        assert!(self.refs[id] > 0, "retain of a free block {id}");
        self.refs[id] += 1;
        self.audit();
    }

    /// Drop one reference; the last drop zeroes the block (codes *and*
    /// scales — stale keys must never leak into a new occupant) and
    /// returns it to the free list.
    pub fn release(&mut self, id: BlockId) {
        assert!(self.refs[id] > 0, "release of a free block {id} (double free?)");
        self.refs[id] -= 1;
        if self.refs[id] == 0 {
            self.zero_block(id);
            self.free.push(id);
        }
        self.audit();
    }

    /// Structural invariant auditor behind the `debug-invariants` feature:
    /// every mutating pool operation (alloc / retain / release / CoW clone)
    /// calls this on exit. Checks, in O(total_blocks):
    ///
    /// 1. **Refcount balance** — every free-listed block has refcount 0 and
    ///    appears on the free list exactly once;
    /// 2. **Capacity partition** — live blocks (refs > 0) and free-listed
    ///    blocks partition the pool: `used + free == total_blocks`, no
    ///    block leaked or double-counted.
    ///
    /// Compiled to a no-op unless the feature is on; even then it only
    /// fires under `debug_assertions` so `--release` bench numbers are
    /// never distorted by the sweep.
    #[cfg(feature = "debug-invariants")]
    pub fn audit(&self) {
        if !cfg!(debug_assertions) {
            return;
        }
        let mut on_free_list = vec![false; self.total_blocks];
        for &id in &self.free {
            assert!(
                !on_free_list[id],
                "pool audit: block {id} appears on the free list twice"
            );
            on_free_list[id] = true;
            assert_eq!(
                self.refs[id], 0,
                "pool audit: free-listed block {id} has live refs"
            );
        }
        let live = self.refs.iter().filter(|&&r| r > 0).count();
        assert_eq!(
            live + self.free.len(),
            self.total_blocks,
            "pool audit: live + free blocks must partition the pool \
             (leaked or double-counted block)"
        );
        for (id, &r) in self.refs.iter().enumerate() {
            assert!(
                r > 0 || on_free_list[id],
                "pool audit: block {id} has refcount 0 but is not free-listed"
            );
        }
    }

    /// No-op twin: without the `debug-invariants` feature the auditor
    /// compiles away entirely.
    #[cfg(not(feature = "debug-invariants"))]
    #[inline(always)]
    pub fn audit(&self) {}

    fn zero_block(&mut self, id: BlockId) {
        let per_block = self.layers * self.block_tokens * self.row();
        let base = id * per_block;
        let (layers, kv_heads) = (self.layers, self.kv_heads);
        match &mut self.data {
            KvData::F32 { k, v } => {
                k[base..base + per_block].fill(0.0);
                v[base..base + per_block].fill(0.0);
            }
            KvData::Bf16 { k, v } => {
                k[base..base + per_block].fill(0);
                v[base..base + per_block].fill(0);
            }
            KvData::Fp8 {
                k, v, k_scale, v_scale, ..
            } => {
                k[base..base + per_block].fill(0);
                v[base..base + per_block].fill(0);
                let si = id * layers * kv_heads;
                k_scale[si..si + layers * kv_heads].fill(1.0);
                v_scale[si..si + layers * kv_heads].fill(1.0);
            }
        }
    }

    /// Dequantize tokens `[0, count)` of block `id` into a strided f32
    /// destination: element `(l, tok)` lands at
    /// `base + l·layer_stride + (tok0 + tok)·row`. Covers both the
    /// `(L, T, Hkv, D)` single-slot layout (`layer_stride = T·row`) and
    /// the `(L, B, T, Hkv, D)` batch layout (`layer_stride = B·T·row`,
    /// `base = bi·T·row`).
    #[allow(clippy::too_many_arguments)]
    pub fn gather_into(
        &self,
        id: BlockId,
        k_out: &mut [f32],
        v_out: &mut [f32],
        base: usize,
        layer_stride: usize,
        tok0: usize,
        count: usize,
    ) {
        let row = self.row();
        let bt = self.block_tokens;
        assert!(count <= bt, "block span overflow");
        for l in 0..self.layers {
            let src = (id * self.layers + l) * bt * row;
            let dst = base + l * layer_stride + tok0 * row;
            let n = count * row;
            match &self.data {
                KvData::F32 { k, v } => {
                    k_out[dst..dst + n].copy_from_slice(&k[src..src + n]);
                    v_out[dst..dst + n].copy_from_slice(&v[src..src + n]);
                }
                KvData::Bf16 { k, v } => {
                    for i in 0..n {
                        k_out[dst + i] = bf16_to_f32(k[src + i]);
                        v_out[dst + i] = bf16_to_f32(v[src + i]);
                    }
                }
                KvData::Fp8 {
                    format,
                    k,
                    v,
                    k_scale,
                    v_scale,
                } => {
                    let table = decode_table(*format);
                    let si = (id * self.layers + l) * self.kv_heads;
                    decode_region_fp8(
                        &k[src..src + n],
                        &mut k_out[dst..dst + n],
                        &k_scale[si..si + self.kv_heads],
                        table,
                        count,
                        self.kv_heads,
                        self.head_dim,
                    );
                    decode_region_fp8(
                        &v[src..src + n],
                        &mut v_out[dst..dst + n],
                        &v_scale[si..si + self.kv_heads],
                        table,
                        count,
                        self.kv_heads,
                        self.head_dim,
                    );
                }
            }
        }
    }

    /// Quantize tokens `[tok0, tok0 + valid)` of a strided f32 source into
    /// block positions `[0, valid)`, zeroing the block's tail. Source
    /// addressing mirrors [`Self::gather_into`]. FP8 recomputes the
    /// block's per-(layer, kv-head) scales from exactly the `valid` tokens
    /// — pad garbage can never coarsen a block's grid.
    #[allow(clippy::too_many_arguments)]
    pub fn scatter_from(
        &mut self,
        id: BlockId,
        k_in: &[f32],
        v_in: &[f32],
        base: usize,
        layer_stride: usize,
        tok0: usize,
        valid: usize,
    ) {
        let row = self.row();
        let bt = self.block_tokens;
        assert!(valid <= bt, "block span overflow");
        let (layers, kv_heads, head_dim) = (self.layers, self.kv_heads, self.head_dim);
        for l in 0..layers {
            let dst = (id * layers + l) * bt * row;
            let src = base + l * layer_stride + tok0 * row;
            let n = valid * row;
            match &mut self.data {
                KvData::F32 { k, v } => {
                    k[dst..dst + n].copy_from_slice(&k_in[src..src + n]);
                    v[dst..dst + n].copy_from_slice(&v_in[src..src + n]);
                    k[dst + n..dst + bt * row].fill(0.0);
                    v[dst + n..dst + bt * row].fill(0.0);
                }
                KvData::Bf16 { k, v } => {
                    for i in 0..n {
                        k[dst + i] = f32_to_bf16(k_in[src + i]);
                        v[dst + i] = f32_to_bf16(v_in[src + i]);
                    }
                    k[dst + n..dst + bt * row].fill(0);
                    v[dst + n..dst + bt * row].fill(0);
                }
                KvData::Fp8 {
                    format,
                    k,
                    v,
                    k_scale,
                    v_scale,
                    ..
                } => {
                    let si = (id * layers + l) * kv_heads;
                    encode_region_fp8(
                        &k_in[src..src + n],
                        &mut k[dst..dst + bt * row],
                        &mut k_scale[si..si + kv_heads],
                        valid,
                        bt,
                        kv_heads,
                        head_dim,
                        *format,
                    );
                    encode_region_fp8(
                        &v_in[src..src + n],
                        &mut v[dst..dst + bt * row],
                        &mut v_scale[si..si + kv_heads],
                        valid,
                        bt,
                        kv_heads,
                        head_dim,
                        *format,
                    );
                }
            }
        }
    }

    /// Physical bytes dequantized through the paged read path since the
    /// last [`Self::reset_bytes_read`].
    pub fn bytes_read(&self) -> u64 {
        self.bytes_read.load(Ordering::Relaxed)
    }

    pub fn reset_bytes_read(&self) {
        self.bytes_read.store(0, Ordering::Relaxed);
    }

    /// Copy-on-write clones performed over the pool's lifetime.
    pub fn cow_clones(&self) -> u64 {
        self.cow_clones
    }

    /// Bytes one [`Self::read_block_head`] call moves: the (layer, kv-head)
    /// share of a block's K+V payload plus, under FP8, its two f32 scales.
    /// Summed over all (layer, kv-head) pairs and a sequence's live blocks
    /// this is exactly `KvLayout::block_bytes(block_tokens)` per block —
    /// the same rate every capacity consumer charges.
    fn block_read_bytes_per_head(&self) -> usize {
        let payload = 2 * self.block_tokens * self.head_dim * self.dtype().elem_bytes();
        let scales = match &self.data {
            KvData::Fp8 { .. } => FP8_SCALE_GROUP_BYTES,
            _ => 0,
        };
        payload + scales
    }

    /// Allocate a private copy of a live block: payload *and* scales are
    /// duplicated. The copy-on-write primitive behind
    /// [`KvStore::append_token`] — unlike the dense scatter (which rewrites
    /// the whole valid span from its batch buffer and can skip the copy),
    /// a single-token append must preserve the shared block's history.
    pub fn clone_block(&mut self, src: BlockId) -> Option<BlockId> {
        assert!(self.refs[src] > 0, "clone of a free block {src}");
        let dst = self.alloc()?;
        let per_block = self.layers * self.block_tokens * self.row();
        let (sb, db) = (src * per_block, dst * per_block);
        let groups = self.layers * self.kv_heads;
        match &mut self.data {
            KvData::F32 { k, v } => {
                k.copy_within(sb..sb + per_block, db);
                v.copy_within(sb..sb + per_block, db);
            }
            KvData::Bf16 { k, v } => {
                k.copy_within(sb..sb + per_block, db);
                v.copy_within(sb..sb + per_block, db);
            }
            KvData::Fp8 {
                k, v, k_scale, v_scale, ..
            } => {
                k.copy_within(sb..sb + per_block, db);
                v.copy_within(sb..sb + per_block, db);
                let (ss, ds) = (src * groups, dst * groups);
                k_scale.copy_within(ss..ss + groups, ds);
                v_scale.copy_within(ss..ss + groups, ds);
            }
        }
        self.cow_clones += 1;
        self.audit();
        Some(dst)
    }

    /// Lift an exclusively-owned block off the device pool (ISSUE 9): the
    /// raw stored payload — FP8 **codes**, never dequantized — plus, under
    /// FP8, the block's per-(layer, kv-head) scales move together into the
    /// returned [`SwappedBlock`], and the block is released back to the
    /// free list. Swap-out of a shared block is a bug: other readers would
    /// see it zeroed (the host tier keeps shared blocks resident instead).
    pub fn swap_out_block(&mut self, id: BlockId) -> SwappedBlock {
        assert_eq!(self.refs[id], 1, "swap-out of a shared or free block {id}");
        let per_block = self.layers * self.block_tokens * self.row();
        let base = id * per_block;
        let groups = self.layers * self.kv_heads;
        let data = match &self.data {
            KvData::F32 { k, v } => SwappedData::F32 {
                k: k[base..base + per_block].to_vec(),
                v: v[base..base + per_block].to_vec(),
            },
            KvData::Bf16 { k, v } => SwappedData::Bf16 {
                k: k[base..base + per_block].to_vec(),
                v: v[base..base + per_block].to_vec(),
            },
            KvData::Fp8 {
                k, v, k_scale, v_scale, ..
            } => {
                let sb = id * groups;
                SwappedData::Fp8 {
                    k: k[base..base + per_block].to_vec(),
                    v: v[base..base + per_block].to_vec(),
                    k_scale: k_scale[sb..sb + groups].to_vec(),
                    v_scale: v_scale[sb..sb + groups].to_vec(),
                }
            }
        };
        self.release(id);
        SwappedBlock { data }
    }

    /// Restore a swapped-out block into a freshly allocated pool block,
    /// **bit-identically**: the codes (and FP8 scales) land exactly as
    /// they were lifted — no re-quantization, so a swap-out/swap-in cycle
    /// is lossless by construction. `None` when the pool is exhausted (the
    /// caller checks [`Self::free_blocks`] before committing a swap-in).
    pub fn swap_in_block(&mut self, swapped: &SwappedBlock) -> Option<BlockId> {
        let id = self.alloc()?;
        let per_block = self.layers * self.block_tokens * self.row();
        let base = id * per_block;
        let groups = self.layers * self.kv_heads;
        match (&mut self.data, &swapped.data) {
            (KvData::F32 { k, v }, SwappedData::F32 { k: sk, v: sv }) => {
                assert_eq!(sk.len(), per_block, "swapped block from another geometry");
                k[base..base + per_block].copy_from_slice(sk);
                v[base..base + per_block].copy_from_slice(sv);
            }
            (KvData::Bf16 { k, v }, SwappedData::Bf16 { k: sk, v: sv }) => {
                assert_eq!(sk.len(), per_block, "swapped block from another geometry");
                k[base..base + per_block].copy_from_slice(sk);
                v[base..base + per_block].copy_from_slice(sv);
            }
            (
                KvData::Fp8 {
                    k, v, k_scale, v_scale, ..
                },
                SwappedData::Fp8 {
                    k: sk,
                    v: sv,
                    k_scale: sks,
                    v_scale: svs,
                },
            ) => {
                assert_eq!(sk.len(), per_block, "swapped block from another geometry");
                assert_eq!(sks.len(), groups, "swapped scales from another geometry");
                k[base..base + per_block].copy_from_slice(sk);
                v[base..base + per_block].copy_from_slice(sv);
                let s0 = id * groups;
                k_scale[s0..s0 + groups].copy_from_slice(sks);
                v_scale[s0..s0 + groups].copy_from_slice(svs);
            }
            // lint:allow(no-unwrap-in-lib): dtype mismatch between a swap record and its pool is a wiring bug, not a runtime condition
            _ => panic!("swapped block dtype does not match the pool"),
        }
        self.audit();
        Some(id)
    }

    /// Per-block FP8 scale refs for one layer of block `id` (kv_heads-long
    /// K and V slices), `None` for scale-free dtypes. This is the scale
    /// metadata a paged kernel loads alongside each block's codes.
    pub fn block_scales(&self, id: BlockId, layer: usize) -> Option<(&[f32], &[f32])> {
        match &self.data {
            KvData::Fp8 {
                k_scale, v_scale, ..
            } => {
                let si = (id * self.layers + layer) * self.kv_heads;
                Some((
                    &k_scale[si..si + self.kv_heads],
                    &v_scale[si..si + self.kv_heads],
                ))
            }
            _ => None,
        }
    }

    /// Dequantize one (layer, kv-head) tile of block `id` — all
    /// `block_tokens` positions × `head_dim` — into `k_out`/`v_out`
    /// (row-major `(token, dim)`). This is the paged kernel's unit of HBM
    /// traffic: a whole block streams regardless of how many of its
    /// positions are valid (the caller masks scores past the sequence
    /// length), which is why [`Self::bytes_read`] charges full blocks.
    /// Uses the LUT dequant kernel; [`Self::read_block_head_with`] selects.
    // lint: hot-path
    pub fn read_block_head(
        &self,
        id: BlockId,
        layer: usize,
        kv_head: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) {
        self.read_block_head_with(id, layer, kv_head, k_out, v_out, Dequant::Lut);
    }

    /// [`Self::read_block_head`] with an explicit dequant kernel. Both
    /// kernels produce bit-identical tiles (the LUT is the exact decode
    /// table); [`Dequant::Scalar`] re-derives every element through the
    /// exponent-math [`decode`] and exists as the honest pre-LUT baseline
    /// the speedup benches compare against.
    // lint: hot-path
    pub fn read_block_head_with(
        &self,
        id: BlockId,
        layer: usize,
        kv_head: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
        dequant: Dequant,
    ) {
        let bt = self.block_tokens;
        let d = self.head_dim;
        let row = self.row();
        assert!(k_out.len() >= bt * d, "k tile too small");
        assert!(v_out.len() >= bt * d, "v tile too small");
        let base = (id * self.layers + layer) * bt * row + kv_head * d;
        match &self.data {
            KvData::F32 { k, v } => {
                for ti in 0..bt {
                    let s = base + ti * row;
                    let o = ti * d;
                    k_out[o..o + d].copy_from_slice(&k[s..s + d]);
                    v_out[o..o + d].copy_from_slice(&v[s..s + d]);
                }
            }
            KvData::Bf16 { k, v } => {
                for ti in 0..bt {
                    let s = base + ti * row;
                    let o = ti * d;
                    for (dst, &src) in k_out[o..o + d].iter_mut().zip(&k[s..s + d]) {
                        *dst = bf16_to_f32(src);
                    }
                    for (dst, &src) in v_out[o..o + d].iter_mut().zip(&v[s..s + d]) {
                        *dst = bf16_to_f32(src);
                    }
                }
            }
            KvData::Fp8 {
                format,
                k,
                v,
                k_scale,
                v_scale,
            } => {
                let si = (id * self.layers + layer) * self.kv_heads + kv_head;
                let (ks, vs) = (k_scale[si], v_scale[si]);
                match dequant {
                    Dequant::Lut => {
                        // Fold the tile's scale into a stack-resident
                        // pre-scaled copy of the shared 256-entry LUT —
                        // one scale multiply per code per tile instead of
                        // one per element — then every element is a single
                        // indexed load. Bit-identical to `table[c] * s`
                        // computed per element: same operands, same
                        // multiply.
                        let table = &decode_table(*format).values;
                        let mut kl = [0.0f32; 256];
                        let mut vl = [0.0f32; 256];
                        for ((kd, vd), &t) in kl.iter_mut().zip(vl.iter_mut()).zip(table.iter()) {
                            *kd = t * ks;
                            *vd = t * vs;
                        }
                        for ti in 0..bt {
                            let s = base + ti * row;
                            let o = ti * d;
                            for (dst, &code) in k_out[o..o + d].iter_mut().zip(&k[s..s + d]) {
                                *dst = kl[code as usize];
                            }
                            for (dst, &code) in v_out[o..o + d].iter_mut().zip(&v[s..s + d]) {
                                *dst = vl[code as usize];
                            }
                        }
                    }
                    Dequant::Scalar => {
                        for ti in 0..bt {
                            let s = base + ti * row;
                            let o = ti * d;
                            for (dst, &code) in k_out[o..o + d].iter_mut().zip(&k[s..s + d]) {
                                *dst = decode(code, *format) * ks;
                            }
                            for (dst, &code) in v_out[o..o + d].iter_mut().zip(&v[s..s + d]) {
                                *dst = decode(code, *format) * vs;
                            }
                        }
                    }
                }
            }
        }
        self.bytes_read
            .fetch_add(self.block_read_bytes_per_head() as u64, Ordering::Relaxed);
    }

    /// Write one token's (L, Hkv, D) K/V rows at block position `tok`,
    /// quantizing to the pool dtype. FP8 re-encodes the block's valid span
    /// `[0, tok]` from its *dequantized* history plus the new row, with
    /// fresh per-(layer, kv-head) scales — exactly the arithmetic the dense
    /// reference performs when it rewrites the hot block from a gathered
    /// (dequantized) batch buffer, so both write paths store identical
    /// bytes. The caller must hold the block exclusively (refcount 1).
    pub fn append_token(&mut self, id: BlockId, tok: usize, k_row: &[f32], v_row: &[f32]) {
        let bt = self.block_tokens;
        let row = self.row();
        assert!(tok < bt, "append past block capacity");
        assert_eq!(k_row.len(), self.layers * row, "append k row size");
        assert_eq!(v_row.len(), self.layers * row, "append v row size");
        debug_assert_eq!(self.refs[id], 1, "append into a shared or free block");
        let (layers, kv_heads, head_dim) = (self.layers, self.kv_heads, self.head_dim);
        match &mut self.data {
            KvData::F32 { k, v } => {
                for l in 0..layers {
                    let dst = (id * layers + l) * bt * row + tok * row;
                    let src = l * row;
                    k[dst..dst + row].copy_from_slice(&k_row[src..src + row]);
                    v[dst..dst + row].copy_from_slice(&v_row[src..src + row]);
                }
            }
            KvData::Bf16 { k, v } => {
                for l in 0..layers {
                    let dst = (id * layers + l) * bt * row + tok * row;
                    let src = l * row;
                    for i in 0..row {
                        k[dst + i] = f32_to_bf16(k_row[src + i]);
                        v[dst + i] = f32_to_bf16(v_row[src + i]);
                    }
                }
            }
            KvData::Fp8 {
                format,
                k,
                v,
                k_scale,
                v_scale,
            } => {
                let table = decode_table(*format);
                let mut ks = vec![0.0f32; bt * row];
                let mut vs = vec![0.0f32; bt * row];
                for l in 0..layers {
                    let bbase = (id * layers + l) * bt * row;
                    let si = (id * layers + l) * kv_heads;
                    decode_region_fp8(
                        &k[bbase..bbase + bt * row],
                        &mut ks,
                        &k_scale[si..si + kv_heads],
                        table,
                        tok,
                        kv_heads,
                        head_dim,
                    );
                    decode_region_fp8(
                        &v[bbase..bbase + bt * row],
                        &mut vs,
                        &v_scale[si..si + kv_heads],
                        table,
                        tok,
                        kv_heads,
                        head_dim,
                    );
                    ks[tok * row..(tok + 1) * row].copy_from_slice(&k_row[l * row..(l + 1) * row]);
                    vs[tok * row..(tok + 1) * row].copy_from_slice(&v_row[l * row..(l + 1) * row]);
                    encode_region_fp8(
                        &ks,
                        &mut k[bbase..bbase + bt * row],
                        &mut k_scale[si..si + kv_heads],
                        tok + 1,
                        bt,
                        kv_heads,
                        head_dim,
                        *format,
                    );
                    encode_region_fp8(
                        &vs,
                        &mut v[bbase..bbase + bt * row],
                        &mut v_scale[si..si + kv_heads],
                        tok + 1,
                        bt,
                        kv_heads,
                        head_dim,
                        *format,
                    );
                }
            }
        }
    }

    /// Dequantize the listed blocks into a caller-owned, persistent f32
    /// pool-operand pair laid out `(block, layer, token, kv_head,
    /// head_dim)` (the compiled pool shape of the paged decode artifact).
    /// Only the listed blocks are written — duplicates (a shared prefix
    /// mapped by several rows) once — and the distinct ids written are
    /// returned so the caller can zero exactly those regions before the
    /// next export instead of re-zeroing the whole pool. A device
    /// deployment keeps the pool resident in HBM and donates it between
    /// steps; this incremental export exists only for the PJRT-CPU stub
    /// runner.
    pub fn export_f32_blocks_into(
        &self,
        ids: &[BlockId],
        k: &mut [f32],
        v: &mut [f32],
    ) -> Vec<BlockId> {
        self.export_f32_blocks_into_par(ids, k, v, 1)
    }

    /// [`Self::export_f32_blocks_into`] fanned out over `workers` scoped
    /// pool workers. The deduped id list is sorted, so each worker's chunk
    /// of blocks covers a contiguous byte span of the export buffers —
    /// disjoint `split_at_mut` regions, no synchronization, and the same
    /// bytes written for every worker count. Small exports (or
    /// `workers <= 1`) run inline.
    pub fn export_f32_blocks_into_par(
        &self,
        ids: &[BlockId],
        k: &mut [f32],
        v: &mut [f32],
        workers: usize,
    ) -> Vec<BlockId> {
        let per_block = self.layers * self.block_tokens * self.row();
        let mut seen = vec![false; self.total_blocks];
        let mut written = Vec::with_capacity(ids.len());
        for &id in ids {
            if seen[id] {
                continue;
            }
            seen[id] = true;
            assert!(
                (id + 1) * per_block <= k.len() && (id + 1) * per_block <= v.len(),
                "block id {id} beyond the export buffers"
            );
            written.push(id);
        }
        // Sorted order makes per-worker chunks contiguous in the buffers
        // and the returned list deterministic regardless of `ids` order.
        written.sort_unstable();
        let export_chunk = |chunk: &[BlockId], k: &mut [f32], v: &mut [f32], off: usize| {
            for &id in chunk {
                if self.refs[id] == 0 {
                    continue; // free block: its (pre-zeroed) region stays zero
                }
                self.gather_into(
                    id,
                    k,
                    v,
                    id * per_block - off,
                    self.block_tokens * self.row(),
                    0,
                    self.block_tokens,
                );
            }
        };
        let w = workers.max(1).min(written.len());
        if w <= 1 || written.len() < 2 * w {
            export_chunk(&written, k, v, 0);
            return written;
        }
        // Chunk i owns blocks written[i*n/w..(i+1)*n/w]; its byte span is
        // [first*per_block, (last+1)*per_block), carved off the front of
        // the remaining buffers (gaps between non-adjacent ids stay inside
        // whichever chunk's span covers them — never written twice).
        let mut jobs: Vec<(&[BlockId], &mut [f32], &mut [f32], usize)> = Vec::with_capacity(w);
        let (mut k_rest, mut v_rest) = (k, v);
        let mut off = 0usize;
        for i in 0..w {
            let r = pool::chunk_range(written.len(), w, i);
            let chunk = &written[r.start..r.end];
            let hi = (chunk[chunk.len() - 1] + 1) * per_block;
            let (ka, kb) = std::mem::take(&mut k_rest).split_at_mut(hi - off);
            let (va, vb) = std::mem::take(&mut v_rest).split_at_mut(hi - off);
            jobs.push((chunk, ka, va, off));
            k_rest = kb;
            v_rest = vb;
            off = hi;
        }
        pool::run_scoped(&mut jobs, |(chunk, k, v, off)| {
            export_chunk(chunk, k, v, *off);
        });
        written
    }
}

/// One sequence's view into the pool: its physical blocks, in token order,
/// plus the valid length. Entries may be shared (refcount > 1) — the
/// store copy-on-writes before any write lands in a shared block.
struct SlotTable {
    blocks: Vec<BlockId>,
    len: usize,
}

/// Host-resident payload of one swapped-out block: the pool's stored
/// bytes verbatim (FP8 codes + per-(layer, kv-head) scales together,
/// per the FP8-vs-INT8 result that codes are meaningless without their
/// scales). Opaque outside this module; only
/// [`BlockPool::swap_in_block`] can turn it back into device bytes.
enum SwappedData {
    F32 {
        k: Vec<f32>,
        v: Vec<f32>,
    },
    Bf16 {
        k: Vec<u16>,
        v: Vec<u16>,
    },
    Fp8 {
        k: Vec<u8>,
        v: Vec<u8>,
        k_scale: Vec<f32>,
        v_scale: Vec<f32>,
    },
}

/// One block lifted off the device pool into host memory (ISSUE 9).
pub struct SwappedBlock {
    data: SwappedData,
}

/// One entry of a swapped-out sequence's block table.
enum SwapEntry {
    /// The block was exclusively this sequence's: its payload moved to
    /// host memory and the device block was freed.
    Moved(SwappedBlock),
    /// The block is shared (another sequence and/or the prefix cache
    /// still reads it): it stays resident and the swap record keeps this
    /// sequence's reference pinned, so the prefix cannot be evicted out
    /// from under the preempted sequence. Zero bytes cross the host link
    /// for this entry.
    Resident(BlockId),
}

/// A preempted sequence's KV state, off-device: per-block host payloads
/// for exclusively-owned blocks, pinned references for shared ones, plus
/// the valid length ([`KvStore::swap_out_slot`] /
/// [`KvStore::swap_in_slot`]). Refcount balance is preserved across the
/// tiers — dropping this without [`KvStore::discard_swapped`] leaks the
/// pinned shared blocks.
pub struct SwappedSlot {
    entries: Vec<SwapEntry>,
    len: usize,
}

impl SwappedSlot {
    /// Valid token count of the swapped sequence.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Blocks whose payload actually moved to the host tier (what a
    /// swap-in must re-allocate on device).
    pub fn moved_blocks(&self) -> usize {
        self.entries
            .iter()
            .filter(|e| matches!(e, SwapEntry::Moved(_)))
            .count()
    }

    /// Shared blocks that stayed device-resident (pinned, not copied).
    pub fn resident_blocks(&self) -> usize {
        self.entries.len() - self.moved_blocks()
    }

    /// Bytes that crossed the host link on swap-out — and will again on
    /// swap-in: moved blocks at the shared [`KvLayout`] block rate (codes
    /// and scales charged together). Resident entries cost zero.
    pub fn swapped_bytes(&self, layout: &KvLayout, block_tokens: usize) -> usize {
        self.moved_blocks() * layout.block_bytes(block_tokens)
    }
}

/// Outcome of a paged single-token write ([`KvStore::append_token`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AppendOutcome {
    /// Token stored; the sequence still has room.
    Appended,
    /// Token stored and the sequence just reached cache capacity
    /// (`len == t`) — the caller must finish the request (the same
    /// "sequence full" signal the dense `scatter_batch` returns).
    Full,
    /// No position to write: the slot is inactive or already at capacity.
    /// The caller's `maybe_finish` retires on this, exactly as it does on
    /// [`Self::Full`] — a further append would have nowhere to land.
    AtCapacity,
}

/// Why a [`KvStore::fork_slot`] could not produce a branch. The two
/// resources a fork consumes are distinct and recover differently — a
/// caller that conflates them retries at the wrong time (a freed *slot*
/// does not help a block-starved fork, and vice versa), so the store
/// names the missing one.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ForkError {
    /// Every slot table is occupied; retry after a sequence retires or
    /// is preempted.
    NoFreeSlot,
    /// The pool has zero free blocks. A fork itself allocates nothing,
    /// but its very first append must copy-on-write the shared hot
    /// block (or open a fresh one) — with no free block that append
    /// would hit the provisioning panic, so the fork is refused up
    /// front. Retry after blocks are released.
    NoFreeBlocks,
    /// `src` holds no active sequence — a caller bookkeeping bug
    /// surfaced as data, not a panic, so schedulers can route it.
    InactiveSource,
}

impl std::fmt::Display for ForkError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ForkError::NoFreeSlot => write!(f, "fork: no free slot"),
            ForkError::NoFreeBlocks => write!(f, "fork: no free blocks for branch divergence"),
            ForkError::InactiveSource => write!(f, "fork: source slot is inactive"),
        }
    }
}

/// One slot's borrowed decode-read state: its physical block table and
/// valid length. Shared entries (refcount > 1) are fine to *read* — only
/// writes trigger copy-on-write.
pub struct PagedSlotView<'a> {
    /// The store slot this row reads.
    pub slot: usize,
    /// Physical block IDs in token order (may extend past `len` when a
    /// longer cached prefix was mapped; blocks past the live range are
    /// never read).
    pub blocks: &'a [BlockId],
    /// Valid token count.
    pub len: usize,
}

impl PagedSlotView<'_> {
    /// Blocks holding valid tokens (`ceil(len / block_tokens)`).
    pub fn live_blocks(&self, block_tokens: usize) -> usize {
        self.len.div_ceil(block_tokens)
    }
}

/// The block-table-native decode read contract (ISSUE 5): per-slot
/// `&[BlockId]` tables plus per-block FP8 scale refs, handed to the
/// compute layer with **no copy, no zero-fill, and no bucket padding**.
/// Reads dequantize at block granularity ([`BlockPool::read_block_head`]),
/// so a decode step's HBM traffic is exactly the group's live block bytes
/// — the quantity [`BlockPool::bytes_read`] instruments and the paged
/// gaudisim pricing charges.
pub struct PagedAttentionView<'a> {
    pool: &'a BlockPool,
    layout: KvLayout,
    slots: Vec<PagedSlotView<'a>>,
}

impl<'a> PagedAttentionView<'a> {
    pub fn num_slots(&self) -> usize {
        self.slots.len()
    }

    pub fn slot(&self, i: usize) -> &PagedSlotView<'a> {
        &self.slots[i]
    }

    pub fn pool(&self) -> &'a BlockPool {
        self.pool
    }

    pub fn block_tokens(&self) -> usize {
        self.pool.block_tokens()
    }

    /// Physical bytes of slot `i`'s live blocks — what one decode step
    /// reads for that row (payload + FP8 block scales, at the shared
    /// `KvLayout` rate).
    pub fn slot_live_block_bytes(&self, i: usize) -> usize {
        self.slots[i].live_blocks(self.pool.block_tokens())
            * self.layout.block_bytes(self.pool.block_tokens())
    }

    /// Total physical bytes one decode step over this group reads — the
    /// sum of each slot's live block bytes, with no bucket padding.
    pub fn live_block_bytes(&self) -> usize {
        (0..self.slots.len())
            .map(|i| self.slot_live_block_bytes(i))
            .sum()
    }

    /// Per-block FP8 scale refs (K, V) for `block_idx` of slot `i` at
    /// `layer`; `None` for scale-free dtypes.
    pub fn block_scales(&self, i: usize, block_idx: usize, layer: usize) -> Option<(&[f32], &[f32])> {
        self.pool.block_scales(self.slots[i].blocks[block_idx], layer)
    }

    /// Single-head paged attention readout for slot `i`: softmax(q·Kᵀ/√d)·V
    /// over the slot's valid positions. Convenience wrapper over
    /// [`Self::attend_into`] that builds a one-task batch and allocates its
    /// own output and scratch — fine for tests and one-off probes;
    /// steady-state decode loops should hold an [`AttendScratch`] and call
    /// `attend_into` with the full task batch.
    pub fn attend(&self, i: usize, layer: usize, kv_head: usize, q: &[f32]) -> Vec<f32> {
        let d = self.layout.head_dim;
        let mut out = vec![0.0f32; d];
        let mut scratch = AttendScratch::new(self.pool.block_tokens(), d);
        let tasks = [AttendTask {
            slot: i,
            layer,
            kv_head,
        }];
        self.attend_into(&tasks, q, &mut out, &mut scratch, &AttendOptions::default());
        out
    }

    /// **The** paged read entry point: run a batch of independent
    /// (slot, layer, kv-head) online-softmax readouts, data-parallel
    /// across the scoped [`crate::util::pool`] workers selected by
    /// `opts.parallelism`, dequantizing with the `opts.dequant` kernel.
    ///
    /// `q` and `out` are row-major `(tasks.len(), head_dim)`; task `t`
    /// reads query row `t` and writes output row `t`. Each task walks its
    /// slot's block table with a streaming softmax — one block-sized K/V
    /// tile in flight per worker, dequantized on read, never a dense
    /// `(T, …)` buffer — and rows of empty sequences come back zero.
    ///
    /// Deterministic by construction: tasks are split into contiguous
    /// chunks (never re-ordered), every task reduces its own tiles in
    /// block-table order, and each owns a disjoint output row — so output
    /// is **bit-identical for every worker count**, and
    /// [`BlockPool::bytes_read`] (atomic, order-independent integer adds)
    /// stays byte-exact. `scratch` is caller-owned and grows to one tile
    /// pair per worker on first use; steady state allocates nothing.
    // lint: hot-path
    pub fn attend_into(
        &self,
        tasks: &[AttendTask],
        q: &[f32],
        out: &mut [f32],
        scratch: &mut AttendScratch,
        opts: &AttendOptions,
    ) {
        let d = self.layout.head_dim;
        assert_eq!(q.len(), tasks.len() * d, "query batch size");
        assert_eq!(out.len(), tasks.len() * d, "output batch size");
        let bt = self.pool.block_tokens();
        assert!(scratch.fits(bt, d), "scratch tiles sized for another pool");
        if tasks.is_empty() {
            return;
        }
        let w = if tasks.len() == 1 {
            1 // single task: skip worker detection, run inline
        } else {
            opts.parallelism.workers().min(tasks.len())
        };
        scratch.ensure_workers(w);
        let dequant = opts.dequant;
        pool::run_partitioned(
            &mut scratch.tiles[..w],
            out,
            tasks.len(),
            d,
            |tile, out_chunk, range| {
                for (j, t) in range.enumerate() {
                    self.attend_task_into(
                        tasks[t],
                        &q[t * d..(t + 1) * d],
                        &mut out_chunk[j * d..(j + 1) * d],
                        &mut tile.k,
                        &mut tile.v,
                        dequant,
                    );
                }
            },
        );
    }

    /// One task's streaming-softmax tile walk — the kernel every worker
    /// runs. Tiles reduce strictly in block-table order and all dot
    /// products / V accumulations are stride-1 slices over the decoded
    /// tile, so the autovectorizer can chunk them.
    // lint: hot-path
    fn attend_task_into(
        &self,
        task: AttendTask,
        q: &[f32],
        out: &mut [f32],
        k_tile: &mut [f32],
        v_tile: &mut [f32],
        dequant: Dequant,
    ) {
        let d = self.layout.head_dim;
        let s = &self.slots[task.slot];
        out.fill(0.0);
        if s.len == 0 {
            return;
        }
        let bt = self.pool.block_tokens();
        let scale = 1.0 / (d as f32).sqrt();
        // Online softmax state: running max, normalizer, weighted V sum.
        let mut m = f32::NEG_INFINITY;
        let mut z = 0.0f32;
        let live = s.len.div_ceil(bt);
        for (bi, &id) in s.blocks.iter().take(live).enumerate() {
            let tok0 = bi * bt;
            let count = bt.min(s.len - tok0);
            self.pool
                .read_block_head_with(id, task.layer, task.kv_head, k_tile, v_tile, dequant);
            for ti in 0..count {
                let krow = &k_tile[ti * d..(ti + 1) * d];
                let mut score = 0.0f32;
                for (qd, kd) in q.iter().zip(krow) {
                    score += qd * kd;
                }
                score *= scale;
                let m_new = m.max(score);
                let corr = (m - m_new).exp(); // first iteration: exp(-inf) = 0
                let w = (score - m_new).exp();
                z = z * corr + w;
                let vrow = &v_tile[ti * d..(ti + 1) * d];
                for (o, vv) in out.iter_mut().zip(vrow) {
                    *o = *o * corr + w * vv;
                }
                m = m_new;
            }
        }
        let inv = 1.0 / z.max(1e-30);
        for a in out.iter_mut() {
            *a *= inv;
        }
    }
}

/// One independent readout in an [`PagedAttentionView::attend_into`]
/// batch: which view row (the `i` of [`PagedAttentionView::slot`] — not
/// the store slot id), layer, and kv-head to attend over.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AttendTask {
    /// Index of the slot row within the view.
    pub slot: usize,
    pub layer: usize,
    pub kv_head: usize,
}

/// FP8 dequant kernel selector for the paged read path. Both kernels are
/// bit-identical (the LUT *is* the exact decode table); `Scalar` is the
/// honest per-element exponent-math baseline the speedup benches compare
/// against. F32/BF16 tiles ignore the selector.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Dequant {
    /// Indexed loads from the shared 256-entry [`crate::fp8::decode_table`]
    /// LUT, scale folded in once per tile.
    #[default]
    Lut,
    /// Per-element exponent-math [`decode`] — the pre-ISSUE-8 baseline.
    Scalar,
}

/// Options for the single paged read entry point
/// ([`PagedAttentionView::attend_into`]): worker-count policy and dequant
/// kernel. `Default` is auto-detected workers (`REPRO_NUM_THREADS` or the
/// machine's parallelism) with LUT dequant.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AttendOptions {
    pub parallelism: Parallelism,
    pub dequant: Dequant,
}

impl AttendOptions {
    /// Sequential LUT readout — one worker, no thread spawn.
    pub fn sequential() -> Self {
        Self {
            parallelism: Parallelism::Sequential,
            dequant: Dequant::Lut,
        }
    }
}

/// Per-worker dequantized K/V tile pair — one block's (token, dim) slab
/// each. `Send` so the scoped pool can hand one to each worker.
struct TileScratch {
    k: Vec<f32>,
    v: Vec<f32>,
}

/// Reusable K/V tile buffers for [`PagedAttentionView::attend_into`]: one
/// block-sized dequantized K tile and V tile **per worker**. Allocate once
/// per decode loop and reuse across steps — the scratch grows to the
/// worker count on first use and the hot path allocates nothing after
/// that.
pub struct AttendScratch {
    tile_elems: usize,
    tiles: Vec<TileScratch>,
}

impl AttendScratch {
    pub fn new(block_tokens: usize, head_dim: usize) -> Self {
        let tile_elems = block_tokens * head_dim;
        Self {
            tile_elems,
            tiles: vec![TileScratch {
                k: vec![0.0f32; tile_elems],
                v: vec![0.0f32; tile_elems],
            }],
        }
    }

    /// True when the tiles can hold one `block_tokens × head_dim` block.
    pub fn fits(&self, block_tokens: usize, head_dim: usize) -> bool {
        self.tile_elems >= block_tokens * head_dim
    }

    /// Grow to at least `workers` tile pairs (amortized: steady-state
    /// decode loops hit the fast path after the first call).
    fn ensure_workers(&mut self, workers: usize) {
        while self.tiles.len() < workers {
            self.tiles.push(TileScratch {
                k: vec![0.0f32; self.tile_elems],
                v: vec![0.0f32; self.tile_elems],
            });
        }
    }
}

/// Host-side paged KV storage for `slots` concurrent sequences of up to
/// `t` tokens each. The contiguous per-slot arena is gone: all bytes live
/// in the shared [`BlockPool`], sequences are block tables, and a prefix
/// hit maps cached physical blocks instead of copying them. The public
/// surface is paged-only: reads through [`Self::paged_view`] /
/// [`PagedAttentionView::attend_into`], writes through
/// [`Self::write_slot`] / [`Self::append_token`]. The dense
/// `(L, B, T, Hkv, D)` gather/scatter reference survives behind the
/// `dense-decode-ref` feature for roundtrip/property tests.
///
/// Storage is [`KvDtype`]-backed: F32 roundtrips bit-exactly, BF16 rounds
/// to 2 B/elem, FP8 quantizes on `write_slot`/`append_token` and
/// dequantizes on read (codes + per-(block, layer, kv-head)
/// scales — the paper's 1 B/elem serving configuration).
pub struct KvStore {
    pub layers: usize,
    pub slots: usize,
    pub t: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pool: BlockPool,
    tables: Vec<Option<SlotTable>>,
}

impl KvStore {
    /// F32 store — the exact-roundtrip legacy configuration.
    pub fn new(layers: usize, slots: usize, t: usize, kv_heads: usize, head_dim: usize) -> Self {
        Self::with_dtype(layers, slots, t, kv_heads, head_dim, KvDtype::F32)
    }

    /// Pool sized for `slots` full sequences, no extra shared-prefix
    /// blocks, at the default block granularity (clamped to `t` so tiny
    /// test stores do not over-provision).
    pub fn with_dtype(
        layers: usize,
        slots: usize,
        t: usize,
        kv_heads: usize,
        head_dim: usize,
        dtype: KvDtype,
    ) -> Self {
        let bt = KV_BLOCK_TOKENS.min(t.max(1));
        Self::with_block_tokens(layers, slots, t, kv_heads, head_dim, dtype, bt, 0)
    }

    /// Full constructor: `extra_blocks` over-provisions the pool for
    /// blocks owned by a co-resident prefix cache (the engine passes its
    /// cache's block budget, so sequences and cached prefixes can never
    /// starve each other).
    #[allow(clippy::too_many_arguments)]
    pub fn with_block_tokens(
        layers: usize,
        slots: usize,
        t: usize,
        kv_heads: usize,
        head_dim: usize,
        dtype: KvDtype,
        block_tokens: usize,
        extra_blocks: usize,
    ) -> Self {
        let bt = block_tokens.max(1);
        let blocks_per_seq = t.div_ceil(bt);
        let pool = BlockPool::new(
            slots * blocks_per_seq + extra_blocks,
            bt,
            layers,
            kv_heads,
            head_dim,
            dtype,
        );
        Self {
            layers,
            slots,
            t,
            kv_heads,
            head_dim,
            pool,
            tables: (0..slots).map(|_| None).collect(),
        }
    }

    pub fn dtype(&self) -> KvDtype {
        self.pool.dtype()
    }

    /// The accounting contract this store's storage follows.
    pub fn layout(&self) -> KvLayout {
        KvLayout::new(self.dtype(), self.layers, self.kv_heads, self.head_dim)
    }

    pub fn block_tokens(&self) -> usize {
        self.pool.block_tokens()
    }

    /// The shared physical pool (prefix caches draw on it too).
    pub fn pool(&self) -> &BlockPool {
        &self.pool
    }

    pub fn pool_mut(&mut self) -> &mut BlockPool {
        &mut self.pool
    }

    fn row(&self) -> usize {
        self.kv_heads * self.head_dim
    }

    /// Elements of one slot's (T, Hkv, D) region per layer.
    fn slot_stride(&self) -> usize {
        self.t * self.row()
    }

    /// Is at least one KV slot unoccupied? The slot table — not the
    /// over-provisioned block pool — is the engine's binding admission
    /// resource, so this is the signal its preemption trigger reads.
    pub fn has_free_slot(&self) -> bool {
        self.tables.iter().any(|t| t.is_none())
    }

    pub fn alloc_slot(&mut self) -> Option<usize> {
        let idx = self.tables.iter().position(|t| t.is_none())?;
        self.tables[idx] = Some(SlotTable {
            blocks: Vec::new(),
            len: 0,
        });
        Some(idx)
    }

    /// Release the slot's block references. A block drops to the free
    /// list (zeroed) only when its *last* reader goes — blocks still
    /// mapped by other sequences or owned by the prefix cache survive.
    pub fn free_slot(&mut self, slot: usize) {
        if let Some(tab) = self.tables[slot].take() {
            for id in tab.blocks {
                self.pool.release(id);
            }
        }
    }

    pub fn len(&self, slot: usize) -> Option<usize> {
        self.tables[slot].as_ref().map(|t| t.len)
    }

    /// Token positions still writable in `slot` (None = slot free).
    pub fn remaining(&self, slot: usize) -> Option<usize> {
        self.len(slot).map(|l| self.t - l)
    }

    /// An active slot whose sequence has reached cache capacity: another
    /// decode step would have no position to write.
    pub fn is_full(&self, slot: usize) -> bool {
        self.len(slot) == Some(self.t)
    }

    pub fn set_len(&mut self, slot: usize, len: usize) {
        assert!(len <= self.t);
        match self.tables[slot].as_mut() {
            Some(tab) => tab.len = len,
            None => {
                self.tables[slot] = Some(SlotTable {
                    blocks: Vec::new(),
                    len,
                })
            }
        }
    }

    pub fn active_slots(&self) -> Vec<usize> {
        (0..self.slots)
            .filter(|s| self.tables[*s].is_some())
            .collect()
    }

    /// The slot's physical block table (for sharing into a prefix cache).
    pub fn slot_blocks(&self, slot: usize) -> Vec<BlockId> {
        self.tables[slot]
            .as_ref()
            .map_or_else(Vec::new, |t| t.blocks.clone())
    }

    /// Borrow `slot`'s table. Every caller sits behind an explicit
    /// activity check or holds an engine-owned active slot, so an
    /// inactive slot here is a block-table bookkeeping bug worth a loud
    /// stop — not an error to propagate.
    fn table(&self, slot: usize) -> &SlotTable {
        // lint:allow(no-unwrap-in-lib): engine-owned active slot; inactive here is a block-table bookkeeping bug
        self.tables[slot].as_ref().expect("active slot")
    }

    /// Mutable twin of [`Self::table`], same contract.
    fn table_mut(&mut self, slot: usize) -> &mut SlotTable {
        // lint:allow(no-unwrap-in-lib): engine-owned active slot; inactive here is a block-table bookkeeping bug
        self.tables[slot].as_mut().expect("active slot")
    }

    /// Allocate from the pool, which [`Self::with_block_tokens`]
    /// provisioned for `slots + prefix cache` blocks — exhaustion is a
    /// provisioning bug, not a runtime condition.
    fn alloc_provisioned(&mut self) -> BlockId {
        // lint:allow(no-unwrap-in-lib): pool provisioned for slots + prefix cache at construction
        self.pool.alloc().expect("pool provisioned for slots + prefix cache")
    }

    /// Can a warm admission map `cached` prefix tokens and still allocate
    /// the private tail of a `prompt_len` prompt from the pool?
    pub fn can_map_tail(&self, prompt_len: usize, cached: usize) -> bool {
        let bt = self.pool.block_tokens();
        let need = prompt_len.div_ceil(bt).saturating_sub(cached / bt);
        need <= self.pool.free_blocks()
    }

    /// Map already-resident physical blocks (a cached prefix) into the
    /// slot's table — sharing, not copying: each block gains a reference.
    /// `len` is the slot's valid length after mapping (the engine sets it
    /// to the first position its tail recompute will write, which may sit
    /// *inside* the last shared block — the copy-on-write in
    /// [`Self::append_token`] keeps that write private).
    pub fn map_shared_prefix(&mut self, slot: usize, blocks: &[BlockId], len: usize) {
        assert!(len <= self.t, "mapped length exceeds the KV window");
        assert!(
            len <= blocks.len() * self.pool.block_tokens(),
            "mapped length exceeds the mapped blocks"
        );
        for &id in blocks {
            self.pool.retain(id);
        }
        let tab = self.table_mut(slot);
        assert!(tab.blocks.is_empty(), "map_shared_prefix into a written slot");
        tab.blocks.extend_from_slice(blocks);
        tab.len = len;
    }

    /// Write a prefill artifact's (L, 1, T, Hkv, D) output into `slot`,
    /// quantizing to the store's dtype. Replaces any previous mapping:
    /// tokens `[0, len)` land in freshly allocated private blocks; the
    /// bucket-padded tail past `len` is dropped (attention never reads it
    /// and FP8 scales must not see it).
    pub fn write_slot(&mut self, slot: usize, k_out: &[f32], v_out: &[f32], len: usize) {
        let ss = self.slot_stride();
        assert_eq!(k_out.len(), self.layers * ss, "prefill kv size");
        assert_eq!(v_out.len(), self.layers * ss, "prefill kv size");
        let len = len.min(self.t);
        if let Some(tab) = self.tables[slot].take() {
            for id in tab.blocks {
                self.pool.release(id);
            }
        }
        let bt = self.pool.block_tokens();
        let nblocks = len.div_ceil(bt);
        let mut blocks = Vec::with_capacity(nblocks);
        for b in 0..nblocks {
            let id = self.alloc_provisioned();
            let tok0 = b * bt;
            let valid = bt.min(len - tok0);
            self.pool.scatter_from(id, k_out, v_out, 0, ss, tok0, valid);
            blocks.push(id);
        }
        self.tables[slot] = Some(SlotTable { blocks, len });
    }

    /// **Dense reference only** (roundtrip/property tests and the
    /// `dense-decode-ref` engine path — not the decode hot path, which
    /// reads through [`Self::paged_view`]): gather `group` slots into a
    /// contiguous (L, B, T, Hkv, D) batch buffer. Returns (k, v, lens).
    #[cfg(feature = "dense-decode-ref")]
    pub fn gather_batch(&self, group: &[usize]) -> (Vec<f32>, Vec<f32>, Vec<i32>) {
        let b = group.len();
        let ss = self.slot_stride();
        let mut k = vec![0.0f32; self.layers * b * ss];
        let mut v = vec![0.0f32; self.layers * b * ss];
        let lens = self.gather_batch_into(group, b, &mut k, &mut v);
        (k, v, lens)
    }

    /// **Dense reference only** — the pre-paged decode staging, kept for
    /// roundtrip/property tests and the feature-gated (`dense-decode-ref`)
    /// reference engine; the hot path reads through [`Self::paged_view`]
    /// with no dense staging at all. Allocation-free gather into
    /// caller-owned buffers sized for a batch of `bucket` rows, walking
    /// each slot's block table and dequantizing to f32 on the way out.
    /// Rows ≥ group.len() are left untouched. Positions at or past each
    /// slot's valid length come back as exact zeros (the pool never
    /// stores masked pad positions).
    #[cfg(feature = "dense-decode-ref")]
    pub fn gather_batch_into(
        &self,
        group: &[usize],
        bucket: usize,
        k: &mut [f32],
        v: &mut [f32],
    ) -> Vec<i32> {
        let b = bucket;
        assert!(group.len() <= b);
        let row = self.row();
        let ss = self.slot_stride();
        assert_eq!(k.len(), self.layers * b * ss, "k buffer size");
        assert_eq!(v.len(), self.layers * b * ss, "v buffer size");
        let layer_stride = b * ss;
        let bt = self.pool.block_tokens();
        let mut lens = Vec::with_capacity(b);
        for (bi, &slot) in group.iter().enumerate() {
            let base = bi * ss;
            let (blocks, len): (&[BlockId], usize) = match &self.tables[slot] {
                Some(tab) => (tab.blocks.as_slice(), tab.len),
                None => (&[], 0),
            };
            lens.push(len as i32);
            let mut covered = 0usize;
            for (bidx, &id) in blocks.iter().enumerate() {
                let tok0 = bidx * bt;
                if tok0 >= len {
                    break;
                }
                let count = bt.min(len - tok0);
                self.pool
                    .gather_into(id, k, v, base, layer_stride, tok0, count);
                covered = tok0 + count;
            }
            // Zero the masked region so reused scratch buffers stay
            // deterministic (same bytes the old contiguous copy touched).
            for l in 0..self.layers {
                let start = base + l * layer_stride + covered * row;
                let end = base + l * layer_stride + self.t * row;
                k[start..end].fill(0.0);
                v[start..end].fill(0.0);
            }
        }
        lens.resize(b, 0);
        lens
    }

    /// **Dense reference only** — the write-side twin of
    /// [`Self::gather_batch_into`]; the hot path appends through
    /// [`Self::append_token`] instead (one token, no batch buffer).
    /// Scatter an updated (L, B, T, Hkv, D) batch back into the slots and
    /// bump their lengths. The paged contract: only the *hot* block — the
    /// one holding the newly appended position — is written, re-encoded
    /// from the buffer's `[block start, len]` span (earlier blocks are
    /// immutable history; under FP8 their write-time scales stand). A hot
    /// block still readable by another sequence or the prefix cache is
    /// first replaced by a private copy-on-write block, so a write can
    /// never leak into a shared prefix.
    ///
    /// Returns the slots whose sequence just reached cache capacity
    /// (`len == t`) — the "sequence full" signal. The caller must finish
    /// those requests: a further decode step has no position to write, and
    /// clamping silently overwrote the last position forever.
    #[cfg(feature = "dense-decode-ref")]
    pub fn scatter_batch(&mut self, group: &[usize], k_in: &[f32], v_in: &[f32]) -> Vec<usize> {
        let b = group.len();
        let ss = self.slot_stride();
        assert_eq!(k_in.len(), self.layers * b * ss);
        assert_eq!(v_in.len(), self.layers * b * ss);
        let layer_stride = b * ss;
        let bt = self.pool.block_tokens();
        let mut full = Vec::new();
        for (bi, &slot) in group.iter().enumerate() {
            let Some(len) = self.tables[slot].as_ref().map(|t| t.len) else {
                continue; // inactive slot: nothing to append to
            };
            if len >= self.t {
                // At capacity: no position to write; keep signalling.
                full.push(slot);
                continue;
            }
            let base = bi * ss;
            let hb = len / bt;
            let valid_in_block = len % bt + 1;
            self.ensure_private_block(slot, hb);
            let id = self.table(slot).blocks[hb];
            self.pool
                .scatter_from(id, k_in, v_in, base, layer_stride, hb * bt, valid_in_block);
            let tab = self.table_mut(slot);
            tab.len = len + 1;
            if tab.len == self.t {
                full.push(slot);
            }
        }
        full
    }

    /// Grow `slot`'s table to cover block index `hb` and make that entry
    /// exclusively writable. A shared entry (refcount > 1: mapped by
    /// another sequence and/or owned by the prefix cache) is swapped for a
    /// fresh private block — copy-on-write; the caller rewrites the whole
    /// valid span from its batch buffer, so no payload copy is needed.
    #[cfg(feature = "dense-decode-ref")]
    fn ensure_private_block(&mut self, slot: usize, hb: usize) {
        while self.table(slot).blocks.len() <= hb {
            let id = self.alloc_provisioned();
            self.table_mut(slot).blocks.push(id);
        }
        let id = self.table(slot).blocks[hb];
        if self.pool.ref_count(id) > 1 {
            let fresh = self.alloc_provisioned();
            self.table_mut(slot).blocks[hb] = fresh;
            self.pool.release(id);
        }
    }

    /// Like `ensure_private_block`, but *payload-preserving*: the paged
    /// append writes a single position, so a shared hot block's valid
    /// history must be cloned into the private replacement
    /// ([`BlockPool::clone_block`]). The dense scatter skips the copy only
    /// because it rewrites the whole valid span from its batch buffer.
    fn ensure_private_hot_block(&mut self, slot: usize, hb: usize) {
        while self.table(slot).blocks.len() <= hb {
            let id = self.alloc_provisioned();
            self.table_mut(slot).blocks.push(id);
        }
        let id = self.table(slot).blocks[hb];
        if self.pool.ref_count(id) > 1 {
            // lint:allow(no-unwrap-in-lib): CoW clone draws from the same provisioned pool as alloc
            let fresh = self.pool.clone_block(id).expect("pool provisioned for slots + prefix cache");
            self.table_mut(slot).blocks[hb] = fresh;
            self.pool.release(id);
        }
    }

    /// The paged decode write path: quantize one token's (L, Hkv, D) K/V
    /// rows into `slot`'s hot block and bump its length — no dense batch
    /// buffer, no rewrite of history. Copy-on-write fires first when the
    /// hot block is still readable by another sequence or the prefix cache
    /// (valid history is cloned, then the append lands privately), and an
    /// append landing exactly on a block boundary allocates the next
    /// block. At capacity nothing is written and
    /// [`AppendOutcome::AtCapacity`] keeps signalling — the caller must
    /// finish the request, exactly as with the dense scatter's "sequence
    /// full" list.
    // lint: hot-path
    pub fn append_token(&mut self, slot: usize, k_row: &[f32], v_row: &[f32]) -> AppendOutcome {
        let row = self.row();
        assert_eq!(k_row.len(), self.layers * row, "append k size");
        assert_eq!(v_row.len(), self.layers * row, "append v size");
        let Some(len) = self.tables[slot].as_ref().map(|t| t.len) else {
            return AppendOutcome::AtCapacity; // inactive slot: nothing to append to
        };
        if len >= self.t {
            return AppendOutcome::AtCapacity;
        }
        let bt = self.pool.block_tokens();
        let hb = len / bt;
        self.ensure_private_hot_block(slot, hb);
        let id = self.table(slot).blocks[hb];
        self.pool.append_token(id, len % bt, k_row, v_row);
        let tab = self.table_mut(slot);
        tab.len = len + 1;
        if tab.len == self.t {
            AppendOutcome::Full
        } else {
            AppendOutcome::Appended
        }
    }

    /// Fork `src` into a fresh slot sharing its *entire* history — the
    /// beam-search primitive, a thin wrapper over the pool's multi-reader
    /// blocks: every block gains a reference, zero bytes are copied, and
    /// each branch's next [`Self::append_token`] copy-on-writes its own
    /// hot block so the branches diverge privately. The typed error says
    /// *which* resource is missing ([`ForkError`]): slot exhaustion and
    /// block exhaustion recover on different events, and a fork admitted
    /// into an empty pool would only defer the failure to the branch's
    /// first CoW append.
    pub fn fork_slot(&mut self, src: usize) -> Result<usize, ForkError> {
        let (blocks, len) = {
            let tab = self.tables[src].as_ref().ok_or(ForkError::InactiveSource)?;
            (tab.blocks.clone(), tab.len)
        };
        if self.pool.free_blocks() == 0 {
            return Err(ForkError::NoFreeBlocks);
        }
        let dst = self.alloc_slot().ok_or(ForkError::NoFreeSlot)?;
        for &id in &blocks {
            self.pool.retain(id);
        }
        self.tables[dst] = Some(SlotTable { blocks, len });
        Ok(dst)
    }

    /// Roll `slot` back to `new_len` tokens — the speculative-decode
    /// reject path. Blocks wholly past the new length are dead: each is
    /// released, which on a *shared* block (a beam sibling or the prefix
    /// cache still reads it) merely drops this sequence's reference and
    /// on an exclusive block returns it to the pool — CoW-safe by the
    /// same refcount discipline as [`Self::free_slot`]. A truncation
    /// landing *inside* a block keeps that block: positions at or past
    /// `new_len` are never read (attention masks by `len`, gathers
    /// zero-fill past it) and the next [`Self::append_token`] re-encodes
    /// the hot block over exactly the valid span, so stale rejected
    /// tokens cannot leak into reads or FP8 scales.
    ///
    /// No-op when `new_len` is not an actual shrink; panics on an
    /// inactive slot (rolling back nothing is a scheduler bug).
    pub fn truncate_slot(&mut self, slot: usize, new_len: usize) {
        let bt = self.pool.block_tokens();
        // lint:allow(no-unwrap-in-lib): truncating an inactive slot is a scheduler bookkeeping bug
        let tab = self.tables[slot].as_mut().expect("truncate of an active slot");
        if new_len >= tab.len {
            return;
        }
        let keep = new_len.div_ceil(bt);
        let dead: Vec<BlockId> = tab.blocks.drain(keep.min(tab.blocks.len())..).collect();
        tab.len = new_len;
        for id in dead {
            self.pool.release(id);
        }
    }

    /// Preempt `slot`: move its exclusively-owned blocks to host memory
    /// and free them on device, keep shared blocks resident with this
    /// sequence's reference pinned inside the record, and free the slot
    /// itself for other work. The returned [`SwappedSlot`] restores the
    /// sequence bit-identically via [`Self::swap_in_slot`], or is priced
    /// for re-prefill and dropped via [`Self::discard_swapped`].
    pub fn swap_out_slot(&mut self, slot: usize) -> SwappedSlot {
        // lint:allow(no-unwrap-in-lib): preempting an inactive slot is a scheduler bookkeeping bug
        let tab = self.tables[slot].take().expect("swap_out of an active slot");
        let mut entries = Vec::with_capacity(tab.blocks.len());
        for id in tab.blocks {
            if self.pool.ref_count(id) > 1 {
                entries.push(SwapEntry::Resident(id));
            } else {
                entries.push(SwapEntry::Moved(self.pool.swap_out_block(id)));
            }
        }
        SwappedSlot {
            entries,
            len: tab.len,
        }
    }

    /// Whether a swap-in of `swapped` can succeed right now: a free slot
    /// plus enough free pool blocks for its moved entries.
    pub fn can_swap_in(&self, swapped: &SwappedSlot) -> bool {
        self.tables.iter().any(|t| t.is_none())
            && swapped.moved_blocks() <= self.pool.free_blocks()
    }

    /// Resume a preempted sequence: allocate a fresh slot, restore each
    /// moved block bit-identically from its host payload, and splice the
    /// pinned resident blocks back into the table (their references
    /// transfer from the record — refcounts balance across the whole
    /// preempt/resume cycle). On failure (no slot, or the pool cannot
    /// hold the moved blocks) nothing is mutated and the record comes
    /// back in `Err` for a later retry.
    pub fn swap_in_slot(&mut self, swapped: SwappedSlot) -> Result<usize, SwappedSlot> {
        if !self.can_swap_in(&swapped) {
            return Err(swapped);
        }
        // lint:allow(no-unwrap-in-lib): can_swap_in just verified a free slot exists
        let slot = self.alloc_slot().expect("free slot verified");
        let mut blocks = Vec::with_capacity(swapped.entries.len());
        for e in swapped.entries {
            match e {
                SwapEntry::Resident(id) => blocks.push(id),
                SwapEntry::Moved(sb) => {
                    // lint:allow(no-unwrap-in-lib): can_swap_in just verified the pool headroom
                    blocks.push(self.pool.swap_in_block(&sb).expect("pool headroom verified"));
                }
            }
        }
        self.tables[slot] = Some(SlotTable {
            blocks,
            len: swapped.len,
        });
        Ok(slot)
    }

    /// Abandon a swap record (the recompute-resume path, or request
    /// abort): release the pinned shared blocks and drop the host
    /// payloads. Required for refcount balance — a record must end in
    /// exactly one of [`Self::swap_in_slot`] or here.
    pub fn discard_swapped(&mut self, swapped: SwappedSlot) {
        for e in swapped.entries {
            if let SwapEntry::Resident(id) = e {
                self.pool.release(id);
            }
        }
    }

    /// Borrow the group's block-table-native read state: per-slot block
    /// tables + lengths over the shared pool. Inactive slots read as
    /// empty. This — not a dense gather — is what the decode step hands
    /// the compute layer.
    pub fn paged_view(&self, group: &[usize]) -> PagedAttentionView<'_> {
        let layout = self.layout();
        let slots = group
            .iter()
            .map(|&slot| {
                let (blocks, len) = match &self.tables[slot] {
                    Some(tab) => (tab.blocks.as_slice(), tab.len),
                    None => (&[] as &[BlockId], 0),
                };
                PagedSlotView { slot, blocks, len }
            })
            .collect();
        PagedAttentionView {
            pool: &self.pool,
            layout,
            slots,
        }
    }

    /// Exact bytes this store's pool provisions:
    /// `total blocks × layout.block_bytes(block_tokens)`.
    pub fn kv_bytes(&self) -> usize {
        self.pool.total_blocks() * self.layout().block_bytes(self.pool.block_tokens())
    }

    /// Physical bytes currently resident (allocated blocks only) — the
    /// number the shared-prefix capacity claims are made of: N sequences
    /// sharing a prefix hold the prefix's blocks once.
    pub fn resident_bytes(&self) -> usize {
        self.pool.used_blocks() * self.layout().block_bytes(self.pool.block_tokens())
    }

    /// Single-step attention readout over the stored KV of `slots` — the
    /// numerical-fidelity probe tests and benches use to measure what KV
    /// quantization does to decode logits. For each (slot, layer, kv-head)
    /// a deterministic N(0,1) query attends (scaled dot-product softmax)
    /// over the valid positions; readouts are concatenated in
    /// (slot, layer, head, dim) order. Two stores holding the same written
    /// data produce comparable vectors regardless of dtype.
    ///
    /// Block-table-native since ISSUE 5, and since ISSUE 8 a thin client
    /// of the single read entry point: queries for every
    /// (slot, layer, head) are drawn first (same RNG order as ever), then
    /// **one** [`PagedAttentionView::attend_into`] call runs the whole
    /// task batch — dequant-on-read at block granularity, no dense gather
    /// — so the probe's HBM traffic is exactly the group's live block
    /// bytes ([`BlockPool::bytes_read`] instruments it) and its output is
    /// bit-identical for every worker count.
    pub fn decode_attention_probe(&self, slots: &[usize], seed: u64) -> Vec<f32> {
        self.decode_attention_probe_opts(slots, seed, &AttendOptions::default())
    }

    /// [`Self::decode_attention_probe`] with explicit [`AttendOptions`] —
    /// the worker-count / dequant-kernel axis the determinism suite and
    /// the speedup benches drive.
    pub fn decode_attention_probe_opts(
        &self,
        slots: &[usize],
        seed: u64,
        opts: &AttendOptions,
    ) -> Vec<f32> {
        let mut rng = XorShiftRng::new(seed);
        let d = self.head_dim;
        let view = self.paged_view(slots);
        let n = slots.len() * self.layers * self.kv_heads;
        let mut tasks = Vec::with_capacity(n);
        let mut q = vec![0.0f32; n * d];
        for bi in 0..slots.len() {
            for l in 0..self.layers {
                for h in 0..self.kv_heads {
                    let at = tasks.len() * d;
                    for qd in q[at..at + d].iter_mut() {
                        *qd = rng.normal();
                    }
                    tasks.push(AttendTask {
                        slot: bi,
                        layer: l,
                        kv_head: h,
                    });
                }
            }
        }
        let mut out = vec![0.0f32; n * d];
        let mut scratch = AttendScratch::new(self.pool.block_tokens(), d);
        view.attend_into(&tasks, &q, &mut out, &mut scratch, opts);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_allocator_accounting() {
        let mut a = BlockAllocator::new(10, 16);
        assert_eq!(a.blocks_for(1), 1);
        assert_eq!(a.blocks_for(16), 1);
        assert_eq!(a.blocks_for(17), 2);
        assert!(a.can_allocate(160));
        assert!(!a.can_allocate(161));
        let got = a.allocate(33).unwrap(); // 3 blocks
        assert_eq!(got, 3);
        assert_eq!(a.free_blocks(), 7);
        assert!(a.allocate(160).is_err());
        a.release(3).unwrap();
        assert_eq!(a.free_blocks(), 10);
        assert_eq!(a.utilization(), 0.0);
    }

    #[test]
    fn block_granular_allocation() {
        let mut a = BlockAllocator::new(10, 16);
        assert!(a.can_allocate_blocks(10));
        assert!(!a.can_allocate_blocks(11));
        a.allocate_blocks(4).unwrap();
        assert_eq!(a.free_blocks(), 6);
        assert!(a.allocate_blocks(7).is_err());
        assert_eq!(a.free_blocks(), 6, "failed allocation must not mutate");
        a.release(4).unwrap();
        assert_eq!(a.free_blocks(), 10);
    }

    #[test]
    fn release_rejects_over_release() {
        let mut a = BlockAllocator::new(10, 16);
        a.allocate(33).unwrap(); // 3 blocks out
        // Double release: the second free of 3 would exceed total_blocks.
        a.release(3).unwrap();
        let e = a.release(3).unwrap_err();
        assert!(format!("{e:#}").contains("over-release"), "{e:#}");
        assert_eq!(a.free_blocks(), 10, "failed release must not corrupt state");
        // Releasing more than ever existed errors too.
        let mut b = BlockAllocator::new(4, 16);
        assert!(b.release(5).is_err());
    }

    #[test]
    fn from_capacity_sizing() {
        // Llama3.1-70B fp8 KV: 163840 B/token; 20 GB budget, 16-token blocks.
        let a = BlockAllocator::from_capacity(20e9, 163_840, 16).unwrap();
        assert_eq!(a.total_blocks, (20e9 / (163_840.0 * 16.0)) as usize);
        // matches Table 6: batch 16 × 8192 ≈ 131k tokens needs 8192 blocks.
        assert!(a.total_blocks > 7000);
    }

    #[test]
    fn from_layout_matches_from_capacity() {
        // The same Llama3.1-70B geometry through the shared contract.
        let fp8 = KvLayout::new(KvDtype::FP8_DEFAULT, 80, 8, 128);
        let a = BlockAllocator::from_layout(20e9, &fp8, 16).unwrap();
        let b = BlockAllocator::from_capacity(20e9, 163_840, 16).unwrap();
        assert_eq!(a.total_blocks, b.total_blocks);
        // f32 KV buys 4× fewer blocks from the same budget.
        let f32_l = KvLayout::new(KvDtype::F32, 80, 8, 128);
        let c = BlockAllocator::from_layout(20e9, &f32_l, 16).unwrap();
        assert!(a.total_blocks / c.total_blocks >= 3);
    }

    #[test]
    fn from_capacity_rejects_degenerate_geometry() {
        assert!(BlockAllocator::from_capacity(20e9, 0, 16).is_err());
        assert!(BlockAllocator::from_capacity(20e9, 163_840, 0).is_err());
        assert!(BlockAllocator::from_capacity(f64::NAN, 163_840, 16).is_err());
        assert!(BlockAllocator::from_capacity(-1.0, 163_840, 16).is_err());
        // Budget smaller than a single block: error, not a 0-block allocator.
        let e = BlockAllocator::from_capacity(1000.0, 163_840, 16).unwrap_err();
        assert!(format!("{e:#}").contains("does not fit"), "{e:#}");
    }

    #[test]
    fn pool_alloc_retain_release_lifecycle() {
        let mut p = BlockPool::new(4, 4, 1, 1, 2, KvDtype::F32);
        assert_eq!(p.free_blocks(), 4);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(p.used_blocks(), 2);
        assert_eq!(p.ref_count(a), 1);
        p.retain(a);
        assert_eq!(p.ref_count(a), 2);
        p.release(a);
        assert_eq!(p.ref_count(a), 1, "one reader left: block survives");
        assert_eq!(p.used_blocks(), 2);
        p.release(a);
        assert_eq!(p.ref_count(a), 0);
        assert_eq!(p.free_blocks(), 3, "last release returns the block");
        p.release(b);
        assert_eq!(p.free_blocks(), 4);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn pool_release_of_free_block_panics() {
        let mut p = BlockPool::new(2, 4, 1, 1, 2, KvDtype::F32);
        let a = p.alloc().unwrap();
        p.release(a);
        p.release(a);
    }

    #[test]
    fn pool_exhaustion_returns_none() {
        let mut p = BlockPool::new(1, 4, 1, 1, 2, KvDtype::F32);
        let a = p.alloc().unwrap();
        assert!(p.alloc().is_none());
        p.release(a);
        assert!(p.alloc().is_some());
    }

    #[test]
    fn slot_lifecycle() {
        let mut s = KvStore::new(2, 3, 8, 2, 4);
        let a = s.alloc_slot().unwrap();
        let b = s.alloc_slot().unwrap();
        assert_ne!(a, b);
        assert_eq!(s.active_slots(), vec![a, b]);
        s.free_slot(a);
        assert_eq!(s.active_slots(), vec![b]);
        let c = s.alloc_slot().unwrap();
        assert_eq!(c, a); // reuses freed slot
    }

    #[test]
    fn write_gather_scatter_roundtrip() {
        let (l, slots, t, kvh, hd) = (2, 4, 8, 2, 4);
        let mut s = KvStore::new(l, slots, t, kvh, hd);
        let slot = s.alloc_slot().unwrap();
        let ss = t * kvh * hd;
        let row = kvh * hd;
        let k_out: Vec<f32> = (0..l * ss).map(|i| i as f32).collect();
        let v_out: Vec<f32> = (0..l * ss).map(|i| -(i as f32)).collect();
        s.write_slot(slot, &k_out, &v_out, 5);
        assert_eq!(s.len(slot), Some(5));
        let (k, v, lens) = s.gather_batch(&[slot]);
        assert_eq!(lens, vec![5]);
        // Valid positions roundtrip bit-for-bit; the bucket-padded tail is
        // dropped by the paged store (attention never reads it).
        for li in 0..l {
            let base = li * ss;
            assert_eq!(k[base..base + 5 * row], k_out[base..base + 5 * row]);
            assert_eq!(v[base..base + 5 * row], v_out[base..base + 5 * row]);
            assert!(k[base + 5 * row..base + ss].iter().all(|x| *x == 0.0));
        }
        // Scatter appends exactly one position (the paged contract: only
        // the hot block's valid span is rewritten from the buffer).
        let k2: Vec<f32> = k.iter().map(|x| x + 1.0).collect();
        let full = s.scatter_batch(&[slot], &k2, &v);
        assert!(full.is_empty(), "5→6 of 8 is not full");
        assert_eq!(s.len(slot), Some(6));
        let (k3, _, _) = s.gather_batch(&[slot]);
        // t=8 < 16 ⇒ one block per slot: the whole valid span [0, 6) was
        // re-written from the +1 buffer.
        for li in 0..l {
            let base = li * ss;
            assert_eq!(k3[base..base + 6 * row], k2[base..base + 6 * row]);
            assert!(k3[base + 6 * row..base + ss].iter().all(|x| *x == 0.0));
        }
    }

    #[test]
    fn multi_block_scatter_touches_only_the_hot_block() {
        // bt = 4, len 6 → blocks [0,4) and [4,6): appending position 6
        // re-encodes only block 1; block 0's bytes are immutable history.
        let (l, t, kvh, hd, bt) = (1, 12, 1, 2, 4);
        let mut s = KvStore::with_block_tokens(l, 1, t, kvh, hd, KvDtype::F32, bt, 0);
        let slot = s.alloc_slot().unwrap();
        let ss = t * kvh * hd;
        let k_out: Vec<f32> = (0..l * ss).map(|i| 1.0 + i as f32).collect();
        s.write_slot(slot, &k_out, &k_out, 6);
        // A buffer that disagrees with history everywhere: only the hot
        // block's span [4, 7) may land.
        let buf: Vec<f32> = vec![99.0; l * ss];
        s.scatter_batch(&[slot], &buf, &buf);
        assert_eq!(s.len(slot), Some(7));
        let (k, _, _) = s.gather_batch(&[slot]);
        let row = kvh * hd;
        assert_eq!(k[..4 * row], k_out[..4 * row], "cold block must not move");
        assert!(k[4 * row..7 * row].iter().all(|x| *x == 99.0));
        assert!(k[7 * row..].iter().all(|x| *x == 0.0));
    }

    #[test]
    fn gather_multi_slot_interleaves_layers() {
        let (l, slots, t, kvh, hd) = (2, 4, 2, 1, 1);
        let mut s = KvStore::new(l, slots, t, kvh, hd);
        let a = s.alloc_slot().unwrap();
        let b = s.alloc_slot().unwrap();
        let ss = t * kvh * hd;
        s.write_slot(a, &vec![1.0; l * ss], &vec![1.5; l * ss], 1);
        s.write_slot(b, &vec![2.0; l * ss], &vec![2.5; l * ss], 2);
        let (k, _v, lens) = s.gather_batch(&[a, b]);
        // layout (L, B, T*, ...): layer0 = [a..., b...], layer1 = [a..., b...]
        // Slot a's second position is past its length: exact zero.
        assert_eq!(k, vec![1.0, 0.0, 2.0, 2.0, 1.0, 0.0, 2.0, 2.0]);
        assert_eq!(lens, vec![1, 2]);
    }

    #[test]
    fn freed_slot_is_zeroed() {
        let mut s = KvStore::new(1, 1, 2, 1, 1);
        let slot = s.alloc_slot().unwrap();
        s.write_slot(slot, &[9.0, 9.0], &[9.0, 9.0], 2);
        s.free_slot(slot);
        let slot = s.alloc_slot().unwrap();
        let (k, v, lens) = s.gather_batch(&[slot]);
        assert_eq!(k, vec![0.0, 0.0]);
        assert_eq!(v, vec![0.0, 0.0]);
        assert_eq!(lens, vec![0]);
    }

    #[test]
    fn freed_slot_is_zeroed_for_code_and_scale_storage() {
        for dtype in [
            KvDtype::Bf16,
            KvDtype::Fp8(Fp8Format::E4M3Gaudi2),
            KvDtype::Fp8(Fp8Format::E4M3),
            KvDtype::Fp8(Fp8Format::E5M2),
        ] {
            let mut s = KvStore::with_dtype(2, 2, 4, 2, 3, dtype);
            let slot = s.alloc_slot().unwrap();
            let n = 2 * 4 * 2 * 3;
            s.write_slot(slot, &vec![123.0; n], &vec![-77.0; n], 4);
            s.free_slot(slot);
            assert_eq!(s.pool().used_blocks(), 0, "{dtype:?}: block leak");
            let slot = s.alloc_slot().unwrap();
            let (k, v, lens) = s.gather_batch(&[slot]);
            assert!(k.iter().all(|x| *x == 0.0), "{dtype:?}: stale K");
            assert!(v.iter().all(|x| *x == 0.0), "{dtype:?}: stale V");
            assert_eq!(lens, vec![0]);
        }
    }

    #[test]
    fn scatter_signals_sequence_full_and_never_exceeds_capacity() {
        let (l, slots, t, kvh, hd) = (1, 2, 4, 1, 2);
        let mut s = KvStore::new(l, slots, t, kvh, hd);
        let slot = s.alloc_slot().unwrap();
        let ss = t * kvh * hd;
        s.write_slot(slot, &vec![1.0; l * ss], &vec![1.0; l * ss], 3);
        let buf = vec![2.0f32; l * ss];
        // 3 → 4 == t: the scatter reports the sequence as full.
        let full = s.scatter_batch(&[slot], &buf, &buf);
        assert_eq!(full, vec![slot]);
        assert_eq!(s.len(slot), Some(t));
        assert!(s.is_full(slot));
        assert_eq!(s.remaining(slot), Some(0));
        // A further (buggy) scatter keeps signalling and never exceeds t.
        let full = s.scatter_batch(&[slot], &buf, &buf);
        assert_eq!(full, vec![slot]);
        assert_eq!(s.len(slot), Some(t));
    }

    #[test]
    fn shared_prefix_blocks_are_mapped_not_copied_and_cow_isolates_writes() {
        let (l, t, kvh, hd, bt) = (1, 16, 1, 2, 4);
        let mut s = KvStore::with_block_tokens(l, 2, t, kvh, hd, KvDtype::F32, bt, 0);
        let ss = t * kvh * hd;
        let row = kvh * hd;
        let writer = s.alloc_slot().unwrap();
        let k_out: Vec<f32> = (0..l * ss).map(|i| 10.0 + i as f32).collect();
        s.write_slot(writer, &k_out, &k_out, 8); // blocks 0, 1
        let shared = s.slot_blocks(writer);
        assert_eq!(shared.len(), 2);

        // Map both blocks into a second slot at len 7 — inside block 1,
        // the engine's full-hit bootstrap shape.
        let reader = s.alloc_slot().unwrap();
        s.map_shared_prefix(reader, &shared, 7);
        assert_eq!(s.pool().ref_count(shared[0]), 2);
        assert_eq!(s.pool().ref_count(shared[1]), 2);
        assert_eq!(s.pool().used_blocks(), 2, "mapping allocates nothing");

        // The reader appends at position 7 → hot block 1 is shared → CoW.
        let buf: Vec<f32> = vec![777.0; l * ss];
        s.scatter_batch(&[reader], &buf, &buf);
        let rblocks = s.slot_blocks(reader);
        assert_eq!(rblocks[0], shared[0], "cold shared block still mapped");
        assert_ne!(rblocks[1], shared[1], "hot block must be copied on write");
        assert_eq!(s.pool().ref_count(shared[1]), 1, "writer keeps its block");
        assert_eq!(s.pool().ref_count(rblocks[1]), 1, "copy is private");

        // The writer's data is untouched; the reader sees its own write.
        let (kw, _, _) = s.gather_batch(&[writer]);
        assert_eq!(kw[..8 * row], k_out[..8 * row]);
        let (kr, _, _) = s.gather_batch(&[reader]);
        assert_eq!(kr[..4 * row], k_out[..4 * row], "block 0 still shared");
        assert!(kr[4 * row..8 * row].iter().all(|x| *x == 777.0));

        // Freeing the reader releases only its references.
        s.free_slot(reader);
        assert_eq!(s.pool().ref_count(shared[0]), 1);
        assert_eq!(s.pool().used_blocks(), 2, "writer's blocks survive");
    }

    #[test]
    fn fp8_store_quantizes_with_bounded_error() {
        let (l, slots, t, kvh, hd) = (2, 2, 8, 2, 4);
        let mut rng = XorShiftRng::new(3);
        let n = l * t * kvh * hd;
        let k_out: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let v_out: Vec<f32> = (0..n).map(|_| rng.normal() * 4.0).collect();
        let mut s = KvStore::with_dtype(l, slots, t, kvh, hd, KvDtype::Fp8(Fp8Format::E4M3Gaudi2));
        let slot = s.alloc_slot().unwrap();
        s.write_slot(slot, &k_out, &v_out, t);
        let (k, v, _) = s.gather_batch(&[slot]);
        // E4M3 (3 mantissa bits): per-element error ≤ maxabs·2^-4.
        let kmax = k_out.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        let vmax = v_out.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        for i in 0..n {
            assert!(
                (k[i] - k_out[i]).abs() <= kmax / 16.0 * 1.001,
                "K[{i}]: {} vs {}",
                k[i],
                k_out[i]
            );
            assert!(
                (v[i] - v_out[i]).abs() <= vmax / 16.0 * 1.001,
                "V[{i}]: {} vs {}",
                v[i],
                v_out[i]
            );
        }
        // A gather→scatter cycle at capacity is the full-signal no-write
        // path: values reproduce exactly.
        let (k0, v0, _) = s.gather_batch(&[slot]);
        s.scatter_batch(&[slot], &k0, &v0);
        let (k1, v1, _) = s.gather_batch(&[slot]);
        assert_eq!(k0, k1, "full-slot scatter must not rewrite history");
        assert_eq!(v0, v1);
    }

    #[test]
    fn fp8_pad_positions_do_not_coarsen_scales() {
        let (l, slots, t, kvh, hd) = (1, 1, 8, 1, 2);
        let mut s = KvStore::with_dtype(l, slots, t, kvh, hd, KvDtype::FP8_DEFAULT);
        let slot = s.alloc_slot().unwrap();
        let ss = t * kvh * hd;
        // Valid prefix of 2 tokens with |x| ≤ 1; the bucket-padded tail
        // holds huge garbage (prefill computes real activations for pad
        // tokens). A scale contaminated by the tail would flush the valid
        // values to zero (0.25 / (1e6/240) is below E4M3's subnormals).
        let mut k = vec![1e6f32; ss];
        k[..4].copy_from_slice(&[0.5, -1.0, 0.25, 1.0]);
        s.write_slot(slot, &k, &k, 2);
        let (kg, _, _) = s.gather_batch(&[slot]);
        for i in 0..4 {
            assert!(
                (kg[i] - k[i]).abs() <= 1.0 / 16.0 * 1.001,
                "valid token quantized on a pad-coarsened grid: kg[{i}]={}",
                kg[i]
            );
        }
        // The garbage tail is never stored, let alone persisted.
        assert!(kg[4..].iter().all(|x| *x == 0.0), "{kg:?}");
    }

    #[test]
    fn kv_bytes_derive_from_layout() {
        // t = 8 < 16 clamps the block to 8 tokens: 3 slots × 1 block each.
        let f32_s = KvStore::new(2, 3, 8, 2, 4);
        let layout = f32_s.layout();
        assert_eq!(f32_s.block_tokens(), 8);
        assert_eq!(f32_s.kv_bytes(), 3 * layout.block_bytes(8));
        assert_eq!(f32_s.kv_bytes(), 3 * 8 * layout.bytes_per_token());
        assert_eq!(f32_s.resident_bytes(), 0, "nothing written yet");
        let fp8_s = KvStore::with_dtype(2, 3, 8, 2, 4, KvDtype::FP8_DEFAULT);
        // 1 B/elem payload + per-block (not per-slot) scale metadata.
        assert_eq!(fp8_s.kv_bytes(), 3 * fp8_s.layout().block_bytes(8));
        assert!(fp8_s.kv_bytes() * 3 < f32_s.kv_bytes(), "fp8 ≈ 4× smaller");
        // Residency follows allocation, not slot count.
        let mut s = KvStore::new(1, 2, 8, 1, 2);
        let slot = s.alloc_slot().unwrap();
        s.write_slot(slot, &vec![1.0; 16], &vec![1.0; 16], 3);
        assert_eq!(s.resident_bytes(), s.layout().block_bytes(8));
    }

    #[test]
    fn append_token_matches_dense_scatter_reference_bitwise() {
        // The same logical writes through both paths — paged append vs
        // dense gather → poke → scatter — must store identical bytes:
        // append re-encodes the hot block from its dequantized history
        // exactly as the dense reference re-encodes it from the gathered
        // (dequantized) batch buffer.
        for dtype in [KvDtype::F32, KvDtype::Bf16, KvDtype::FP8_DEFAULT] {
            let (l, t, kvh, hd, bt) = (2, 12, 2, 3, 4);
            let mut a = KvStore::with_block_tokens(l, 1, t, kvh, hd, dtype, bt, 0);
            let mut b = KvStore::with_block_tokens(l, 1, t, kvh, hd, dtype, bt, 0);
            let sa = a.alloc_slot().unwrap();
            let sb = b.alloc_slot().unwrap();
            let mut rng = XorShiftRng::new(5);
            let ss = t * kvh * hd;
            let n = l * ss;
            let k0: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let v0: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            a.write_slot(sa, &k0, &v0, 6);
            b.write_slot(sb, &k0, &v0, 6);
            let row = kvh * hd;
            for step in 0..3 {
                let kr: Vec<f32> = (0..l * row).map(|_| rng.normal()).collect();
                let vr: Vec<f32> = (0..l * row).map(|_| rng.normal()).collect();
                assert_eq!(a.append_token(sa, &kr, &vr), AppendOutcome::Appended);
                let (mut kg, mut vg, _) = b.gather_batch(&[sb]);
                let len = b.len(sb).unwrap();
                for li in 0..l {
                    let base = (li * t + len) * row;
                    kg[base..base + row].copy_from_slice(&kr[li * row..(li + 1) * row]);
                    vg[base..base + row].copy_from_slice(&vr[li * row..(li + 1) * row]);
                }
                b.scatter_batch(&[sb], &kg, &vg);
                let (ka, va, la) = a.gather_batch(&[sa]);
                let (kb, vb, lb) = b.gather_batch(&[sb]);
                assert_eq!(la, lb, "{dtype:?} step {step}");
                for i in 0..n {
                    assert_eq!(ka[i].to_bits(), kb[i].to_bits(), "{dtype:?} K[{i}] step {step}");
                    assert_eq!(va[i].to_bits(), vb[i].to_bits(), "{dtype:?} V[{i}] step {step}");
                }
            }
        }
    }

    #[test]
    fn fork_slot_shares_history_and_isolates_branch_writes() {
        let (l, t, kvh, hd, bt) = (1, 16, 1, 2, 4);
        let mut s = KvStore::with_block_tokens(l, 2, t, kvh, hd, KvDtype::F32, bt, 0);
        let a = s.alloc_slot().unwrap();
        let ss = t * kvh * hd;
        let row = kvh * hd;
        let k0: Vec<f32> = (0..l * ss).map(|i| 1.0 + i as f32).collect();
        s.write_slot(a, &k0, &k0, 6); // blocks: [0, 4) full + [4, 6) partial
        let b = s.fork_slot(a).expect("free slot");
        assert_eq!(s.slot_blocks(a), s.slot_blocks(b), "fork maps, never copies");
        assert_eq!(s.len(b), Some(6));
        let shared = s.slot_blocks(a);
        assert_eq!(s.pool().ref_count(shared[0]), 2);
        assert_eq!(s.pool().ref_count(shared[1]), 2);
        assert_eq!(s.pool().used_blocks(), 2, "fork allocates nothing");
        // The branches diverge: each append CoWs its own hot block.
        let ka = vec![111.0f32; l * row];
        let kb = vec![222.0f32; l * row];
        assert_eq!(s.append_token(a, &ka, &ka), AppendOutcome::Appended);
        assert_eq!(s.append_token(b, &kb, &kb), AppendOutcome::Appended);
        let (nab, nbb) = (s.slot_blocks(a), s.slot_blocks(b));
        assert_eq!(nab[0], nbb[0], "cold shared history stays mapped once");
        assert_ne!(nab[1], nbb[1], "hot block must diverge per branch");
        assert_eq!(s.pool().used_blocks(), 3, "one CoW copy, shared root once");
        assert_eq!(s.pool().ref_count(nab[0]), 2);
        assert_eq!(s.pool().ref_count(nab[1]), 1);
        assert_eq!(s.pool().ref_count(nbb[1]), 1);
        // Each branch reads the shared history plus exactly its own write.
        let (kra, _, _) = s.gather_batch(&[a]);
        let (krb, _, _) = s.gather_batch(&[b]);
        assert_eq!(kra[..6 * row], k0[..6 * row]);
        assert_eq!(krb[..6 * row], k0[..6 * row]);
        assert!(kra[6 * row..7 * row].iter().all(|x| *x == 111.0));
        assert!(krb[6 * row..7 * row].iter().all(|x| *x == 222.0));
        s.free_slot(b);
        assert_eq!(s.pool().ref_count(nab[0]), 1, "branch release keeps a's refs");
        assert_eq!(s.pool().used_blocks(), 2);
    }

    #[test]
    fn fork_slot_reports_which_resource_is_missing() {
        // slots = 3, t = 12, bt = 4 → pool = 9 blocks.
        let (l, t, kvh, hd, bt) = (1, 12, 1, 2, 4);
        let mut s = KvStore::with_block_tokens(l, 3, t, kvh, hd, KvDtype::F32, bt, 0);
        let ss = t * kvh * hd;
        let a = s.alloc_slot().unwrap();
        s.write_slot(a, &vec![1.0; l * ss], &vec![1.0; l * ss], t); // 3 blocks
        assert_eq!(s.fork_slot(2), Err(ForkError::InactiveSource));
        // Slot axis: all tables occupied while free blocks remain.
        let b = s.fork_slot(a).expect("slots + blocks free");
        let c = s.alloc_slot().unwrap();
        s.write_slot(c, &vec![2.0; l * ss], &vec![2.0; l * ss], t); // 3 blocks
        assert!(s.pool().free_blocks() > 0);
        assert_eq!(s.fork_slot(a), Err(ForkError::NoFreeSlot));
        // Block axis: preempting the two beam siblings frees their slots
        // but their shared blocks stay pinned in the swap records, so the
        // pool can reach zero free blocks *with* free slots — exactly the
        // state a fork must refuse (its first append could not CoW).
        let rec_b = s.swap_out_slot(b); // shared with a → all Resident
        let d = s.alloc_slot().unwrap();
        s.write_slot(d, &vec![3.0; l * ss], &vec![3.0; l * ss], t); // last 3 blocks
        let rec_a = s.swap_out_slot(a); // shared with rec_b → all Resident
        assert_eq!(s.pool().free_blocks(), 0);
        assert!(s.has_free_slot());
        assert_eq!(s.fork_slot(c), Err(ForkError::NoFreeBlocks));
        // Dropping the records releases the pinned history: the identical
        // fork now succeeds — the two failures recover on different
        // events, which is why the error is typed.
        s.discard_swapped(rec_a);
        s.discard_swapped(rec_b);
        assert!(s.pool().free_blocks() > 0);
        let e = s.fork_slot(c).expect("blocks recovered");
        assert_eq!(s.len(e), Some(t));
    }

    #[test]
    fn truncate_slot_releases_dead_tail_blocks() {
        // bt = 4: write 11 tokens (3 blocks), roll back to 5 (2 blocks).
        let (l, t, kvh, hd, bt) = (2, 16, 1, 2, 4);
        let mut s = KvStore::with_block_tokens(l, 1, t, kvh, hd, KvDtype::F32, bt, 0);
        let slot = s.alloc_slot().unwrap();
        let ss = t * kvh * hd;
        let k0: Vec<f32> = (0..l * ss).map(|i| 1.0 + i as f32).collect();
        s.write_slot(slot, &k0, &k0, 11);
        assert_eq!(s.slot_blocks(slot).len(), 3);
        let used = s.pool().used_blocks();
        s.truncate_slot(slot, 5);
        assert_eq!(s.len(slot), Some(5));
        assert_eq!(s.slot_blocks(slot).len(), 2, "block 2 is wholly dead");
        assert_eq!(s.pool().used_blocks(), used - 1, "dead block returned");
        // Positions < 5 are untouched; past-len reads are exact zeros.
        let row = kvh * hd;
        let (k, _, lens) = s.gather_batch(&[slot]);
        assert_eq!(lens, vec![5]);
        for li in 0..l {
            let base = li * ss;
            assert_eq!(k[base..base + 5 * row], k0[base..base + 5 * row]);
            assert!(k[base + 5 * row..base + ss].iter().all(|x| *x == 0.0));
        }
        // Growing again is a plain append at the rollback point.
        let kr = vec![42.0f32; l * row];
        assert_eq!(s.append_token(slot, &kr, &kr), AppendOutcome::Appended);
        assert_eq!(s.len(slot), Some(6));
        let (k, _, _) = s.gather_batch(&[slot]);
        for li in 0..l {
            let base = li * ss;
            assert!(k[base + 5 * row..base + 6 * row].iter().all(|x| *x == 42.0));
        }
        // Truncate to a *larger* length is a no-op, never a grow.
        s.truncate_slot(slot, 12);
        assert_eq!(s.len(slot), Some(6));
    }

    #[test]
    fn truncate_inside_a_shared_block_keeps_the_block_and_its_readers() {
        // Fork at len 6 (blocks [0,4) + [4,6) shared), then roll the
        // branch back to 5 — inside shared block 1. The block must stay
        // mapped for both readers with refcounts unchanged, and the
        // branch's next append must CoW away exactly as a fresh fork
        // would.
        let (l, t, kvh, hd, bt) = (1, 16, 1, 2, 4);
        let mut s = KvStore::with_block_tokens(l, 2, t, kvh, hd, KvDtype::F32, bt, 0);
        let a = s.alloc_slot().unwrap();
        let ss = t * kvh * hd;
        let row = kvh * hd;
        let k0: Vec<f32> = (0..l * ss).map(|i| 1.0 + i as f32).collect();
        s.write_slot(a, &k0, &k0, 6);
        let b = s.fork_slot(a).expect("fork");
        let shared = s.slot_blocks(a);
        s.truncate_slot(b, 5);
        assert_eq!(s.len(b), Some(5));
        assert_eq!(s.slot_blocks(b), shared, "partial block survives rollback");
        assert_eq!(s.pool().ref_count(shared[0]), 2);
        assert_eq!(s.pool().ref_count(shared[1]), 2);
        let kb = vec![9.0f32; l * row];
        assert_eq!(s.append_token(b, &kb, &kb), AppendOutcome::Appended);
        let bb = s.slot_blocks(b);
        assert_ne!(bb[1], shared[1], "append after rollback CoWs the shared hot block");
        assert_eq!(s.pool().ref_count(shared[1]), 1, "a keeps its block");
        // a still reads its full 6-token history bit-for-bit; b reads 5
        // shared tokens plus its own divergent write at position 5.
        let (ka, _, _) = s.gather_batch(&[a]);
        assert_eq!(ka[..6 * row], k0[..6 * row]);
        let (kbr, _, _) = s.gather_batch(&[b]);
        assert_eq!(kbr[..5 * row], k0[..5 * row]);
        assert!(kbr[5 * row..6 * row].iter().all(|x| *x == 9.0));
    }

    #[test]
    fn truncate_then_append_reencodes_fp8_scales_over_the_valid_span_only() {
        // The rollback contract for scaled storage: stale rejected tokens
        // left inside the kept hot block must not poison the scales of
        // later appends. Write a huge-magnitude token at position 3,
        // roll back to 3, then append small tokens — their quantization
        // error must be on the small-value grid.
        let (l, t, kvh, hd, bt) = (1, 8, 1, 2, 4);
        let mut s = KvStore::with_block_tokens(l, 1, t, kvh, hd, KvDtype::FP8_DEFAULT, bt, 0);
        let slot = s.alloc_slot().unwrap();
        let ss = t * kvh * hd;
        let row = kvh * hd;
        let mut k0 = vec![0.0f32; l * ss];
        for (i, x) in k0.iter_mut().enumerate().take(3 * row) {
            *x = 0.25 + (i % 3) as f32 * 0.25; // |x| ≤ 0.75
        }
        k0[3 * row..4 * row].iter_mut().for_each(|x| *x = 1e6); // speculative junk
        s.write_slot(slot, &k0, &k0, 4);
        s.truncate_slot(slot, 3);
        let kr = vec![0.5f32; l * row];
        assert_eq!(s.append_token(slot, &kr, &kr), AppendOutcome::Appended);
        let (k, _, _) = s.gather_batch(&[slot]);
        // E4M3 on a maxabs ≈ 0.75 grid: error ≤ maxabs/16. A scale still
        // contaminated by the rejected 1e6 token would flush everything
        // to zero.
        for i in 0..4 * row {
            let want = if i < 3 * row { k0[i] } else { 0.5 };
            assert!(
                (k[i] - want).abs() <= 0.75 / 16.0 * 1.001,
                "stale rejected token poisoned the hot-block scale: k[{i}]={} want {}",
                k[i],
                want
            );
        }
    }

    #[test]
    fn paged_probe_reads_exactly_the_live_block_bytes() {
        // The zero-dense-materialization contract: a decode step's reads
        // equal the sum over the group of each slot's live block bytes —
        // no bucket padding, no window padding.
        for dtype in [KvDtype::F32, KvDtype::Bf16, KvDtype::FP8_DEFAULT] {
            let (l, t, kvh, hd, bt) = (2, 32, 2, 4, 4);
            let mut s = KvStore::with_block_tokens(l, 3, t, kvh, hd, dtype, bt, 0);
            let ss = t * kvh * hd;
            let buf: Vec<f32> = (0..l * ss).map(|i| (i % 7) as f32 * 0.25).collect();
            let lens = [5usize, 12, 32];
            let mut group = Vec::new();
            for &len in &lens {
                let slot = s.alloc_slot().unwrap();
                s.write_slot(slot, &buf, &buf, len);
                group.push(slot);
            }
            s.pool().reset_bytes_read();
            let _ = s.decode_attention_probe(&group, 3);
            let view = s.paged_view(&group);
            let expect = view.live_block_bytes();
            assert_eq!(s.pool().bytes_read(), expect as u64, "{dtype:?}");
            // The same number through the shared accounting contract.
            let blocks: usize = lens.iter().map(|&x| x.div_ceil(bt)).sum();
            assert_eq!(expect, blocks * s.layout().block_bytes(bt), "{dtype:?}");
            // Strictly less than any dense staging of the (B, T) window.
            let dense = group.len() * t.div_ceil(bt) * s.layout().block_bytes(bt);
            assert!(expect < dense, "{dtype:?}: padding crept back in");
        }
    }

    #[test]
    fn paged_view_exposes_tables_and_scale_refs() {
        let (l, t, kvh, hd, bt) = (2, 16, 2, 4, 4);
        let mut s = KvStore::with_block_tokens(l, 2, t, kvh, hd, KvDtype::FP8_DEFAULT, bt, 0);
        let slot = s.alloc_slot().unwrap();
        let ss = t * kvh * hd;
        let buf: Vec<f32> = (0..l * ss).map(|i| 0.5 + (i % 11) as f32).collect();
        s.write_slot(slot, &buf, &buf, 10);
        let view = s.paged_view(&[slot]);
        assert_eq!(view.num_slots(), 1);
        assert_eq!(view.slot(0).len, 10);
        assert_eq!(view.slot(0).blocks, s.slot_blocks(slot).as_slice());
        assert_eq!(view.slot(0).live_blocks(bt), 3);
        let (ks, vs) = view.block_scales(0, 0, 1).expect("fp8 has block scales");
        assert_eq!(ks.len(), kvh);
        assert_eq!(vs.len(), kvh);
        assert!(ks.iter().all(|x| *x > 0.0));
        // Scale-free dtypes expose no scale metadata.
        let mut f = KvStore::with_block_tokens(l, 1, t, kvh, hd, KvDtype::F32, bt, 0);
        let fs = f.alloc_slot().unwrap();
        f.write_slot(fs, &buf, &buf, 4);
        assert!(f.paged_view(&[fs]).block_scales(0, 0, 0).is_none());
    }

    #[test]
    fn append_token_capacity_and_boundary_semantics() {
        let (l, t, kvh, hd, bt) = (1, 8, 1, 2, 4);
        let mut s = KvStore::with_block_tokens(l, 1, t, kvh, hd, KvDtype::F32, bt, 0);
        let slot = s.alloc_slot().unwrap();
        let row = kvh * hd;
        let ss = t * row;
        s.write_slot(slot, &vec![1.0; l * ss], &vec![1.0; l * ss], 4); // exactly one full block
        assert_eq!(s.slot_blocks(slot).len(), 1);
        // Append exactly on the block boundary: allocates block 1.
        let kr = vec![2.0f32; l * row];
        assert_eq!(s.append_token(slot, &kr, &kr), AppendOutcome::Appended);
        assert_eq!(s.slot_blocks(slot).len(), 2);
        assert_eq!(s.len(slot), Some(5));
        // Fill to capacity: the append that reaches t reports Full…
        for _ in 5..t - 1 {
            assert_eq!(s.append_token(slot, &kr, &kr), AppendOutcome::Appended);
        }
        assert_eq!(s.append_token(slot, &kr, &kr), AppendOutcome::Full);
        assert_eq!(s.len(slot), Some(t));
        // …and past capacity nothing is written; the signal persists.
        let (k_before, _, _) = s.gather_batch(&[slot]);
        assert_eq!(s.append_token(slot, &kr, &kr), AppendOutcome::AtCapacity);
        assert_eq!(s.len(slot), Some(t));
        let (k_after, _, _) = s.gather_batch(&[slot]);
        assert_eq!(k_before, k_after, "at-capacity append must not write");
    }

    #[test]
    fn attention_probe_close_between_f32_and_fp8() {
        let (l, slots, t, kvh, hd) = (2, 2, 16, 2, 8);
        let mut rng = XorShiftRng::new(11);
        let n = l * t * kvh * hd;
        let k_out: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let v_out: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mut exact = KvStore::new(l, slots, t, kvh, hd);
        let mut quant = KvStore::with_dtype(l, slots, t, kvh, hd, KvDtype::FP8_DEFAULT);
        let se = exact.alloc_slot().unwrap();
        let sq = quant.alloc_slot().unwrap();
        exact.write_slot(se, &k_out, &v_out, t);
        quant.write_slot(sq, &k_out, &v_out, t);
        let pe = exact.decode_attention_probe(&[se], 99);
        let pq = quant.decode_attention_probe(&[sq], 99);
        assert_eq!(pe.len(), pq.len());
        let mse: f64 = pe
            .iter()
            .zip(&pq)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / pe.len() as f64;
        assert!(mse < 1e-2, "decode readout MSE {mse}");
        // And the exact store agrees with itself bit-for-bit.
        assert_eq!(pe, exact.decode_attention_probe(&[se], 99));
    }

    #[test]
    fn swap_roundtrip_is_bit_identical_for_every_dtype() {
        for dtype in [KvDtype::F32, KvDtype::Bf16, KvDtype::FP8_DEFAULT] {
            let (l, t, kvh, hd, bt) = (2, 24, 2, 3, 4);
            let mut s = KvStore::with_block_tokens(l, 2, t, kvh, hd, dtype, bt, 0);
            let slot = s.alloc_slot().unwrap();
            let ss = t * kvh * hd;
            let mut rng = XorShiftRng::new(17);
            let k0: Vec<f32> = (0..l * ss).map(|_| rng.normal()).collect();
            let v0: Vec<f32> = (0..l * ss).map(|_| rng.normal() * 3.0).collect();
            s.write_slot(slot, &k0, &v0, 14); // blocks 0..4, last partial
            let before = s.decode_attention_probe(&[slot], 7);
            let scales_before: Vec<Vec<f32>> = s
                .slot_blocks(slot)
                .iter()
                .filter_map(|&id| s.pool().block_scales(id, 1))
                .map(|(ks, vs)| ks.iter().chain(vs).copied().collect())
                .collect();

            let swapped = s.swap_out_slot(slot);
            assert_eq!(swapped.len(), 14, "{dtype:?}");
            assert_eq!(swapped.moved_blocks(), 4, "{dtype:?}");
            assert_eq!(swapped.resident_blocks(), 0, "{dtype:?}");
            assert_eq!(s.pool().used_blocks(), 0, "{dtype:?}: device fully freed");
            let rate = s.layout().block_bytes(bt);
            assert_eq!(swapped.swapped_bytes(&s.layout(), bt), 4 * rate, "{dtype:?}");

            let restored = s
                .swap_in_slot(swapped)
                .unwrap_or_else(|_| panic!("{dtype:?}: swap-in must succeed with a free pool"));
            assert_eq!(s.len(restored), Some(14), "{dtype:?}");
            // Codes and scales came back bit-for-bit: the probe — which
            // dequantizes every stored byte — reproduces exactly.
            let after = s.decode_attention_probe(&[restored], 7);
            assert_eq!(before.len(), after.len());
            for (i, (a, b)) in before.iter().zip(&after).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{dtype:?} probe[{i}]");
            }
            let scales_after: Vec<Vec<f32>> = s
                .slot_blocks(restored)
                .iter()
                .filter_map(|&id| s.pool().block_scales(id, 1))
                .map(|(ks, vs)| ks.iter().chain(vs).copied().collect())
                .collect();
            assert_eq!(scales_before, scales_after, "{dtype:?}: scales must travel");
        }
    }

    #[test]
    fn shared_blocks_stay_resident_and_pinned_across_a_swap() {
        let (l, t, kvh, hd, bt) = (1, 16, 1, 2, 4);
        let mut s = KvStore::with_block_tokens(l, 2, t, kvh, hd, KvDtype::F32, bt, 0);
        let ss = t * kvh * hd;
        let writer = s.alloc_slot().unwrap();
        let k0: Vec<f32> = (0..l * ss).map(|i| 1.0 + i as f32).collect();
        s.write_slot(writer, &k0, &k0, 8); // blocks 0, 1
        let shared = s.slot_blocks(writer);
        let reader = s.alloc_slot().unwrap();
        s.map_shared_prefix(reader, &shared, 8);
        // Grow the reader past the shared prefix: one private block.
        let kr = vec![9.0f32; l * kvh * hd];
        assert_eq!(s.append_token(reader, &kr, &kr), AppendOutcome::Appended);
        let rblocks = s.slot_blocks(reader);
        assert_eq!(rblocks.len(), 3);

        let swapped = s.swap_out_slot(reader);
        // Only the private tail block moved; the shared prefix stayed
        // resident with the reader's reference pinned in the record.
        assert_eq!(swapped.moved_blocks(), 1);
        assert_eq!(swapped.resident_blocks(), 2);
        assert_eq!(s.pool().ref_count(shared[0]), 2, "pin survives the swap");
        assert_eq!(s.pool().ref_count(shared[1]), 2);
        assert_eq!(s.pool().used_blocks(), 2, "private block left the device");

        let restored = s
            .swap_in_slot(swapped)
            .unwrap_or_else(|_| panic!("swap-in must succeed"));
        assert_eq!(s.len(restored), Some(9));
        let nb = s.slot_blocks(restored);
        assert_eq!(&nb[..2], &shared[..], "prefix re-spliced, not copied");
        assert_eq!(s.pool().ref_count(shared[0]), 2);
        let (krr, _, _) = s.gather_batch(&[restored]);
        let row = kvh * hd;
        assert_eq!(krr[..8 * row], k0[..8 * row]);
        assert!(krr[8 * row..9 * row].iter().all(|x| *x == 9.0));

        // Discard path (recompute-resume): pinned refs are released.
        let swapped = s.swap_out_slot(restored);
        s.discard_swapped(swapped);
        assert_eq!(s.pool().ref_count(shared[0]), 1, "pin released on discard");
        assert_eq!(s.pool().used_blocks(), 2, "writer keeps the prefix alive");
    }

    #[test]
    fn swap_in_fails_cleanly_without_headroom() {
        let (l, t, kvh, hd, bt) = (1, 8, 1, 2, 4);
        // Pool of exactly 2 blocks (t=8, bt=4, 1 slot, no extra).
        let mut s = KvStore::with_block_tokens(l, 1, t, kvh, hd, KvDtype::F32, bt, 0);
        let slot = s.alloc_slot().unwrap();
        let ss = t * kvh * hd;
        s.write_slot(slot, &vec![1.0; l * ss], &vec![2.0; l * ss], 8);
        let swapped = s.swap_out_slot(slot);
        assert_eq!(swapped.moved_blocks(), 2);
        // Refill the pool so the swap-in has a slot but no blocks.
        let hog = s.alloc_slot().unwrap();
        s.write_slot(hog, &vec![5.0; l * ss], &vec![5.0; l * ss], 8);
        assert!(!s.can_swap_in(&swapped));
        let swapped = match s.swap_in_slot(swapped) {
            Err(back) => back,
            Ok(_) => panic!("swap-in must fail with a full pool"),
        };
        assert_eq!(s.pool().free_blocks(), 0, "failed swap-in must not mutate");
        // Free the hog: now it goes through, data intact.
        s.free_slot(hog);
        let restored = s
            .swap_in_slot(swapped)
            .unwrap_or_else(|_| panic!("headroom restored"));
        let (k, v, _) = s.gather_batch(&[restored]);
        assert!(k.iter().all(|x| *x == 1.0));
        assert!(v.iter().all(|x| *x == 2.0));
    }
}
