//! KV-cache management: a page/block accounting allocator (the admission
//! model behind Table 6's OOM frontier) and the slot-based host KV store
//! the engine streams in/out of the decode artifacts.
//!
//! # The `KvLayout` accounting contract
//!
//! Every component that answers "what does a KV token cost?" derives the
//! rate from one shared [`KvLayout`] (dtype + model geometry):
//!
//! * [`BlockAllocator::from_layout`] — admission control sizes its block
//!   pool from `layout.bytes_per_token()`;
//! * `gaudisim::MemoryModel` — the Table 6 OOM frontier charges the same
//!   rate (FP8 KV by default, as in the paper);
//! * `router::SimReplica` — fleet admission budgets HBM minus FP8 weights
//!   at the same rate;
//! * [`KvStore`] — the host store's actual allocation is exactly
//!   `slots × layout.seq_bytes(t)`.
//!
//! FP8 KV stores one f32 max-abs scale per (slot, layer, kv-head) group
//! for each of K and V. That metadata is per-*sequence*, not per-token
//! (`layout.scale_bytes_per_seq()`, < 0.01% of any realistic sequence
//! payload), and is charged against the fixed workspace reserve so the
//! per-token rate — and with it the Table 6 frontier — stays exact.

use anyhow::{bail, Result};

use crate::fp8::bf16::{bf16_to_f32, f32_to_bf16};
use crate::fp8::{encode_rne, CastMode, DecodeTable, Fp8Format};
use crate::quant::{weight_scale_per_tensor, KvDtype, KvLayout};
use crate::util::rng::XorShiftRng;

/// Page-granular KV accounting (vLLM-style). Used for admission control and
/// by the gaudisim capacity experiments; pure bookkeeping, no data.
#[derive(Clone, Debug)]
pub struct BlockAllocator {
    pub block_tokens: usize,
    pub total_blocks: usize,
    free_blocks: usize,
}

impl BlockAllocator {
    pub fn new(total_blocks: usize, block_tokens: usize) -> Self {
        Self {
            block_tokens,
            total_blocks,
            free_blocks: total_blocks,
        }
    }

    /// Capacity sized from device HBM: bytes available for KV / bytes per
    /// block. Degenerate geometry (zero-sized blocks, non-finite or
    /// too-small budgets) is an error — a 0-block allocator would silently
    /// reject every request.
    pub fn from_capacity(
        kv_bytes_budget: f64,
        bytes_per_token: usize,
        block_tokens: usize,
    ) -> Result<Self> {
        if bytes_per_token == 0 || block_tokens == 0 {
            bail!(
                "degenerate KV block geometry: bytes_per_token={bytes_per_token}, \
                 block_tokens={block_tokens} (both must be > 0)"
            );
        }
        if !kv_bytes_budget.is_finite() || kv_bytes_budget < 0.0 {
            bail!("invalid KV byte budget {kv_bytes_budget}");
        }
        let block_bytes = (bytes_per_token * block_tokens) as f64;
        let blocks = (kv_bytes_budget / block_bytes).floor() as usize;
        if blocks == 0 {
            bail!(
                "KV budget {kv_bytes_budget:.0} B below one {block_bytes:.0}-B block \
                 ({block_tokens} tokens × {bytes_per_token} B/token) — model does not fit"
            );
        }
        Ok(Self::new(blocks, block_tokens))
    }

    /// Capacity sized from the shared accounting contract: bytes/token
    /// comes from the [`KvLayout`], the single source of truth also used
    /// by `MemoryModel` and `SimReplica`.
    pub fn from_layout(
        kv_bytes_budget: f64,
        layout: &KvLayout,
        block_tokens: usize,
    ) -> Result<Self> {
        Self::from_capacity(kv_bytes_budget, layout.bytes_per_token(), block_tokens)
    }

    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    pub fn free_blocks(&self) -> usize {
        self.free_blocks
    }

    pub fn can_allocate(&self, tokens: usize) -> bool {
        self.blocks_for(tokens) <= self.free_blocks
    }

    pub fn can_allocate_blocks(&self, blocks: usize) -> bool {
        blocks <= self.free_blocks
    }

    /// Allocate an exact block count (the prefix cache shares the pool at
    /// block granularity, so token-rounding must happen exactly once, at
    /// the caller).
    pub fn allocate_blocks(&mut self, blocks: usize) -> Result<()> {
        if blocks > self.free_blocks {
            bail!(
                "KV OOM: need {blocks} blocks, {} free of {}",
                self.free_blocks,
                self.total_blocks
            );
        }
        self.free_blocks -= blocks;
        Ok(())
    }

    pub fn allocate(&mut self, tokens: usize) -> Result<usize> {
        let need = self.blocks_for(tokens);
        if need > self.free_blocks {
            bail!(
                "KV OOM: need {need} blocks, {} free of {}",
                self.free_blocks,
                self.total_blocks
            );
        }
        self.free_blocks -= need;
        Ok(need)
    }

    /// Checked release: freeing more blocks than are outstanding is a
    /// double-release accounting bug, not a condition to clamp over —
    /// clamping would hide the corruption until admission over-commits.
    pub fn release(&mut self, blocks: usize) -> Result<()> {
        if self.free_blocks + blocks > self.total_blocks {
            bail!(
                "KV block over-release: freeing {blocks} with {} free of {} \
                 (double release?)",
                self.free_blocks,
                self.total_blocks
            );
        }
        self.free_blocks += blocks;
        Ok(())
    }

    pub fn utilization(&self) -> f64 {
        1.0 - self.free_blocks as f64 / self.total_blocks.max(1) as f64
    }
}

/// Dtype-specific backing storage of a [`KvStore`]: raw values (F32/BF16)
/// or FP8 codes plus per-(layer, slot, kv-head) max-abs scales, K and V
/// scaled independently.
enum KvData {
    F32 {
        k: Vec<f32>,
        v: Vec<f32>,
    },
    Bf16 {
        k: Vec<u16>,
        v: Vec<u16>,
    },
    Fp8 {
        format: Fp8Format,
        table: DecodeTable,
        k: Vec<u8>,
        v: Vec<u8>,
        /// One scale per (layer, slot, kv-head), row-major in that order;
        /// freed groups reset to 1.0.
        k_scale: Vec<f32>,
        v_scale: Vec<f32>,
    },
}

/// Quantize one (T, Hkv, D) region with a fresh max-abs scale per kv-head.
/// The scale is `maxabs / r_q` (sanitized to 1.0 for all-zero groups), so
/// the group's max lands exactly on the largest representable magnitude.
///
/// Only positions `< valid_t` are scanned and encoded; the tail is zeroed.
/// Prefill artifacts hand over bucket-padded buffers whose positions past
/// the prompt hold real (pad-token) activations — attention masks them,
/// but letting them into the max-abs would coarsen the valid tokens' grid.
#[allow(clippy::too_many_arguments)]
fn encode_region_fp8(
    src: &[f32],
    dst: &mut [u8],
    scales: &mut [f32],
    valid_t: usize,
    t: usize,
    kv_heads: usize,
    head_dim: usize,
    format: Fp8Format,
) {
    for h in 0..kv_heads {
        let mut maxabs = 0.0f32;
        for ti in 0..valid_t {
            let base = (ti * kv_heads + h) * head_dim;
            for d in 0..head_dim {
                maxabs = maxabs.max(src[base + d].abs());
            }
        }
        // Clamp to the f32 normal range: a deep-subnormal group max would
        // otherwise yield a scale whose reciprocal overflows to infinity
        // and poisons the codes with NaN.
        let s = weight_scale_per_tensor(maxabs, format).max(f32::MIN_POSITIVE);
        scales[h] = s;
        let inv = 1.0 / s;
        for ti in 0..valid_t {
            let base = (ti * kv_heads + h) * head_dim;
            for d in 0..head_dim {
                dst[base + d] = encode_rne(src[base + d] * inv, format, CastMode::SatFinite);
            }
        }
        for ti in valid_t..t {
            let base = (ti * kv_heads + h) * head_dim;
            dst[base..base + head_dim].fill(0);
        }
    }
}

/// Dequantize one (T, Hkv, D) region using the per-head scales.
fn decode_region_fp8(
    src: &[u8],
    dst: &mut [f32],
    scales: &[f32],
    table: &DecodeTable,
    t: usize,
    kv_heads: usize,
    head_dim: usize,
) {
    for h in 0..kv_heads {
        let s = scales[h];
        for ti in 0..t {
            let base = (ti * kv_heads + h) * head_dim;
            for d in 0..head_dim {
                dst[base + d] = table.get(src[base + d]) * s;
            }
        }
    }
}

/// Host-side KV storage for `slots` concurrent sequences with capacity `t`
/// tokens each, layout (L, slot, T, Hkv, D) matching the decode artifact.
/// Storage is [`KvDtype`]-backed: F32 roundtrips bit-exactly, BF16 rounds
/// to 2 B/elem, FP8 quantizes on `write_slot`/`scatter_batch` and
/// dequantizes on `gather_batch_into` (codes + per-(slot, layer, kv-head)
/// scales — the paper's 1 B/elem serving configuration).
pub struct KvStore {
    pub layers: usize,
    pub slots: usize,
    pub t: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    data: KvData,
    /// Valid tokens per slot; None = slot free.
    lens: Vec<Option<usize>>,
}

impl KvStore {
    /// F32 store — the exact-roundtrip legacy configuration.
    pub fn new(layers: usize, slots: usize, t: usize, kv_heads: usize, head_dim: usize) -> Self {
        Self::with_dtype(layers, slots, t, kv_heads, head_dim, KvDtype::F32)
    }

    pub fn with_dtype(
        layers: usize,
        slots: usize,
        t: usize,
        kv_heads: usize,
        head_dim: usize,
        dtype: KvDtype,
    ) -> Self {
        let n = layers * slots * t * kv_heads * head_dim;
        let data = match dtype {
            KvDtype::F32 => KvData::F32 {
                k: vec![0.0; n],
                v: vec![0.0; n],
            },
            KvDtype::Bf16 => KvData::Bf16 {
                k: vec![0; n],
                v: vec![0; n],
            },
            KvDtype::Fp8(format) => KvData::Fp8 {
                format,
                table: DecodeTable::new(format),
                k: vec![0; n],
                v: vec![0; n],
                k_scale: vec![1.0; layers * slots * kv_heads],
                v_scale: vec![1.0; layers * slots * kv_heads],
            },
        };
        Self {
            layers,
            slots,
            t,
            kv_heads,
            head_dim,
            data,
            lens: vec![None; slots],
        }
    }

    pub fn dtype(&self) -> KvDtype {
        match &self.data {
            KvData::F32 { .. } => KvDtype::F32,
            KvData::Bf16 { .. } => KvDtype::Bf16,
            KvData::Fp8 { format, .. } => KvDtype::Fp8(*format),
        }
    }

    /// The accounting contract this store's storage follows.
    pub fn layout(&self) -> KvLayout {
        KvLayout::new(self.dtype(), self.layers, self.kv_heads, self.head_dim)
    }

    fn slot_stride(&self) -> usize {
        self.t * self.kv_heads * self.head_dim
    }

    fn layer_stride(&self) -> usize {
        self.slots * self.slot_stride()
    }

    fn scale_idx(&self, layer: usize, slot: usize) -> usize {
        (layer * self.slots + slot) * self.kv_heads
    }

    pub fn alloc_slot(&mut self) -> Option<usize> {
        let idx = self.lens.iter().position(|l| l.is_none())?;
        self.lens[idx] = Some(0);
        Some(idx)
    }

    pub fn free_slot(&mut self, slot: usize) {
        self.lens[slot] = None;
        // Zero the slot (and reset scales) so stale keys can never leak
        // into a new request.
        let (ls, ss) = (self.layer_stride(), self.slot_stride());
        let (layers, slots, hk) = (self.layers, self.slots, self.kv_heads);
        match &mut self.data {
            KvData::F32 { k, v } => {
                for l in 0..layers {
                    let base = l * ls + slot * ss;
                    k[base..base + ss].fill(0.0);
                    v[base..base + ss].fill(0.0);
                }
            }
            KvData::Bf16 { k, v } => {
                for l in 0..layers {
                    let base = l * ls + slot * ss;
                    k[base..base + ss].fill(0);
                    v[base..base + ss].fill(0);
                }
            }
            KvData::Fp8 {
                k, v, k_scale, v_scale, ..
            } => {
                for l in 0..layers {
                    let base = l * ls + slot * ss;
                    k[base..base + ss].fill(0);
                    v[base..base + ss].fill(0);
                    let si = (l * slots + slot) * hk;
                    k_scale[si..si + hk].fill(1.0);
                    v_scale[si..si + hk].fill(1.0);
                }
            }
        }
    }

    pub fn len(&self, slot: usize) -> Option<usize> {
        self.lens[slot]
    }

    /// Token positions still writable in `slot` (None = slot free).
    pub fn remaining(&self, slot: usize) -> Option<usize> {
        self.lens[slot].map(|l| self.t - l)
    }

    /// An active slot whose sequence has reached cache capacity: another
    /// decode step would have no position to write.
    pub fn is_full(&self, slot: usize) -> bool {
        self.lens[slot] == Some(self.t)
    }

    pub fn set_len(&mut self, slot: usize, len: usize) {
        assert!(len <= self.t);
        self.lens[slot] = Some(len);
    }

    pub fn active_slots(&self) -> Vec<usize> {
        (0..self.slots).filter(|s| self.lens[*s].is_some()).collect()
    }

    /// Write a prefill artifact's (L, 1, T, Hkv, D) output into `slot`,
    /// quantizing to the store's dtype.
    pub fn write_slot(&mut self, slot: usize, k_out: &[f32], v_out: &[f32], len: usize) {
        let ss = self.slot_stride();
        assert_eq!(k_out.len(), self.layers * ss, "prefill kv size");
        assert_eq!(v_out.len(), self.layers * ss, "prefill kv size");
        let ls = self.layer_stride();
        let (layers, slots, t) = (self.layers, self.slots, self.t);
        let (hk, d) = (self.kv_heads, self.head_dim);
        match &mut self.data {
            KvData::F32 { k, v } => {
                for l in 0..layers {
                    let dst = l * ls + slot * ss;
                    k[dst..dst + ss].copy_from_slice(&k_out[l * ss..(l + 1) * ss]);
                    v[dst..dst + ss].copy_from_slice(&v_out[l * ss..(l + 1) * ss]);
                }
            }
            KvData::Bf16 { k, v } => {
                for l in 0..layers {
                    let dst = l * ls + slot * ss;
                    for i in 0..ss {
                        k[dst + i] = f32_to_bf16(k_out[l * ss + i]);
                        v[dst + i] = f32_to_bf16(v_out[l * ss + i]);
                    }
                }
            }
            KvData::Fp8 {
                format,
                k,
                v,
                k_scale,
                v_scale,
                ..
            } => {
                let valid = len.min(t);
                for l in 0..layers {
                    let dst = l * ls + slot * ss;
                    let si = (l * slots + slot) * hk;
                    encode_region_fp8(
                        &k_out[l * ss..(l + 1) * ss],
                        &mut k[dst..dst + ss],
                        &mut k_scale[si..si + hk],
                        valid,
                        t,
                        hk,
                        d,
                        *format,
                    );
                    encode_region_fp8(
                        &v_out[l * ss..(l + 1) * ss],
                        &mut v[dst..dst + ss],
                        &mut v_scale[si..si + hk],
                        valid,
                        t,
                        hk,
                        d,
                        *format,
                    );
                }
            }
        }
        self.set_len(slot, len);
    }

    /// Gather `group` slots into a contiguous (L, B, T, Hkv, D) batch
    /// buffer for the decode artifact. Returns (k, v, lens).
    pub fn gather_batch(&self, group: &[usize]) -> (Vec<f32>, Vec<f32>, Vec<i32>) {
        let b = group.len();
        let ss = self.slot_stride();
        let mut k = vec![0.0f32; self.layers * b * ss];
        let mut v = vec![0.0f32; self.layers * b * ss];
        let lens = self.gather_batch_into(group, b, &mut k, &mut v);
        (k, v, lens)
    }

    /// Allocation-free gather into caller-owned buffers sized for a batch
    /// of `bucket` rows (§Perf L3: the per-step `vec!` zero-fill dominated
    /// the gather path), dequantizing to f32 on the way out. Rows ≥
    /// group.len() are left untouched — the engine zeroes padding rows only
    /// when the bucket grows. An FP8 store returns zeros past each slot's
    /// valid prefix (quantization never stored the masked pad positions);
    /// F32/BF16 stores pass whatever was written straight through.
    pub fn gather_batch_into(
        &self,
        group: &[usize],
        bucket: usize,
        k: &mut [f32],
        v: &mut [f32],
    ) -> Vec<i32> {
        let b = bucket;
        assert!(group.len() <= b);
        let ss = self.slot_stride();
        let ls = self.layer_stride();
        assert_eq!(k.len(), self.layers * b * ss, "k buffer size");
        assert_eq!(v.len(), self.layers * b * ss, "v buffer size");
        let mut lens = Vec::with_capacity(b);
        for (bi, &slot) in group.iter().enumerate() {
            lens.push(self.lens[slot].unwrap_or(0) as i32);
            for l in 0..self.layers {
                let src = l * ls + slot * ss;
                let dst = (l * b + bi) * ss;
                match &self.data {
                    KvData::F32 { k: ks, v: vs } => {
                        k[dst..dst + ss].copy_from_slice(&ks[src..src + ss]);
                        v[dst..dst + ss].copy_from_slice(&vs[src..src + ss]);
                    }
                    KvData::Bf16 { k: ks, v: vs } => {
                        for i in 0..ss {
                            k[dst + i] = bf16_to_f32(ks[src + i]);
                            v[dst + i] = bf16_to_f32(vs[src + i]);
                        }
                    }
                    KvData::Fp8 {
                        k: ks,
                        v: vs,
                        k_scale,
                        v_scale,
                        table,
                        ..
                    } => {
                        let si = self.scale_idx(l, slot);
                        decode_region_fp8(
                            &ks[src..src + ss],
                            &mut k[dst..dst + ss],
                            &k_scale[si..si + self.kv_heads],
                            table,
                            self.t,
                            self.kv_heads,
                            self.head_dim,
                        );
                        decode_region_fp8(
                            &vs[src..src + ss],
                            &mut v[dst..dst + ss],
                            &v_scale[si..si + self.kv_heads],
                            table,
                            self.t,
                            self.kv_heads,
                            self.head_dim,
                        );
                    }
                }
            }
        }
        lens.resize(b, 0);
        lens
    }

    /// Scatter an updated (L, B, T, Hkv, D) batch back into the slots
    /// (quantizing to the store's dtype) and bump their lengths.
    ///
    /// Returns the slots whose sequence just reached cache capacity
    /// (`len == t`) — the "sequence full" signal. The caller must finish
    /// those requests: a further decode step has no position to write, and
    /// the pre-signal behavior of clamping `len` at capacity silently
    /// overwrote the last position forever.
    pub fn scatter_batch(&mut self, group: &[usize], k_in: &[f32], v_in: &[f32]) -> Vec<usize> {
        let b = group.len();
        let ss = self.slot_stride();
        let ls = self.layer_stride();
        assert_eq!(k_in.len(), self.layers * b * ss);
        assert_eq!(v_in.len(), self.layers * b * ss);
        let (layers, slots, t) = (self.layers, self.slots, self.t);
        let (hk, d) = (self.kv_heads, self.head_dim);
        for (bi, &slot) in group.iter().enumerate() {
            // The decode step appended one position at the old length; only
            // that prefix carries real tokens (the tail is pad garbage the
            // attention mask hides — it must stay out of the FP8 scales).
            let valid = self.lens[slot].map_or(t, |l| (l + 1).min(t));
            for l in 0..layers {
                let dst = l * ls + slot * ss;
                let src = (l * b + bi) * ss;
                match &mut self.data {
                    KvData::F32 { k, v } => {
                        k[dst..dst + ss].copy_from_slice(&k_in[src..src + ss]);
                        v[dst..dst + ss].copy_from_slice(&v_in[src..src + ss]);
                    }
                    KvData::Bf16 { k, v } => {
                        for i in 0..ss {
                            k[dst + i] = f32_to_bf16(k_in[src + i]);
                            v[dst + i] = f32_to_bf16(v_in[src + i]);
                        }
                    }
                    KvData::Fp8 {
                        format,
                        k,
                        v,
                        k_scale,
                        v_scale,
                        ..
                    } => {
                        let si = (l * slots + slot) * hk;
                        encode_region_fp8(
                            &k_in[src..src + ss],
                            &mut k[dst..dst + ss],
                            &mut k_scale[si..si + hk],
                            valid,
                            t,
                            hk,
                            d,
                            *format,
                        );
                        encode_region_fp8(
                            &v_in[src..src + ss],
                            &mut v[dst..dst + ss],
                            &mut v_scale[si..si + hk],
                            valid,
                            t,
                            hk,
                            d,
                            *format,
                        );
                    }
                }
            }
        }
        let mut full = Vec::new();
        for &slot in group {
            if let Some(len) = self.lens[slot] {
                let bumped = (len + 1).min(self.t);
                self.lens[slot] = Some(bumped);
                if bumped == self.t {
                    full.push(slot);
                }
            }
        }
        full
    }

    /// Exact bytes this store allocates, derived from the shared layout:
    /// `slots × (t × bytes_per_token + scale_bytes_per_seq)`.
    pub fn kv_bytes(&self) -> usize {
        self.slots * self.layout().seq_bytes(self.t)
    }

    /// Single-step attention readout over the stored KV of `slots` — the
    /// numerical-fidelity probe tests and benches use to measure what KV
    /// quantization does to decode logits. For each (slot, layer, kv-head)
    /// a deterministic N(0,1) query attends (scaled dot-product softmax)
    /// over the valid positions; readouts are concatenated in
    /// (slot, layer, head, dim) order. Two stores holding the same written
    /// data produce comparable vectors regardless of dtype.
    pub fn decode_attention_probe(&self, slots: &[usize], seed: u64) -> Vec<f32> {
        let mut rng = XorShiftRng::new(seed);
        let d = self.head_dim;
        let ss = self.slot_stride();
        let (k, v, lens) = self.gather_batch(slots);
        let b = slots.len();
        let mut out = Vec::with_capacity(b * self.layers * self.kv_heads * d);
        for bi in 0..b {
            let len = (lens[bi].max(1)) as usize;
            for l in 0..self.layers {
                let base = (l * b + bi) * ss;
                for h in 0..self.kv_heads {
                    let q: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
                    let mut scores = Vec::with_capacity(len);
                    for ti in 0..len {
                        let off = base + (ti * self.kv_heads + h) * d;
                        let mut s = 0.0f32;
                        for (di, qd) in q.iter().enumerate() {
                            s += qd * k[off + di];
                        }
                        scores.push(s / (d as f32).sqrt());
                    }
                    let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let mut ws: Vec<f32> = scores.iter().map(|s| (s - m).exp()).collect();
                    let z: f32 = ws.iter().sum::<f32>().max(1e-30);
                    for w in &mut ws {
                        *w /= z;
                    }
                    for di in 0..d {
                        let mut acc = 0.0f32;
                        for (ti, w) in ws.iter().enumerate() {
                            let off = base + (ti * self.kv_heads + h) * d;
                            acc += w * v[off + di];
                        }
                        out.push(acc);
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_allocator_accounting() {
        let mut a = BlockAllocator::new(10, 16);
        assert_eq!(a.blocks_for(1), 1);
        assert_eq!(a.blocks_for(16), 1);
        assert_eq!(a.blocks_for(17), 2);
        assert!(a.can_allocate(160));
        assert!(!a.can_allocate(161));
        let got = a.allocate(33).unwrap(); // 3 blocks
        assert_eq!(got, 3);
        assert_eq!(a.free_blocks(), 7);
        assert!(a.allocate(160).is_err());
        a.release(3).unwrap();
        assert_eq!(a.free_blocks(), 10);
        assert_eq!(a.utilization(), 0.0);
    }

    #[test]
    fn block_granular_allocation() {
        let mut a = BlockAllocator::new(10, 16);
        assert!(a.can_allocate_blocks(10));
        assert!(!a.can_allocate_blocks(11));
        a.allocate_blocks(4).unwrap();
        assert_eq!(a.free_blocks(), 6);
        assert!(a.allocate_blocks(7).is_err());
        assert_eq!(a.free_blocks(), 6, "failed allocation must not mutate");
        a.release(4).unwrap();
        assert_eq!(a.free_blocks(), 10);
    }

    #[test]
    fn release_rejects_over_release() {
        let mut a = BlockAllocator::new(10, 16);
        a.allocate(33).unwrap(); // 3 blocks out
        // Double release: the second free of 3 would exceed total_blocks.
        a.release(3).unwrap();
        let e = a.release(3).unwrap_err();
        assert!(format!("{e:#}").contains("over-release"), "{e:#}");
        assert_eq!(a.free_blocks(), 10, "failed release must not corrupt state");
        // Releasing more than ever existed errors too.
        let mut b = BlockAllocator::new(4, 16);
        assert!(b.release(5).is_err());
    }

    #[test]
    fn from_capacity_sizing() {
        // Llama3.1-70B fp8 KV: 163840 B/token; 20 GB budget, 16-token blocks.
        let a = BlockAllocator::from_capacity(20e9, 163_840, 16).unwrap();
        assert_eq!(a.total_blocks, (20e9 / (163_840.0 * 16.0)) as usize);
        // matches Table 6: batch 16 × 8192 ≈ 131k tokens needs 8192 blocks.
        assert!(a.total_blocks > 7000);
    }

    #[test]
    fn from_layout_matches_from_capacity() {
        // The same Llama3.1-70B geometry through the shared contract.
        let fp8 = KvLayout::new(KvDtype::FP8_DEFAULT, 80, 8, 128);
        let a = BlockAllocator::from_layout(20e9, &fp8, 16).unwrap();
        let b = BlockAllocator::from_capacity(20e9, 163_840, 16).unwrap();
        assert_eq!(a.total_blocks, b.total_blocks);
        // f32 KV buys 4× fewer blocks from the same budget.
        let f32_l = KvLayout::new(KvDtype::F32, 80, 8, 128);
        let c = BlockAllocator::from_layout(20e9, &f32_l, 16).unwrap();
        assert!(a.total_blocks / c.total_blocks >= 3);
    }

    #[test]
    fn from_capacity_rejects_degenerate_geometry() {
        assert!(BlockAllocator::from_capacity(20e9, 0, 16).is_err());
        assert!(BlockAllocator::from_capacity(20e9, 163_840, 0).is_err());
        assert!(BlockAllocator::from_capacity(f64::NAN, 163_840, 16).is_err());
        assert!(BlockAllocator::from_capacity(-1.0, 163_840, 16).is_err());
        // Budget smaller than a single block: error, not a 0-block allocator.
        let e = BlockAllocator::from_capacity(1000.0, 163_840, 16).unwrap_err();
        assert!(format!("{e:#}").contains("does not fit"), "{e:#}");
    }

    #[test]
    fn slot_lifecycle() {
        let mut s = KvStore::new(2, 3, 8, 2, 4);
        let a = s.alloc_slot().unwrap();
        let b = s.alloc_slot().unwrap();
        assert_ne!(a, b);
        assert_eq!(s.active_slots(), vec![a, b]);
        s.free_slot(a);
        assert_eq!(s.active_slots(), vec![b]);
        let c = s.alloc_slot().unwrap();
        assert_eq!(c, a); // reuses freed slot
    }

    #[test]
    fn write_gather_scatter_roundtrip() {
        let (l, slots, t, kvh, hd) = (2, 4, 8, 2, 4);
        let mut s = KvStore::new(l, slots, t, kvh, hd);
        let slot = s.alloc_slot().unwrap();
        let ss = t * kvh * hd;
        let k_out: Vec<f32> = (0..l * ss).map(|i| i as f32).collect();
        let v_out: Vec<f32> = (0..l * ss).map(|i| -(i as f32)).collect();
        s.write_slot(slot, &k_out, &v_out, 5);
        assert_eq!(s.len(slot), Some(5));
        let (k, v, lens) = s.gather_batch(&[slot]);
        assert_eq!(k, k_out);
        assert_eq!(v, v_out);
        assert_eq!(lens, vec![5]);
        // scatter back modified data and check the bump.
        let k2: Vec<f32> = k.iter().map(|x| x + 1.0).collect();
        let full = s.scatter_batch(&[slot], &k2, &v);
        assert!(full.is_empty(), "5→6 of 8 is not full");
        assert_eq!(s.len(slot), Some(6));
        let (k3, _, _) = s.gather_batch(&[slot]);
        assert_eq!(k3, k2);
    }

    #[test]
    fn gather_multi_slot_interleaves_layers() {
        let (l, slots, t, kvh, hd) = (2, 4, 2, 1, 1);
        let mut s = KvStore::new(l, slots, t, kvh, hd);
        let a = s.alloc_slot().unwrap();
        let b = s.alloc_slot().unwrap();
        let ss = t * kvh * hd;
        s.write_slot(a, &vec![1.0; l * ss], &vec![1.5; l * ss], 1);
        s.write_slot(b, &vec![2.0; l * ss], &vec![2.5; l * ss], 2);
        let (k, _v, lens) = s.gather_batch(&[a, b]);
        // layout (L, B, T*, ...): layer0 = [a..., b...], layer1 = [a..., b...]
        assert_eq!(k, vec![1.0, 1.0, 2.0, 2.0, 1.0, 1.0, 2.0, 2.0]);
        assert_eq!(lens, vec![1, 2]);
    }

    #[test]
    fn freed_slot_is_zeroed() {
        let mut s = KvStore::new(1, 1, 2, 1, 1);
        let slot = s.alloc_slot().unwrap();
        s.write_slot(slot, &[9.0, 9.0], &[9.0, 9.0], 2);
        s.free_slot(slot);
        let slot = s.alloc_slot().unwrap();
        let (k, v, lens) = s.gather_batch(&[slot]);
        assert_eq!(k, vec![0.0, 0.0]);
        assert_eq!(v, vec![0.0, 0.0]);
        assert_eq!(lens, vec![0]);
    }

    #[test]
    fn freed_slot_is_zeroed_for_code_and_scale_storage() {
        for dtype in [
            KvDtype::Bf16,
            KvDtype::Fp8(Fp8Format::E4M3Gaudi2),
            KvDtype::Fp8(Fp8Format::E4M3),
            KvDtype::Fp8(Fp8Format::E5M2),
        ] {
            let mut s = KvStore::with_dtype(2, 2, 4, 2, 3, dtype);
            let slot = s.alloc_slot().unwrap();
            let n = 2 * 4 * 2 * 3;
            s.write_slot(slot, &vec![123.0; n], &vec![-77.0; n], 4);
            s.free_slot(slot);
            let slot = s.alloc_slot().unwrap();
            let (k, v, lens) = s.gather_batch(&[slot]);
            assert!(k.iter().all(|x| *x == 0.0), "{dtype:?}: stale K");
            assert!(v.iter().all(|x| *x == 0.0), "{dtype:?}: stale V");
            assert_eq!(lens, vec![0]);
        }
    }

    #[test]
    fn scatter_signals_sequence_full_and_never_exceeds_capacity() {
        let (l, slots, t, kvh, hd) = (1, 2, 4, 1, 2);
        let mut s = KvStore::new(l, slots, t, kvh, hd);
        let slot = s.alloc_slot().unwrap();
        let ss = t * kvh * hd;
        s.write_slot(slot, &vec![1.0; l * ss], &vec![1.0; l * ss], 3);
        let buf = vec![2.0f32; l * ss];
        // 3 → 4 == t: the scatter reports the sequence as full.
        let full = s.scatter_batch(&[slot], &buf, &buf);
        assert_eq!(full, vec![slot]);
        assert_eq!(s.len(slot), Some(t));
        assert!(s.is_full(slot));
        assert_eq!(s.remaining(slot), Some(0));
        // A further (buggy) scatter keeps signalling and never exceeds t.
        let full = s.scatter_batch(&[slot], &buf, &buf);
        assert_eq!(full, vec![slot]);
        assert_eq!(s.len(slot), Some(t));
    }

    #[test]
    fn fp8_store_quantizes_with_bounded_error() {
        let (l, slots, t, kvh, hd) = (2, 2, 8, 2, 4);
        let mut rng = XorShiftRng::new(3);
        let n = l * t * kvh * hd;
        let k_out: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let v_out: Vec<f32> = (0..n).map(|_| rng.normal() * 4.0).collect();
        let mut s = KvStore::with_dtype(l, slots, t, kvh, hd, KvDtype::Fp8(Fp8Format::E4M3Gaudi2));
        let slot = s.alloc_slot().unwrap();
        s.write_slot(slot, &k_out, &v_out, t);
        let (k, v, _) = s.gather_batch(&[slot]);
        // E4M3 (3 mantissa bits): per-element error ≤ maxabs·2^-4.
        let kmax = k_out.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        let vmax = v_out.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        for i in 0..n {
            assert!(
                (k[i] - k_out[i]).abs() <= kmax / 16.0 * 1.001,
                "K[{i}]: {} vs {}",
                k[i],
                k_out[i]
            );
            assert!(
                (v[i] - v_out[i]).abs() <= vmax / 16.0 * 1.001,
                "V[{i}]: {} vs {}",
                v[i],
                v_out[i]
            );
        }
        // Requantizing already-quantized data must not drift: the codes are
        // stable (values sit on grid points, far from rounding midpoints),
        // and only the recomputed scale may move by one f32 ulp — so a
        // gather→scatter cycle reproduces every value to ~2^-22 relative.
        let (k0, v0, _) = s.gather_batch(&[slot]);
        s.scatter_batch(&[slot], &k0, &v0);
        let (k1, v1, _) = s.gather_batch(&[slot]);
        for (a, b) in k0.iter().zip(&k1).chain(v0.iter().zip(&v1)) {
            assert!(
                (a - b).abs() <= a.abs() * 3e-7,
                "requantization drift: {a} vs {b}"
            );
        }
    }

    #[test]
    fn fp8_pad_positions_do_not_coarsen_scales() {
        let (l, slots, t, kvh, hd) = (1, 1, 8, 1, 2);
        let mut s = KvStore::with_dtype(l, slots, t, kvh, hd, KvDtype::FP8_DEFAULT);
        let slot = s.alloc_slot().unwrap();
        let ss = t * kvh * hd;
        // Valid prefix of 2 tokens with |x| ≤ 1; the bucket-padded tail
        // holds huge garbage (prefill computes real activations for pad
        // tokens). A scale contaminated by the tail would flush the valid
        // values to zero (0.25 / (1e6/240) is below E4M3's subnormals).
        let mut k = vec![1e6f32; ss];
        k[..4].copy_from_slice(&[0.5, -1.0, 0.25, 1.0]);
        s.write_slot(slot, &k, &k, 2);
        let (kg, _, _) = s.gather_batch(&[slot]);
        for i in 0..4 {
            assert!(
                (kg[i] - k[i]).abs() <= 1.0 / 16.0 * 1.001,
                "valid token quantized on a pad-coarsened grid: kg[{i}]={}",
                kg[i]
            );
        }
        // The garbage tail is zeroed, not persisted.
        assert!(kg[4..].iter().all(|x| *x == 0.0), "{kg:?}");
    }

    #[test]
    fn kv_bytes_derive_from_layout() {
        let f32_s = KvStore::new(2, 3, 8, 2, 4);
        assert_eq!(f32_s.kv_bytes(), 2 * 2 * 3 * 8 * 2 * 4 * 4);
        assert_eq!(f32_s.kv_bytes(), 3 * f32_s.layout().seq_bytes(8));
        let fp8_s = KvStore::with_dtype(2, 3, 8, 2, 4, KvDtype::FP8_DEFAULT);
        // 1 B payload + 2·L·Hkv·4 B scales per slot.
        assert_eq!(fp8_s.kv_bytes(), 3 * (8 * 2 * 2 * 2 * 4 + 2 * 2 * 2 * 4));
        assert!(fp8_s.kv_bytes() * 3 < f32_s.kv_bytes(), "fp8 ≈ 4× smaller");
    }

    #[test]
    fn attention_probe_close_between_f32_and_fp8() {
        let (l, slots, t, kvh, hd) = (2, 2, 16, 2, 8);
        let mut rng = XorShiftRng::new(11);
        let n = l * t * kvh * hd;
        let k_out: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let v_out: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mut exact = KvStore::new(l, slots, t, kvh, hd);
        let mut quant = KvStore::with_dtype(l, slots, t, kvh, hd, KvDtype::FP8_DEFAULT);
        let se = exact.alloc_slot().unwrap();
        let sq = quant.alloc_slot().unwrap();
        exact.write_slot(se, &k_out, &v_out, t);
        quant.write_slot(sq, &k_out, &v_out, t);
        let pe = exact.decode_attention_probe(&[se], 99);
        let pq = quant.decode_attention_probe(&[sq], 99);
        assert_eq!(pe.len(), pq.len());
        let mse: f64 = pe
            .iter()
            .zip(&pq)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / pe.len() as f64;
        assert!(mse < 1e-2, "decode readout MSE {mse}");
        // And the exact store agrees with itself bit-for-bit.
        assert_eq!(pe, exact.decode_attention_probe(&[se], 99));
    }
}
