//! Shared-prefix KV cache: a radix tree over token-ID prefixes whose nodes
//! own refcounted **physical block IDs** in the paged [`BlockPool`].
//!
//! Serving traffic is dominated by requests that share a long common prompt
//! prefix (system prompts, few-shot preambles, multi-turn history). Without
//! sharing, every request re-prefills and re-stores its full prompt — the
//! prefill FLOPs and KV bytes that bound the paper's end-to-end numbers
//! (Tables 5–6). This module caches prompt KV at *block* granularity in a
//! radix tree so a new request pays only for its uncached tail — and since
//! PR 4, a hit **maps** the cached physical blocks into the request's block
//! table instead of copying an assembled prefix into a private slot: N
//! concurrent requests sharing a P-token prompt hold P tokens of HBM once.
//!
//! * **Tree shape** — every edge label is a positive multiple of
//!   `block_tokens`; children of a node always differ somewhere inside
//!   their first block (splits happen at block-aligned divergence points),
//!   so at most one child can match a whole block of a probe prompt.
//! * **Per-block refcounts** — each cached block counts the active
//!   sequences whose acquired prefix reaches into it. Splits slice the
//!   refcount vector along with the edge label, so pins survive tree
//!   restructuring exactly.
//! * **Eviction** — only refcount-0 *leaves* are evictable (an interior
//!   node is the prefix of its children and must outlive them); victims go
//!   LRU-first by `last_use`. A referenced block is never freed. Evicting
//!   a physical-backed node releases its block IDs back to the pool
//!   ([`PrefixCache::evict_blocks_pooled`]).
//! * **Byte accounting** — capacity is expressed in blocks, converted
//!   from/to bytes through the shared [`KvLayout`] contract
//!   ([`PrefixCacheConfig::from_bytes_budget`], [`PrefixCache::cached_bytes`]),
//!   so admission control charges cached prefixes at exactly the rate the
//!   rest of the stack charges KV.
//! * **Physical payloads** — engine-side caches adopt the freshly
//!   prefilled slot's blocks via [`PrefixCache::insert_shared`] (one
//!   `retain` per block — no bytes move) and hand hits out through
//!   [`PrefixCache::mapped_blocks`]. The simulated replicas cache
//!   accounting only ([`PrefixCache::insert`], no block IDs).

use super::kvcache::{BlockId, BlockPool};
use crate::quant::KvLayout;

/// Configuration for a [`PrefixCache`].
#[derive(Clone, Debug)]
pub struct PrefixCacheConfig {
    /// Cache granularity in tokens; matches only whole blocks are shared.
    pub block_tokens: usize,
    /// Hard bound on cached blocks; inserts evict (or truncate) to fit.
    pub max_blocks: usize,
    /// The byte-accounting contract cached blocks are charged through.
    pub layout: KvLayout,
}

impl PrefixCacheConfig {
    /// Size the block budget from a byte budget at the layout's rate.
    pub fn from_bytes_budget(layout: KvLayout, block_tokens: usize, bytes: f64) -> Self {
        let bt = block_tokens.max(1);
        let block_bytes = (layout.bytes_per_token() * bt).max(1) as f64;
        let max_blocks = if bytes.is_finite() && bytes > 0.0 {
            (bytes / block_bytes).floor() as usize
        } else {
            0
        };
        Self {
            block_tokens: bt,
            max_blocks,
            layout,
        }
    }
}

/// Counters the cache maintains internally (callers thread hit/miss into
/// their own `ServeMetrics` — the cache cannot tell a routing probe from an
/// admission).
#[derive(Clone, Debug, Default)]
pub struct PrefixStats {
    /// Tokens newly added to the tree by `insert`.
    pub inserted_tokens: u64,
    /// Evicted subtree count.
    pub evictions: u64,
    /// Blocks freed by eviction.
    pub evicted_blocks: u64,
}

struct Node {
    /// Edge label from the parent; a positive multiple of `block_tokens`
    /// (the root's is empty).
    tokens: Vec<i32>,
    /// Active sequences whose acquired prefix reaches into each block.
    block_refs: Vec<u32>,
    children: Vec<Node>,
    /// LRU clock value of the last acquire touching this node.
    last_use: u64,
    /// Physical pool blocks backing this edge, one per block of the label
    /// (`None` = accounting-only, the simulator path). The cache holds one
    /// pool reference per ID; eviction releases them.
    phys: Option<Vec<BlockId>>,
}

impl Node {
    fn evictable(&self) -> bool {
        self.children.is_empty() && self.block_refs.iter().all(|r| *r == 0)
    }
}

/// Result of a [`PrefixCache::insert`] / [`PrefixCache::insert_shared`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct InsertReport {
    /// Tokens newly added to the tree (block-aligned; existing prefix
    /// tokens are shared, not re-added).
    pub new_tokens: usize,
    /// Blocks evicted to make room (already removed from `cached_blocks`;
    /// on the pooled path their IDs are already back in the pool).
    pub evicted_blocks: usize,
}

/// The radix-tree prefix cache. See the module docs for the invariants.
pub struct PrefixCache {
    cfg: PrefixCacheConfig,
    root: Node,
    tick: u64,
    cached_blocks: usize,
    stats: PrefixStats,
}

/// Longest common prefix of `edge` and `rest`, floored to block alignment.
fn aligned_lcp(bt: usize, edge: &[i32], rest: &[i32]) -> usize {
    let lim = edge.len().min(rest.len());
    let mut i = 0;
    while i < lim && edge[i] == rest[i] {
        i += 1;
    }
    i - i % bt
}

fn lookup_rec(node: &Node, rest: &[i32], bt: usize) -> usize {
    for c in &node.children {
        let a = aligned_lcp(bt, &c.tokens, rest);
        if a == 0 {
            continue;
        }
        return if a == c.tokens.len() {
            a + lookup_rec(c, &rest[a..], bt)
        } else {
            a
        };
    }
    0
}

/// Shared walk for acquire (`delta = +1`) and release (`delta = -1`):
/// adjusts the per-block refcount of every block the matched prefix
/// reaches. Returns the matched (block-aligned) token count.
fn pin_rec(node: &mut Node, rest: &[i32], bt: usize, tick: u64, delta: i64) -> usize {
    for c in node.children.iter_mut() {
        let a = aligned_lcp(bt, &c.tokens, rest);
        if a == 0 {
            continue;
        }
        if delta > 0 {
            c.last_use = tick;
        }
        for r in &mut c.block_refs[..a / bt] {
            if delta > 0 {
                *r += 1;
            } else {
                debug_assert!(*r > 0, "prefix release without matching acquire");
                *r = r.saturating_sub(1);
            }
        }
        return if a == c.tokens.len() {
            a + pin_rec(c, &rest[a..], bt, tick, delta)
        } else {
            a
        };
    }
    0
}

fn split_node(c: &mut Node, at: usize, bt: usize) {
    debug_assert!(at % bt == 0 && at > 0 && at < c.tokens.len());
    let tail_tokens = c.tokens.split_off(at);
    let tail_refs = c.block_refs.split_off(at / bt);
    // The physical IDs slice exactly like the refcounts: a split moves
    // block ownership, never a byte of payload.
    let tail_phys = c.phys.as_mut().map(|ids| ids.split_off(at / bt));
    let tail = Node {
        tokens: tail_tokens,
        block_refs: tail_refs,
        children: std::mem::take(&mut c.children),
        last_use: c.last_use,
        phys: tail_phys,
    };
    c.children.push(tail);
}

/// Insert walk. `phys`/`pool` are both `Some` on the engine path: newly
/// created nodes adopt `phys[offset/bt ..]` (one `pool.retain` per adopted
/// ID) — and both `None` on the accounting path.
fn insert_rec(
    node: &mut Node,
    rest: &[i32],
    offset: usize,
    phys: Option<&[BlockId]>,
    pool: &mut Option<&mut BlockPool>,
    bt: usize,
    tick: u64,
) -> usize {
    if rest.is_empty() {
        return 0;
    }
    let mut pick: Option<(usize, usize)> = None;
    for (i, c) in node.children.iter().enumerate() {
        let a = aligned_lcp(bt, &c.tokens, rest);
        if a > 0 {
            pick = Some((i, a));
            break;
        }
    }
    match pick {
        None => {
            let node_phys = phys.map(|ids| {
                let span = &ids[offset / bt..(offset + rest.len()) / bt];
                if let Some(p) = pool.as_mut() {
                    for &id in span {
                        p.retain(id);
                    }
                }
                span.to_vec()
            });
            node.children.push(Node {
                tokens: rest.to_vec(),
                block_refs: vec![0; rest.len() / bt],
                children: Vec::new(),
                last_use: tick,
                phys: node_phys,
            });
            rest.len()
        }
        Some((i, a)) => {
            let c = &mut node.children[i];
            c.last_use = tick;
            if a < c.tokens.len() {
                split_node(c, a, bt);
            }
            if a == rest.len() {
                0
            } else {
                insert_rec(&mut node.children[i], &rest[a..], offset + a, phys, pool, bt, tick)
            }
        }
    }
}

/// Collect the physical IDs along the matched path of `rest` into `out`.
/// Returns false when any node on the path is accounting-only.
fn mapped_rec(node: &Node, rest: &[i32], bt: usize, out: &mut Vec<BlockId>) -> bool {
    if rest.is_empty() {
        return true;
    }
    for c in &node.children {
        let a = aligned_lcp(bt, &c.tokens, rest);
        if a == 0 {
            continue;
        }
        let Some(ids) = &c.phys else {
            return false;
        };
        out.extend_from_slice(&ids[..a / bt]);
        return if a == c.tokens.len() {
            mapped_rec(c, &rest[a..], bt, out)
        } else {
            // `rest` continues past the block-aligned divergence point; the
            // caller asked for exactly the acquired span, so it ends here.
            a == rest.len()
        };
    }
    false
}

fn oldest_evictable(node: &Node) -> Option<u64> {
    let mut best: Option<u64> = None;
    for c in &node.children {
        let cand = if c.evictable() {
            Some(c.last_use)
        } else {
            oldest_evictable(c)
        };
        if let Some(t) = cand {
            best = Some(best.map_or(t, |b| b.min(t)));
        }
    }
    best
}

/// Detach and return the evictable leaf whose `last_use` equals `target`.
fn remove_evictable(node: &mut Node, target: u64) -> Option<Node> {
    for i in 0..node.children.len() {
        if node.children[i].evictable() && node.children[i].last_use == target {
            return Some(node.children.remove(i));
        }
        if let Some(victim) = remove_evictable(&mut node.children[i], target) {
            return Some(victim);
        }
    }
    None
}

fn total_refs_rec(node: &Node) -> u64 {
    node.block_refs.iter().map(|r| *r as u64).sum::<u64>()
        + node.children.iter().map(total_refs_rec).sum::<u64>()
}

fn referenced_blocks_rec(node: &Node) -> usize {
    node.block_refs.iter().filter(|r| **r > 0).count()
        + node
            .children
            .iter()
            .map(referenced_blocks_rec)
            .sum::<usize>()
}

fn hot_paths_rec(node: &Node, prefix: &mut Vec<i32>, out: &mut Vec<Vec<i32>>) {
    if node.children.is_empty() {
        if !prefix.is_empty() {
            out.push(prefix.clone());
        }
        return;
    }
    for c in &node.children {
        prefix.extend_from_slice(&c.tokens);
        hot_paths_rec(c, prefix, out);
        prefix.truncate(prefix.len() - c.tokens.len());
    }
}

fn owned_blocks_rec(node: &Node, out: &mut Vec<BlockId>) {
    if let Some(ids) = &node.phys {
        out.extend_from_slice(ids);
    }
    for c in &node.children {
        owned_blocks_rec(c, out);
    }
}

impl PrefixCache {
    pub fn new(cfg: PrefixCacheConfig) -> Self {
        let cfg = PrefixCacheConfig {
            block_tokens: cfg.block_tokens.max(1),
            ..cfg
        };
        Self {
            cfg,
            root: Node {
                tokens: Vec::new(),
                block_refs: Vec::new(),
                children: Vec::new(),
                last_use: 0,
                phys: None,
            },
            tick: 0,
            cached_blocks: 0,
            stats: PrefixStats::default(),
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.cfg.block_tokens
    }

    pub fn max_blocks(&self) -> usize {
        self.cfg.max_blocks
    }

    pub fn cached_blocks(&self) -> usize {
        self.cached_blocks
    }

    pub fn cached_tokens(&self) -> usize {
        self.cached_blocks * self.cfg.block_tokens
    }

    /// Bytes the cached blocks represent under the shared byte contract.
    pub fn cached_bytes(&self) -> usize {
        self.cached_tokens() * self.cfg.layout.bytes_per_token()
    }

    pub fn stats(&self) -> &PrefixStats {
        &self.stats
    }

    /// Sum of all per-block refcounts (diagnostic / test hook).
    pub fn total_refs(&self) -> u64 {
        total_refs_rec(&self.root)
    }

    /// Cached blocks currently pinned by at least one active sequence.
    pub fn referenced_blocks(&self) -> usize {
        referenced_blocks_rec(&self.root)
    }

    /// Every physical block ID the tree currently owns (diagnostic / test
    /// hook — the pool-accounting invariant `free + mapped + cache-owned =
    /// capacity` is checked against this set).
    pub fn owned_blocks(&self) -> Vec<BlockId> {
        let mut out = Vec::with_capacity(self.cached_blocks);
        owned_blocks_rec(&self.root, &mut out);
        out
    }

    /// Every cached root-to-leaf token path (interior prefixes are implied
    /// by their leaves): re-`insert`ing the paths into a fresh cache
    /// reproduces the tree's contents. This is the persistence surface the
    /// host-tier snapshot/restore rides across replica restarts (ISSUE 9).
    pub fn hot_paths(&self) -> Vec<Vec<i32>> {
        let mut out = Vec::new();
        let mut prefix = Vec::new();
        hot_paths_rec(&self.root, &mut prefix, &mut out);
        out
    }

    fn floor_block(&self, n: usize) -> usize {
        n - n % self.cfg.block_tokens
    }

    /// Longest cached block-aligned prefix of `prompt`, without pinning —
    /// the routing/planning probe.
    pub fn lookup(&self, prompt: &[i32]) -> usize {
        lookup_rec(&self.root, prompt, self.cfg.block_tokens)
    }

    /// Match and *pin* the longest cached prefix of `prompt`: every reached
    /// block's refcount is incremented so eviction cannot free it while the
    /// sequence runs. Returns the matched token count; the caller must
    /// [`PrefixCache::release`] exactly that count when the sequence
    /// retires.
    pub fn acquire(&mut self, prompt: &[i32]) -> usize {
        self.tick += 1;
        pin_rec(&mut self.root, prompt, self.cfg.block_tokens, self.tick, 1)
    }

    /// Drop the pins a matching [`PrefixCache::acquire`] took (`tokens` is
    /// the value acquire returned).
    pub fn release(&mut self, prompt: &[i32], tokens: usize) {
        let take = tokens.min(prompt.len());
        debug_assert_eq!(take % self.cfg.block_tokens, 0);
        pin_rec(&mut self.root, &prompt[..take], self.cfg.block_tokens, self.tick, -1);
    }

    /// Cache the block-aligned prefix of `prompt`, accounting only (the
    /// simulator path — no physical blocks). The insert is truncated
    /// (after evicting refcount-0 LRU leaves) if the budget cannot hold it.
    pub fn insert(&mut self, prompt: &[i32]) -> InsertReport {
        self.insert_impl(prompt, None, None)
    }

    /// Cache the block-aligned prefix of `prompt` by **adopting** the
    /// prompt's physical blocks: `blocks[i]` backs tokens
    /// `[i·bt, (i+1)·bt)` (the writing slot's block table). Every newly
    /// cached span retains its IDs in `pool` — no payload is copied — and
    /// any blocks evicted to make room are released back to `pool`.
    pub fn insert_shared(
        &mut self,
        prompt: &[i32],
        blocks: &[BlockId],
        pool: &mut BlockPool,
    ) -> InsertReport {
        let aligned = self.floor_block(prompt.len());
        assert!(
            blocks.len() * self.cfg.block_tokens >= aligned,
            "insert_shared: {} blocks cannot back a {aligned}-token prefix",
            blocks.len()
        );
        self.insert_impl(prompt, Some(blocks), Some(pool))
    }

    fn insert_impl(
        &mut self,
        prompt: &[i32],
        phys: Option<&[BlockId]>,
        mut pool: Option<&mut BlockPool>,
    ) -> InsertReport {
        let mut aligned = self.floor_block(prompt.len());
        if aligned == 0 {
            return InsertReport::default();
        }
        // Pin the existing matched path so making room cannot evict it.
        let pinned = self.acquire(&prompt[..aligned]);
        let existing = pinned;
        let mut want = (aligned - existing) / self.cfg.block_tokens;
        let mut evicted = 0;
        if want > 0 {
            let free = self.cfg.max_blocks.saturating_sub(self.cached_blocks);
            if want > free {
                evicted = self.evict_impl(want - free, pool.as_deref_mut());
            }
            let free = self.cfg.max_blocks.saturating_sub(self.cached_blocks);
            if want > free {
                // Budget cannot hold the full prefix: insert what fits.
                want = free;
                aligned = existing + want * self.cfg.block_tokens;
            }
        }
        let added = if want == 0 {
            0
        } else {
            self.tick += 1;
            insert_rec(
                &mut self.root,
                &prompt[..aligned],
                0,
                phys,
                &mut pool,
                self.cfg.block_tokens,
                self.tick,
            )
        };
        debug_assert_eq!(added, want * self.cfg.block_tokens);
        self.cached_blocks += added / self.cfg.block_tokens;
        self.stats.inserted_tokens += added as u64;
        self.release(prompt, pinned);
        InsertReport {
            new_tokens: added,
            evicted_blocks: evicted,
        }
    }

    /// The physical block IDs backing `prompt[..tokens]`, in token order —
    /// what a hit maps into the requesting sequence's block table (the
    /// caller retains them via `KvStore::map_shared_prefix`). Returns
    /// `None` when any node on the path is accounting-only: a cache
    /// without physical payloads cannot materialize data.
    pub fn mapped_blocks(&self, prompt: &[i32], tokens: usize) -> Option<Vec<BlockId>> {
        let want = tokens.min(prompt.len());
        let mut out = Vec::with_capacity(want / self.cfg.block_tokens);
        if mapped_rec(&self.root, &prompt[..want], self.cfg.block_tokens, &mut out) {
            Some(out)
        } else {
            None
        }
    }

    /// Evict refcount-0 LRU leaf subtrees until at least `want` blocks are
    /// freed or nothing evictable remains (accounting caches only —
    /// physical-backed trees must use [`Self::evict_blocks_pooled`] or the
    /// freed IDs would leak). Returns the blocks actually freed.
    pub fn evict_blocks(&mut self, want: usize) -> usize {
        self.evict_impl(want, None)
    }

    /// Like [`Self::evict_blocks`], releasing every evicted node's
    /// physical blocks back to `pool` (they hit the free list — zeroed —
    /// unless a still-running sequence has them mapped).
    pub fn evict_blocks_pooled(&mut self, want: usize, pool: &mut BlockPool) -> usize {
        self.evict_impl(want, Some(pool))
    }

    fn evict_impl(&mut self, want: usize, mut pool: Option<&mut BlockPool>) -> usize {
        let mut freed = 0;
        while freed < want {
            let Some(oldest) = oldest_evictable(&self.root) else {
                break;
            };
            let Some(victim) = remove_evictable(&mut self.root, oldest) else {
                break;
            };
            let got = victim.block_refs.len();
            if let Some(ids) = &victim.phys {
                match pool.as_mut() {
                    Some(p) => {
                        for &id in ids {
                            p.release(id);
                        }
                    }
                    None => debug_assert!(
                        ids.is_empty(),
                        "evicting physical blocks without a pool leaks them"
                    ),
                }
            }
            if got == 0 {
                break;
            }
            freed += got;
            self.cached_blocks -= got;
            self.stats.evictions += 1;
            self.stats.evicted_blocks += got as u64;
        }
        freed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{KvDtype, KvLayout};

    fn cache(bt: usize, max_blocks: usize) -> PrefixCache {
        let layout = KvLayout::new(KvDtype::FP8_DEFAULT, 2, 2, 4);
        PrefixCache::new(PrefixCacheConfig {
            block_tokens: bt,
            max_blocks,
            layout,
        })
    }

    fn prompt(blocks: &[i32], bt: usize) -> Vec<i32> {
        // One distinct token value repeated per block keeps block
        // boundaries obvious in failures.
        blocks.iter().flat_map(|b| vec![*b; bt]).collect()
    }

    /// A pool plus `n` pre-allocated blocks to adopt (the shape a freshly
    /// prefilled slot's table has).
    fn pool_with_blocks(n: usize, bt: usize) -> (BlockPool, Vec<BlockId>) {
        let mut pool = BlockPool::new(n + 8, bt, 1, 1, 2, KvDtype::F32);
        let ids: Vec<BlockId> = (0..n).map(|_| pool.alloc().unwrap()).collect();
        (pool, ids)
    }

    #[test]
    fn lookup_matches_block_aligned_prefixes_only() {
        let mut c = cache(4, 64);
        let p = prompt(&[1, 2, 3], 4); // 12 tokens
        assert_eq!(c.insert(&p).new_tokens, 12);
        assert_eq!(c.cached_blocks(), 3);
        assert_eq!(c.lookup(&p), 12);
        // Shares two whole blocks, diverges in the third.
        let q = prompt(&[1, 2, 9], 4);
        assert_eq!(c.lookup(&q), 8);
        // Shares 4 whole tokens then diverges mid-block: only the aligned
        // block counts.
        let mut r = prompt(&[1, 1], 4);
        r[6] = 77;
        assert_eq!(c.lookup(&r), 4);
        assert_eq!(c.lookup(&prompt(&[9], 4)), 0);
        // Sub-block prompts can never match.
        assert_eq!(c.lookup(&[1, 1]), 0);
    }

    #[test]
    fn insert_splits_at_block_aligned_divergence() {
        let mut c = cache(4, 64);
        let a = prompt(&[1, 2, 3, 4], 4);
        let b = prompt(&[1, 2, 8, 9], 4);
        assert_eq!(c.insert(&a).new_tokens, 16);
        // Only the divergent tail is new.
        assert_eq!(c.insert(&b).new_tokens, 8);
        assert_eq!(c.cached_blocks(), 6);
        assert_eq!(c.lookup(&a), 16);
        assert_eq!(c.lookup(&b), 16);
        // Re-inserting is free.
        assert_eq!(c.insert(&a).new_tokens, 0);
        assert_eq!(c.cached_blocks(), 6);
    }

    #[test]
    fn acquire_release_balance_refcounts_across_splits() {
        let mut c = cache(4, 64);
        let a = prompt(&[1, 2, 3, 4], 4);
        assert_eq!(c.insert(&a).new_tokens, 16);
        let got = c.acquire(&a);
        assert_eq!(got, 16);
        assert_eq!(c.total_refs(), 4);
        assert_eq!(c.referenced_blocks(), 4);
        // A divergent insert splits the pinned edge; pins must survive.
        let b = prompt(&[1, 2, 8], 4);
        c.insert(&b);
        assert_eq!(c.total_refs(), 4, "split must preserve per-block pins");
        let got_b = c.acquire(&b);
        assert_eq!(got_b, 12);
        assert_eq!(c.total_refs(), 4 + 3);
        c.release(&b, got_b);
        c.release(&a, got);
        assert_eq!(c.total_refs(), 0);
        assert_eq!(c.referenced_blocks(), 0);
    }

    #[test]
    fn eviction_is_lru_and_never_frees_referenced_blocks() {
        let mut c = cache(4, 64);
        let a = prompt(&[1, 2], 4);
        let b = prompt(&[5, 6], 4);
        c.insert(&a);
        c.insert(&b);
        let pinned = c.acquire(&a);
        assert_eq!(pinned, 8);
        // Unlimited eviction demand: only `b`'s unreferenced leaf goes.
        let freed = c.evict_blocks(usize::MAX);
        assert_eq!(freed, 2, "only the unpinned subtree is evictable");
        assert_eq!(c.lookup(&a), 8, "pinned path survives");
        assert_eq!(c.lookup(&b), 0);
        assert_eq!(c.stats().evictions, 1);
        assert_eq!(c.stats().evicted_blocks, 2);
        c.release(&a, pinned);
        // Now everything is evictable, leaf-first.
        let freed = c.evict_blocks(usize::MAX);
        assert_eq!(freed, 2);
        assert_eq!(c.cached_blocks(), 0);
    }

    #[test]
    fn lru_order_prefers_oldest_leaf() {
        let mut c = cache(4, 64);
        let a = prompt(&[1], 4);
        let b = prompt(&[2], 4);
        c.insert(&a);
        c.insert(&b);
        // Touch `a` so `b` becomes the LRU leaf.
        let got = c.acquire(&a);
        c.release(&a, got);
        assert_eq!(c.evict_blocks(1), 1);
        assert_eq!(c.lookup(&a), 4, "recently used path must survive");
        assert_eq!(c.lookup(&b), 0, "LRU leaf evicted first");
    }

    #[test]
    fn budget_truncates_inserts_after_eviction() {
        let mut c = cache(4, 3); // room for 3 blocks
        let a = prompt(&[1, 2, 3, 4], 4); // wants 4
        let rep = c.insert(&a);
        assert_eq!(rep.new_tokens, 12, "insert truncated to the budget");
        assert_eq!(c.cached_blocks(), 3);
        assert_eq!(c.lookup(&a), 12);
        // A disjoint insert evicts the old path (refcount 0) to fit.
        let b = prompt(&[7, 8], 4);
        let rep = c.insert(&b);
        assert_eq!(rep.new_tokens, 8);
        assert!(rep.evicted_blocks >= 2);
        assert!(c.cached_blocks() <= 3);
    }

    #[test]
    fn shared_insert_adopts_blocks_and_mapped_blocks_survive_splits() {
        let bt = 4usize;
        let mut c = cache(bt, 64);
        let p = prompt(&[1, 2, 3], bt); // 12 tokens, 3 blocks
        let (mut pool, ids) = pool_with_blocks(3, bt);
        assert_eq!(c.insert_shared(&p, &ids, &mut pool).new_tokens, 12);
        // Adoption = one retain per block, zero copies.
        for &id in &ids {
            assert_eq!(pool.ref_count(id), 2, "cache must co-own block {id}");
        }
        assert_eq!(c.mapped_blocks(&p, 12), Some(ids.clone()));
        // Partial span maps the matching prefix of IDs.
        assert_eq!(c.mapped_blocks(&p, 8), Some(ids[..2].to_vec()));

        // A divergent sibling forces a split of the ID-carrying edge; the
        // ID vector slices with the refcounts.
        let q = prompt(&[1, 9], bt);
        let qids = vec![pool.alloc().unwrap(), pool.alloc().unwrap()];
        assert_eq!(c.insert_shared(&q, &qids, &mut pool).new_tokens, 4);
        assert_eq!(c.mapped_blocks(&p, 12), Some(ids.clone()), "split kept IDs");
        assert_eq!(c.mapped_blocks(&q, 8), Some(vec![ids[0], qids[1]]));

        // Accounting-only trees cannot map.
        let mut c2 = cache(bt, 64);
        c2.insert(&p);
        assert_eq!(c2.mapped_blocks(&p, 12), None);
        // Releasing the writer's references leaves the cache as the owner.
        for &id in &ids {
            pool.release(id);
            assert_eq!(pool.ref_count(id), 1);
        }
    }

    #[test]
    fn pooled_eviction_releases_adopted_blocks() {
        let bt = 4usize;
        let mut c = cache(bt, 64);
        let p = prompt(&[1, 2], bt);
        let (mut pool, ids) = pool_with_blocks(2, bt);
        c.insert_shared(&p, &ids, &mut pool);
        // The writer retires: only the cache owns the blocks now.
        for &id in &ids {
            pool.release(id);
        }
        assert_eq!(pool.used_blocks(), 2);
        // Pinned prefixes are never evicted — and their blocks stay.
        let pinned = c.acquire(&p);
        assert_eq!(c.evict_blocks_pooled(usize::MAX, &mut pool), 0);
        assert_eq!(pool.used_blocks(), 2);
        c.release(&p, pinned);
        // Unpinned: eviction frees the subtree and the pool gets the
        // blocks back (zeroed, refcount 0).
        let freed = c.evict_blocks_pooled(usize::MAX, &mut pool);
        assert_eq!(freed, 2);
        assert_eq!(c.cached_blocks(), 0);
        assert_eq!(pool.used_blocks(), 0);
        for &id in &ids {
            assert_eq!(pool.ref_count(id), 0);
        }
    }

    #[test]
    fn interleaved_ops_keep_refcounts_balanced() {
        use crate::util::rng::XorShiftRng;
        let bt = 4usize;
        let mut c = cache(bt, 32);
        let mut rng = XorShiftRng::new(0xC0FFEE);
        // A small family of prompts sharing prefixes at various depths.
        let family: Vec<Vec<i32>> = (0..8)
            .map(|i| {
                let mut blocks = vec![1, 2];
                blocks.push(3 + (i % 4) as i32);
                blocks.push(10 + i as i32);
                prompt(&blocks, bt)
            })
            .collect();
        let mut live: Vec<(usize, usize)> = Vec::new(); // (family idx, tokens)
        for _ in 0..2000 {
            match rng.below(4) {
                0 => {
                    let i = rng.below(family.len());
                    let got = c.acquire(&family[i]);
                    live.push((i, got));
                }
                1 => {
                    if !live.is_empty() {
                        let (i, got) = live.swap_remove(rng.below(live.len()));
                        c.release(&family[i], got);
                    }
                }
                2 => {
                    let i = rng.below(family.len());
                    c.insert(&family[i]);
                }
                _ => {
                    c.evict_blocks(rng.below(4));
                }
            }
            let expected: u64 = live.iter().map(|(_, t)| (t / bt) as u64).sum();
            assert_eq!(c.total_refs(), expected, "dangling or lost refcount");
            assert!(c.referenced_blocks() <= c.cached_blocks());
            assert!(c.cached_blocks() <= c.max_blocks());
            // Every live acquisition's path must still be materializable
            // by lookup (eviction must not have freed pinned blocks).
            for (i, t) in &live {
                assert!(c.lookup(&family[*i]) >= *t, "pinned path evicted");
            }
        }
        for (i, got) in live.drain(..) {
            c.release(&family[i], got);
        }
        assert_eq!(c.total_refs(), 0);
        // With no pins the cache must drain completely.
        c.evict_blocks(usize::MAX);
        assert_eq!(c.cached_blocks(), 0);
        assert_eq!(c.cached_bytes(), 0);
    }

    #[test]
    fn hot_paths_round_trip_through_a_fresh_cache() {
        let mut c = cache(4, 64);
        let a = prompt(&[1, 2, 3], 4);
        let b = prompt(&[1, 2, 9], 4); // shares two blocks, splits the edge
        c.insert(&a);
        c.insert(&b);
        let paths = c.hot_paths();
        assert_eq!(paths.len(), 2, "one path per leaf: {paths:?}");
        let mut fresh = cache(4, 64);
        for p in &paths {
            fresh.insert(p);
        }
        assert_eq!(fresh.cached_blocks(), c.cached_blocks());
        assert_eq!(fresh.lookup(&a), 12);
        assert_eq!(fresh.lookup(&b), 12);
        // An empty cache exports nothing.
        assert!(cache(4, 8).hot_paths().is_empty());
    }

    #[test]
    fn bytes_follow_the_layout_contract() {
        let layout = KvLayout::new(KvDtype::FP8_DEFAULT, 2, 2, 4);
        let mut c = PrefixCache::new(PrefixCacheConfig {
            block_tokens: 8,
            max_blocks: 16,
            layout,
        });
        let p: Vec<i32> = (0..32).collect();
        c.insert(&p);
        assert_eq!(c.cached_tokens(), 32);
        assert_eq!(c.cached_bytes(), 32 * layout.bytes_per_token());
        // from_bytes_budget inverts the rate.
        let budget = (64 * layout.bytes_per_token()) as f64;
        let cfg = PrefixCacheConfig::from_bytes_budget(layout, 8, budget);
        assert_eq!(cfg.max_blocks, 8);
        let cfg = PrefixCacheConfig::from_bytes_budget(layout, 8, 0.0);
        assert_eq!(cfg.max_blocks, 0);
    }
}
