//! Shared-prefix KV cache: a radix tree over token-ID prefixes.
//!
//! Serving traffic is dominated by requests that share a long common prompt
//! prefix (system prompts, few-shot preambles, multi-turn history). Without
//! sharing, every request re-prefills and re-stores its full prompt — the
//! prefill FLOPs and KV bytes that bound the paper's end-to-end numbers
//! (Tables 5–6). This module caches prompt KV at *block* granularity in a
//! radix tree so a new request pays only for its uncached tail:
//!
//! * **Tree shape** — every edge label is a positive multiple of
//!   `block_tokens`; children of a node always differ somewhere inside
//!   their first block (splits happen at block-aligned divergence points),
//!   so at most one child can match a whole block of a probe prompt.
//! * **Per-block refcounts** — each cached block counts the active
//!   sequences whose acquired prefix reaches into it. Splits slice the
//!   refcount vector along with the edge label, so pins survive tree
//!   restructuring exactly.
//! * **Eviction** — only refcount-0 *leaves* are evictable (an interior
//!   node is the prefix of its children and must outlive them); victims go
//!   LRU-first by `last_use`. A referenced block is never freed.
//! * **Byte accounting** — capacity is expressed in blocks, converted
//!   from/to bytes through the shared [`KvLayout`] contract
//!   ([`PrefixCacheConfig::from_bytes_budget`], [`PrefixCache::cached_bytes`]),
//!   so admission control charges cached prefixes at exactly the rate the
//!   rest of the stack charges KV.
//! * **Payloads** — nodes optionally carry the prefix's KV data
//!   (f32, `(layers, span, kv_heads, head_dim)` row-major) so the engine
//!   can materialize a cached prefix into a fresh slot
//!   ([`PrefixCache::assemble`]); the simulated replicas cache accounting
//!   only and insert without payloads.

use crate::quant::KvLayout;

/// Configuration for a [`PrefixCache`].
#[derive(Clone, Debug)]
pub struct PrefixCacheConfig {
    /// Cache granularity in tokens; matches only whole blocks are shared.
    pub block_tokens: usize,
    /// Hard bound on cached blocks; inserts evict (or truncate) to fit.
    pub max_blocks: usize,
    /// The byte-accounting contract cached blocks are charged through.
    pub layout: KvLayout,
}

impl PrefixCacheConfig {
    /// Size the block budget from a byte budget at the layout's rate.
    pub fn from_bytes_budget(layout: KvLayout, block_tokens: usize, bytes: f64) -> Self {
        let bt = block_tokens.max(1);
        let block_bytes = (layout.bytes_per_token() * bt).max(1) as f64;
        let max_blocks = if bytes.is_finite() && bytes > 0.0 {
            (bytes / block_bytes).floor() as usize
        } else {
            0
        };
        Self {
            block_tokens: bt,
            max_blocks,
            layout,
        }
    }
}

/// Counters the cache maintains internally (callers thread hit/miss into
/// their own `ServeMetrics` — the cache cannot tell a routing probe from an
/// admission).
#[derive(Clone, Debug, Default)]
pub struct PrefixStats {
    /// Tokens newly added to the tree by `insert`.
    pub inserted_tokens: u64,
    /// Evicted subtree count.
    pub evictions: u64,
    /// Blocks freed by eviction.
    pub evicted_blocks: u64,
}

/// A node's KV payload: `(layers, span, kv_heads·head_dim)` row-major,
/// `span` = edge tokens.
#[derive(Clone)]
struct NodeKv {
    layers: usize,
    /// Elements per token per layer (`kv_heads · head_dim`).
    row: usize,
    k: Vec<f32>,
    v: Vec<f32>,
}

impl NodeKv {
    fn span(&self) -> usize {
        let per = self.layers * self.row;
        if per == 0 {
            0
        } else {
            self.k.len() / per
        }
    }

    /// Split at token `at`: `self` keeps `[0, at)`, the tail is returned.
    fn split_off(&mut self, at: usize) -> NodeKv {
        let span = self.span();
        let row = self.row;
        let mut k_head = Vec::with_capacity(self.layers * at * row);
        let mut v_head = Vec::with_capacity(self.layers * at * row);
        let mut k_tail = Vec::with_capacity(self.layers * (span - at) * row);
        let mut v_tail = Vec::with_capacity(self.layers * (span - at) * row);
        for l in 0..self.layers {
            let base = l * span * row;
            let cut = base + at * row;
            let end = base + span * row;
            k_head.extend_from_slice(&self.k[base..cut]);
            k_tail.extend_from_slice(&self.k[cut..end]);
            v_head.extend_from_slice(&self.v[base..cut]);
            v_tail.extend_from_slice(&self.v[cut..end]);
        }
        self.k = k_head;
        self.v = v_head;
        NodeKv {
            layers: self.layers,
            row,
            k: k_tail,
            v: v_tail,
        }
    }
}

/// Borrowed view of a prefill artifact's KV output, layout
/// `(layers, t_src, kv_heads, head_dim)` row-major (slot dimension already
/// selected), from which inserted nodes copy their token spans.
pub struct KvSpanSource<'a> {
    pub k: &'a [f32],
    pub v: &'a [f32],
    /// Token capacity of the source buffer (the compiled bucket / cache T).
    pub t_src: usize,
    pub layers: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
}

impl KvSpanSource<'_> {
    fn row(&self) -> usize {
        self.kv_heads * self.head_dim
    }

    fn copy_span(&self, start: usize, len: usize) -> NodeKv {
        let row = self.row();
        let mut k = Vec::with_capacity(self.layers * len * row);
        let mut v = Vec::with_capacity(self.layers * len * row);
        for l in 0..self.layers {
            let base = (l * self.t_src + start) * row;
            k.extend_from_slice(&self.k[base..base + len * row]);
            v.extend_from_slice(&self.v[base..base + len * row]);
        }
        NodeKv {
            layers: self.layers,
            row,
            k,
            v,
        }
    }
}

struct Node {
    /// Edge label from the parent; a positive multiple of `block_tokens`
    /// (the root's is empty).
    tokens: Vec<i32>,
    /// Active sequences whose acquired prefix reaches into each block.
    block_refs: Vec<u32>,
    children: Vec<Node>,
    /// LRU clock value of the last acquire touching this node.
    last_use: u64,
    kv: Option<NodeKv>,
}

impl Node {
    fn evictable(&self) -> bool {
        self.children.is_empty() && self.block_refs.iter().all(|r| *r == 0)
    }
}

/// Result of a [`PrefixCache::insert`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct InsertReport {
    /// Tokens newly added to the tree (block-aligned; existing prefix
    /// tokens are shared, not re-added).
    pub new_tokens: usize,
    /// Blocks evicted to make room (already removed from `cached_blocks`).
    pub evicted_blocks: usize,
}

/// The radix-tree prefix cache. See the module docs for the invariants.
pub struct PrefixCache {
    cfg: PrefixCacheConfig,
    root: Node,
    tick: u64,
    cached_blocks: usize,
    stats: PrefixStats,
}

/// Longest common prefix of `edge` and `rest`, floored to block alignment.
fn aligned_lcp(bt: usize, edge: &[i32], rest: &[i32]) -> usize {
    let lim = edge.len().min(rest.len());
    let mut i = 0;
    while i < lim && edge[i] == rest[i] {
        i += 1;
    }
    i - i % bt
}

fn lookup_rec(node: &Node, rest: &[i32], bt: usize) -> usize {
    for c in &node.children {
        let a = aligned_lcp(bt, &c.tokens, rest);
        if a == 0 {
            continue;
        }
        return if a == c.tokens.len() {
            a + lookup_rec(c, &rest[a..], bt)
        } else {
            a
        };
    }
    0
}

/// Shared walk for acquire (`delta = +1`) and release (`delta = -1`):
/// adjusts the per-block refcount of every block the matched prefix
/// reaches. Returns the matched (block-aligned) token count.
fn pin_rec(node: &mut Node, rest: &[i32], bt: usize, tick: u64, delta: i64) -> usize {
    for c in node.children.iter_mut() {
        let a = aligned_lcp(bt, &c.tokens, rest);
        if a == 0 {
            continue;
        }
        if delta > 0 {
            c.last_use = tick;
        }
        for r in &mut c.block_refs[..a / bt] {
            if delta > 0 {
                *r += 1;
            } else {
                debug_assert!(*r > 0, "prefix release without matching acquire");
                *r = r.saturating_sub(1);
            }
        }
        return if a == c.tokens.len() {
            a + pin_rec(c, &rest[a..], bt, tick, delta)
        } else {
            a
        };
    }
    0
}

fn split_node(c: &mut Node, at: usize, bt: usize) {
    debug_assert!(at % bt == 0 && at > 0 && at < c.tokens.len());
    let tail_tokens = c.tokens.split_off(at);
    let tail_refs = c.block_refs.split_off(at / bt);
    let tail_kv = c.kv.as_mut().map(|kv| kv.split_off(at));
    let tail = Node {
        tokens: tail_tokens,
        block_refs: tail_refs,
        children: std::mem::take(&mut c.children),
        last_use: c.last_use,
        kv: tail_kv,
    };
    c.children.push(tail);
}

fn insert_rec(
    node: &mut Node,
    rest: &[i32],
    offset: usize,
    kv: Option<&KvSpanSource<'_>>,
    bt: usize,
    tick: u64,
) -> usize {
    if rest.is_empty() {
        return 0;
    }
    let mut pick: Option<(usize, usize)> = None;
    for (i, c) in node.children.iter().enumerate() {
        let a = aligned_lcp(bt, &c.tokens, rest);
        if a > 0 {
            pick = Some((i, a));
            break;
        }
    }
    match pick {
        None => {
            node.children.push(Node {
                tokens: rest.to_vec(),
                block_refs: vec![0; rest.len() / bt],
                children: Vec::new(),
                last_use: tick,
                kv: kv.map(|s| s.copy_span(offset, rest.len())),
            });
            rest.len()
        }
        Some((i, a)) => {
            let c = &mut node.children[i];
            c.last_use = tick;
            if a < c.tokens.len() {
                split_node(c, a, bt);
            }
            if a == rest.len() {
                0
            } else {
                insert_rec(&mut node.children[i], &rest[a..], offset + a, kv, bt, tick)
            }
        }
    }
}

fn assemble_rec(
    node: &Node,
    rest: &[i32],
    offset: usize,
    t: usize,
    k_out: &mut [f32],
    v_out: &mut [f32],
    bt: usize,
) -> bool {
    if rest.is_empty() {
        return true;
    }
    for c in &node.children {
        let a = aligned_lcp(bt, &c.tokens, rest);
        if a == 0 {
            continue;
        }
        let Some(kv) = &c.kv else {
            return false;
        };
        let row = kv.row;
        let span = kv.span();
        for l in 0..kv.layers {
            let src = l * span * row;
            let dst = (l * t + offset) * row;
            k_out[dst..dst + a * row].copy_from_slice(&kv.k[src..src + a * row]);
            v_out[dst..dst + a * row].copy_from_slice(&kv.v[src..src + a * row]);
        }
        return if a == c.tokens.len() {
            assemble_rec(c, &rest[a..], offset + a, t, k_out, v_out, bt)
        } else {
            // `rest` continues past the block-aligned divergence point; the
            // caller asked for exactly the acquired span, so it ends here.
            a == rest.len()
        };
    }
    false
}

fn oldest_evictable(node: &Node) -> Option<u64> {
    let mut best: Option<u64> = None;
    for c in &node.children {
        let cand = if c.evictable() {
            Some(c.last_use)
        } else {
            oldest_evictable(c)
        };
        if let Some(t) = cand {
            best = Some(best.map_or(t, |b| b.min(t)));
        }
    }
    best
}

fn remove_evictable(node: &mut Node, target: u64) -> usize {
    for i in 0..node.children.len() {
        if node.children[i].evictable() && node.children[i].last_use == target {
            let victim = node.children.remove(i);
            return victim.block_refs.len();
        }
        let freed = remove_evictable(&mut node.children[i], target);
        if freed > 0 {
            return freed;
        }
    }
    0
}

fn total_refs_rec(node: &Node) -> u64 {
    node.block_refs.iter().map(|r| *r as u64).sum::<u64>()
        + node.children.iter().map(total_refs_rec).sum::<u64>()
}

fn referenced_blocks_rec(node: &Node) -> usize {
    node.block_refs.iter().filter(|r| **r > 0).count()
        + node
            .children
            .iter()
            .map(referenced_blocks_rec)
            .sum::<usize>()
}

impl PrefixCache {
    pub fn new(cfg: PrefixCacheConfig) -> Self {
        let cfg = PrefixCacheConfig {
            block_tokens: cfg.block_tokens.max(1),
            ..cfg
        };
        Self {
            cfg,
            root: Node {
                tokens: Vec::new(),
                block_refs: Vec::new(),
                children: Vec::new(),
                last_use: 0,
                kv: None,
            },
            tick: 0,
            cached_blocks: 0,
            stats: PrefixStats::default(),
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.cfg.block_tokens
    }

    pub fn max_blocks(&self) -> usize {
        self.cfg.max_blocks
    }

    pub fn cached_blocks(&self) -> usize {
        self.cached_blocks
    }

    pub fn cached_tokens(&self) -> usize {
        self.cached_blocks * self.cfg.block_tokens
    }

    /// Bytes the cached blocks represent under the shared byte contract.
    pub fn cached_bytes(&self) -> usize {
        self.cached_tokens() * self.cfg.layout.bytes_per_token()
    }

    pub fn stats(&self) -> &PrefixStats {
        &self.stats
    }

    /// Sum of all per-block refcounts (diagnostic / test hook).
    pub fn total_refs(&self) -> u64 {
        total_refs_rec(&self.root)
    }

    /// Cached blocks currently pinned by at least one active sequence.
    pub fn referenced_blocks(&self) -> usize {
        referenced_blocks_rec(&self.root)
    }

    fn floor_block(&self, n: usize) -> usize {
        n - n % self.cfg.block_tokens
    }

    /// Longest cached block-aligned prefix of `prompt`, without pinning —
    /// the routing/planning probe.
    pub fn lookup(&self, prompt: &[i32]) -> usize {
        lookup_rec(&self.root, prompt, self.cfg.block_tokens)
    }

    /// Match and *pin* the longest cached prefix of `prompt`: every reached
    /// block's refcount is incremented so eviction cannot free it while the
    /// sequence runs. Returns the matched token count; the caller must
    /// [`PrefixCache::release`] exactly that count when the sequence
    /// retires.
    pub fn acquire(&mut self, prompt: &[i32]) -> usize {
        self.tick += 1;
        pin_rec(&mut self.root, prompt, self.cfg.block_tokens, self.tick, 1)
    }

    /// Drop the pins a matching [`PrefixCache::acquire`] took (`tokens` is
    /// the value acquire returned).
    pub fn release(&mut self, prompt: &[i32], tokens: usize) {
        let take = tokens.min(prompt.len());
        debug_assert_eq!(take % self.cfg.block_tokens, 0);
        pin_rec(&mut self.root, &prompt[..take], self.cfg.block_tokens, self.tick, -1);
    }

    /// Cache the block-aligned prefix of `prompt`, splitting edges at
    /// block-aligned divergence points. Newly added spans copy their KV
    /// from `kv` when given (the engine path); `None` caches accounting
    /// only (the simulator path). The insert is truncated (after evicting
    /// refcount-0 LRU leaves) if the block budget cannot hold it.
    pub fn insert(&mut self, prompt: &[i32], kv: Option<&KvSpanSource<'_>>) -> InsertReport {
        let mut aligned = self.floor_block(prompt.len());
        if aligned == 0 {
            return InsertReport::default();
        }
        // Pin the existing matched path so making room cannot evict it.
        let pinned = self.acquire(&prompt[..aligned]);
        let existing = pinned;
        let mut want = (aligned - existing) / self.cfg.block_tokens;
        let mut evicted = 0;
        if want > 0 {
            let free = self.cfg.max_blocks.saturating_sub(self.cached_blocks);
            if want > free {
                evicted = self.evict_blocks(want - free);
            }
            let free = self.cfg.max_blocks.saturating_sub(self.cached_blocks);
            if want > free {
                // Budget cannot hold the full prefix: insert what fits.
                want = free;
                aligned = existing + want * self.cfg.block_tokens;
            }
        }
        let added = if want == 0 {
            0
        } else {
            self.tick += 1;
            insert_rec(
                &mut self.root,
                &prompt[..aligned],
                0,
                kv,
                self.cfg.block_tokens,
                self.tick,
            )
        };
        debug_assert_eq!(added, want * self.cfg.block_tokens);
        self.cached_blocks += added / self.cfg.block_tokens;
        self.stats.inserted_tokens += added as u64;
        self.release(prompt, pinned);
        InsertReport {
            new_tokens: added,
            evicted_blocks: evicted,
        }
    }

    /// Copy the cached KV for `prompt[..tokens]` into `(layers, t, kv_heads,
    /// head_dim)` row-major buffers (token positions `[0, tokens)`; the rest
    /// is left untouched). Returns false when any node on the path carries
    /// no payload — accounting-only caches cannot materialize data.
    pub fn assemble(
        &self,
        prompt: &[i32],
        tokens: usize,
        t: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) -> bool {
        let want = tokens.min(prompt.len());
        assemble_rec(
            &self.root,
            &prompt[..want],
            0,
            t,
            k_out,
            v_out,
            self.cfg.block_tokens,
        )
    }

    /// Evict refcount-0 LRU leaf subtrees until at least `want` blocks are
    /// freed or nothing evictable remains. Returns the blocks actually
    /// freed (the caller returns them to its allocator when the cache
    /// shares a block pool). Referenced blocks are never freed.
    pub fn evict_blocks(&mut self, want: usize) -> usize {
        let mut freed = 0;
        while freed < want {
            let Some(oldest) = oldest_evictable(&self.root) else {
                break;
            };
            let got = remove_evictable(&mut self.root, oldest);
            if got == 0 {
                break;
            }
            freed += got;
            self.cached_blocks -= got;
            self.stats.evictions += 1;
            self.stats.evicted_blocks += got as u64;
        }
        freed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{KvDtype, KvLayout};

    fn cache(bt: usize, max_blocks: usize) -> PrefixCache {
        let layout = KvLayout::new(KvDtype::FP8_DEFAULT, 2, 2, 4);
        PrefixCache::new(PrefixCacheConfig {
            block_tokens: bt,
            max_blocks,
            layout,
        })
    }

    fn prompt(blocks: &[i32], bt: usize) -> Vec<i32> {
        // One distinct token value repeated per block keeps block
        // boundaries obvious in failures.
        blocks.iter().flat_map(|b| vec![*b; bt]).collect()
    }

    #[test]
    fn lookup_matches_block_aligned_prefixes_only() {
        let mut c = cache(4, 64);
        let p = prompt(&[1, 2, 3], 4); // 12 tokens
        assert_eq!(c.insert(&p, None).new_tokens, 12);
        assert_eq!(c.cached_blocks(), 3);
        assert_eq!(c.lookup(&p), 12);
        // Shares two whole blocks, diverges in the third.
        let q = prompt(&[1, 2, 9], 4);
        assert_eq!(c.lookup(&q), 8);
        // Shares 4 whole tokens then diverges mid-block: only the aligned
        // block counts.
        let mut r = prompt(&[1, 1], 4);
        r[6] = 77;
        assert_eq!(c.lookup(&r), 4);
        assert_eq!(c.lookup(&prompt(&[9], 4)), 0);
        // Sub-block prompts can never match.
        assert_eq!(c.lookup(&[1, 1]), 0);
    }

    #[test]
    fn insert_splits_at_block_aligned_divergence() {
        let mut c = cache(4, 64);
        let a = prompt(&[1, 2, 3, 4], 4);
        let b = prompt(&[1, 2, 8, 9], 4);
        assert_eq!(c.insert(&a, None).new_tokens, 16);
        // Only the divergent tail is new.
        assert_eq!(c.insert(&b, None).new_tokens, 8);
        assert_eq!(c.cached_blocks(), 6);
        assert_eq!(c.lookup(&a), 16);
        assert_eq!(c.lookup(&b), 16);
        // Re-inserting is free.
        assert_eq!(c.insert(&a, None).new_tokens, 0);
        assert_eq!(c.cached_blocks(), 6);
    }

    #[test]
    fn acquire_release_balance_refcounts_across_splits() {
        let mut c = cache(4, 64);
        let a = prompt(&[1, 2, 3, 4], 4);
        assert_eq!(c.insert(&a, None).new_tokens, 16);
        let got = c.acquire(&a);
        assert_eq!(got, 16);
        assert_eq!(c.total_refs(), 4);
        assert_eq!(c.referenced_blocks(), 4);
        // A divergent insert splits the pinned edge; pins must survive.
        let b = prompt(&[1, 2, 8], 4);
        c.insert(&b, None);
        assert_eq!(c.total_refs(), 4, "split must preserve per-block pins");
        let got_b = c.acquire(&b);
        assert_eq!(got_b, 12);
        assert_eq!(c.total_refs(), 4 + 3);
        c.release(&b, got_b);
        c.release(&a, got);
        assert_eq!(c.total_refs(), 0);
        assert_eq!(c.referenced_blocks(), 0);
    }

    #[test]
    fn eviction_is_lru_and_never_frees_referenced_blocks() {
        let mut c = cache(4, 64);
        let a = prompt(&[1, 2], 4);
        let b = prompt(&[5, 6], 4);
        c.insert(&a, None);
        c.insert(&b, None);
        let pinned = c.acquire(&a);
        assert_eq!(pinned, 8);
        // Unlimited eviction demand: only `b`'s unreferenced leaf goes.
        let freed = c.evict_blocks(usize::MAX);
        assert_eq!(freed, 2, "only the unpinned subtree is evictable");
        assert_eq!(c.lookup(&a), 8, "pinned path survives");
        assert_eq!(c.lookup(&b), 0);
        assert_eq!(c.stats().evictions, 1);
        assert_eq!(c.stats().evicted_blocks, 2);
        c.release(&a, pinned);
        // Now everything is evictable, leaf-first.
        let freed = c.evict_blocks(usize::MAX);
        assert_eq!(freed, 2);
        assert_eq!(c.cached_blocks(), 0);
    }

    #[test]
    fn lru_order_prefers_oldest_leaf() {
        let mut c = cache(4, 64);
        let a = prompt(&[1], 4);
        let b = prompt(&[2], 4);
        c.insert(&a, None);
        c.insert(&b, None);
        // Touch `a` so `b` becomes the LRU leaf.
        let got = c.acquire(&a);
        c.release(&a, got);
        assert_eq!(c.evict_blocks(1), 1);
        assert_eq!(c.lookup(&a), 4, "recently used path must survive");
        assert_eq!(c.lookup(&b), 0, "LRU leaf evicted first");
    }

    #[test]
    fn budget_truncates_inserts_after_eviction() {
        let mut c = cache(4, 3); // room for 3 blocks
        let a = prompt(&[1, 2, 3, 4], 4); // wants 4
        let rep = c.insert(&a, None);
        assert_eq!(rep.new_tokens, 12, "insert truncated to the budget");
        assert_eq!(c.cached_blocks(), 3);
        assert_eq!(c.lookup(&a), 12);
        // A disjoint insert evicts the old path (refcount 0) to fit.
        let b = prompt(&[7, 8], 4);
        let rep = c.insert(&b, None);
        assert_eq!(rep.new_tokens, 8);
        assert!(rep.evicted_blocks >= 2);
        assert!(c.cached_blocks() <= 3);
    }

    #[test]
    fn payload_roundtrip_through_assemble() {
        let (layers, kv_heads, head_dim, bt) = (2usize, 2usize, 3usize, 4usize);
        let row = kv_heads * head_dim;
        let t_src = 16usize;
        // Source buffer (L, T, H, D) with position-identifying values.
        let n = layers * t_src * row;
        let k_src: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let v_src: Vec<f32> = (0..n).map(|i| -(i as f32)).collect();
        let src = KvSpanSource {
            k: &k_src,
            v: &v_src,
            t_src,
            layers,
            kv_heads,
            head_dim,
        };
        let mut c = cache(bt, 64);
        let p = prompt(&[1, 2, 3], bt); // 12 tokens
        assert_eq!(c.insert(&p, Some(&src)).new_tokens, 12);
        // Divergent sibling forces a split of the payload-carrying edge.
        let q = prompt(&[1, 9], bt);
        c.insert(&q, Some(&src));

        let t_dst = 20usize;
        let mut k_out = vec![0.0f32; layers * t_dst * row];
        let mut v_out = vec![0.0f32; layers * t_dst * row];
        assert!(c.assemble(&p, 12, t_dst, &mut k_out, &mut v_out));
        for l in 0..layers {
            for tok in 0..12 {
                for e in 0..row {
                    let want = ((l * t_src + tok) * row + e) as f32;
                    let got = k_out[(l * t_dst + tok) * row + e];
                    assert_eq!(got, want, "k layer {l} tok {tok} elem {e}");
                    assert_eq!(v_out[(l * t_dst + tok) * row + e], -want);
                }
            }
        }
        // Accounting-only nodes cannot materialize.
        let mut c2 = cache(bt, 64);
        c2.insert(&p, None);
        assert!(!c2.assemble(&p, 12, t_dst, &mut k_out, &mut v_out));
    }

    #[test]
    fn interleaved_ops_keep_refcounts_balanced() {
        use crate::util::rng::XorShiftRng;
        let bt = 4usize;
        let mut c = cache(bt, 32);
        let mut rng = XorShiftRng::new(0xC0FFEE);
        // A small family of prompts sharing prefixes at various depths.
        let family: Vec<Vec<i32>> = (0..8)
            .map(|i| {
                let mut blocks = vec![1, 2];
                blocks.push(3 + (i % 4) as i32);
                blocks.push(10 + i as i32);
                prompt(&blocks, bt)
            })
            .collect();
        let mut live: Vec<(usize, usize)> = Vec::new(); // (family idx, tokens)
        for _ in 0..2000 {
            match rng.below(4) {
                0 => {
                    let i = rng.below(family.len());
                    let got = c.acquire(&family[i]);
                    live.push((i, got));
                }
                1 => {
                    if !live.is_empty() {
                        let (i, got) = live.swap_remove(rng.below(live.len()));
                        c.release(&family[i], got);
                    }
                }
                2 => {
                    let i = rng.below(family.len());
                    c.insert(&family[i], None);
                }
                _ => {
                    c.evict_blocks(rng.below(4));
                }
            }
            let expected: u64 = live.iter().map(|(_, t)| (t / bt) as u64).sum();
            assert_eq!(c.total_refs(), expected, "dangling or lost refcount");
            assert!(c.referenced_blocks() <= c.cached_blocks());
            assert!(c.cached_blocks() <= c.max_blocks());
            // Every live acquisition's path must still be materializable
            // by lookup (eviction must not have freed pinned blocks).
            for (i, t) in &live {
                assert!(c.lookup(&family[*i]) >= *t, "pinned path evicted");
            }
        }
        for (i, got) in live.drain(..) {
            c.release(&family[i], got);
        }
        assert_eq!(c.total_refs(), 0);
        // With no pins the cache must drain completely.
        c.evict_blocks(usize::MAX);
        assert_eq!(c.cached_blocks(), 0);
        assert_eq!(c.cached_bytes(), 0);
    }

    #[test]
    fn bytes_follow_the_layout_contract() {
        let layout = KvLayout::new(KvDtype::FP8_DEFAULT, 2, 2, 4);
        let mut c = PrefixCache::new(PrefixCacheConfig {
            block_tokens: 8,
            max_blocks: 16,
            layout,
        });
        let p: Vec<i32> = (0..32).collect();
        c.insert(&p, None);
        assert_eq!(c.cached_tokens(), 32);
        assert_eq!(c.cached_bytes(), 32 * layout.bytes_per_token());
        // from_bytes_budget inverts the rate.
        let budget = (64 * layout.bytes_per_token()) as f64;
        let cfg = PrefixCacheConfig::from_bytes_budget(layout, 8, budget);
        assert_eq!(cfg.max_blocks, 8);
        let cfg = PrefixCacheConfig::from_bytes_budget(layout, 8, 0.0);
        assert_eq!(cfg.max_blocks, 0);
    }
}
