//! Admission queue + continuous-batching plan construction.

use std::collections::VecDeque;

use super::request::{Request, RequestId};

/// FIFO admission queue with a capacity bound (backpressure).
#[derive(Debug)]
pub struct AdmissionQueue {
    queue: VecDeque<Request>,
    pub capacity: usize,
    rejected: u64,
}

impl AdmissionQueue {
    pub fn new(capacity: usize) -> Self {
        Self {
            queue: VecDeque::new(),
            capacity,
            rejected: 0,
        }
    }

    /// Returns false (and counts a rejection) when full.
    pub fn push(&mut self, req: Request) -> bool {
        if self.queue.len() >= self.capacity {
            self.rejected += 1;
            return false;
        }
        self.queue.push_back(req);
        true
    }

    pub fn pop(&mut self) -> Option<Request> {
        self.queue.pop_front()
    }

    pub fn peek(&self) -> Option<&Request> {
        self.queue.front()
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn rejected(&self) -> u64 {
        self.rejected
    }

    /// Prompt + generation-budget tokens across everything queued — the
    /// load signal routing policies use.
    pub fn queued_tokens(&self) -> usize {
        self.queue
            .iter()
            .map(|r| r.prompt.len() + r.max_new_tokens)
            .sum()
    }

    /// Remove and return every queued request (used when a replica is
    /// marked down and its backlog must be re-routed).
    pub fn drain_all(&mut self) -> Vec<Request> {
        self.queue.drain(..).collect()
    }
}

/// One engine iteration's work: at most one prefill plus one decode group.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BatchPlan {
    /// Request to prefill this iteration (admitted into `slot`).
    pub prefill: Option<(RequestId, usize)>,
    /// Slots to run one decode step for.
    pub decode_slots: Vec<usize>,
}

impl BatchPlan {
    pub fn is_idle(&self) -> bool {
        self.prefill.is_none() && self.decode_slots.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order() {
        let mut q = AdmissionQueue::new(4);
        for i in 0..3 {
            assert!(q.push(Request::new(i, vec![1], 4)));
        }
        assert_eq!(q.pop().unwrap().id, 0);
        assert_eq!(q.pop().unwrap().id, 1);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let mut q = AdmissionQueue::new(2);
        assert!(q.push(Request::new(0, vec![1], 1)));
        assert!(q.push(Request::new(1, vec![1], 1)));
        assert!(!q.push(Request::new(2, vec![1], 1)));
        assert_eq!(q.rejected(), 1);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn queued_tokens_and_drain() {
        let mut q = AdmissionQueue::new(4);
        q.push(Request::new(0, vec![1, 2, 3], 5));
        q.push(Request::new(1, vec![1], 2));
        assert_eq!(q.queued_tokens(), 3 + 5 + 1 + 2);
        let drained = q.drain_all();
        assert_eq!(drained.len(), 2);
        assert!(q.is_empty());
        assert_eq!(q.queued_tokens(), 0);
    }

    #[test]
    fn plan_idle() {
        assert!(BatchPlan::default().is_idle());
        let p = BatchPlan {
            prefill: None,
            decode_slots: vec![0],
        };
        assert!(!p.is_idle());
    }
}
