//! Admission queue + continuous-batching plan construction.

use std::collections::VecDeque;

use super::request::{Request, RequestId};

/// FIFO admission queue with a capacity bound (backpressure).
#[derive(Debug)]
pub struct AdmissionQueue {
    queue: VecDeque<Request>,
    pub capacity: usize,
    rejected: u64,
}

impl AdmissionQueue {
    pub fn new(capacity: usize) -> Self {
        Self {
            queue: VecDeque::new(),
            capacity,
            rejected: 0,
        }
    }

    /// Enqueue, or hand the request back when the queue is at capacity —
    /// the caller owns the reject path (mirroring the router's typed
    /// rejects) instead of the request being silently dropped. A bounce
    /// increments [`AdmissionQueue::rejected`] exactly once.
    pub fn push(&mut self, req: Request) -> Result<(), Request> {
        if self.queue.len() >= self.capacity {
            self.rejected += 1;
            return Err(req);
        }
        self.queue.push_back(req);
        Ok(())
    }

    pub fn pop(&mut self) -> Option<Request> {
        self.queue.pop_front()
    }

    pub fn peek(&self) -> Option<&Request> {
        self.queue.front()
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Push attempts bounced off a full queue (once per attempt).
    pub fn rejected(&self) -> u64 {
        self.rejected
    }

    /// Prompt + generation-budget tokens across everything queued — the
    /// load signal routing policies use.
    pub fn queued_tokens(&self) -> usize {
        self.queue
            .iter()
            .map(|r| r.prompt.len() + r.max_new_tokens)
            .sum()
    }

    /// Remove and return every queued request (used when a replica is
    /// marked down and its backlog must be re-routed).
    pub fn drain_all(&mut self) -> Vec<Request> {
        self.queue.drain(..).collect()
    }
}

/// A planned prefill admission, prefix-cache aware: the cached head of the
/// prompt is skipped and the uncached tail is computed in fixed-size
/// chunks interleavable with decode steps.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PrefillPlan {
    pub id: RequestId,
    /// KV slot allocated for the request.
    pub slot: usize,
    /// Prompt tokens served from the prefix cache (block-aligned; 0 = cold).
    pub cached_tokens: usize,
    /// Uncached tail chunks `(start, len)` in order; empty = full hit (the
    /// zero-tail plan: no prefill compute, only the first-token sample).
    pub chunks: Vec<(usize, usize)>,
}

/// One engine iteration's work: at most one prefill plus one decode group.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BatchPlan {
    /// Request to prefill this iteration (admitted into its slot).
    pub prefill: Option<PrefillPlan>,
    /// Slots to run one decode step for.
    pub decode_slots: Vec<usize>,
}

impl BatchPlan {
    pub fn is_idle(&self) -> bool {
        self.prefill.is_none() && self.decode_slots.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order() {
        let mut q = AdmissionQueue::new(4);
        for i in 0..3 {
            assert!(q.push(Request::new(i, vec![1], 4)).is_ok());
        }
        assert_eq!(q.pop().unwrap().id, 0);
        assert_eq!(q.pop().unwrap().id, 1);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn backpressure_returns_request_and_counts_once() {
        let mut q = AdmissionQueue::new(2);
        assert!(q.push(Request::new(0, vec![1], 1)).is_ok());
        assert!(q.push(Request::new(1, vec![1], 1)).is_ok());
        // The rejected request comes back to the caller intact...
        let bounced = q.push(Request::new(2, vec![1, 2, 3], 1)).unwrap_err();
        assert_eq!(bounced.id, 2);
        assert_eq!(bounced.prompt, vec![1, 2, 3]);
        // ...and is counted exactly once per attempt, not twice.
        assert_eq!(q.rejected(), 1);
        assert_eq!(q.len(), 2);
        // The caller may retry the same request later; each bounce is one
        // count.
        let bounced = q.push(bounced).unwrap_err();
        assert_eq!(q.rejected(), 2);
        let _ = q.pop();
        assert!(q.push(bounced).is_ok(), "retry succeeds once a slot frees");
        assert_eq!(q.rejected(), 2);
    }

    #[test]
    fn queued_tokens_and_drain() {
        let mut q = AdmissionQueue::new(4);
        q.push(Request::new(0, vec![1, 2, 3], 5)).unwrap();
        q.push(Request::new(1, vec![1], 2)).unwrap();
        assert_eq!(q.queued_tokens(), 3 + 5 + 1 + 2);
        let drained = q.drain_all();
        assert_eq!(drained.len(), 2);
        assert!(q.is_empty());
        assert_eq!(q.queued_tokens(), 0);
    }

    #[test]
    fn plan_idle() {
        assert!(BatchPlan::default().is_idle());
        let p = BatchPlan {
            prefill: None,
            decode_slots: vec![0],
        };
        assert!(!p.is_idle());
        let p = BatchPlan {
            prefill: Some(PrefillPlan {
                id: 1,
                slot: 0,
                cached_tokens: 0,
                chunks: vec![(0, 8)],
            }),
            decode_slots: vec![],
        };
        assert!(!p.is_idle());
    }
}
