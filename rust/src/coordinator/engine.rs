//! The serving engine: continuous-batching loop over the AOT artifacts.
//!
//! Each `step()`:
//!   1. asks the [`Scheduler`] for a plan (admit-one-prefill + decode-all);
//!   2. runs the prefill artifact for the admitted request (prompt padded
//!      to the compiled bucket), writes its KV into the allocated slot, and
//!      samples the first token (TTFT);
//!   3. runs one decode step per artifact-sized group of active slots with
//!      per-row (ragged) positions, samples greedily, retires finished
//!      requests.
//!
//! All compute is the PJRT executables; the engine only moves bytes and
//! makes decisions — the "Python never on the request path" invariant.

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::batcher::AdmissionQueue;
use super::kvcache::KvStore;
use super::metrics::ServeMetrics;
use super::request::{Request, RequestId, RequestOutput};
use super::scheduler::{SchedulePolicy, Scheduler};
use crate::quant::{KvDtype, KvLayout};
use crate::router::{Admission, ReplicaHandle};
use crate::runtime::{load_params_bin, Artifact, ArtifactKey, ArtifactRegistry, Runtime, TensorIn};
use crate::util::json::Json;

/// Parsed artifacts/meta.json.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub vocab: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub cache_t: usize,
    pub prefill_seqs: Vec<usize>,
    pub decode_batches: Vec<usize>,
    pub prefill_variants: Vec<String>,
    pub decode_variants: Vec<String>,
}

impl ModelMeta {
    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("meta.json"))
            .with_context(|| format!("reading {dir:?}/meta.json — run `make artifacts`"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("meta.json: {e}"))?;
        let model = j.get("model").ok_or_else(|| anyhow!("meta: no model"))?;
        let geti = |obj: &Json, k: &str| -> Result<usize> {
            obj.get(k)
                .and_then(Json::as_f64)
                .map(|v| v as usize)
                .ok_or_else(|| anyhow!("meta: missing {k}"))
        };
        let get_list = |k: &str| -> Result<Vec<usize>> {
            Ok(j.get(k)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("meta: missing {k}"))?
                .iter()
                .filter_map(Json::as_f64)
                .map(|v| v as usize)
                .collect())
        };
        let get_strs = |k: &str| -> Vec<String> {
            j.get(k)
                .and_then(Json::as_arr)
                .map(|a| {
                    a.iter()
                        .filter_map(Json::as_str)
                        .map(str::to_string)
                        .collect()
                })
                .unwrap_or_default()
        };
        Ok(Self {
            vocab: geti(model, "vocab")?,
            hidden: geti(model, "hidden")?,
            layers: geti(model, "layers")?,
            heads: geti(model, "heads")?,
            kv_heads: geti(model, "kv_heads")?,
            cache_t: geti(&j, "cache_t")?,
            prefill_seqs: get_list("prefill_seqs")?,
            decode_batches: get_list("decode_batches")?,
            prefill_variants: get_strs("prefill_variants"),
            decode_variants: get_strs("decode_variants"),
        })
    }
}

#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub artifacts_dir: std::path::PathBuf,
    /// Quantization variant served ("bf16", "fp8_pt", "fp8_pc").
    pub variant: String,
    /// Concurrent KV slots (≥ max decode batch bucket is wasteful; ≤ is
    /// fine — groups are chunked).
    pub slots: usize,
    pub policy: SchedulePolicy,
    pub queue_capacity: usize,
    /// Host KV-cache storage dtype. `F32` preserves the exact legacy
    /// roundtrip; `Fp8` stores codes + per-(slot, layer, kv-head) scales
    /// at 1/4 the bytes (the paper's serving configuration).
    pub kv_dtype: KvDtype,
}

impl EngineConfig {
    pub fn new(artifacts_dir: &Path, variant: &str) -> Self {
        Self {
            artifacts_dir: artifacts_dir.to_path_buf(),
            variant: variant.to_string(),
            slots: 8,
            policy: SchedulePolicy::PrefillFirst,
            queue_capacity: 256,
            kv_dtype: KvDtype::F32,
        }
    }
}

struct ActiveRequest {
    id: RequestId,
    prompt_len: usize,
    max_new_tokens: usize,
    stop_token: Option<i32>,
    arrival: Instant,
    first_token_at: Option<Instant>,
    generated: Vec<i32>,
    last_token: i32,
}

pub struct Engine {
    pub cfg: EngineConfig,
    pub meta: ModelMeta,
    registry: ArtifactRegistry,
    /// Model weights as long-lived PJRT literals, in artifact arg order.
    param_literals: Vec<xla::Literal>,
    kv: KvStore,
    queue: AdmissionQueue,
    scheduler: Scheduler,
    active: HashMap<usize, ActiveRequest>, // slot → request
    pub metrics: ServeMetrics,
    finished: Vec<RequestOutput>,
    /// Reusable decode-batch KV staging buffers (§Perf L3: avoids a
    /// multi-MB alloc + zero-fill per decode step).
    scratch_k: Vec<f32>,
    scratch_v: Vec<f32>,
    scratch_bucket: usize,
}

impl Engine {
    pub fn new(cfg: EngineConfig) -> Result<Self> {
        let meta = ModelMeta::load(&cfg.artifacts_dir)?;
        if !meta.decode_variants.iter().any(|v| v == &cfg.variant) {
            bail!(
                "variant {:?} has no decode artifacts (available: {:?})",
                cfg.variant,
                meta.decode_variants
            );
        }
        let rt = Runtime::cpu()?;
        let registry = ArtifactRegistry::new(rt, &cfg.artifacts_dir);
        let params = load_params_bin(&cfg.artifacts_dir.join("weights_tiny.bin"))?;
        let param_literals = params
            .iter()
            .map(|p| TensorIn::f32(&p.dims, p.data.clone()).to_literal())
            .collect::<Result<Vec<_>>>()?;
        let kv = KvStore::with_dtype(
            meta.layers,
            cfg.slots,
            meta.cache_t,
            meta.kv_heads,
            meta.head_dim(),
            cfg.kv_dtype,
        );
        let scheduler = Scheduler::new(
            cfg.policy,
            meta.prefill_seqs.clone(),
            meta.decode_batches.clone(),
        );
        Ok(Self {
            queue: AdmissionQueue::new(cfg.queue_capacity),
            active: HashMap::new(),
            metrics: ServeMetrics::new(),
            finished: Vec::new(),
            cfg,
            meta,
            registry,
            param_literals,
            kv,
            scheduler,
            scratch_k: Vec::new(),
            scratch_v: Vec::new(),
            scratch_bucket: 0,
        })
    }

    /// The KV accounting contract this engine's host store follows — the
    /// same [`KvLayout`] the capacity model and fleet replicas charge.
    pub fn kv_layout(&self) -> KvLayout {
        self.kv.layout()
    }

    /// Pre-compile the artifacts this engine will use, so TTFT/TPOT metrics
    /// measure service latency rather than first-use XLA compilation.
    pub fn warmup(&mut self) -> Result<()> {
        for &b in &self.meta.decode_batches.clone() {
            self.artifact(&ArtifactKey::decode(&self.cfg.variant, b))?;
        }
        for &s in &self.meta.prefill_seqs.clone() {
            self.artifact(&ArtifactKey::prefill(&self.cfg.variant, 1, s))?;
        }
        Ok(())
    }

    pub fn submit(&mut self, req: Request) -> bool {
        self.metrics.prompt_tokens += req.prompt.len() as u64;
        self.queue.push(req)
    }

    pub fn pending(&self) -> usize {
        self.queue.len() + self.active.len()
    }

    pub fn take_finished(&mut self) -> Vec<RequestOutput> {
        std::mem::take(&mut self.finished)
    }

    /// One engine iteration. Returns false when there is nothing to do.
    pub fn step(&mut self) -> Result<bool> {
        let plan = self.scheduler.plan(&self.queue, &mut self.kv);
        if plan.is_idle() && self.queue.is_empty() {
            return Ok(false);
        }

        if let Some((_, slot)) = plan.prefill {
            let req = self.queue.pop().expect("planned prefill without request");
            self.run_prefill(req, slot)?;
        } else if plan.decode_slots.is_empty() {
            // Nothing active and nothing admissible (e.g. oversized prompt).
            if let Some(req) = self.queue.pop() {
                self.finished.push(RequestOutput {
                    id: req.id,
                    prompt_len: req.prompt.len(),
                    tokens: Vec::new(),
                    ttft_s: 0.0,
                    tpot_s: 0.0,
                    total_s: 0.0,
                });
                // Counted so completion totals agree with emitted outputs.
                self.metrics.requests_completed += 1;
                return Ok(true);
            }
            return Ok(false);
        }

        let active: Vec<usize> = {
            let mut s: Vec<usize> = self.active.keys().copied().collect();
            s.sort_unstable();
            s
        };
        for group in self.scheduler.decode_groups(&active) {
            self.run_decode_group(&group)?;
        }
        Ok(true)
    }

    /// Drive until every submitted request completes.
    pub fn run_to_completion(&mut self) -> Result<Vec<RequestOutput>> {
        while self.pending() > 0 {
            self.step()?;
        }
        Ok(self.take_finished())
    }

    fn artifact(&self, key: &ArtifactKey) -> Result<std::sync::Arc<Artifact>> {
        self.registry.get(key)
    }

    fn run_prefill(&mut self, req: Request, slot: usize) -> Result<()> {
        let bucket = self
            .scheduler
            .prefill_bucket(req.prompt.len())
            .ok_or_else(|| anyhow!("prompt of {} exceeds buckets", req.prompt.len()))?;
        let key = ArtifactKey::prefill(&self.cfg.variant, 1, bucket);
        let art = self.artifact(&key)?;
        let t0 = Instant::now();

        let mut tokens = req.prompt.clone();
        tokens.resize(bucket, 0);
        let mut literals = self.param_literals.clone();
        literals.push(TensorIn::i32(&[1, bucket], tokens).to_literal()?);
        let outs = art.run_literals(&literals)?;
        // outputs: logits (1, S, V), k (L,1,T,Hkv,D), v (...)
        let logits = &outs[0];
        let v = self.meta.vocab;
        let last = req.prompt.len() - 1;
        let row = &logits.data[last * v..(last + 1) * v];
        let first_token = argmax(row);

        self.kv
            .write_slot(slot, &outs[1].data, &outs[2].data, req.prompt.len());
        self.metrics.prefill_steps += 1;
        self.metrics.prefill_time.record(t0.elapsed().as_secs_f64());
        let now = Instant::now();
        self.metrics
            .ttft
            .record(now.duration_since(req.arrival).as_secs_f64());

        self.active.insert(
            slot,
            ActiveRequest {
                id: req.id,
                prompt_len: req.prompt.len(),
                max_new_tokens: req.max_new_tokens,
                stop_token: req.stop_token,
                arrival: req.arrival,
                first_token_at: Some(now),
                generated: vec![first_token],
                last_token: first_token,
            },
        );
        self.metrics.generated_tokens += 1;
        // Immediately-finished request (max_new_tokens == 1, stop token, or
        // a prompt that already fills the cache).
        let kv_full = self.kv.is_full(slot);
        self.maybe_finish(slot, kv_full);
        Ok(())
    }

    fn run_decode_group(&mut self, group: &[usize]) -> Result<()> {
        if group.is_empty() {
            return Ok(());
        }
        let bucket = self.scheduler.decode_bucket(group.len());
        let key = ArtifactKey::decode(&self.cfg.variant, bucket);
        let art = self.artifact(&key)?;
        let t0 = Instant::now();

        let ss = self.meta.cache_t * self.meta.kv_heads * self.meta.head_dim();
        // Stage the batch in reusable scratch (padding rows beyond the group
        // carry stale-but-masked data; pos=0 hides them from attention and
        // their outputs are never scattered back).
        let need = self.meta.layers * bucket * ss;
        if self.scratch_bucket != bucket {
            self.scratch_k.clear();
            self.scratch_k.resize(need, 0.0);
            self.scratch_v.clear();
            self.scratch_v.resize(need, 0.0);
            self.scratch_bucket = bucket;
        }
        let lens = self
            .kv
            .gather_batch_into(group, bucket, &mut self.scratch_k, &mut self.scratch_v);
        // One unavoidable copy into the PJRT literal; the scratch persists.
        let (k, v) = (self.scratch_k.clone(), self.scratch_v.clone());
        let tokens: Vec<i32> = {
            let mut t: Vec<i32> = group
                .iter()
                .map(|s| self.active[s].last_token)
                .collect();
            t.resize(bucket, 0);
            t
        };

        let kv_dims = [
            self.meta.layers,
            bucket,
            self.meta.cache_t,
            self.meta.kv_heads,
            self.meta.head_dim(),
        ];
        let mut literals = self.param_literals.clone();
        literals.push(TensorIn::i32(&[bucket], tokens).to_literal()?);
        literals.push(TensorIn::f32(&kv_dims, k).to_literal()?);
        literals.push(TensorIn::f32(&kv_dims, v).to_literal()?);
        literals.push(TensorIn::i32(&[bucket], lens).to_literal()?);
        let outs = art.run_literals(&literals)?;

        // outputs: logits (B, V), k, v.
        let vsz = self.meta.vocab;
        // Scatter back only the real rows.
        let (l, b) = (self.meta.layers, group.len());
        let (mut kr, mut vr) = (vec![0.0f32; l * b * ss], vec![0.0f32; l * b * ss]);
        for li in 0..l {
            for bi in 0..b {
                let src = (li * bucket + bi) * ss;
                let dst = (li * b + bi) * ss;
                kr[dst..dst + ss].copy_from_slice(&outs[1].data[src..src + ss]);
                vr[dst..dst + ss].copy_from_slice(&outs[2].data[src..src + ss]);
            }
        }
        // "Sequence full" slots must finish below: the store clamps their
        // length at cache_t, and another decode step would silently
        // overwrite the last position.
        let full_slots = self.kv.scatter_batch(group, &kr, &vr);

        let now = Instant::now();
        for (bi, &slot) in group.iter().enumerate() {
            let row = &outs[0].data[bi * vsz..(bi + 1) * vsz];
            let tok = argmax(row);
            let a = self.active.get_mut(&slot).unwrap();
            a.generated.push(tok);
            a.last_token = tok;
            if let Some(ft) = a.first_token_at {
                self.metrics
                    .tpot
                    .record(now.duration_since(ft).as_secs_f64() / a.generated.len().max(1) as f64);
            }
        }
        self.metrics.generated_tokens += group.len() as u64;
        self.metrics.decode_steps += 1;
        self.metrics.decode_batch_sum += group.len() as u64;
        self.metrics.decode_time.record(t0.elapsed().as_secs_f64());

        for &slot in group {
            self.maybe_finish(slot, full_slots.contains(&slot));
        }
        Ok(())
    }

    fn maybe_finish(&mut self, slot: usize, kv_full: bool) {
        let done = {
            let Some(a) = self.active.get(&slot) else {
                return;
            };
            let hit_stop = a
                .stop_token
                .map(|s| a.generated.last() == Some(&s))
                .unwrap_or(false);
            a.generated.len() >= a.max_new_tokens || hit_stop || kv_full
        };
        if done {
            let a = self.active.remove(&slot).unwrap();
            self.kv.free_slot(slot);
            let total = a.arrival.elapsed().as_secs_f64();
            let ttft = a
                .first_token_at
                .map(|t| t.duration_since(a.arrival).as_secs_f64())
                .unwrap_or(total);
            let n = a.generated.len();
            self.finished.push(RequestOutput {
                id: a.id,
                prompt_len: a.prompt_len,
                tokens: a.generated,
                ttft_s: ttft,
                tpot_s: if n > 1 { (total - ttft) / (n - 1) as f64 } else { 0.0 },
                total_s: total,
            });
            self.metrics.requests_completed += 1;
        }
    }
}

/// The fleet router drives engines through [`ReplicaHandle`] — a narrow
/// interface extracted from the inherent methods above, so replicas can be
/// real PJRT engines or gaudisim-backed simulations interchangeably.
impl ReplicaHandle for Engine {
    fn label(&self) -> String {
        format!("engine[{}]", self.cfg.variant)
    }

    /// Wall-clock replica: elapsed seconds since construction.
    fn clock_s(&self) -> f64 {
        self.metrics.started.elapsed().as_secs_f64()
    }

    fn advance_clock_to(&mut self, _t_s: f64) {
        // Wall clocks advance themselves.
    }

    fn queued(&self) -> usize {
        self.queue.len()
    }

    fn active(&self) -> usize {
        self.active.len()
    }

    fn outstanding_tokens(&self) -> usize {
        let resident: usize = self
            .active
            .values()
            .map(|a| a.prompt_len + a.max_new_tokens.saturating_sub(a.generated.len()))
            .sum();
        self.queue.queued_tokens() + resident
    }

    fn queue_capacity(&self) -> usize {
        self.cfg.queue_capacity
    }

    fn could_ever_admit(&self, prompt_len: usize, max_new_tokens: usize) -> Admission {
        if self.scheduler.prefill_bucket(prompt_len).is_none() {
            return Admission::PromptTooLong;
        }
        if prompt_len + max_new_tokens > self.meta.cache_t {
            return Admission::KvWouldOom;
        }
        Admission::Accept
    }

    fn submit(&mut self, req: Request, _arrival_s: f64) -> bool {
        Engine::submit(self, req)
    }

    fn step(&mut self) -> Result<bool> {
        Engine::step(self)
    }

    fn take_finished(&mut self) -> Vec<RequestOutput> {
        Engine::take_finished(self)
    }

    fn evict_queued(&mut self) -> Vec<Request> {
        self.queue.drain_all()
    }

    fn abort_active(&mut self) -> Vec<RequestId> {
        let slots: Vec<usize> = self.active.keys().copied().collect();
        let mut ids = Vec::with_capacity(slots.len());
        for slot in slots {
            let a = self.active.remove(&slot).expect("slot key just listed");
            self.kv.free_slot(slot);
            ids.push(a.id);
        }
        ids
    }

    fn metrics(&self) -> &ServeMetrics {
        &self.metrics
    }
}

fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0]), 1);
        assert_eq!(argmax(&[f32::NEG_INFINITY, -1.0]), 1);
    }

    // Engine integration tests (require artifacts) are in
    // rust/tests/serving_integration.rs.
}
