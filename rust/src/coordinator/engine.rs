//! The serving engine: continuous-batching loop over the AOT artifacts.
//!
//! Each `step()`:
//!   1. advances an in-flight chunked prefill by one chunk, if any;
//!   2. asks the [`Scheduler`] for a plan (admit-one-prefill + decode-all);
//!   3. on a cold admission, runs the prefill artifact for the whole
//!      prompt (padded to the compiled bucket), writes its KV into the
//!      allocated slot's block table, samples the first token (TTFT), and
//!      shares the block-aligned prompt KV into the prefix cache — the
//!      cache *adopts* the slot's physical blocks (refcount, no copy);
//!   4. on a warm admission (prefix-cache hit), **maps** the cached
//!      physical blocks into the request's block table (the prefix is
//!      never copied; a copy-on-write fires only if the bootstrap chunk
//!      rewrites the tail of the last shared block) and recomputes only
//!      the uncached tail — token by token through the decode artifact
//!      (numerically the same model as prefill, with the cached prefix as
//!      attention context) — in `prefill_chunk`-sized chunks interleaved
//!      with decode steps;
//!   5. runs one decode step per artifact-sized group of active slots with
//!      per-row (ragged) positions, samples greedily, retires finished
//!      requests.
//!
//! All compute is the PJRT executables; the engine only moves bytes and
//! makes decisions — the "Python never on the request path" invariant.
//!
//! # Block-table-native decode (ISSUE 5)
//!
//! The decode and chunked-prefill hot paths no longer densify the KV
//! cache. The old loop gathered every slot's block table into a dense
//! `(L, B, cache_t, Hkv, D)` scratch pair, handed that to the decode
//! artifact, and scattered the whole buffer back — per-step traffic
//! proportional to `bucket × cache_t` regardless of live context. Now:
//!
//! * the engine hands the **paged decode artifact**
//!   (`decode_paged_<variant>_b<B>.hlo.txt`, lowered by
//!   `python/compile/aot.py::lower_decode_paged`) per-row block tables and
//!   lengths that reference the pool *in place* — the kernel walks the
//!   tables and dequantizes blocks on read, vLLM-style;
//! * the artifact returns logits plus only the **appended token's** KV
//!   `(L, B, 1, Hkv, D)`, which [`KvStore::append_token`] quantizes into
//!   each row's hot block (copy-on-write preserved) — the full dense
//!   scatter is gone;
//! * on real hardware the pool is device-resident and donated between
//!   steps; the PJRT-CPU stub runner exports exactly the group's blocks
//!   instead (`BlockPool::export_f32_blocks_into`, persistent and
//!   incrementally updated), still with no per-sequence window or bucket
//!   padding.
//!
//! The pre-paged dense staging survives only behind the
//! `dense-decode-ref` cargo feature ([`Engine::run_decode_group_dense`])
//! as the reference implementation for paged-vs-dense roundtrip tests.

use std::collections::{HashMap, VecDeque};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::batcher::{AdmissionQueue, PrefillPlan};
use super::hosttier::HostTier;
use super::kvcache::{AppendOutcome, KvStore, SwappedSlot};
use super::metrics::ServeMetrics;
use super::prefix::{PrefixCache, PrefixCacheConfig};
use super::request::{Request, RequestId, RequestOutput};
use super::scheduler::{
    chunk_spans, select_preemption_victim, warm_admittable_without_bucket, PreemptCandidate,
    PreemptPolicy, SchedulePolicy, Scheduler,
};
use crate::model::{DraftLm, ModelConfig, ModelFamily};
use crate::obs::{Clock, TraceEventKind, TraceRecorder};
use crate::quant::{KvDtype, KvLayout, KV_BLOCK_TOKENS};
use crate::router::{Admission, ReplicaHandle};
use crate::runtime::{load_params_bin, Artifact, ArtifactKey, ArtifactRegistry, Runtime, TensorIn};
use crate::util::json::Json;
use crate::util::pool::Parallelism;

/// Block granularity of the engine's prefix cache and paged block pool
/// (tokens) — one constant, shared with the whole KV subsystem, so cached
/// prefixes and slot block tables tile identically.
pub const PREFIX_BLOCK_TOKENS: usize = KV_BLOCK_TOKENS;

/// Parsed artifacts/meta.json.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub vocab: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub cache_t: usize,
    pub prefill_seqs: Vec<usize>,
    pub decode_batches: Vec<usize>,
    pub prefill_variants: Vec<String>,
    pub decode_variants: Vec<String>,
    /// Pool capacity the paged decode artifacts were compiled for
    /// (`None` = legacy dense-only artifact set).
    pub paged_pool_blocks: Option<usize>,
    /// Block granularity the paged artifacts were compiled for.
    pub paged_block_tokens: usize,
}

impl ModelMeta {
    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("meta.json"))
            .with_context(|| format!("reading {dir:?}/meta.json — run `make artifacts`"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("meta.json: {e}"))?;
        let model = j.get("model").ok_or_else(|| anyhow!("meta: no model"))?;
        let geti = |obj: &Json, k: &str| -> Result<usize> {
            obj.get(k)
                .and_then(Json::as_f64)
                .map(|v| v as usize)
                .ok_or_else(|| anyhow!("meta: missing {k}"))
        };
        let get_list = |k: &str| -> Result<Vec<usize>> {
            Ok(j.get(k)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("meta: missing {k}"))?
                .iter()
                .filter_map(Json::as_f64)
                .map(|v| v as usize)
                .collect())
        };
        let get_strs = |k: &str| -> Vec<String> {
            j.get(k)
                .and_then(Json::as_arr)
                .map(|a| {
                    a.iter()
                        .filter_map(Json::as_str)
                        .map(str::to_string)
                        .collect()
                })
                .unwrap_or_default()
        };
        Ok(Self {
            vocab: geti(model, "vocab")?,
            hidden: geti(model, "hidden")?,
            layers: geti(model, "layers")?,
            heads: geti(model, "heads")?,
            kv_heads: geti(model, "kv_heads")?,
            cache_t: geti(&j, "cache_t")?,
            prefill_seqs: get_list("prefill_seqs")?,
            decode_batches: get_list("decode_batches")?,
            prefill_variants: get_strs("prefill_variants"),
            decode_variants: get_strs("decode_variants"),
            paged_pool_blocks: j
                .get("paged_pool_blocks")
                .and_then(Json::as_f64)
                .map(|v| v as usize),
            paged_block_tokens: j
                .get("paged_block_tokens")
                .and_then(Json::as_f64)
                .map(|v| v as usize)
                .unwrap_or(KV_BLOCK_TOKENS),
        })
    }
}

#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub artifacts_dir: std::path::PathBuf,
    /// Quantization variant served ("bf16", "fp8_pt", "fp8_pc").
    pub variant: String,
    /// Concurrent KV slots (≥ max decode batch bucket is wasteful; ≤ is
    /// fine — groups are chunked).
    pub slots: usize,
    pub policy: SchedulePolicy,
    pub queue_capacity: usize,
    /// Host KV-cache storage dtype. `F32` preserves the exact legacy
    /// roundtrip; `Fp8` stores codes + per-(slot, layer, kv-head) scales
    /// at 1/4 the bytes (the paper's serving configuration).
    pub kv_dtype: KvDtype,
    /// Shared-prefix KV cache byte budget (None = prefix caching off).
    /// Charged through the same [`KvLayout`] rate as everything else.
    pub prefix_cache_bytes: Option<f64>,
    /// Chunked-prefill chunk size in tokens per engine step for cache-hit
    /// tails; 0 = process the whole tail in one step.
    pub prefill_chunk: usize,
    /// Host-memory KV tier byte budget for preemption swap-outs
    /// (ISSUE 9); 0.0 disables the tier and with it slot preemption,
    /// preserving the legacy admission behavior exactly.
    pub host_kv_bytes: f64,
    /// Preemption resume policy. `Swap` round-trips the victim's KV
    /// through the host tier; `Recompute` drops the blocks and replays
    /// the victim's context through the forced-decode chain on resume;
    /// `Auto` prices the two arms against each other with *measured*
    /// EMAs (seconds/block over the host link vs. seconds/token of
    /// re-prefill) — the wall-clock engine has no analytic device model,
    /// so it measures instead, falling back to `Swap` until both EMAs
    /// are seeded. Preemption stays gated on `host_kv_bytes > 0` except
    /// under pure `Recompute`, which needs no host bytes at all.
    pub preempt_policy: PreemptPolicy,
    /// Draft-verify speculative decoding (ISSUE 10): the prompt-lookup
    /// draft proposes this many tokens per round and the target verifies
    /// them with a greedy accept-prefix pass (0 = off). Accepted output
    /// is bit-identical to plain greedy decode; a rejection rolls the
    /// slot back by block truncation. Applied to lone decode rows only —
    /// batched rows already amortize the step overhead speculation
    /// exists to beat.
    pub spec_gamma: usize,
    /// Default beam width for width-k beam groups (1 = off; requests can
    /// override per-request). A beam request forks `k-1` branches off
    /// the shared prompt KV at its first token, seeds each with one of
    /// the top-k first tokens, decodes the branches as one co-resident
    /// group, and emits the best cumulative-log-prob branch; the rest
    /// are pruned forks.
    pub beam_width: usize,
    /// Worker-count policy for the host-side paged KV hot path — the
    /// scoped `util::pool` workers behind the per-step pool export in
    /// [`Engine::paged_decode_forward`] (and the chunked-prefill
    /// forced-decode path that routes through it). `Auto` honors
    /// `REPRO_NUM_THREADS`; byte-for-byte deterministic at any count.
    pub kv_parallelism: Parallelism,
    /// Route decode groups through the dense reference implementation
    /// ([`Engine::run_decode_group_dense`]) instead of the paged path —
    /// the paged-vs-dense roundtrip switch, compiled only with the
    /// `dense-decode-ref` feature.
    #[cfg(feature = "dense-decode-ref")]
    pub use_dense_decode: bool,
}

impl EngineConfig {
    pub fn new(artifacts_dir: &Path, variant: &str) -> Self {
        Self {
            artifacts_dir: artifacts_dir.to_path_buf(),
            variant: variant.to_string(),
            slots: 8,
            policy: SchedulePolicy::PrefillFirst,
            queue_capacity: 256,
            kv_dtype: KvDtype::F32,
            prefix_cache_bytes: None,
            prefill_chunk: 0,
            host_kv_bytes: 0.0,
            preempt_policy: PreemptPolicy::Auto,
            spec_gamma: 0,
            beam_width: 1,
            kv_parallelism: Parallelism::Auto,
            #[cfg(feature = "dense-decode-ref")]
            use_dense_decode: false,
        }
    }
}

struct ActiveRequest {
    id: RequestId,
    prompt: Vec<i32>,
    /// Prompt tokens pinned in the prefix cache (released at retirement).
    cache_tokens: usize,
    max_new_tokens: usize,
    stop_token: Option<i32>,
    /// The request's arrival clock (`arrival.now_s()` = age in seconds).
    arrival: Clock,
    /// Clock anchored when the first token was produced; `None` until
    /// prefill completes.
    first_token_at: Option<Clock>,
    /// Refreshed every time this request is part of a decode group; its
    /// elapsed reading is the idleness key victim selection maximizes.
    last_scheduled: Clock,
    generated: Vec<i32>,
    last_token: i32,
    /// Beam membership: the owning request's id when this slot is one
    /// branch of a width-k beam group, `None` for plain requests.
    beam_group: Option<RequestId>,
    /// Cumulative log-softmax score of this branch's sampled tokens —
    /// the beam's pruning key at retirement.
    beam_score: f64,
}

/// How a preempted sequence's KV comes back on resume.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ResumeKind {
    /// Blocks round-trip through the host tier bit-identically.
    Swap,
    /// Blocks were dropped; resume replays the context through the
    /// forced-decode chain (the chunked-prefill workhorse).
    Recompute,
}

/// A preempted sequence parked off-device, FIFO behind its peers.
struct PreemptedSeq {
    a: ActiveRequest,
    kind: ResumeKind,
}

/// Aggregate finish state of one width-k beam group: branches retire
/// individually, the group emits once — the best-scoring branch wins.
struct BeamPending {
    width: usize,
    done: usize,
    best_score: f64,
    best: Option<RequestOutput>,
}

/// A warm admission whose uncached tail is still being recomputed, one
/// chunk per engine step.
struct ChunkedPrefill {
    req: Request,
    slot: usize,
    /// Pinned cached-prefix tokens (released at retirement).
    cache_tokens: usize,
    /// Remaining tail chunks `(start, len)` from the plan, in order; a
    /// full hit carries one synthetic chunk recomputing the last prompt
    /// position (its logits are the first-token sample).
    chunks: std::collections::VecDeque<(usize, usize)>,
    last_logits: Vec<f32>,
}

pub struct Engine {
    pub cfg: EngineConfig,
    pub meta: ModelMeta,
    registry: ArtifactRegistry,
    /// Model weights as long-lived PJRT literals, in artifact arg order.
    param_literals: Vec<xla::Literal>,
    kv: KvStore,
    queue: AdmissionQueue,
    scheduler: Scheduler,
    active: HashMap<usize, ActiveRequest>, // slot → request
    /// Radix-tree shared-prefix cache (None = off).
    prefix: Option<PrefixCache>,
    /// At most one chunked prefill in flight (the one-prefill-per-step
    /// interleave discipline).
    chunked: Option<ChunkedPrefill>,
    /// Preempted sequences awaiting resume, FIFO. Swap victims' KV
    /// payloads (moved blocks: FP8 codes + scales together) live in
    /// `host`, keyed by request id; recompute victims carry no payload.
    /// Re-admission holds strict priority over new arrivals (no
    /// admission while this is non-empty).
    preempted: VecDeque<PreemptedSeq>,
    /// Host-memory KV tier for swap-outs (None = swap arm off).
    host: Option<HostTier<SwappedSlot>>,
    /// Prompt-lookup draft model for speculative rounds (`spec_gamma > 0`).
    draft: Option<DraftLm>,
    /// Beam groups in flight, keyed by the owning request id.
    beams: HashMap<RequestId, BeamPending>,
    /// Measured seconds/token of re-prefill (cold prefills, warm chunks,
    /// and recompute resumes all feed it) — prices `Auto`'s recompute arm.
    reprefill_s_per_token: Option<f64>,
    /// Measured seconds/block over the host link (swap-outs and
    /// swap-ins feed it) — prices `Auto`'s swap arm.
    swap_s_per_block: Option<f64>,
    pub metrics: ServeMetrics,
    finished: Vec<RequestOutput>,
    /// Lifecycle-event recorder (None = tracing off, the hot-path default).
    /// Wall-clocked: the engine measures real service latency, so its
    /// timeline is directly comparable with a SimReplica's virtual one.
    trace: Option<TraceRecorder>,
    // The dense scratch pairs (`scratch_k`/`scratch_v`/`chunk_k`/`chunk_v`)
    // that staged every decode step's bucket-padded (L, B, cache_t, …)
    // gather are gone — the paged path reads block tables in place and
    // appends one token. What remains is the CPU-stub runner's pool
    // export (on device the pool is donated, not exported), kept
    // persistent and updated incrementally: each step zeroes only the
    // regions `pool_exported` lists and rewrites only the new group's
    // blocks — work proportional to the group, never to the pool.
    /// Persistent paged pool export pair, sized to the compiled pool.
    pool_export_k: Vec<f32>,
    pool_export_v: Vec<f32>,
    /// Blocks currently materialized in the export pair (zeroed before
    /// the next export).
    pool_exported: Vec<usize>,
}

impl Engine {
    pub fn new(cfg: EngineConfig) -> Result<Self> {
        let meta = ModelMeta::load(&cfg.artifacts_dir)?;
        if !meta.decode_variants.iter().any(|v| v == &cfg.variant) {
            bail!(
                "variant {:?} has no decode artifacts (available: {:?})",
                cfg.variant,
                meta.decode_variants
            );
        }
        let rt = Runtime::cpu()?;
        let registry = ArtifactRegistry::new(rt, &cfg.artifacts_dir);
        let params = load_params_bin(&cfg.artifacts_dir.join("weights_tiny.bin"))?;
        let param_literals = params
            .iter()
            .map(|p| TensorIn::f32(&p.dims, p.data.clone()).to_literal())
            .collect::<Result<Vec<_>>>()?;
        // The prefix cache owns blocks in the same physical pool the slot
        // store pages, so its budget is charged at the store's dtype rate
        // (`--prefix-cache-mb` bounds real pool bytes) and the pool is
        // over-provisioned by exactly the cache's block budget — slots and
        // cached prefixes can never starve each other.
        let bt = PREFIX_BLOCK_TOKENS.min(meta.cache_t.max(1));
        let layout = KvLayout::new(cfg.kv_dtype, meta.layers, meta.kv_heads, meta.head_dim());
        let cache_cfg = cfg
            .prefix_cache_bytes
            .map(|bytes| PrefixCacheConfig::from_bytes_budget(layout, bt, bytes));
        let cache_blocks = cache_cfg.as_ref().map_or(0, |c| c.max_blocks);
        let kv = KvStore::with_block_tokens(
            meta.layers,
            cfg.slots,
            meta.cache_t,
            meta.kv_heads,
            meta.head_dim(),
            cfg.kv_dtype,
            bt,
            cache_blocks,
        );
        let prefix = cache_cfg.map(PrefixCache::new);
        // Paged artifacts compile a fixed pool shape: the engine's pool
        // must tile identically and fit inside it (the export is padded up
        // to the compiled block count).
        if let Some(nb) = meta.paged_pool_blocks {
            if kv.block_tokens() != meta.paged_block_tokens {
                bail!(
                    "engine block size {} ≠ compiled paged block size {} — \
                     regenerate artifacts with `make artifacts`",
                    kv.block_tokens(),
                    meta.paged_block_tokens
                );
            }
            if kv.pool().total_blocks() > nb {
                bail!(
                    "engine pool of {} blocks exceeds the compiled paged-artifact \
                     pool of {nb} — lower --slots / --prefix-cache-mb or recompile \
                     the artifacts with a larger pool",
                    kv.pool().total_blocks()
                );
            }
        }
        let scheduler = Scheduler::new(
            cfg.policy,
            meta.prefill_seqs.clone(),
            meta.decode_batches.clone(),
        );
        let host = if cfg.host_kv_bytes > 0.0 {
            Some(HostTier::new(cfg.host_kv_bytes as usize, &layout, bt))
        } else {
            None
        };
        // The draft shares the target's vocabulary (its proposals are fed
        // straight to the target's embedding) but keeps the tiny synthetic
        // geometry — the whole point is that drafting is nearly free.
        let draft = (cfg.spec_gamma > 0).then(|| {
            let mut dc = ModelConfig::synthetic_tiny(ModelFamily::Llama3);
            dc.vocab = meta.vocab;
            DraftLm::new(dc)
        });
        Ok(Self {
            queue: AdmissionQueue::new(cfg.queue_capacity),
            active: HashMap::new(),
            prefix,
            chunked: None,
            preempted: VecDeque::new(),
            host,
            draft,
            beams: HashMap::new(),
            reprefill_s_per_token: None,
            swap_s_per_block: None,
            metrics: ServeMetrics::new(),
            finished: Vec::new(),
            trace: None,
            cfg,
            meta,
            registry,
            param_literals,
            kv,
            scheduler,
            pool_export_k: Vec::new(),
            pool_export_v: Vec::new(),
            pool_exported: Vec::new(),
        })
    }

    /// The KV accounting contract this engine's host store follows — the
    /// same [`KvLayout`] the capacity model and fleet replicas charge.
    pub fn kv_layout(&self) -> KvLayout {
        self.kv.layout()
    }

    /// The engine's prefix cache, when enabled.
    pub fn prefix_cache(&self) -> Option<&PrefixCache> {
        self.prefix.as_ref()
    }

    /// Pre-compile the artifacts this engine will use, so TTFT/TPOT metrics
    /// measure service latency rather than first-use XLA compilation.
    pub fn warmup(&mut self) -> Result<()> {
        let paged = self.meta.paged_pool_blocks.is_some();
        for &b in &self.meta.decode_batches.clone() {
            let key = if paged {
                ArtifactKey::decode_paged(&self.cfg.variant, b)
            } else {
                ArtifactKey::decode(&self.cfg.variant, b)
            };
            self.artifact(&key)?;
            // The dense-reference switch decodes through the legacy dense
            // artifacts: warm those too, or the A/B comparison's first
            // step would absorb an XLA compilation.
            #[cfg(feature = "dense-decode-ref")]
            if self.cfg.use_dense_decode && paged {
                self.artifact(&ArtifactKey::decode(&self.cfg.variant, b))?;
            }
        }
        for &s in &self.meta.prefill_seqs.clone() {
            self.artifact(&ArtifactKey::prefill(&self.cfg.variant, 1, s))?;
        }
        Ok(())
    }

    pub fn submit(&mut self, req: Request) -> bool {
        let prompt_tokens = req.prompt.len() as u64;
        match self.queue.push(req) {
            Ok(()) => {
                self.metrics.prompt_tokens += prompt_tokens;
                true
            }
            Err(_rejected) => false,
        }
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
            + self.active.len()
            + self.preempted.len()
            + usize::from(self.chunked.is_some())
    }

    pub fn take_finished(&mut self) -> Vec<RequestOutput> {
        std::mem::take(&mut self.finished)
    }

    /// One engine iteration. Returns false when there is nothing to do.
    pub fn step(&mut self) -> Result<bool> {
        let mut worked = false;
        if self.chunked.is_some() {
            self.advance_chunked()?;
            worked = true;
        }
        // Preempted sequences resume ahead of every new arrival (strict
        // FIFO priority): as long as any is parked, admission stays
        // closed — except in the one step where a victim was just swapped
        // out to make room for the queue head it yielded to.
        let resumed = self.chunked.is_none() && self.resume_one_preempted()?;
        worked |= resumed;
        let made_room = !resumed
            && self.chunked.is_none()
            && self.preempted.is_empty()
            && self.preempt_for_queue_head();
        worked |= made_room;
        let allow_admit = self.chunked.is_none() && (self.preempted.is_empty() || made_room);
        let plan = self.scheduler.plan_with_prefix(
            &self.queue,
            &mut self.kv,
            self.prefix.as_ref(),
            self.cfg.prefill_chunk,
            allow_admit,
        );
        if !worked && plan.is_idle() && self.queue.is_empty() {
            return Ok(false);
        }

        if let Some(pp) = plan.prefill.clone() {
            // lint:allow(no-unwrap-in-lib): the scheduler only plans a prefill for a queued request
            let req = self.queue.pop().expect("planned prefill without request");
            if pp.cached_tokens > 0 {
                self.begin_chunked_prefill(req, &pp)?;
            } else {
                self.run_prefill(req, pp.slot)?;
            }
            worked = true;
        } else if !worked && plan.decode_slots.is_empty() {
            // Nothing active and nothing admissible (e.g. oversized prompt).
            if let Some(req) = self.queue.pop() {
                self.finish_unservable(req);
                return Ok(true);
            }
            return Ok(false);
        }

        // Beam branches decode as one co-scheduled cohort (never split
        // across groups); everything else is a singleton cohort, so with
        // no beams in flight this reduces to the legacy grouping exactly.
        let groups = {
            let mut slots: Vec<usize> = self.active.keys().copied().collect();
            slots.sort_unstable();
            let mut cohorts: Vec<Vec<usize>> = Vec::new();
            let mut beam_cohorts: HashMap<RequestId, Vec<usize>> = HashMap::new();
            let mut beam_order: Vec<RequestId> = Vec::new();
            for s in slots {
                match self.active[&s].beam_group {
                    Some(g) => {
                        let c = beam_cohorts.entry(g).or_default();
                        if c.is_empty() {
                            beam_order.push(g);
                        }
                        c.push(s);
                    }
                    None => cohorts.push(vec![s]),
                }
            }
            for g in beam_order {
                // lint:allow(no-unwrap-in-lib): every ordered id was inserted just above
                cohorts.push(beam_cohorts.remove(&g).expect("ordered beam cohort"));
            }
            self.scheduler.decode_groups_cohorts(&cohorts)
        };
        for group in groups {
            // Speculative fast path: a lone non-beam row with a draft
            // attached and room for the whole γ+1 verify chain.
            if group.len() == 1
                && self.draft.is_some()
                && self.active[&group[0]].beam_group.is_none()
                && self.kv.remaining(group[0]).unwrap_or(0) > self.cfg.spec_gamma
            {
                self.run_speculative_round(group[0])?;
            } else {
                self.run_decode_group(&group)?;
            }
        }
        self.sync_observability();
        Ok(true)
    }

    /// Fold pool-level telemetry into the metrics snapshot: copy-on-write
    /// clones since the last sync become one `CowCopy` trace event, and the
    /// ring buffer's drop count is mirrored so `json_row`/`report` can warn.
    fn sync_observability(&mut self) {
        let cow = self.kv.pool().cow_clones();
        let delta = cow - self.metrics.cow_block_copies;
        if delta > 0 {
            if let Some(tr) = self.trace.as_mut() {
                tr.record(None, TraceEventKind::CowCopy { blocks: delta });
            }
        }
        self.metrics.cow_block_copies = cow;
        if let Some(tr) = &self.trace {
            self.metrics.trace_events_dropped = tr.dropped();
        }
    }

    /// Record the physical pool's occupancy into the windowed gauge (and
    /// its peak), returning the sampled value for trace events.
    fn note_occupancy(&mut self) -> f64 {
        let pool = self.kv.pool();
        let occ = pool.used_blocks() as f64 / pool.total_blocks().max(1) as f64;
        self.metrics.pool_occupancy.record(occ);
        if occ > self.metrics.pool_occupancy_peak {
            self.metrics.pool_occupancy_peak = occ;
        }
        occ
    }

    /// Drive until every submitted request completes.
    pub fn run_to_completion(&mut self) -> Result<Vec<RequestOutput>> {
        while self.pending() > 0 {
            self.step()?;
        }
        Ok(self.take_finished())
    }

    fn artifact(&self, key: &ArtifactKey) -> Result<std::sync::Arc<Artifact>> {
        self.registry.get(key)
    }

    /// Complete a request that can never run here with an empty output.
    fn finish_unservable(&mut self, req: Request) {
        if let Some(tr) = self.trace.as_mut() {
            tr.record(
                Some(req.id),
                TraceEventKind::Reject {
                    reason: "unservable".to_string(),
                },
            );
        }
        self.finished.push(RequestOutput {
            id: req.id,
            prompt_len: req.prompt.len(),
            tokens: Vec::new(),
            ttft_s: 0.0,
            tpot_s: 0.0,
            total_s: 0.0,
        });
        // Counted so completion totals agree with emitted outputs.
        self.metrics.requests_completed += 1;
    }

    fn run_prefill(&mut self, req: Request, slot: usize) -> Result<()> {
        let bucket = self
            .scheduler
            .prefill_bucket(req.prompt.len())
            .ok_or_else(|| anyhow!("prompt of {} exceeds buckets", req.prompt.len()))?;
        let key = ArtifactKey::prefill(&self.cfg.variant, 1, bucket);
        let art = self.artifact(&key)?;
        let t0 = Clock::wall();

        let mut tokens = req.prompt.clone();
        tokens.resize(bucket, 0);
        let mut literals = self.param_literals.clone();
        literals.push(TensorIn::i32(&[1, bucket], tokens).to_literal()?);
        let outs = art.run_literals(&literals)?;
        // outputs: logits (1, S, V), k (L,1,T,Hkv,D), v (...)
        let logits = &outs[0];
        let v = self.meta.vocab;
        let last = req.prompt.len() - 1;
        let row = &logits.data[last * v..(last + 1) * v];

        self.kv
            .write_slot(slot, &outs[1].data, &outs[2].data, req.prompt.len());
        // Share the freshly computed prompt KV: the cache *adopts* the
        // slot's physical blocks (one refcount each, zero bytes copied), so
        // future requests with this prefix skip its prefill FLOPs and map
        // the very same HBM. The request then pins the cached span for its
        // lifetime so LRU stays honest.
        let mut cache_tokens = 0;
        if self.prefix.is_some() {
            self.metrics.prefix_misses += 1;
            let blocks = self.kv.slot_blocks(slot);
            // lint:allow(no-unwrap-in-lib): guarded by the is_some() branch above
            let p = self.prefix.as_mut().expect("checked above");
            let rep = p.insert_shared(&req.prompt, &blocks, self.kv.pool_mut());
            self.metrics.prefix_evicted_blocks += rep.evicted_blocks as u64;
            cache_tokens = p.acquire(&req.prompt);
            if rep.evicted_blocks > 0 {
                if let Some(tr) = self.trace.as_mut() {
                    tr.record(
                        None,
                        TraceEventKind::Evict {
                            blocks: rep.evicted_blocks as u64,
                        },
                    );
                }
            }
        }
        self.metrics.prefill_steps += 1;
        let prefill_s = t0.now_s();
        self.metrics.prefill_time.record(prefill_s);
        ema_update(
            &mut self.reprefill_s_per_token,
            prefill_s / req.prompt.len().max(1) as f64,
        );
        self.note_occupancy();
        if let Some(tr) = self.trace.as_mut() {
            let end_s = tr.now_s();
            let start_s = (end_s - prefill_s).max(0.0);
            // Arrival→prefill-start interval: both clocks read "seconds
            // ago", so the difference of their readings is the gap.
            let queued_s = (req.arrival.now_s() - t0.now_s()).max(0.0);
            tr.record_at(start_s, Some(req.id), TraceEventKind::Admit { queued_s });
            tr.record_span(
                Some(req.id),
                start_s,
                prefill_s,
                TraceEventKind::PrefillChunk {
                    tokens: req.prompt.len(),
                    // The real engine runs on the PJRT-CPU stub: there is no
                    // analytic device model to divide by, so MFU stays 0 and
                    // the summaries populate only on simulated replicas.
                    mfu: 0.0,
                },
            );
        }

        self.activate_request(req, slot, cache_tokens, row);
        Ok(())
    }

    /// Activate an admitted request off its first-token logits. Width-1
    /// requests take the argmax, exactly the legacy path; width-k beam
    /// requests fork `k-1` branches off the shared prompt KV
    /// ([`KvStore::fork_slot`] — refcounts, zero bytes copied), seed each
    /// branch with one of the top-k first tokens and its log-prob, and
    /// register the group for best-branch retirement. Fork failures
    /// (typed: no slot / no blocks) degrade the width to whatever fit —
    /// a beam never blocks admission.
    fn activate_request(&mut self, req: Request, slot: usize, cache_tokens: usize, row: &[f32]) {
        let now = Clock::wall();
        self.metrics.ttft.record(req.arrival.now_s());
        let width = req
            .beam_width
            .unwrap_or(self.cfg.beam_width)
            .max(1)
            .min(self.meta.decode_batches.last().copied().unwrap_or(1).max(1));
        let (toks, scores) = top_k_log_softmax(row, width);
        let mut branch_slots = vec![slot];
        for _ in 1..toks.len() {
            match self.kv.fork_slot(slot) {
                Ok(fork) => {
                    branch_slots.push(fork);
                    self.metrics.beam_forks += 1;
                }
                // Degrade: serve the branches that fit.
                Err(_) => break,
            }
        }
        let nb = branch_slots.len();
        if nb > 1 {
            self.beams.insert(
                req.id,
                BeamPending {
                    width: nb,
                    done: 0,
                    best_score: f64::NEG_INFINITY,
                    best: None,
                },
            );
        }
        for (i, &bslot) in branch_slots.iter().enumerate() {
            self.active.insert(
                bslot,
                ActiveRequest {
                    id: req.id,
                    prompt: req.prompt.clone(),
                    // Only the root branch pins the cached prefix; forks
                    // hold the shared blocks through their own refcounts.
                    cache_tokens: if i == 0 { cache_tokens } else { 0 },
                    max_new_tokens: req.max_new_tokens,
                    stop_token: req.stop_token,
                    arrival: req.arrival.clone(),
                    first_token_at: Some(now.clone()),
                    last_scheduled: Clock::wall(),
                    generated: vec![toks[i]],
                    last_token: toks[i],
                    beam_group: (nb > 1).then_some(req.id),
                    beam_score: scores[i],
                },
            );
        }
        self.metrics.generated_tokens += nb as u64;
        // Immediately-finished request (max_new_tokens == 1, stop token, or
        // a prompt that already fills the cache).
        for &bslot in &branch_slots {
            let kv_full = self.kv.is_full(bslot);
            self.maybe_finish(bslot, kv_full);
        }
    }

    /// Start a warm prefill: map the cached prefix's physical blocks into
    /// the slot's block table (shared, not copied — this is what makes
    /// "N requests share a P-token prefix at P·bytes" true in HBM); the
    /// uncached tail is recomputed chunk-by-chunk across steps.
    fn begin_chunked_prefill(&mut self, req: Request, pp: &PrefillPlan) -> Result<()> {
        let prompt_len = req.prompt.len();
        let (cached, blocks) = {
            // lint:allow(no-unwrap-in-lib): a warm plan is only produced when a prefix cache is attached
            let prefix = self.prefix.as_mut().expect("warm plan without a cache");
            let cached = prefix.acquire(&req.prompt).min(prompt_len);
            let blocks = if cached > 0 {
                prefix.mapped_blocks(&req.prompt, cached)
            } else {
                None
            };
            if blocks.is_none() && cached > 0 {
                prefix.release(&req.prompt, cached);
            }
            (cached, blocks)
        };
        let Some(blocks) = blocks else {
            // Physical blocks missing (accounting-only insert): fall back
            // cold (run_prefill counts the miss).
            if self.scheduler.prefill_bucket(prompt_len).is_some() {
                return self.run_prefill(req, pp.slot);
            }
            self.kv.free_slot(pp.slot);
            self.finish_unservable(req);
            return Ok(());
        };
        self.metrics.prefix_hits += 1;
        self.metrics.prefix_hit_tokens += cached as u64;
        if let Some(tr) = self.trace.as_mut() {
            let queued_s = req.arrival.now_s();
            tr.record(Some(req.id), TraceEventKind::Admit { queued_s });
            tr.record(Some(req.id), TraceEventKind::PrefixHit { tokens: cached });
        }
        // Execute the plan's chunk list (re-derived only if the cache
        // changed between planning and admission, which a single-threaded
        // step cannot actually produce).
        let mut chunks: std::collections::VecDeque<(usize, usize)> =
            if pp.cached_tokens == cached {
                pp.chunks.iter().copied().collect()
            } else {
                chunk_spans(prompt_len, cached, self.cfg.prefill_chunk)
                    .into_iter()
                    .collect()
            };
        // A full hit still recomputes the last prompt position so its
        // logits (the first-token sample) come out of the decode artifact.
        // That write lands *inside* the last shared block — the store
        // copy-on-writes it, so the cached original stays intact for
        // everyone else.
        if chunks.is_empty() {
            chunks.push_back((prompt_len - 1, 1));
        }
        // lint:allow(no-unwrap-in-lib): the branch above just guaranteed at least one chunk
        let start = chunks.front().expect("chunk list non-empty").0;
        self.kv.map_shared_prefix(pp.slot, &blocks, start);
        self.chunked = Some(ChunkedPrefill {
            req,
            slot: pp.slot,
            cache_tokens: cached,
            chunks,
            last_logits: Vec::new(),
        });
        Ok(())
    }

    /// Advance the in-flight chunked prefill by one chunk; on the last
    /// chunk, sample the first token and activate the request.
    fn advance_chunked(&mut self) -> Result<()> {
        let Some(mut cp) = self.chunked.take() else {
            return Ok(());
        };
        let t0 = Clock::wall();
        let mut chunk_tokens = 0usize;
        if let Some((start, len)) = cp.chunks.pop_front() {
            for pos in start..start + len {
                cp.last_logits = self.forced_decode(cp.slot, cp.req.prompt[pos])?;
            }
            chunk_tokens = len;
        }
        self.metrics.prefill_chunks += 1;
        let chunk_s = t0.now_s();
        self.metrics.prefill_time.record(chunk_s);
        if chunk_tokens > 0 {
            ema_update(
                &mut self.reprefill_s_per_token,
                chunk_s / chunk_tokens as f64,
            );
        }
        if chunk_tokens > 0 {
            if let Some(tr) = self.trace.as_mut() {
                let end_s = tr.now_s();
                tr.record_span(
                    Some(cp.req.id),
                    (end_s - chunk_s).max(0.0),
                    chunk_s,
                    TraceEventKind::PrefillChunk {
                        tokens: chunk_tokens,
                        mfu: 0.0,
                    },
                );
            }
        }
        if !cp.chunks.is_empty() {
            self.chunked = Some(cp);
            return Ok(());
        }
        // Tail complete: the last forced decode's logits are the
        // first-token distribution.
        self.metrics.prefill_steps += 1;
        let row = std::mem::take(&mut cp.last_logits);
        self.activate_request(cp.req, cp.slot, cp.cache_tokens, &row);
        Ok(())
    }

    /// One paged decode-artifact call for `rows` of (slot, input token).
    ///
    /// The KV side is block-table-native: per-row block tables + lengths
    /// reference the pool in place — no dense `(L, B, cache_t, …)`
    /// staging, no zero-fill, no bucket padding of the context. The
    /// artifact returns logits plus only the appended token's KV, which
    /// [`KvStore::append_token`] quantizes into each row's hot block
    /// (copy-on-write preserved). Returns (logits rows, full slots, KV
    /// bytes the step's table walk covers — each row charged its own live
    /// blocks at the pool dtype rate, the same convention as
    /// [`crate::gaudisim::kv_read_bytes_paged`]).
    fn paged_decode_forward(
        &mut self,
        rows: &[(usize, i32)],
    ) -> Result<(Vec<f32>, Vec<usize>, u64)> {
        let Some(pool_blocks) = self.meta.paged_pool_blocks else {
            bail!(
                "artifacts at {:?} predate the paged decode ABI — regenerate them \
                 with `make artifacts` (or build with `--features dense-decode-ref` \
                 and drive the dense reference path explicitly)",
                self.cfg.artifacts_dir
            );
        };
        let bucket = self.scheduler.decode_bucket(rows.len());
        let key = ArtifactKey::decode_paged(&self.cfg.variant, bucket);
        let art = self.artifact(&key)?;
        let bt = self.kv.block_tokens();
        let mb = self.meta.cache_t.div_ceil(bt);
        let mut tokens = vec![0i32; bucket];
        let mut tables = vec![0i32; bucket * mb];
        let mut lens = vec![0i32; bucket];
        let mut group_blocks = Vec::new();
        for (bi, &(slot, tok)) in rows.iter().enumerate() {
            tokens[bi] = tok;
            lens[bi] = self.kv.len(slot).unwrap_or(0) as i32;
            for (j, id) in self.kv.slot_blocks(slot).iter().take(mb).enumerate() {
                tables[bi * mb + j] = *id as i32;
                group_blocks.push(*id);
            }
        }
        let kv_bytes = (group_blocks.len() * self.kv.layout().block_bytes(bt)) as u64;
        // On device the pool stays resident and is donated between steps;
        // the PJRT-CPU stub runner maintains a persistent export pair and
        // updates it incrementally: zero last step's block regions, write
        // only this group's blocks (shared prefix blocks once, everything
        // else zero — the artifact's table gathers never read it).
        let per_block = self.meta.layers * bt * self.meta.kv_heads * self.meta.head_dim();
        let mut pk = std::mem::take(&mut self.pool_export_k);
        let mut pv = std::mem::take(&mut self.pool_export_v);
        let n = pool_blocks * per_block;
        if pk.len() != n {
            pk = vec![0.0; n];
            pv = vec![0.0; n];
            self.pool_exported.clear();
        }
        for &id in &self.pool_exported {
            let at = id * per_block;
            pk[at..at + per_block].fill(0.0);
            pv[at..at + per_block].fill(0.0);
        }
        // Fan the export across the scoped pool workers (cfg knob /
        // REPRO_NUM_THREADS) — sorted block chunks write disjoint spans,
        // so the exported bytes are identical at any worker count.
        self.pool_exported = self.kv.pool().export_f32_blocks_into_par(
            &group_blocks,
            &mut pk,
            &mut pv,
            self.cfg.kv_parallelism.workers(),
        );
        let pool_dims = [
            pool_blocks,
            self.meta.layers,
            bt,
            self.meta.kv_heads,
            self.meta.head_dim(),
        ];
        let mut literals = self.param_literals.clone();
        literals.push(TensorIn::i32(&[bucket], tokens).to_literal()?);
        // Pool literals straight from the persistent buffers: exactly one
        // host copy into each literal, no intermediate clone.
        let pool_dims_i64: Vec<i64> = pool_dims.iter().map(|x| *x as i64).collect();
        literals.push(xla::Literal::vec1(&pk).reshape(&pool_dims_i64)?);
        literals.push(xla::Literal::vec1(&pv).reshape(&pool_dims_i64)?);
        self.pool_export_k = pk;
        self.pool_export_v = pv;
        literals.push(TensorIn::i32(&[bucket, mb], tables).to_literal()?);
        literals.push(TensorIn::i32(&[bucket], lens).to_literal()?);
        let mut outs = art.run_literals(&literals)?;

        // outputs: logits (B, V), new_k (L, B, 1, Hkv, D), new_v.
        let l = self.meta.layers;
        let row = self.meta.kv_heads * self.meta.head_dim();
        let mut full = Vec::new();
        let (mut kr, mut vr) = (vec![0.0f32; l * row], vec![0.0f32; l * row]);
        for (bi, &(slot, _)) in rows.iter().enumerate() {
            for li in 0..l {
                let src = (li * bucket + bi) * row;
                kr[li * row..(li + 1) * row].copy_from_slice(&outs[1].data[src..src + row]);
                vr[li * row..(li + 1) * row].copy_from_slice(&outs[2].data[src..src + row]);
            }
            match self.kv.append_token(slot, &kr, &vr) {
                AppendOutcome::Appended => {}
                // Both must finish below: another decode step would have no
                // position to write.
                AppendOutcome::Full | AppendOutcome::AtCapacity => full.push(slot),
            }
        }
        Ok((std::mem::take(&mut outs[0].data), full, kv_bytes))
    }

    /// One decode call for `slot` with a forced input token — the
    /// chunked-prefill workhorse: the KV already mapped in the slot's
    /// block table is the attention context and the forced token's KV is
    /// appended at the slot's current length. Returns the logits row.
    fn forced_decode(&mut self, slot: usize, token: i32) -> Result<Vec<f32>> {
        #[cfg(feature = "dense-decode-ref")]
        if self.cfg.use_dense_decode {
            return self.forced_decode_dense(slot, token);
        }
        let (logits, _full, kv_bytes) = self.paged_decode_forward(&[(slot, token)])?;
        self.metrics.kv_bytes_read += kv_bytes;
        Ok(logits[..self.meta.vocab].to_vec())
    }

    /// The pre-paged dense `forced_decode` — reference implementation for
    /// the `use_dense_decode` switch, so warm (chunked-prefill) requests
    /// stay on the dense artifacts end to end during A/B comparisons.
    #[cfg(feature = "dense-decode-ref")]
    fn forced_decode_dense(&mut self, slot: usize, token: i32) -> Result<Vec<f32>> {
        let bucket = self.scheduler.decode_bucket(1);
        let key = ArtifactKey::decode(&self.cfg.variant, bucket);
        let art = self.artifact(&key)?;
        let ss = self.meta.cache_t * self.meta.kv_heads * self.meta.head_dim();
        let n = self.meta.layers * bucket * ss;
        let mut k = vec![0.0f32; n];
        let mut v = vec![0.0f32; n];
        let lens = self.kv.gather_batch_into(&[slot], bucket, &mut k, &mut v);
        let mut tokens = vec![0i32; bucket];
        tokens[0] = token;
        let kv_dims = [
            self.meta.layers,
            bucket,
            self.meta.cache_t,
            self.meta.kv_heads,
            self.meta.head_dim(),
        ];
        let mut literals = self.param_literals.clone();
        literals.push(TensorIn::i32(&[bucket], tokens).to_literal()?);
        literals.push(TensorIn::f32(&kv_dims, k).to_literal()?);
        literals.push(TensorIn::f32(&kv_dims, v).to_literal()?);
        literals.push(TensorIn::i32(&[bucket], lens).to_literal()?);
        let outs = art.run_literals(&literals)?;
        // Scatter row 0 back; the scatter appends at the slot's length.
        let l = self.meta.layers;
        let (mut kr, mut vr) = (vec![0.0f32; l * ss], vec![0.0f32; l * ss]);
        for li in 0..l {
            let src = li * bucket * ss;
            let dst = li * ss;
            kr[dst..dst + ss].copy_from_slice(&outs[1].data[src..src + ss]);
            vr[dst..dst + ss].copy_from_slice(&outs[2].data[src..src + ss]);
        }
        let _full = self.kv.scatter_batch(&[slot], &kr, &vr);
        Ok(outs[0].data[..self.meta.vocab].to_vec())
    }

    fn run_decode_group(&mut self, group: &[usize]) -> Result<()> {
        if group.is_empty() {
            return Ok(());
        }
        #[cfg(feature = "dense-decode-ref")]
        if self.cfg.use_dense_decode {
            return self.run_decode_group_dense(group);
        }
        let t0 = Clock::wall();
        let rows: Vec<(usize, i32)> = group
            .iter()
            .map(|s| (*s, self.active[s].last_token))
            .collect();
        // "Sequence full" slots must finish below: the store clamps their
        // length at cache_t, and another decode step would silently
        // overwrite the last position.
        let (logits, full_slots, kv_bytes) = self.paged_decode_forward(&rows)?;

        let vsz = self.meta.vocab;
        for (bi, &slot) in group.iter().enumerate() {
            let row = &logits[bi * vsz..(bi + 1) * vsz];
            let tok = argmax(row);
            // lint:allow(no-unwrap-in-lib): group is built from self.active's live slot keys
            let a = self.active.get_mut(&slot).unwrap();
            if a.beam_group.is_some() {
                a.beam_score += log_softmax_at(row, tok as usize);
            }
            a.generated.push(tok);
            a.last_token = tok;
            a.last_scheduled = Clock::wall();
            if let Some(ft) = &a.first_token_at {
                self.metrics
                    .tpot
                    .record(ft.now_s() / a.generated.len().max(1) as f64);
            }
        }
        self.metrics.generated_tokens += group.len() as u64;
        self.metrics.decode_steps += 1;
        self.metrics.decode_batch_sum += group.len() as u64;
        let step_s = t0.now_s();
        self.metrics.decode_time.record(step_s);
        self.metrics.kv_bytes_read += kv_bytes;
        let occ = self.note_occupancy();
        if let Some(tr) = self.trace.as_mut() {
            let end_s = tr.now_s();
            tr.record_span(
                None,
                (end_s - step_s).max(0.0),
                step_s,
                TraceEventKind::DecodeStep {
                    batch: group.len(),
                    mfu: 0.0,
                    kv_bytes,
                    pool_occupancy: occ,
                },
            );
        }

        for &slot in group {
            self.maybe_finish(slot, full_slots.contains(&slot));
        }
        Ok(())
    }

    /// One draft-verify speculative round for a lone decode row (the
    /// tentpole of ISSUE 10).
    ///
    /// The prompt-lookup draft proposes γ tokens; the target then runs
    /// the γ+1-token verify chain — `forced_decode` over `last_token`
    /// followed by every draft token, each call appending its input's KV
    /// (the chunked-prefill machinery verbatim, so the chain *is* the
    /// chunked multi-token step). Greedy accept-prefix rule: draft token
    /// `j` stands iff it equals the target's `j`-th argmax, and the round
    /// emits the accepted prefix plus the target's first divergent token.
    /// By induction every emitted token — and every accepted token's KV —
    /// is bit-identical to plain token-by-token greedy decode. Rejected
    /// tokens' KV is rolled back with [`KvStore::truncate_slot`]
    /// (CoW-safe block-truncation; accepted KV stands), and the FP8 store
    /// re-encodes scales over the valid span on the next append, so stale
    /// codes can never poison a scale.
    fn run_speculative_round(&mut self, slot: usize) -> Result<()> {
        let gamma = self.cfg.spec_gamma;
        let (id, last, context) = {
            let a = &self.active[&slot];
            let mut ctx = a.prompt.clone();
            ctx.extend_from_slice(&a.generated);
            (a.id, a.last_token, ctx)
        };
        // lint:allow(no-unwrap-in-lib): the step loop schedules speculation only with a draft attached
        let drafts = self
            .draft
            .as_ref()
            .expect("speculative round without a draft")
            .propose(&context, gamma);
        let t0 = Clock::wall();
        if let Some(tr) = self.trace.as_mut() {
            tr.record(Some(id), TraceEventKind::DraftPropose { gamma });
        }
        let base_len = self.kv.len(slot).unwrap_or(0);
        // Optimistic verify chain: as long as drafts[..j] were accepted,
        // targets[j] is the model's true greedy choice at position j.
        let mut targets = Vec::with_capacity(gamma + 1);
        targets.push(argmax(&self.forced_decode(slot, last)?));
        for &d in &drafts {
            targets.push(argmax(&self.forced_decode(slot, d)?));
        }
        let accepted = drafts
            .iter()
            .zip(&targets)
            .take_while(|(d, t)| d == t)
            .count();
        let rejected = gamma - accepted;
        // The chain appended 1 + γ tokens; only 1 + `accepted` are real
        // (the divergent token's own KV is appended next round, exactly
        // the plain-decode pending-last-token invariant).
        let blocks_before = self.kv.slot_blocks(slot).len();
        if rejected > 0 {
            self.kv.truncate_slot(slot, base_len + 1 + accepted);
        }
        let blocks_freed = (blocks_before - self.kv.slot_blocks(slot).len()) as u64;
        let mut pushed = 0usize;
        {
            // lint:allow(no-unwrap-in-lib): slot is a live key of self.active
            let a = self.active.get_mut(&slot).unwrap();
            for &tok in &targets[..=accepted] {
                a.generated.push(tok);
                a.last_token = tok;
                pushed += 1;
                if let Some(ft) = &a.first_token_at {
                    self.metrics
                        .tpot
                        .record(ft.now_s() / a.generated.len().max(1) as f64);
                }
                let hit_stop = a.stop_token.is_some_and(|s| tok == s);
                if hit_stop || a.generated.len() >= a.max_new_tokens {
                    break;
                }
            }
            a.last_scheduled = Clock::wall();
        }
        self.metrics.generated_tokens += pushed as u64;
        self.metrics.spec_rounds += 1;
        self.metrics.spec_accepted_tokens += accepted as u64;
        self.metrics.spec_rejected_tokens += rejected as u64;
        if rejected > 0 {
            self.metrics.spec_rollbacks += 1;
        }
        // Each chain link is one batch-1 artifact call.
        self.metrics.decode_steps += (gamma + 1) as u64;
        self.metrics.decode_batch_sum += (gamma + 1) as u64;
        let round_s = t0.now_s();
        self.metrics.decode_time.record(round_s);
        self.note_occupancy();
        if let Some(tr) = self.trace.as_mut() {
            let end_s = tr.now_s();
            tr.record_span(
                Some(id),
                (end_s - round_s).max(0.0),
                round_s,
                TraceEventKind::VerifyAccept {
                    accepted,
                    emitted: pushed,
                },
            );
            if rejected > 0 {
                tr.record(
                    Some(id),
                    TraceEventKind::Rollback {
                        tokens: rejected,
                        blocks: blocks_freed,
                    },
                );
            }
        }
        let kv_full = self.kv.is_full(slot);
        self.maybe_finish(slot, kv_full);
        Ok(())
    }

    /// The pre-paged dense decode step — **reference implementation only**,
    /// kept for paged-vs-dense roundtrip tests against real artifacts:
    /// gathers the group into a dense `(L, B, cache_t, …)` pair, runs the
    /// legacy dense decode artifact, and scatters the whole buffer back.
    /// Allocates its staging locally (the persistent scratch this used to
    /// justify is gone from the hot path).
    #[cfg(feature = "dense-decode-ref")]
    pub fn run_decode_group_dense(&mut self, group: &[usize]) -> Result<()> {
        if group.is_empty() {
            return Ok(());
        }
        let bucket = self.scheduler.decode_bucket(group.len());
        let key = ArtifactKey::decode(&self.cfg.variant, bucket);
        let art = self.artifact(&key)?;
        let t0 = Clock::wall();

        let ss = self.meta.cache_t * self.meta.kv_heads * self.meta.head_dim();
        let need = self.meta.layers * bucket * ss;
        let mut k = vec![0.0f32; need];
        let mut v = vec![0.0f32; need];
        let lens = self.kv.gather_batch_into(group, bucket, &mut k, &mut v);
        let tokens: Vec<i32> = {
            let mut t: Vec<i32> = group.iter().map(|s| self.active[s].last_token).collect();
            t.resize(bucket, 0);
            t
        };

        let kv_dims = [
            self.meta.layers,
            bucket,
            self.meta.cache_t,
            self.meta.kv_heads,
            self.meta.head_dim(),
        ];
        let mut literals = self.param_literals.clone();
        literals.push(TensorIn::i32(&[bucket], tokens).to_literal()?);
        literals.push(TensorIn::f32(&kv_dims, k).to_literal()?);
        literals.push(TensorIn::f32(&kv_dims, v).to_literal()?);
        literals.push(TensorIn::i32(&[bucket], lens).to_literal()?);
        let outs = art.run_literals(&literals)?;

        // outputs: logits (B, V), k, v — scatter back only the real rows.
        let vsz = self.meta.vocab;
        let (l, b) = (self.meta.layers, group.len());
        let (mut kr, mut vr) = (vec![0.0f32; l * b * ss], vec![0.0f32; l * b * ss]);
        for li in 0..l {
            for bi in 0..b {
                let src = (li * bucket + bi) * ss;
                let dst = (li * b + bi) * ss;
                kr[dst..dst + ss].copy_from_slice(&outs[1].data[src..src + ss]);
                vr[dst..dst + ss].copy_from_slice(&outs[2].data[src..src + ss]);
            }
        }
        let full_slots = self.kv.scatter_batch(group, &kr, &vr);

        for (bi, &slot) in group.iter().enumerate() {
            let row = &outs[0].data[bi * vsz..(bi + 1) * vsz];
            let tok = argmax(row);
            // lint:allow(no-unwrap-in-lib): group is built from self.active's live slot keys
            let a = self.active.get_mut(&slot).unwrap();
            if a.beam_group.is_some() {
                a.beam_score += log_softmax_at(row, tok as usize);
            }
            a.generated.push(tok);
            a.last_token = tok;
            a.last_scheduled = Clock::wall();
            if let Some(ft) = &a.first_token_at {
                self.metrics
                    .tpot
                    .record(ft.now_s() / a.generated.len().max(1) as f64);
            }
        }
        self.metrics.generated_tokens += group.len() as u64;
        self.metrics.decode_steps += 1;
        self.metrics.decode_batch_sum += group.len() as u64;
        let step_s = t0.now_s();
        self.metrics.decode_time.record(step_s);
        // Dense staging reads the whole bucket-padded window regardless of
        // live context — the cost shape the paged path exists to beat.
        let kv_bytes =
            (bucket * self.meta.cache_t * self.kv.layout().bytes_per_token()) as u64;
        self.metrics.kv_bytes_read += kv_bytes;
        let occ = self.note_occupancy();
        if let Some(tr) = self.trace.as_mut() {
            let end_s = tr.now_s();
            tr.record_span(
                None,
                (end_s - step_s).max(0.0),
                step_s,
                TraceEventKind::DecodeStep {
                    batch: group.len(),
                    mfu: 0.0,
                    kv_bytes,
                    pool_occupancy: occ,
                },
            );
        }

        for &slot in group {
            self.maybe_finish(slot, full_slots.contains(&slot));
        }
        Ok(())
    }

    /// Evict the least-recently-scheduled active sequence so the queue
    /// head can take its slot this step. Fires only when preemption is
    /// enabled (a host tier, or pure `Recompute` which needs none),
    /// every slot is occupied, and the queue head could actually run
    /// here. Beam branches are never victims: a group is co-resident by
    /// contract and would otherwise be torn apart one branch at a time.
    /// The victim goes out through [`Self::choose_preempt_kind`]'s arm.
    /// Returns true when a slot was freed.
    fn preempt_for_queue_head(&mut self) -> bool {
        let enabled =
            self.host.is_some() || self.cfg.preempt_policy == PreemptPolicy::Recompute;
        if !enabled || self.queue.is_empty() || self.kv.has_free_slot() {
            return false;
        }
        let head_fits = self.queue.peek().is_some_and(|r| {
            (self.scheduler.prefill_bucket(r.prompt.len()).is_some()
                || warm_admittable_without_bucket(self.prefix.as_ref(), &r.prompt))
                && r.prompt.len() + r.max_new_tokens <= self.meta.cache_t
        });
        if !head_fits {
            return false;
        }
        let slots: Vec<usize> = {
            let mut s: Vec<usize> = self
                .active
                .iter()
                .filter(|(_, a)| a.beam_group.is_none())
                .map(|(s, _)| *s)
                .collect();
            s.sort_unstable();
            s
        };
        let cands: Vec<PreemptCandidate> = slots
            .iter()
            .enumerate()
            .map(|(i, s)| PreemptCandidate {
                idx: i,
                idle_s: self.active[s].last_scheduled.now_s(),
                generated: self.active[s].generated.len(),
            })
            .collect();
        let Some(pick) = select_preemption_victim(&cands) else {
            return false;
        };
        let slot = slots[pick];
        let table_blocks = self.kv.slot_blocks(slot).len();
        let Some(kind) = self.choose_preempt_kind(slot, table_blocks) else {
            return false;
        };
        // lint:allow(no-unwrap-in-lib): slot is a live key of self.active
        let a = self.active.remove(&slot).expect("victim slot is active");
        match kind {
            ResumeKind::Swap => {
                let sw0 = Clock::wall();
                let record = self.kv.swap_out_slot(slot);
                let moved = record.moved_blocks();
                let bytes = record.swapped_bytes(&self.kv.layout(), self.kv.block_tokens());
                // lint:allow(no-unwrap-in-lib): choose_preempt_kind only picks Swap with a tier
                let host = self.host.as_mut().expect("swap arm requires a tier");
                let stored = host.store(a.id, moved, record);
                debug_assert!(stored, "can_store admitted a superset of moved blocks");
                if moved > 0 {
                    ema_update(&mut self.swap_s_per_block, sw0.now_s() / moved as f64);
                }
                self.metrics.preemptions += 1;
                self.metrics.swapped_out_blocks += moved as u64;
                self.metrics.host_swap_bytes += bytes as u64;
                if let Some(tr) = self.trace.as_mut() {
                    tr.record(
                        Some(a.id),
                        TraceEventKind::Preempt {
                            blocks: moved as u64,
                            swap: true,
                        },
                    );
                    let now = tr.now_s();
                    tr.record_span(
                        Some(a.id),
                        now,
                        0.0,
                        TraceEventKind::SwapOut {
                            blocks: moved as u64,
                            bytes: bytes as u64,
                        },
                    );
                }
                self.preempted.push_back(PreemptedSeq {
                    a,
                    kind: ResumeKind::Swap,
                });
            }
            ResumeKind::Recompute => {
                // Drop the victim's blocks outright — shared prefix
                // blocks just lose one refcount; resume replays the
                // context instead of moving bytes.
                self.kv.free_slot(slot);
                self.metrics.preemptions += 1;
                if let Some(tr) = self.trace.as_mut() {
                    tr.record(
                        Some(a.id),
                        TraceEventKind::Preempt {
                            blocks: table_blocks as u64,
                            swap: false,
                        },
                    );
                }
                self.preempted.push_back(PreemptedSeq {
                    a,
                    kind: ResumeKind::Recompute,
                });
            }
        }
        true
    }

    /// Pick the eviction arm for one victim (PR 9 residual). `Swap` and
    /// `Recompute` are fixed arms (`Swap` additionally requires the tier
    /// to fit the victim's worst case — swap_out is not reversible, so
    /// the budget check happens here, before the slot is touched).
    /// `Auto` prices the arms with the engine's *measured* EMAs: a swap
    /// costs the table's blocks over the host link twice (out + in), a
    /// recompute costs the live context through re-prefill. Until both
    /// EMAs are seeded, `Auto` falls back to the bit-identical swap arm.
    fn choose_preempt_kind(&self, slot: usize, table_blocks: usize) -> Option<ResumeKind> {
        let host_fits = self
            .host
            .as_ref()
            .is_some_and(|h| h.can_store(table_blocks));
        match self.cfg.preempt_policy {
            PreemptPolicy::Swap => host_fits.then_some(ResumeKind::Swap),
            PreemptPolicy::Recompute => Some(ResumeKind::Recompute),
            PreemptPolicy::Auto => {
                if !host_fits {
                    return Some(ResumeKind::Recompute);
                }
                match (self.swap_s_per_block, self.reprefill_s_per_token) {
                    (Some(per_block), Some(per_token)) => {
                        let swap_s = 2.0 * table_blocks as f64 * per_block;
                        let rec_s = self.kv.len(slot).unwrap_or(0) as f64 * per_token;
                        Some(if rec_s < swap_s {
                            ResumeKind::Recompute
                        } else {
                            ResumeKind::Swap
                        })
                    }
                    _ => Some(ResumeKind::Swap),
                }
            }
        }
    }

    /// Resume the oldest preempted sequence when a slot is free. Swap
    /// victims restore bit-identically (moved blocks: codes + scales;
    /// resident shared blocks splice back refcount-balanced); recompute
    /// victims replay their context through the forced-decode chain.
    /// Returns true when a sequence rejoined the active set.
    fn resume_one_preempted(&mut self) -> Result<bool> {
        let Some((front_id, kind)) = self.preempted.front().map(|p| (p.a.id, p.kind)) else {
            return Ok(false);
        };
        if !self.kv.has_free_slot() {
            return Ok(false);
        }
        if kind == ResumeKind::Recompute {
            return self.resume_by_recompute();
        }
        let Some(host) = self.host.as_mut() else {
            return Ok(false);
        };
        let Some((blocks, record)) = host.take(front_id) else {
            debug_assert!(false, "preempted sequence missing from the host tier");
            return Ok(false);
        };
        let bytes = record.swapped_bytes(&self.kv.layout(), self.kv.block_tokens());
        let moved = record.moved_blocks();
        let sw0 = Clock::wall();
        match self.kv.swap_in_slot(record) {
            Ok(slot) => {
                if moved > 0 {
                    ema_update(&mut self.swap_s_per_block, sw0.now_s() / moved as f64);
                }
                // lint:allow(no-unwrap-in-lib): front() produced front_id just above
                let mut p = self.preempted.pop_front().expect("front exists");
                p.a.last_scheduled = Clock::wall();
                self.metrics.swapped_in_blocks += moved as u64;
                self.metrics.host_swap_bytes += bytes as u64;
                if let Some(tr) = self.trace.as_mut() {
                    let now = tr.now_s();
                    tr.record_span(
                        Some(p.a.id),
                        now,
                        0.0,
                        TraceEventKind::SwapIn {
                            blocks: moved as u64,
                            bytes: bytes as u64,
                        },
                    );
                }
                self.active.insert(slot, p.a);
                Ok(true)
            }
            Err(record) => {
                // Pool can't hold the moved blocks right now: put the
                // payload back and retry on a later step.
                let restored = host.store(front_id, blocks, record);
                debug_assert!(restored, "re-storing a just-taken record must fit");
                Ok(false)
            }
        }
    }

    /// Re-admit the queue-front recompute victim: replay its prompt plus
    /// every generated token but the last (whose KV is always pending —
    /// the plain-decode invariant) through the forced-decode chain into a
    /// fresh slot. The replayed KV is computed by the same artifacts over
    /// the same tokens, so the sequence continues bit-identically; the
    /// measured chain time feeds the re-prefill EMA that `Auto` prices
    /// future victims with.
    fn resume_by_recompute(&mut self) -> Result<bool> {
        let Some(slot) = self.kv.alloc_slot() else {
            return Ok(false);
        };
        // lint:allow(no-unwrap-in-lib): the caller checked front() exists
        let mut p = self.preempted.pop_front().expect("front exists");
        let t0 = Clock::wall();
        let n_ctx = p.a.prompt.len() + p.a.generated.len() - 1;
        let mut chain: Vec<i32> = Vec::with_capacity(n_ctx);
        chain.extend_from_slice(&p.a.prompt);
        chain.extend_from_slice(&p.a.generated[..p.a.generated.len() - 1]);
        for &tok in &chain {
            self.forced_decode(slot, tok)?;
        }
        let re_s = t0.now_s();
        self.metrics.prefill_time.record(re_s);
        if n_ctx > 0 {
            ema_update(&mut self.reprefill_s_per_token, re_s / n_ctx as f64);
        }
        self.metrics.recompute_resumes += 1;
        p.a.last_scheduled = Clock::wall();
        if let Some(tr) = self.trace.as_mut() {
            let end_s = tr.now_s();
            tr.record_span(
                Some(p.a.id),
                (end_s - re_s).max(0.0),
                re_s,
                TraceEventKind::PrefillChunk {
                    tokens: n_ctx,
                    mfu: 0.0,
                },
            );
        }
        self.active.insert(slot, p.a);
        Ok(true)
    }

    fn maybe_finish(&mut self, slot: usize, kv_full: bool) {
        let done = {
            let Some(a) = self.active.get(&slot) else {
                return;
            };
            let hit_stop = a
                .stop_token
                .is_some_and(|s| a.generated.last() == Some(&s));
            a.generated.len() >= a.max_new_tokens || hit_stop || kv_full
        };
        if done {
            // lint:allow(no-unwrap-in-lib): get() on the same key succeeded just above
            let a = self.active.remove(&slot).unwrap();
            self.kv.free_slot(slot);
            if a.cache_tokens > 0 {
                if let Some(p) = self.prefix.as_mut() {
                    p.release(&a.prompt, a.cache_tokens);
                }
            }
            let total = a.arrival.now_s();
            // Arrival→first-token gap: both clocks read "seconds ago".
            let ttft = a
                .first_token_at
                .as_ref()
                .map(|t| (a.arrival.now_s() - t.now_s()).max(0.0))
                .unwrap_or(total);
            let n = a.generated.len();
            let tpot_s = if n > 1 { (total - ttft) / (n - 1) as f64 } else { 0.0 };
            let out = RequestOutput {
                id: a.id,
                prompt_len: a.prompt.len(),
                tokens: a.generated,
                ttft_s: ttft,
                tpot_s,
                total_s: total,
            };
            if let Some(gid) = a.beam_group {
                // Fold the branch into its beam group: the branch with the
                // best cumulative log-prob is the request's output; the
                // group emits once, when its last branch retires — losers
                // are pruned forks (their blocks were just released).
                // lint:allow(no-unwrap-in-lib): beam_group is set only by the fork path that registers the group
                let pending = self.beams.get_mut(&gid).expect("beam branch without a group");
                pending.done += 1;
                if pending.best.is_none() || a.beam_score > pending.best_score {
                    pending.best_score = a.beam_score;
                    pending.best = Some(out);
                }
                if pending.done >= pending.width {
                    // lint:allow(no-unwrap-in-lib): the entry was read two lines above
                    let group = self.beams.remove(&gid).expect("entry exists");
                    self.metrics.beam_prunes += (group.width - 1) as u64;
                    // lint:allow(no-unwrap-in-lib): done > 0 means a branch was folded in
                    let best = group.best.expect("a finished branch was folded");
                    if let Some(tr) = self.trace.as_mut() {
                        tr.record(
                            Some(best.id),
                            TraceEventKind::Retire {
                                generated: best.tokens.len(),
                                ttft_s: best.ttft_s,
                                tpot_s: best.tpot_s,
                                total_s: best.total_s,
                            },
                        );
                    }
                    self.finished.push(best);
                    self.metrics.requests_completed += 1;
                }
                return;
            }
            if let Some(tr) = self.trace.as_mut() {
                tr.record(
                    Some(out.id),
                    TraceEventKind::Retire {
                        generated: n,
                        ttft_s: ttft,
                        tpot_s,
                        total_s: total,
                    },
                );
            }
            self.finished.push(out);
            self.metrics.requests_completed += 1;
        }
    }
}

/// The fleet router drives engines through [`ReplicaHandle`] — a narrow
/// interface extracted from the inherent methods above, so replicas can be
/// real PJRT engines or gaudisim-backed simulations interchangeably.
impl ReplicaHandle for Engine {
    fn label(&self) -> String {
        format!("engine[{}]", self.cfg.variant)
    }

    /// Wall-clock replica: elapsed seconds since construction.
    fn clock_s(&self) -> f64 {
        self.metrics.started.now_s()
    }

    fn advance_clock_to(&mut self, _t_s: f64) {
        // Wall clocks advance themselves.
    }

    fn queued(&self) -> usize {
        self.queue.len()
    }

    fn active(&self) -> usize {
        // Preempted sequences are resident work-in-progress (their KV
        // sits in the host tier): counting them keeps has_work() true so
        // the driver keeps stepping until they resume and finish.
        self.active.len() + self.preempted.len() + usize::from(self.chunked.is_some())
    }

    fn outstanding_tokens(&self) -> usize {
        let resident: usize = self
            .active
            .values()
            .chain(self.preempted.iter().map(|p| &p.a))
            .map(|a| a.prompt.len() + a.max_new_tokens.saturating_sub(a.generated.len()))
            .sum();
        let chunked: usize = self
            .chunked
            .as_ref()
            .map(|cp| cp.req.prompt.len() + cp.req.max_new_tokens)
            .unwrap_or(0);
        self.queue.queued_tokens() + resident + chunked
    }

    fn queue_capacity(&self) -> usize {
        self.cfg.queue_capacity
    }

    fn could_ever_admit(&self, prompt: &[i32], max_new_tokens: usize) -> Admission {
        let prompt_len = prompt.len();
        if self.scheduler.prefill_bucket(prompt_len).is_none()
            && !warm_admittable_without_bucket(self.prefix.as_ref(), prompt)
        {
            // No compiled bucket fits a cold start and no cached prefix
            // makes the warm chunked-tail path worthwhile.
            return Admission::PromptTooLong;
        }
        if prompt_len + max_new_tokens > self.meta.cache_t {
            return Admission::KvWouldOom;
        }
        Admission::Accept
    }

    fn cached_prefix_tokens(&self, prompt: &[i32]) -> usize {
        self.prefix.as_ref().map_or(0, |p| p.lookup(prompt))
    }

    fn cached_prefix_bytes(&self) -> usize {
        self.prefix.as_ref().map_or(0, |p| p.cached_bytes())
    }

    fn submit(&mut self, req: Request, _arrival_s: f64) -> bool {
        Engine::submit(self, req)
    }

    fn step(&mut self) -> Result<bool> {
        Engine::step(self)
    }

    fn take_finished(&mut self) -> Vec<RequestOutput> {
        Engine::take_finished(self)
    }

    fn evict_queued(&mut self) -> Vec<Request> {
        self.queue.drain_all()
    }

    fn abort_active(&mut self) -> Vec<RequestId> {
        let mut ids = Vec::new();
        if let Some(cp) = self.chunked.take() {
            if cp.cache_tokens > 0 {
                if let Some(p) = self.prefix.as_mut() {
                    p.release(&cp.req.prompt, cp.cache_tokens);
                }
            }
            self.kv.free_slot(cp.slot);
            ids.push(cp.req.id);
        }
        let slots: Vec<usize> = self.active.keys().copied().collect();
        for slot in slots {
            // lint:allow(no-unwrap-in-lib): iterating keys collected from the same map
            let a = self.active.remove(&slot).expect("slot key just listed");
            self.kv.free_slot(slot);
            if a.cache_tokens > 0 {
                if let Some(p) = self.prefix.as_mut() {
                    p.release(&a.prompt, a.cache_tokens);
                }
            }
            ids.push(a.id);
        }
        // Preempted sequences hold no slot, but swap victims' records pin
        // resident shared blocks and their pins hold cache spans —
        // discard both so the pool drains clean (recompute victims have
        // no record to take).
        while let Some(p) = self.preempted.pop_front() {
            if let Some(host) = self.host.as_mut() {
                if let Some((_blocks, record)) = host.take(p.a.id) {
                    self.kv.discard_swapped(record);
                }
            }
            if p.a.cache_tokens > 0 {
                if let Some(pc) = self.prefix.as_mut() {
                    pc.release(&p.a.prompt, p.a.cache_tokens);
                }
            }
            ids.push(p.a.id);
        }
        // Beam branches share one request id — report each aborted
        // request once.
        self.beams.clear();
        ids.sort_unstable();
        ids.dedup();
        ids
    }

    fn metrics(&self) -> &ServeMetrics {
        &self.metrics
    }

    fn enable_trace(&mut self, replica: usize, capacity: usize) {
        self.trace = Some(TraceRecorder::with_capacity(
            replica,
            Clock::wall(),
            capacity,
        ));
    }

    fn trace(&self) -> Option<&TraceRecorder> {
        self.trace.as_ref()
    }
}

fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best as i32
}

/// Top-k token ids by logit (descending) with their log-softmax scores.
/// Ties break toward the lower index, so the first entry always equals
/// [`argmax`] — beam width 1 reduces to plain greedy exactly.
fn top_k_log_softmax(row: &[f32], k: usize) -> (Vec<i32>, Vec<f64>) {
    let mut idx: Vec<usize> = (0..row.len()).collect();
    idx.sort_by(|&a, &b| {
        row[b]
            .partial_cmp(&row[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let lz = row.iter().map(|&x| (x as f64 - m).exp()).sum::<f64>().ln();
    idx.into_iter()
        .take(k.min(row.len()))
        .map(|i| (i as i32, (row[i] as f64 - m) - lz))
        .unzip()
}

/// Log-softmax of `row[idx]`, accumulated in f64 — the per-step beam
/// score increment.
fn log_softmax_at(row: &[f32], idx: usize) -> f64 {
    let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let lz = row.iter().map(|&x| (x as f64 - m).exp()).sum::<f64>().ln();
    (row[idx] as f64 - m) - lz
}

/// Exponential moving average with a 0.3 step: seeded by the first
/// sample, then recent measurements dominate within a handful — what
/// `Auto` preemption wants on a machine whose load shifts.
fn ema_update(cur: &mut Option<f64>, sample: f64) {
    *cur = Some(match *cur {
        Some(c) => 0.7 * c + 0.3 * sample,
        None => sample,
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0]), 1);
        assert_eq!(argmax(&[f32::NEG_INFINITY, -1.0]), 1);
    }

    #[test]
    fn top_k_agrees_with_argmax_and_normalizes() {
        let row = [0.5f32, 2.0, 2.0, -1.0];
        let (toks, scores) = top_k_log_softmax(&row, 3);
        // Ties break toward the lower index, matching argmax.
        assert_eq!(toks[0], argmax(&row));
        assert_eq!(toks, vec![1, 2, 0]);
        // Scores are log-probs: the full softmax sums to 1.
        let total: f64 = (0..row.len()).map(|i| log_softmax_at(&row, i).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9, "{total}");
        assert!((scores[0] - scores[1]).abs() < 1e-9);
        assert!(scores[1] > scores[2]);
        // k larger than the vocab clamps.
        assert_eq!(top_k_log_softmax(&row, 10).0.len(), 4);
    }

    #[test]
    fn ema_seeds_then_tracks() {
        let mut e = None;
        ema_update(&mut e, 10.0);
        assert_eq!(e, Some(10.0));
        ema_update(&mut e, 0.0);
        assert_eq!(e, Some(7.0));
    }

    // Engine integration tests (require artifacts) are in
    // rust/tests/serving_integration.rs.
}
