//! The host-memory KV tier (ISSUE 9): a byte-budgeted store for
//! swapped-out sequences, sitting one rung below the device
//! [`super::kvcache::BlockPool`] in the memory hierarchy the
//! datacenter-TCO analysis prices (HBM bytes are scarce and expensive;
//! host DRAM is plentiful but sits behind the PCIe link).
//!
//! The tier is deliberately dumb: it holds opaque per-key payloads —
//! typically a [`super::kvcache::SwappedSlot`] carrying FP8 codes and
//! their per-(block, layer, kv-head) scales together — and accounts
//! capacity in **blocks at the shared [`KvLayout`] rate**, the same
//! bytes-per-block every other capacity consumer charges. Victim
//! selection, transfer pricing, and the swap-vs-recompute decision all
//! live with the callers (engine / sim replica); the tier only answers
//! "does this fit" and "give it back".

use crate::quant::KvLayout;

/// Byte-budgeted host-memory store for swapped-out KV state, keyed by
/// request id. Generic over the payload so the engine can park real
/// [`super::kvcache::SwappedSlot`]s while the virtual-clock sim, which
/// models transfers without materializing bytes, parks `()`.
pub struct HostTier<P> {
    capacity_bytes: usize,
    block_bytes: usize,
    entries: Vec<(u64, usize, P)>,
    swapped_out_blocks: u64,
    swapped_in_blocks: u64,
}

impl<P> HostTier<P> {
    /// A tier holding up to `capacity_bytes` of swapped KV, accounted in
    /// blocks at the layout's block rate (codes + scales together — the
    /// same rate the device pool charges, so a block costs identical
    /// bytes on either side of the link).
    pub fn new(capacity_bytes: usize, layout: &KvLayout, block_tokens: usize) -> Self {
        Self {
            capacity_bytes,
            block_bytes: layout.block_bytes(block_tokens),
            entries: Vec::new(),
            swapped_out_blocks: 0,
            swapped_in_blocks: 0,
        }
    }

    /// Bytes one stored block occupies (the shared layout rate).
    pub fn block_bytes(&self) -> usize {
        self.block_bytes
    }

    pub fn capacity_bytes(&self) -> usize {
        self.capacity_bytes
    }

    /// Bytes currently held, at the block rate.
    pub fn used_bytes(&self) -> usize {
        let blocks: usize = self.entries.iter().map(|(_, b, _)| *b).sum();
        blocks * self.block_bytes
    }

    /// Whether `blocks` more blocks fit the remaining budget.
    pub fn can_store(&self, blocks: usize) -> bool {
        blocks * self.block_bytes <= self.capacity_bytes.saturating_sub(self.used_bytes())
    }

    /// Park `blocks` blocks of payload under `key`. Returns `false` —
    /// payload dropped, nothing stored — when over budget or the key is
    /// already present (a sequence is never swapped out twice).
    pub fn store(&mut self, key: u64, blocks: usize, payload: P) -> bool {
        if !self.can_store(blocks) || self.contains(key) {
            return false;
        }
        self.entries.push((key, blocks, payload));
        self.swapped_out_blocks += blocks as u64;
        true
    }

    /// Reclaim `key`'s payload (swap-in or discard), freeing its budget.
    pub fn take(&mut self, key: u64) -> Option<(usize, P)> {
        let i = self.entries.iter().position(|(k, _, _)| *k == key)?;
        let (_, blocks, payload) = self.entries.swap_remove(i);
        self.swapped_in_blocks += blocks as u64;
        Some((blocks, payload))
    }

    pub fn contains(&self, key: u64) -> bool {
        self.entries.iter().any(|(k, _, _)| *k == key)
    }

    /// Sequences currently parked.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Blocks ever stored (telemetry behind `repro_swapped_out_blocks`).
    pub fn swapped_out_blocks(&self) -> u64 {
        self.swapped_out_blocks
    }

    /// Blocks ever reclaimed (telemetry behind `repro_swapped_in_blocks`).
    pub fn swapped_in_blocks(&self) -> u64 {
        self.swapped_in_blocks
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::KvDtype;

    fn tier(capacity_blocks: usize) -> HostTier<&'static str> {
        let layout = KvLayout::new(KvDtype::FP8_DEFAULT, 2, 2, 4);
        let bb = layout.block_bytes(16);
        HostTier::new(capacity_blocks * bb, &layout, 16)
    }

    #[test]
    fn budget_is_enforced_at_the_block_rate() {
        let mut t = tier(4);
        assert!(t.is_empty());
        assert!(t.can_store(4));
        assert!(!t.can_store(5));
        assert!(t.store(1, 3, "a"));
        assert_eq!(t.used_bytes(), 3 * t.block_bytes());
        assert!(!t.store(2, 2, "b"), "over budget");
        assert!(t.store(2, 1, "b"));
        assert_eq!(t.len(), 2);
        assert!(!t.can_store(1), "budget exhausted");
        // Reclaim frees the budget.
        let (blocks, payload) = t.take(1).expect("stored");
        assert_eq!((blocks, payload), (3, "a"));
        assert!(t.can_store(3));
        assert!(t.take(1).is_none(), "already reclaimed");
    }

    #[test]
    fn duplicate_keys_are_rejected_and_counters_accumulate() {
        let mut t = tier(8);
        assert!(t.store(7, 2, "x"));
        assert!(!t.store(7, 1, "y"), "a sequence is never swapped out twice");
        assert!(t.contains(7));
        assert!(!t.contains(8));
        t.take(7);
        assert!(t.store(7, 3, "z"), "key reusable after reclaim");
        t.take(7);
        assert_eq!(t.swapped_out_blocks(), 5);
        assert_eq!(t.swapped_in_blocks(), 5);
    }

    #[test]
    fn zero_capacity_tier_stores_nothing() {
        let layout = KvLayout::new(KvDtype::FP8_DEFAULT, 2, 2, 4);
        let mut t: HostTier<()> = HostTier::new(0, &layout, 16);
        assert!(!t.can_store(1));
        assert!(!t.store(1, 1, ()));
        assert!(t.can_store(0), "degenerate zero-block record still fits");
    }
}
