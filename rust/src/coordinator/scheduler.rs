//! Scheduling policy: prefill/decode interleave, shape-bucket selection,
//! and prefix-cache-aware chunked prefill planning.
//!
//! The AOT architecture compiles one executable per (variant, batch, seq)
//! bucket, so the scheduler's job includes *bucketing*: choosing the
//! smallest compiled prefill length ≥ prompt, and the smallest compiled
//! decode batch ≥ active slots.
//!
//! With a [`PrefixCache`] attached, [`Scheduler::plan_with_prefix`] matches
//! the longest cached prefix of the queue head and plans only the uncached
//! tail, split into fixed-size chunks the engine interleaves with decode
//! steps. A full hit produces a **zero-tail** plan: no prefill compute at
//! all, just the first-token bootstrap.

use super::batcher::{AdmissionQueue, BatchPlan, PrefillPlan};
use super::kvcache::KvStore;
use super::prefix::PrefixCache;

/// Prefill/decode interleave policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulePolicy {
    /// Admit new work as soon as a slot frees (lower TTFT, can stall
    /// decodes behind prefills).
    PrefillFirst,
    /// Only admit when the decode group would go below `min_decode` active
    /// slots (protects TPOT under load).
    DecodeFirst { min_decode: usize },
}

/// Is a warm (cache-hit) start worth taking? The warm path recomputes the
/// uncached tail through the decode machinery, which only beats one
/// bucketed whole-prompt prefill when most of the prompt is cached — a
/// one-block hit on a long prompt would make TTFT *worse*. Exception:
/// when no compiled prefill bucket fits the prompt, the warm path is the
/// only way to serve it at all.
pub fn warm_start_pays(cached: usize, prompt_len: usize, cold_bucket_exists: bool) -> bool {
    cached > 0 && (cached * 2 >= prompt_len || !cold_bucket_exists)
}

/// Router-level screening for a prompt that fits **no** compiled prefill
/// bucket: admissible only when a cached prefix makes the warm chunked
/// tail worthwhile (`warm_start_pays` with no cold option). Shared by
/// `Engine::could_ever_admit` and `SimReplica::could_ever_admit` so the
/// two stay in lockstep with the scheduler's own warm gate.
///
/// The lookup is deliberately *unpinned* — screening must not hold cache
/// blocks for requests that may never arrive. The race is accepted: if
/// the prefix is evicted between screening and admission, the replica
/// completes the request unservable (empty output, counted) through the
/// same path that has always handled requests that become impossible
/// after queueing, rather than wedging.
pub fn warm_admittable_without_bucket(prefix: Option<&PrefixCache>, prompt: &[i32]) -> bool {
    let cached = prefix.map_or(0, |p| p.lookup(prompt).min(prompt.len()));
    warm_start_pays(cached, prompt.len(), false)
}

/// Fixed-size chunk spans `(start, len)` covering the uncached prefill
/// tail `[cached, prompt_len)`. Empty for a full hit; `chunk_tokens == 0`
/// emits the whole tail as a single chunk.
pub fn chunk_spans(prompt_len: usize, cached: usize, chunk_tokens: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut pos = cached.min(prompt_len);
    let step = if chunk_tokens == 0 {
        prompt_len.saturating_sub(pos).max(1)
    } else {
        chunk_tokens
    };
    while pos < prompt_len {
        let len = step.min(prompt_len - pos);
        out.push((pos, len));
        pos += len;
    }
    out
}

/// How a preempted sequence gets back on the device (ISSUE 9).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PreemptPolicy {
    /// Always move blocks to the host tier and swap them back in.
    Swap,
    /// Always drop the blocks and re-prefill the context chunked.
    Recompute,
    /// Per-victim choice: price chunked re-prefill against the modeled
    /// host-link transfer and take the cheaper path (swap also requires
    /// host-tier headroom).
    Auto,
}

impl PreemptPolicy {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "swap" => Some(PreemptPolicy::Swap),
            "recompute" => Some(PreemptPolicy::Recompute),
            "auto" => Some(PreemptPolicy::Auto),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            PreemptPolicy::Swap => "swap",
            PreemptPolicy::Recompute => "recompute",
            PreemptPolicy::Auto => "auto",
        }
    }
}

/// One running sequence as seen by victim selection.
#[derive(Clone, Copy, Debug)]
pub struct PreemptCandidate {
    /// Caller-side index (slot id, vec position — opaque to selection).
    pub idx: usize,
    /// Seconds since this sequence was last scheduled for a step.
    pub idle_s: f64,
    /// Tokens generated so far (progress already banked).
    pub generated: usize,
}

/// Pick the preemption victim: the **least-recently-scheduled** sequence
/// (max `idle_s`), breaking ties toward the **fewest generated tokens**
/// (least banked progress to stall), then toward the smallest `idx` so the
/// choice is deterministic under equal inputs. Returns the winning `idx`,
/// or `None` for an empty field.
pub fn select_preemption_victim(cands: &[PreemptCandidate]) -> Option<usize> {
    cands
        .iter()
        .max_by(|a, b| {
            a.idle_s
                .total_cmp(&b.idle_s)
                .then(b.generated.cmp(&a.generated))
                .then(b.idx.cmp(&a.idx))
        })
        .map(|c| c.idx)
}

pub struct Scheduler {
    pub policy: SchedulePolicy,
    /// Compiled prefill sequence buckets (ascending).
    pub prefill_seqs: Vec<usize>,
    /// Compiled decode batch buckets (ascending).
    pub decode_batches: Vec<usize>,
}

impl Scheduler {
    pub fn new(policy: SchedulePolicy, prefill_seqs: Vec<usize>, decode_batches: Vec<usize>) -> Self {
        let mut s = prefill_seqs;
        s.sort_unstable();
        let mut b = decode_batches;
        b.sort_unstable();
        Self {
            policy,
            prefill_seqs: s,
            decode_batches: b,
        }
    }

    /// Smallest compiled prefill length that fits `prompt_len`, or None if
    /// the prompt exceeds every bucket.
    pub fn prefill_bucket(&self, prompt_len: usize) -> Option<usize> {
        self.prefill_seqs.iter().copied().find(|s| *s >= prompt_len)
    }

    /// Smallest compiled decode batch ≥ `active`, or the largest if the
    /// group must be split (caller then runs multiple groups). With no
    /// compiled buckets at all, degrade to the exact group size instead of
    /// panicking (shape-polymorphic backends have no bucket list).
    pub fn decode_bucket(&self, active: usize) -> usize {
        self.decode_batches
            .iter()
            .copied()
            .find(|b| *b >= active)
            .or_else(|| self.decode_batches.last().copied())
            .unwrap_or_else(|| active.max(1))
    }

    /// Partition active slots into artifact-sized decode groups.
    ///
    /// **Relaxed for paged decode** (ISSUE 5): the block-table-native path
    /// reads each slot's exact live blocks, so a group has no shared
    /// context shape to pad to — plain order-preserving chunks are optimal
    /// and slots never wait to be packed with similar lengths. Dense
    /// batched-attention kernels, which bucket-pad every row to the
    /// group-max context, should group via
    /// [`Self::decode_groups_dense_ctx`] instead.
    pub fn decode_groups(&self, slots: &[usize]) -> Vec<Vec<usize>> {
        let max_b = self
            .decode_batches
            .last()
            .copied()
            .unwrap_or_else(|| slots.len())
            .max(1);
        let mut groups = Vec::new();
        for chunk in slots.chunks(max_b) {
            groups.push(chunk.to_vec());
        }
        groups
    }

    /// Grouping for **beam decode**: each cohort is a set of slots that
    /// must step together in one decode group (a beam's branches share a
    /// softmax round — splitting them would let one branch run ahead of
    /// its siblings and break the lockstep scoring contract). Cohorts are
    /// packed order-preserving into groups of the same max batch as
    /// [`Self::decode_groups`], but a cohort is never split across a
    /// group boundary: if it does not fit the current group's remaining
    /// room it starts the next group, and a cohort *larger* than the max
    /// batch gets a group of its own (the artifact runner pads to the
    /// next bucket; correctness over packing).
    pub fn decode_groups_cohorts(&self, cohorts: &[Vec<usize>]) -> Vec<Vec<usize>> {
        let max_b = self
            .decode_batches
            .last()
            .copied()
            .unwrap_or_else(|| cohorts.iter().map(|c| c.len()).sum::<usize>())
            .max(1);
        let mut groups: Vec<Vec<usize>> = Vec::new();
        let mut cur: Vec<usize> = Vec::new();
        for cohort in cohorts {
            if cohort.is_empty() {
                continue;
            }
            if !cur.is_empty() && cur.len() + cohort.len() > max_b {
                groups.push(std::mem::take(&mut cur));
            }
            cur.extend_from_slice(cohort);
            if cur.len() >= max_b {
                groups.push(std::mem::take(&mut cur));
            }
        }
        if !cur.is_empty() {
            groups.push(cur);
        }
        groups
    }

    /// Grouping for the **dense reference** path: a dense batched-attention
    /// kernel pads every row of a group to the group-max context, so slots
    /// are sorted by context (descending, slot id tie-break for
    /// determinism) before chunking — packing similar lengths together
    /// minimizes the padded bytes the group-max rule wastes. The paged hot
    /// path does not need this; see [`Self::decode_groups`].
    pub fn decode_groups_dense_ctx(&self, slots_ctx: &[(usize, usize)]) -> Vec<Vec<usize>> {
        let mut sorted: Vec<(usize, usize)> = slots_ctx.to_vec();
        sorted.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        let ids: Vec<usize> = sorted.iter().map(|(s, _)| *s).collect();
        self.decode_groups(&ids)
    }

    /// Build the next iteration's plan (no prefix cache, single-chunk
    /// prefills — the legacy entry point).
    pub fn plan(&self, queue: &AdmissionQueue, kv: &mut KvStore) -> BatchPlan {
        self.plan_with_prefix(queue, kv, None, 0, true)
    }

    /// Build the next iteration's plan, prefix-cache aware.
    ///
    /// Admission rules: a *cold* prompt must fit a compiled prefill
    /// bucket; a *warm* prompt (cached prefix > 0) recomputes only its
    /// tail through the decode path, so it needs only to fit the KV
    /// window. `allow_admit = false` suppresses admission entirely (the
    /// engine passes this while a chunked prefill is still in flight).
    pub fn plan_with_prefix(
        &self,
        queue: &AdmissionQueue,
        kv: &mut KvStore,
        prefix: Option<&PrefixCache>,
        chunk_tokens: usize,
        allow_admit: bool,
    ) -> BatchPlan {
        let active = kv.active_slots();
        let mut plan = BatchPlan {
            prefill: None,
            decode_slots: active.clone(),
        };
        let admit = allow_admit
            && match self.policy {
                SchedulePolicy::PrefillFirst => true,
                SchedulePolicy::DecodeFirst { min_decode } => active.len() < min_decode,
            };
        if admit {
            if let Some(req) = queue.peek() {
                let hit = prefix.map_or(0, |p| p.lookup(&req.prompt).min(req.prompt.len()));
                let has_bucket = self.prefill_bucket(req.prompt.len()).is_some();
                // Small hits start cold: the tail recompute would cost
                // more than the bucketed prefill it replaces.
                let cached = if warm_start_pays(hit, req.prompt.len(), has_bucket) {
                    hit
                } else {
                    0
                };
                // Admission is physical: beyond the bucket/window checks,
                // the paged pool must actually hold the prompt's *private*
                // blocks (a warm prompt's cached prefix is mapped, not
                // allocated, so only the uncached tail counts).
                let admissible = if cached > 0 {
                    req.prompt.len() <= kv.t && kv.can_map_tail(req.prompt.len(), cached)
                } else {
                    has_bucket && kv.can_map_tail(req.prompt.len(), 0)
                };
                if admissible {
                    if let Some(slot) = kv.alloc_slot() {
                        plan.prefill = Some(PrefillPlan {
                            id: req.id,
                            slot,
                            cached_tokens: cached,
                            chunks: chunk_spans(req.prompt.len(), cached, chunk_tokens),
                        });
                    }
                }
            }
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::prefix::{PrefixCache, PrefixCacheConfig};
    use crate::coordinator::request::Request;
    use crate::quant::{KvDtype, KvLayout};

    fn sched(policy: SchedulePolicy) -> Scheduler {
        Scheduler::new(policy, vec![16, 32, 64, 128], vec![1, 2, 4, 8])
    }

    #[test]
    fn prefill_bucketing() {
        let s = sched(SchedulePolicy::PrefillFirst);
        assert_eq!(s.prefill_bucket(1), Some(16));
        assert_eq!(s.prefill_bucket(16), Some(16));
        assert_eq!(s.prefill_bucket(17), Some(32));
        assert_eq!(s.prefill_bucket(128), Some(128));
        assert_eq!(s.prefill_bucket(129), None);
    }

    #[test]
    fn decode_bucketing() {
        let s = sched(SchedulePolicy::PrefillFirst);
        assert_eq!(s.decode_bucket(1), 1);
        assert_eq!(s.decode_bucket(3), 4);
        assert_eq!(s.decode_bucket(8), 8);
        assert_eq!(s.decode_bucket(9), 8); // split into groups
        assert_eq!(s.decode_groups(&[0, 1, 2, 3, 4, 5, 6, 7, 8]).len(), 2);
    }

    #[test]
    fn cohort_grouping_never_splits_a_beam() {
        let s = sched(SchedulePolicy::PrefillFirst); // max batch 8
        // Singles pack exactly like decode_groups.
        let singles: Vec<Vec<usize>> = (0..9).map(|i| vec![i]).collect();
        let ids: Vec<usize> = (0..9).collect();
        assert_eq!(s.decode_groups_cohorts(&singles), s.decode_groups(&ids));
        // A width-4 beam + singles: the beam that would straddle the
        // boundary starts the next group instead of splitting.
        let mut cohorts: Vec<Vec<usize>> = (0..6).map(|i| vec![i]).collect();
        cohorts.push(vec![10, 11, 12, 13]);
        let groups = s.decode_groups_cohorts(&cohorts);
        assert_eq!(groups, vec![vec![0, 1, 2, 3, 4, 5], vec![10, 11, 12, 13]]);
        for g in &groups {
            let beam: Vec<usize> = g.iter().copied().filter(|&x| x >= 10).collect();
            assert!(beam.is_empty() || beam == vec![10, 11, 12, 13], "beam split across groups");
        }
        // A cohort larger than the max batch still steps as one group.
        let wide: Vec<usize> = (0..10).collect();
        assert_eq!(s.decode_groups_cohorts(&[wide.clone()]), vec![wide]);
        // Empty cohorts vanish; order is preserved across the rest.
        assert_eq!(
            s.decode_groups_cohorts(&[vec![], vec![7, 8], vec![], vec![9]]),
            vec![vec![7, 8, 9]]
        );
    }

    #[test]
    fn chunk_spans_cover_the_tail_exactly() {
        assert_eq!(chunk_spans(10, 0, 0), vec![(0, 10)]);
        assert_eq!(chunk_spans(10, 4, 0), vec![(4, 6)]);
        assert_eq!(chunk_spans(10, 4, 3), vec![(4, 3), (7, 3)]);
        assert_eq!(chunk_spans(11, 4, 3), vec![(4, 3), (7, 3), (10, 1)]);
        // Full hit: the zero-tail plan.
        assert_eq!(chunk_spans(8, 8, 4), Vec::<(usize, usize)>::new());
        // Chunks tile the tail exactly once, in order.
        let spans = chunk_spans(1000, 128, 96);
        let mut pos = 128;
        for (start, len) in &spans {
            assert_eq!(*start, pos);
            assert!(*len > 0 && *len <= 96);
            pos += len;
        }
        assert_eq!(pos, 1000);
    }

    #[test]
    fn prefill_first_admits_when_slot_free() {
        let s = sched(SchedulePolicy::PrefillFirst);
        let mut q = AdmissionQueue::new(8);
        q.push(Request::new(1, vec![0; 20], 4)).unwrap();
        let mut kv = KvStore::new(2, 2, 160, 2, 4);
        let plan = s.plan(&q, &mut kv);
        let pp = plan.prefill.expect("admitted");
        assert_eq!(pp.cached_tokens, 0);
        assert_eq!(pp.chunks, vec![(0, 20)]);
        assert!(plan.decode_slots.is_empty());
    }

    #[test]
    fn decode_first_defers_admission() {
        let s = sched(SchedulePolicy::DecodeFirst { min_decode: 1 });
        let mut q = AdmissionQueue::new(8);
        q.push(Request::new(1, vec![0; 20], 4)).unwrap();
        let mut kv = KvStore::new(2, 2, 160, 2, 4);
        // One active slot already decoding → admission deferred.
        let slot = kv.alloc_slot().unwrap();
        kv.set_len(slot, 5);
        let plan = s.plan(&q, &mut kv);
        assert!(plan.prefill.is_none());
        assert_eq!(plan.decode_slots, vec![slot]);
    }

    #[test]
    fn oversized_prompt_not_admitted() {
        let s = sched(SchedulePolicy::PrefillFirst);
        let mut q = AdmissionQueue::new(8);
        q.push(Request::new(1, vec![0; 300], 4)).unwrap();
        let mut kv = KvStore::new(2, 2, 160, 2, 4);
        let plan = s.plan(&q, &mut kv);
        assert!(plan.prefill.is_none());
    }

    #[test]
    fn prompt_longer_than_every_bucket_stays_queued() {
        // A prompt that exceeds even the largest compiled bucket must not be
        // admitted under either interleave policy, and must not consume a
        // KV slot.
        for policy in [
            SchedulePolicy::PrefillFirst,
            SchedulePolicy::DecodeFirst { min_decode: 4 },
        ] {
            let s = sched(policy);
            let mut q = AdmissionQueue::new(8);
            q.push(Request::new(1, vec![0; 129], 4)).unwrap();
            let mut kv = KvStore::new(2, 2, 160, 2, 4);
            let plan = s.plan(&q, &mut kv);
            assert!(plan.prefill.is_none(), "{policy:?}");
            assert!(kv.active_slots().is_empty(), "slot leaked under {policy:?}");
            assert_eq!(q.len(), 1, "request must remain queued");
        }
    }

    #[test]
    fn split_group_path_above_largest_batch() {
        // 19 active slots with max compiled batch 8 → groups of 8, 8, 3;
        // each group buckets to the smallest compiled batch that fits.
        let s = sched(SchedulePolicy::PrefillFirst);
        let slots: Vec<usize> = (0..19).collect();
        let groups = s.decode_groups(&slots);
        assert_eq!(groups.len(), 3);
        assert_eq!(groups[0].len(), 8);
        assert_eq!(groups[1].len(), 8);
        assert_eq!(groups[2].len(), 3);
        assert_eq!(s.decode_bucket(groups[2].len()), 4);
        // Slots survive the partition exactly once, in order.
        let flat: Vec<usize> = groups.into_iter().flatten().collect();
        assert_eq!(flat, slots);
    }

    #[test]
    fn dense_grouping_packs_similar_contexts_paged_grouping_stays_relaxed() {
        let s = sched(SchedulePolicy::PrefillFirst);
        // (slot, context) in admission order: short/long interleaved.
        let slots_ctx = [(0usize, 100usize), (1, 4000), (2, 120), (3, 3900)];
        // Dense kernels pad each group to its max context: packed groups
        // [4000, 3900] + [120, 100] waste far fewer padded bytes than the
        // order-preserving split [100, 4000] + [120, 3900].
        let s2 = Scheduler::new(SchedulePolicy::PrefillFirst, vec![16], vec![1, 2]);
        let dense = s2.decode_groups_dense_ctx(&slots_ctx);
        assert_eq!(dense, vec![vec![1, 3], vec![2, 0]]);
        let padded = |groups: &[Vec<usize>]| -> usize {
            groups
                .iter()
                .map(|g| {
                    let max = g
                        .iter()
                        .map(|s| slots_ctx.iter().find(|(id, _)| id == s).unwrap().1)
                        .max()
                        .unwrap();
                    max * g.len()
                })
                .sum()
        };
        let naive = s2.decode_groups(&[0, 1, 2, 3]);
        assert!(padded(&dense) < padded(&naive), "{dense:?} vs {naive:?}");
        // The paged path needs no packing: groups preserve slot order
        // exactly (no reordering latency games, no group-max padding).
        assert_eq!(s.decode_groups(&[5, 9, 2]), vec![vec![5, 9, 2]]);
    }

    #[test]
    fn empty_bucket_lists_do_not_panic() {
        let s = Scheduler::new(SchedulePolicy::PrefillFirst, vec![], vec![]);
        assert_eq!(s.prefill_bucket(1), None);
        assert_eq!(s.prefill_bucket(4096), None);
        // No compiled decode buckets: degrade to the exact group size.
        assert_eq!(s.decode_bucket(0), 1);
        assert_eq!(s.decode_bucket(3), 3);
        assert_eq!(s.decode_groups(&[]), Vec::<Vec<usize>>::new());
        assert_eq!(s.decode_groups(&[7, 8, 9]), vec![vec![7, 8, 9]]);
        // Planning with empty buckets: nothing admissible, nothing planned.
        let mut q = AdmissionQueue::new(4);
        q.push(Request::new(1, vec![0; 8], 4)).unwrap();
        let mut kv = KvStore::new(2, 2, 160, 2, 4);
        let plan = s.plan(&q, &mut kv);
        assert!(plan.prefill.is_none());
    }

    #[test]
    fn no_slot_no_prefill() {
        let s = sched(SchedulePolicy::PrefillFirst);
        let mut q = AdmissionQueue::new(8);
        q.push(Request::new(1, vec![0; 8], 4)).unwrap();
        let mut kv = KvStore::new(2, 1, 160, 2, 4);
        kv.alloc_slot().unwrap(); // occupy the only slot
        let plan = s.plan(&q, &mut kv);
        assert!(plan.prefill.is_none());
        assert_eq!(plan.decode_slots.len(), 1);
    }

    fn warm_cache(prompt: &[i32]) -> PrefixCache {
        let layout = KvLayout::new(KvDtype::FP8_DEFAULT, 2, 2, 4);
        let mut p = PrefixCache::new(PrefixCacheConfig {
            block_tokens: 16,
            max_blocks: 64,
            layout,
        });
        p.insert(prompt);
        p
    }

    #[test]
    fn full_hit_produces_zero_tail_plan() {
        let s = sched(SchedulePolicy::PrefillFirst);
        let prompt = vec![7i32; 64]; // block-aligned: fully cacheable
        let cache = warm_cache(&prompt);
        let mut q = AdmissionQueue::new(8);
        q.push(Request::new(1, prompt, 4)).unwrap();
        let mut kv = KvStore::new(2, 2, 160, 2, 4);
        let plan = s.plan_with_prefix(&q, &mut kv, Some(&cache), 16, true);
        let pp = plan.prefill.expect("full hit must admit");
        assert_eq!(pp.cached_tokens, 64);
        assert!(pp.chunks.is_empty(), "full hit ⇒ zero-tail prefill plan");
    }

    #[test]
    fn partial_hit_plans_chunked_tail_only() {
        let s = sched(SchedulePolicy::PrefillFirst);
        let shared = vec![7i32; 64];
        let cache = warm_cache(&shared);
        let mut prompt = shared.clone();
        prompt.extend_from_slice(&[9i32; 40]); // 104 total, 64 cached
        let mut q = AdmissionQueue::new(8);
        q.push(Request::new(1, prompt, 4)).unwrap();
        let mut kv = KvStore::new(2, 2, 160, 2, 4);
        let plan = s.plan_with_prefix(&q, &mut kv, Some(&cache), 16, true);
        let pp = plan.prefill.expect("warm prompt must admit");
        assert_eq!(pp.cached_tokens, 64);
        assert_eq!(pp.chunks, vec![(64, 16), (80, 16), (96, 8)]);
    }

    #[test]
    fn warm_prompt_admits_past_the_prefill_buckets() {
        // 160-token prompt exceeds every compiled bucket (max 128) but is
        // fully cached: the tail goes through the decode path, so the
        // bucket limit no longer gates admission — only the KV window does.
        let s = sched(SchedulePolicy::PrefillFirst);
        let prompt = vec![3i32; 160];
        let cache = warm_cache(&prompt);
        let mut q = AdmissionQueue::new(8);
        q.push(Request::new(1, prompt.clone(), 4)).unwrap();
        let mut kv = KvStore::new(2, 2, 160, 2, 4);
        let plan = s.plan_with_prefix(&q, &mut kv, Some(&cache), 0, true);
        assert!(plan.prefill.is_some());
        // But not past the KV window.
        let mut q2 = AdmissionQueue::new(8);
        let long = vec![3i32; 192];
        let cache2 = warm_cache(&long);
        q2.push(Request::new(2, long, 4)).unwrap();
        let mut kv2 = KvStore::new(2, 2, 160, 2, 4);
        let plan = s.plan_with_prefix(&q2, &mut kv2, Some(&cache2), 0, true);
        assert!(plan.prefill.is_none());
    }

    #[test]
    fn small_hit_starts_cold() {
        // One cached block of a 128-token prompt: recomputing a 112-token
        // tail through the decode path costs more than one bucketed
        // prefill, so the plan must go cold (and a half-cached prompt must
        // still go warm).
        assert!(!warm_start_pays(16, 128, true));
        assert!(warm_start_pays(64, 128, true));
        assert!(warm_start_pays(16, 128, false), "warm is the only option");
        assert!(!warm_start_pays(0, 128, false));
        let s = sched(SchedulePolicy::PrefillFirst);
        let shared = vec![7i32; 16];
        let cache = warm_cache(&shared);
        let mut prompt = shared;
        prompt.extend_from_slice(&[9i32; 112]);
        let mut q = AdmissionQueue::new(8);
        q.push(Request::new(1, prompt, 4)).unwrap();
        let mut kv = KvStore::new(2, 2, 160, 2, 4);
        let plan = s.plan_with_prefix(&q, &mut kv, Some(&cache), 0, true);
        let pp = plan.prefill.expect("cold admission");
        assert_eq!(pp.cached_tokens, 0, "one-block hit must not go warm");
        assert_eq!(pp.chunks, vec![(0, 128)]);
    }

    #[test]
    fn preempt_policy_parse_and_label_roundtrip() {
        for p in [PreemptPolicy::Swap, PreemptPolicy::Recompute, PreemptPolicy::Auto] {
            assert_eq!(PreemptPolicy::parse(p.label()), Some(p));
        }
        assert_eq!(PreemptPolicy::parse("evict"), None);
    }

    #[test]
    fn victim_selection_prefers_idle_then_least_progress() {
        let c = |idx, idle_s, generated| PreemptCandidate {
            idx,
            idle_s,
            generated,
        };
        assert_eq!(select_preemption_victim(&[]), None);
        // Most idle wins outright.
        assert_eq!(
            select_preemption_victim(&[c(0, 0.1, 9), c(1, 2.0, 50), c(2, 0.5, 0)]),
            Some(1)
        );
        // Idle tie → fewest generated tokens (least banked progress).
        assert_eq!(
            select_preemption_victim(&[c(0, 1.0, 9), c(1, 1.0, 2), c(2, 1.0, 5)]),
            Some(1)
        );
        // Full tie → smallest idx, and order of candidates doesn't matter.
        assert_eq!(
            select_preemption_victim(&[c(2, 1.0, 3), c(0, 1.0, 3), c(1, 1.0, 3)]),
            Some(0)
        );
    }

    #[test]
    fn allow_admit_false_suppresses_prefill() {
        let s = sched(SchedulePolicy::PrefillFirst);
        let mut q = AdmissionQueue::new(8);
        q.push(Request::new(1, vec![0; 20], 4)).unwrap();
        let mut kv = KvStore::new(2, 2, 160, 2, 4);
        let plan = s.plan_with_prefix(&q, &mut kv, None, 0, false);
        assert!(plan.prefill.is_none());
        assert!(kv.active_slots().is_empty(), "no slot may be consumed");
    }
}
