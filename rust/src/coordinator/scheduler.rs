//! Scheduling policy: prefill/decode interleave and shape-bucket selection.
//!
//! The AOT architecture compiles one executable per (variant, batch, seq)
//! bucket, so the scheduler's job includes *bucketing*: choosing the
//! smallest compiled prefill length ≥ prompt, and the smallest compiled
//! decode batch ≥ active slots.

use super::batcher::{AdmissionQueue, BatchPlan};
use super::kvcache::KvStore;

/// Prefill/decode interleave policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulePolicy {
    /// Admit new work as soon as a slot frees (lower TTFT, can stall
    /// decodes behind prefills).
    PrefillFirst,
    /// Only admit when the decode group would go below `min_decode` active
    /// slots (protects TPOT under load).
    DecodeFirst { min_decode: usize },
}

pub struct Scheduler {
    pub policy: SchedulePolicy,
    /// Compiled prefill sequence buckets (ascending).
    pub prefill_seqs: Vec<usize>,
    /// Compiled decode batch buckets (ascending).
    pub decode_batches: Vec<usize>,
}

impl Scheduler {
    pub fn new(policy: SchedulePolicy, prefill_seqs: Vec<usize>, decode_batches: Vec<usize>) -> Self {
        let mut s = prefill_seqs;
        s.sort_unstable();
        let mut b = decode_batches;
        b.sort_unstable();
        Self {
            policy,
            prefill_seqs: s,
            decode_batches: b,
        }
    }

    /// Smallest compiled prefill length that fits `prompt_len`, or None if
    /// the prompt exceeds every bucket.
    pub fn prefill_bucket(&self, prompt_len: usize) -> Option<usize> {
        self.prefill_seqs.iter().copied().find(|s| *s >= prompt_len)
    }

    /// Smallest compiled decode batch ≥ `active`, or the largest if the
    /// group must be split (caller then runs multiple groups). With no
    /// compiled buckets at all, degrade to the exact group size instead of
    /// panicking (shape-polymorphic backends have no bucket list).
    pub fn decode_bucket(&self, active: usize) -> usize {
        self.decode_batches
            .iter()
            .copied()
            .find(|b| *b >= active)
            .or_else(|| self.decode_batches.last().copied())
            .unwrap_or_else(|| active.max(1))
    }

    /// Partition active slots into artifact-sized decode groups.
    pub fn decode_groups(&self, slots: &[usize]) -> Vec<Vec<usize>> {
        let max_b = self
            .decode_batches
            .last()
            .copied()
            .unwrap_or_else(|| slots.len())
            .max(1);
        let mut groups = Vec::new();
        for chunk in slots.chunks(max_b) {
            groups.push(chunk.to_vec());
        }
        groups
    }

    /// Build the next iteration's plan.
    pub fn plan(&self, queue: &AdmissionQueue, kv: &mut KvStore) -> BatchPlan {
        let active = kv.active_slots();
        let mut plan = BatchPlan {
            prefill: None,
            decode_slots: active.clone(),
        };
        let admit = match self.policy {
            SchedulePolicy::PrefillFirst => true,
            SchedulePolicy::DecodeFirst { min_decode } => active.len() < min_decode,
        };
        if admit {
            if let Some(req) = queue.peek() {
                if self.prefill_bucket(req.prompt.len()).is_some() {
                    if let Some(slot) = kv.alloc_slot() {
                        plan.prefill = Some((req.id, slot));
                    }
                }
            }
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Request;

    fn sched(policy: SchedulePolicy) -> Scheduler {
        Scheduler::new(policy, vec![16, 32, 64, 128], vec![1, 2, 4, 8])
    }

    #[test]
    fn prefill_bucketing() {
        let s = sched(SchedulePolicy::PrefillFirst);
        assert_eq!(s.prefill_bucket(1), Some(16));
        assert_eq!(s.prefill_bucket(16), Some(16));
        assert_eq!(s.prefill_bucket(17), Some(32));
        assert_eq!(s.prefill_bucket(128), Some(128));
        assert_eq!(s.prefill_bucket(129), None);
    }

    #[test]
    fn decode_bucketing() {
        let s = sched(SchedulePolicy::PrefillFirst);
        assert_eq!(s.decode_bucket(1), 1);
        assert_eq!(s.decode_bucket(3), 4);
        assert_eq!(s.decode_bucket(8), 8);
        assert_eq!(s.decode_bucket(9), 8); // split into groups
        assert_eq!(s.decode_groups(&[0, 1, 2, 3, 4, 5, 6, 7, 8]).len(), 2);
    }

    #[test]
    fn prefill_first_admits_when_slot_free() {
        let s = sched(SchedulePolicy::PrefillFirst);
        let mut q = AdmissionQueue::new(8);
        q.push(Request::new(1, vec![0; 20], 4));
        let mut kv = KvStore::new(2, 2, 160, 2, 4);
        let plan = s.plan(&q, &mut kv);
        assert!(plan.prefill.is_some());
        assert!(plan.decode_slots.is_empty());
    }

    #[test]
    fn decode_first_defers_admission() {
        let s = sched(SchedulePolicy::DecodeFirst { min_decode: 1 });
        let mut q = AdmissionQueue::new(8);
        q.push(Request::new(1, vec![0; 20], 4));
        let mut kv = KvStore::new(2, 2, 160, 2, 4);
        // One active slot already decoding → admission deferred.
        let slot = kv.alloc_slot().unwrap();
        kv.set_len(slot, 5);
        let plan = s.plan(&q, &mut kv);
        assert!(plan.prefill.is_none());
        assert_eq!(plan.decode_slots, vec![slot]);
    }

    #[test]
    fn oversized_prompt_not_admitted() {
        let s = sched(SchedulePolicy::PrefillFirst);
        let mut q = AdmissionQueue::new(8);
        q.push(Request::new(1, vec![0; 300], 4));
        let mut kv = KvStore::new(2, 2, 160, 2, 4);
        let plan = s.plan(&q, &mut kv);
        assert!(plan.prefill.is_none());
    }

    #[test]
    fn prompt_longer_than_every_bucket_stays_queued() {
        // A prompt that exceeds even the largest compiled bucket must not be
        // admitted under either interleave policy, and must not consume a
        // KV slot.
        for policy in [
            SchedulePolicy::PrefillFirst,
            SchedulePolicy::DecodeFirst { min_decode: 4 },
        ] {
            let s = sched(policy);
            let mut q = AdmissionQueue::new(8);
            q.push(Request::new(1, vec![0; 129], 4));
            let mut kv = KvStore::new(2, 2, 160, 2, 4);
            let plan = s.plan(&q, &mut kv);
            assert!(plan.prefill.is_none(), "{policy:?}");
            assert!(kv.active_slots().is_empty(), "slot leaked under {policy:?}");
            assert_eq!(q.len(), 1, "request must remain queued");
        }
    }

    #[test]
    fn split_group_path_above_largest_batch() {
        // 19 active slots with max compiled batch 8 → groups of 8, 8, 3;
        // each group buckets to the smallest compiled batch that fits.
        let s = sched(SchedulePolicy::PrefillFirst);
        let slots: Vec<usize> = (0..19).collect();
        let groups = s.decode_groups(&slots);
        assert_eq!(groups.len(), 3);
        assert_eq!(groups[0].len(), 8);
        assert_eq!(groups[1].len(), 8);
        assert_eq!(groups[2].len(), 3);
        assert_eq!(s.decode_bucket(groups[2].len()), 4);
        // Slots survive the partition exactly once, in order.
        let flat: Vec<usize> = groups.into_iter().flatten().collect();
        assert_eq!(flat, slots);
    }

    #[test]
    fn empty_bucket_lists_do_not_panic() {
        let s = Scheduler::new(SchedulePolicy::PrefillFirst, vec![], vec![]);
        assert_eq!(s.prefill_bucket(1), None);
        assert_eq!(s.prefill_bucket(4096), None);
        // No compiled decode buckets: degrade to the exact group size.
        assert_eq!(s.decode_bucket(0), 1);
        assert_eq!(s.decode_bucket(3), 3);
        assert_eq!(s.decode_groups(&[]), Vec::<Vec<usize>>::new());
        assert_eq!(s.decode_groups(&[7, 8, 9]), vec![vec![7, 8, 9]]);
        // Planning with empty buckets: nothing admissible, nothing planned.
        let mut q = AdmissionQueue::new(4);
        q.push(Request::new(1, vec![0; 8], 4));
        let mut kv = KvStore::new(2, 2, 160, 2, 4);
        let plan = s.plan(&q, &mut kv);
        assert!(plan.prefill.is_none());
    }

    #[test]
    fn no_slot_no_prefill() {
        let s = sched(SchedulePolicy::PrefillFirst);
        let mut q = AdmissionQueue::new(8);
        q.push(Request::new(1, vec![0; 8], 4));
        let mut kv = KvStore::new(2, 1, 160, 2, 4);
        kv.alloc_slot().unwrap(); // occupy the only slot
        let plan = s.plan(&q, &mut kv);
        assert!(plan.prefill.is_none());
        assert_eq!(plan.decode_slots.len(), 1);
    }
}
