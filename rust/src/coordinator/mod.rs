//! L3 serving coordinator — the vLLM-style layer the paper's end-to-end
//! numbers (Tables 5–6) presuppose: request admission, continuous batching
//! with prefill/decode interleave, paged KV management (one refcounted
//! physical [`kvcache::BlockPool`] + per-sequence block tables, read on
//! the decode hot path through the block-table-native
//! [`kvcache::PagedAttentionView`] and written one token at a time via
//! [`kvcache::KvStore::append_token`]), a
//! radix-tree shared-prefix KV cache with chunked prefill ([`prefix`])
//! whose hits map physical blocks instead of copying, and metrics.
//!
//! Everything here is plain Rust (std threads + channels — the request path
//! has no Python and no async runtime); the compute is the AOT artifacts
//! executed through [`crate::runtime`].

pub mod batcher;
pub mod engine;
pub mod hosttier;
pub mod kvcache;
pub mod metrics;
pub mod prefix;
pub mod request;
pub mod scheduler;

pub use batcher::{AdmissionQueue, BatchPlan, PrefillPlan};
pub use engine::{Engine, EngineConfig};
pub use hosttier::HostTier;
pub use kvcache::{
    AppendOutcome, AttendOptions, AttendScratch, AttendTask, BlockAllocator, BlockId, BlockPool,
    Dequant, ForkError, KvStore, PagedAttentionView, PagedSlotView, SwappedBlock, SwappedSlot,
};
pub use metrics::{LatencyStat, ServeMetrics};
pub use prefix::{PrefixCache, PrefixCacheConfig, PrefixStats};
pub use request::{Request, RequestId, RequestOutput, RequestState};
pub use scheduler::{
    chunk_spans, select_preemption_victim, warm_admittable_without_bucket, warm_start_pays,
    PreemptCandidate, PreemptPolicy, SchedulePolicy, Scheduler,
};
