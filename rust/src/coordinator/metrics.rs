//! Serving metrics: TTFT / TPOT / throughput, in the units the paper's
//! e2e evaluation reports.

use crate::obs::Clock;
use crate::util::rng::XorShiftRng;

/// Retained samples per [`LatencyStat`] — bounds memory while keeping
/// percentiles meaningful; shared by `record` and `merge`.
const RESERVOIR: usize = 4096;

/// Seed for the Algorithm-R replacement draws. Fixed (not per-instance)
/// so every run of the same workload reports identical percentiles.
const RESERVOIR_SEED: u64 = 0x0b5e_51a7_5eed_0001;

/// Streaming latency statistic (count / mean / min / max / percentiles via
/// a uniform reservoir sample of everything seen).
#[derive(Clone, Debug)]
pub struct LatencyStat {
    pub count: u64,
    pub sum_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    /// Algorithm-R reservoir: a uniform sample of all `count` recordings,
    /// not a sliding window of the most recent ones.
    recent: Vec<f64>,
    rng: XorShiftRng,
}

impl Default for LatencyStat {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyStat {
    pub fn new() -> Self {
        Self {
            count: 0,
            sum_s: 0.0,
            min_s: f64::INFINITY,
            max_s: 0.0,
            recent: Vec::new(),
            rng: XorShiftRng::new(RESERVOIR_SEED),
        }
    }

    pub fn record(&mut self, seconds: f64) {
        self.count += 1;
        self.sum_s += seconds;
        self.min_s = self.min_s.min(seconds);
        self.max_s = self.max_s.max(seconds);
        if self.recent.len() < RESERVOIR {
            self.recent.push(seconds);
        } else {
            // Algorithm R: the i-th sample replaces a reservoir slot with
            // probability RESERVOIR/i, keeping the reservoir a uniform
            // sample of the whole stream. (The previous modulo overwrite
            // kept only the most recent window, recency-biasing long-run
            // percentiles.)
            let j = self.rng.below(self.count as usize);
            if j < RESERVOIR {
                self.recent[j] = seconds;
            }
        }
    }

    pub fn mean_s(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_s / self.count as f64
        }
    }

    pub fn p50_s(&self) -> f64 {
        self.percentile_s(0.5)
    }

    pub fn p95_s(&self) -> f64 {
        self.percentile_s(0.95)
    }

    /// Arbitrary quantile over the retained samples (`q` in [0, 1],
    /// nearest-rank on the sorted reservoir).
    pub fn percentile_s(&self, q: f64) -> f64 {
        if self.recent.is_empty() {
            return 0.0;
        }
        let mut v = self.recent.clone();
        v.sort_by(|a, b| a.total_cmp(b));
        let idx = ((v.len() as f64 - 1.0) * q.clamp(0.0, 1.0)).round() as usize;
        v[idx.min(v.len() - 1)]
    }

    pub fn p99_s(&self) -> f64 {
        self.percentile_s(0.99)
    }

    /// Fold another stat into this one. Exact for count/sum/min/max; the
    /// percentile reservoir concatenates both sides and, past the
    /// retention cap, downsamples evenly. NOTE: chaining pairwise merges
    /// repeatedly re-downsamples the earlier sides — merging many stats at
    /// once should use [`LatencyStat::merge_many`], which downsamples once.
    pub fn merge(&mut self, other: &LatencyStat) {
        *self = LatencyStat::merge_many([&*self, other]);
    }

    /// Merge any number of stats with a single downsampling pass, so every
    /// source's reservoir stays proportionally represented in the merged
    /// percentiles.
    pub fn merge_many<'a, I>(stats: I) -> LatencyStat
    where
        I: IntoIterator<Item = &'a LatencyStat>,
    {
        let mut out = LatencyStat::new();
        let mut combined: Vec<f64> = Vec::new();
        for s in stats {
            out.count += s.count;
            out.sum_s += s.sum_s;
            if s.count > 0 {
                out.min_s = out.min_s.min(s.min_s);
                out.max_s = out.max_s.max(s.max_s);
            }
            combined.extend_from_slice(&s.recent);
        }
        if combined.len() > RESERVOIR {
            // Sort before the stride downsample: the result is then a
            // deterministic quantile sketch of the union — independent of
            // the order the sources were merged in.
            combined.sort_by(|a, b| a.total_cmp(b));
            let stride = combined.len() as f64 / RESERVOIR as f64;
            out.recent = (0..RESERVOIR)
                .map(|i| combined[(i as f64 * stride) as usize])
                .collect();
        } else {
            out.recent = combined;
        }
        out
    }
}

/// Engine-level counters.
#[derive(Clone, Debug)]
pub struct ServeMetrics {
    /// Clock anchored when this metrics object was created:
    /// `started.now_s()` is the serve-loop age in seconds. A
    /// [`Clock`] rather than a raw `Instant` so throughput accounting
    /// works identically under wall and virtual time.
    pub started: Clock,
    pub requests_completed: u64,
    pub prompt_tokens: u64,
    pub generated_tokens: u64,
    pub prefill_steps: u64,
    pub decode_steps: u64,
    pub decode_batch_sum: u64,
    /// Admissions whose prompt matched a cached prefix (> 0 tokens).
    pub prefix_hits: u64,
    /// Admissions that found no cached prefix (counted only when a prefix
    /// cache is attached).
    pub prefix_misses: u64,
    /// Prompt tokens served from the prefix cache instead of prefilled.
    pub prefix_hit_tokens: u64,
    /// KV blocks reclaimed from the prefix cache by LRU eviction.
    pub prefix_evicted_blocks: u64,
    /// Chunked-prefill chunks executed (tail pieces, not whole prefills).
    pub prefill_chunks: u64,
    /// Physical KV bytes decode steps read (paged path).
    pub kv_bytes_read: u64,
    /// Copy-on-write block clones (a shared block went private under a
    /// single-token append).
    pub cow_block_copies: u64,
    /// Events the bounded trace ring buffer refused (0 = complete trace).
    pub trace_events_dropped: u64,
    /// Peak KV block-pool occupancy observed across steps (0–1).
    pub pool_occupancy_peak: f64,
    /// Sequences preempted off the device under pool pressure (ISSUE 9).
    pub preemptions: u64,
    /// KV blocks moved device → host tier by preemption swap-outs.
    pub swapped_out_blocks: u64,
    /// KV blocks moved host tier → device by swap-in resumes.
    pub swapped_in_blocks: u64,
    /// Bytes that crossed the host link in either direction (blocks at
    /// the shared `KvLayout` rate — codes and scales together).
    pub host_swap_bytes: u64,
    /// Preempted sequences resumed by chunked re-prefill instead of
    /// swap-in (the recompute arm of the cost model).
    pub recompute_resumes: u64,
    /// Speculative draft-verify rounds executed (ISSUE 10).
    pub spec_rounds: u64,
    /// Draft tokens the target's greedy verify accepted.
    pub spec_accepted_tokens: u64,
    /// Draft tokens rejected and rolled back by block truncation.
    pub spec_rejected_tokens: u64,
    /// Verify rounds that ended in a truncation rollback (< full accept).
    pub spec_rollbacks: u64,
    /// Beam branches forked off a live sequence (`fork_slot` successes).
    pub beam_forks: u64,
    /// Beam branches pruned (fork released before winning the beam).
    pub beam_prunes: u64,
    pub ttft: LatencyStat,
    pub tpot: LatencyStat,
    pub prefill_time: LatencyStat,
    pub decode_time: LatencyStat,
    /// Per-step model-FLOPs utilization vs the device FP8 peak (0–1);
    /// dimensionless but the same windowed-reservoir machinery applies.
    pub mfu: LatencyStat,
    /// Per-step KV block-pool occupancy samples (0–1).
    pub pool_occupancy: LatencyStat,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServeMetrics {
    pub fn new() -> Self {
        Self {
            started: Clock::wall(),
            requests_completed: 0,
            prompt_tokens: 0,
            generated_tokens: 0,
            prefill_steps: 0,
            decode_steps: 0,
            decode_batch_sum: 0,
            prefix_hits: 0,
            prefix_misses: 0,
            prefix_hit_tokens: 0,
            prefix_evicted_blocks: 0,
            prefill_chunks: 0,
            kv_bytes_read: 0,
            cow_block_copies: 0,
            trace_events_dropped: 0,
            pool_occupancy_peak: 0.0,
            preemptions: 0,
            swapped_out_blocks: 0,
            swapped_in_blocks: 0,
            host_swap_bytes: 0,
            recompute_resumes: 0,
            spec_rounds: 0,
            spec_accepted_tokens: 0,
            spec_rejected_tokens: 0,
            spec_rollbacks: 0,
            beam_forks: 0,
            beam_prunes: 0,
            ttft: LatencyStat::new(),
            tpot: LatencyStat::new(),
            prefill_time: LatencyStat::new(),
            decode_time: LatencyStat::new(),
            mfu: LatencyStat::new(),
            pool_occupancy: LatencyStat::new(),
        }
    }

    pub fn tokens_per_s(&self) -> f64 {
        let el = self.started.now_s();
        if el > 0.0 {
            self.generated_tokens as f64 / el
        } else {
            0.0
        }
    }

    pub fn mean_decode_batch(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.decode_batch_sum as f64 / self.decode_steps as f64
        }
    }

    /// Fold another engine's counters into this one. For merging a whole
    /// fleet, prefer [`ServeMetrics::merge_many`] (single reservoir
    /// downsampling pass).
    pub fn merge(&mut self, other: &ServeMetrics) {
        *self = ServeMetrics::merge_many(&[&*self, other]);
    }

    /// Merge every replica's metrics into one fleet-level view.
    pub fn merge_many(all: &[&ServeMetrics]) -> ServeMetrics {
        let mut out = ServeMetrics::new();
        for m in all {
            // Earliest start = the clock that has been running longest.
            if m.started.now_s() > out.started.now_s() {
                out.started = m.started.clone();
            }
            out.requests_completed += m.requests_completed;
            out.prompt_tokens += m.prompt_tokens;
            out.generated_tokens += m.generated_tokens;
            out.prefill_steps += m.prefill_steps;
            out.decode_steps += m.decode_steps;
            out.decode_batch_sum += m.decode_batch_sum;
            out.prefix_hits += m.prefix_hits;
            out.prefix_misses += m.prefix_misses;
            out.prefix_hit_tokens += m.prefix_hit_tokens;
            out.prefix_evicted_blocks += m.prefix_evicted_blocks;
            out.prefill_chunks += m.prefill_chunks;
            out.kv_bytes_read += m.kv_bytes_read;
            out.cow_block_copies += m.cow_block_copies;
            out.trace_events_dropped += m.trace_events_dropped;
            out.pool_occupancy_peak = out.pool_occupancy_peak.max(m.pool_occupancy_peak);
            out.preemptions += m.preemptions;
            out.swapped_out_blocks += m.swapped_out_blocks;
            out.swapped_in_blocks += m.swapped_in_blocks;
            out.host_swap_bytes += m.host_swap_bytes;
            out.recompute_resumes += m.recompute_resumes;
            out.spec_rounds += m.spec_rounds;
            out.spec_accepted_tokens += m.spec_accepted_tokens;
            out.spec_rejected_tokens += m.spec_rejected_tokens;
            out.spec_rollbacks += m.spec_rollbacks;
            out.beam_forks += m.beam_forks;
            out.beam_prunes += m.beam_prunes;
        }
        out.ttft = LatencyStat::merge_many(all.iter().map(|m| &m.ttft));
        out.tpot = LatencyStat::merge_many(all.iter().map(|m| &m.tpot));
        out.prefill_time = LatencyStat::merge_many(all.iter().map(|m| &m.prefill_time));
        out.decode_time = LatencyStat::merge_many(all.iter().map(|m| &m.decode_time));
        out.mfu = LatencyStat::merge_many(all.iter().map(|m| &m.mfu));
        out.pool_occupancy = LatencyStat::merge_many(all.iter().map(|m| &m.pool_occupancy));
        out
    }

    /// Fraction of prefix-cache-attached admissions that hit (0 when no
    /// cache was in play).
    pub fn prefix_hit_rate(&self) -> f64 {
        let total = self.prefix_hits + self.prefix_misses;
        if total == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / total as f64
        }
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "requests={} gen_tokens={} tok/s={:.1} ttft_mean={:.1}ms ttft_p95={:.1}ms \
             tpot_mean={:.2}ms decode_steps={} mean_batch={:.2}",
            self.requests_completed,
            self.generated_tokens,
            self.tokens_per_s(),
            self.ttft.mean_s() * 1e3,
            self.ttft.p95_s() * 1e3,
            self.tpot.mean_s() * 1e3,
            self.decode_steps,
            self.mean_decode_batch()
        );
        if self.prefix_hits + self.prefix_misses > 0 {
            s.push_str(&format!(
                " prefix_hit_rate={:.2} prefix_hit_tokens={} prefix_evicted_blocks={}",
                self.prefix_hit_rate(),
                self.prefix_hit_tokens,
                self.prefix_evicted_blocks
            ));
        }
        if self.mfu.count > 0 {
            s.push_str(&format!(
                " mfu_mean={:.3} mfu_p50={:.3} mfu_p99={:.3} pool_occupancy_peak={:.2}",
                self.mfu.mean_s(),
                self.mfu.p50_s(),
                self.mfu.p99_s(),
                self.pool_occupancy_peak
            ));
        }
        if self.preemptions > 0 {
            s.push_str(&format!(
                " preemptions={} swapped_out_blocks={} swapped_in_blocks={} \
                 host_swap_bytes={} recompute_resumes={}",
                self.preemptions,
                self.swapped_out_blocks,
                self.swapped_in_blocks,
                self.host_swap_bytes,
                self.recompute_resumes
            ));
        }
        if self.spec_rounds > 0 {
            s.push_str(&format!(
                " spec_rounds={} spec_accepted_tokens={} spec_rejected_tokens={} \
                 spec_rollbacks={} spec_acceptance={:.2}",
                self.spec_rounds,
                self.spec_accepted_tokens,
                self.spec_rejected_tokens,
                self.spec_rollbacks,
                self.spec_acceptance_rate()
            ));
        }
        if self.beam_forks > 0 {
            s.push_str(&format!(
                " beam_forks={} beam_prunes={}",
                self.beam_forks, self.beam_prunes
            ));
        }
        if self.trace_events_dropped > 0 {
            s.push_str(&format!(
                "\nwarning: trace ring buffer dropped {} events (raise --trace-capacity for a complete timeline)",
                self.trace_events_dropped
            ));
        }
        s
    }

    /// Fraction of draft tokens the greedy verify accepted (0 when no
    /// speculative rounds ran).
    pub fn spec_acceptance_rate(&self) -> f64 {
        let total = self.spec_accepted_tokens + self.spec_rejected_tokens;
        if total == 0 {
            0.0
        } else {
            self.spec_accepted_tokens as f64 / total as f64
        }
    }

    /// One machine-readable JSON object per snapshot (the serve-side analog
    /// of the fleet bench rows).
    pub fn json_row(&self, label: &str) -> String {
        format!(
            "{{\"label\":\"{}\",\"requests_completed\":{},\"prompt_tokens\":{},\
             \"generated_tokens\":{},\"decode_steps\":{},\"mean_decode_batch\":{:.4},\
             \"ttft_mean_ms\":{:.4},\"ttft_p50_ms\":{:.4},\"ttft_p95_ms\":{:.4},\
             \"ttft_p99_ms\":{:.4},\"tpot_mean_ms\":{:.5},\"tpot_p50_ms\":{:.5},\
             \"tpot_p99_ms\":{:.5},\"prefix_hit_rate\":{:.4},\"prefix_hit_tokens\":{},\
             \"mfu_mean\":{:.6},\"mfu_p50\":{:.6},\"mfu_p99\":{:.6},\
             \"pool_occupancy_peak\":{:.6},\"kv_bytes_read\":{},\"cow_block_copies\":{},\
             \"trace_events_dropped\":{},\"preemptions\":{},\"swapped_out_blocks\":{},\
             \"swapped_in_blocks\":{},\"host_swap_bytes\":{},\"recompute_resumes\":{},\
             \"spec_rounds\":{},\"spec_accepted_tokens\":{},\"spec_rejected_tokens\":{},\
             \"spec_rollbacks\":{},\"beam_forks\":{},\"beam_prunes\":{}}}",
            label.replace(['"', '\\'], "_"),
            self.requests_completed,
            self.prompt_tokens,
            self.generated_tokens,
            self.decode_steps,
            self.mean_decode_batch(),
            self.ttft.mean_s() * 1e3,
            self.ttft.p50_s() * 1e3,
            self.ttft.p95_s() * 1e3,
            self.ttft.p99_s() * 1e3,
            self.tpot.mean_s() * 1e3,
            self.tpot.p50_s() * 1e3,
            self.tpot.p99_s() * 1e3,
            self.prefix_hit_rate(),
            self.prefix_hit_tokens,
            self.mfu.mean_s(),
            self.mfu.p50_s(),
            self.mfu.p99_s(),
            self.pool_occupancy_peak,
            self.kv_bytes_read,
            self.cow_block_copies,
            self.trace_events_dropped,
            self.preemptions,
            self.swapped_out_blocks,
            self.swapped_in_blocks,
            self.host_swap_bytes,
            self.recompute_resumes,
            self.spec_rounds,
            self.spec_accepted_tokens,
            self.spec_rejected_tokens,
            self.spec_rollbacks,
            self.beam_forks,
            self.beam_prunes,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_stat_moments() {
        let mut s = LatencyStat::new();
        for v in [0.1, 0.2, 0.3] {
            s.record(v);
        }
        assert_eq!(s.count, 3);
        assert!((s.mean_s() - 0.2).abs() < 1e-12);
        assert_eq!(s.min_s, 0.1);
        assert_eq!(s.max_s, 0.3);
        assert!((s.p50_s() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn p95_on_many_samples() {
        let mut s = LatencyStat::new();
        for i in 0..100 {
            s.record(i as f64 / 100.0);
        }
        assert!(s.p95_s() >= 0.9);
    }

    #[test]
    fn percentile_and_p99() {
        let mut s = LatencyStat::new();
        for i in 0..100 {
            s.record((i + 1) as f64);
        }
        assert_eq!(s.percentile_s(0.0), 1.0);
        assert_eq!(s.percentile_s(1.0), 100.0);
        assert!(s.p99_s() >= 99.0);
        assert!(LatencyStat::new().p99_s() == 0.0);
    }

    #[test]
    fn latency_merge_is_exact_on_moments() {
        let mut a = LatencyStat::new();
        let mut b = LatencyStat::new();
        for v in [0.1, 0.4] {
            a.record(v);
        }
        for v in [0.2, 0.8] {
            b.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count, 4);
        assert!((a.sum_s - 1.5).abs() < 1e-12);
        assert_eq!(a.min_s, 0.1);
        assert_eq!(a.max_s, 0.8);
        // merging an empty stat is a no-op
        let before = a.count;
        a.merge(&LatencyStat::new());
        assert_eq!(a.count, before);
        assert_eq!(a.min_s, 0.1);
    }

    #[test]
    fn serve_metrics_merge_sums_counters() {
        let mut a = ServeMetrics::new();
        a.generated_tokens = 10;
        a.requests_completed = 1;
        a.ttft.record(0.5);
        let mut b = ServeMetrics::new();
        b.generated_tokens = 20;
        b.requests_completed = 3;
        b.ttft.record(0.25);
        a.merge(&b);
        assert_eq!(a.generated_tokens, 30);
        assert_eq!(a.requests_completed, 4);
        assert_eq!(a.ttft.count, 2);
        assert_eq!(a.ttft.min_s, 0.25);
    }

    #[test]
    fn prefix_counters_merge_and_rate() {
        let mut a = ServeMetrics::new();
        a.prefix_hits = 3;
        a.prefix_misses = 1;
        a.prefix_hit_tokens = 3072;
        a.prefill_chunks = 5;
        let mut b = ServeMetrics::new();
        b.prefix_hits = 1;
        b.prefix_misses = 3;
        b.prefix_evicted_blocks = 7;
        a.merge(&b);
        assert_eq!(a.prefix_hits, 4);
        assert_eq!(a.prefix_misses, 4);
        assert_eq!(a.prefix_hit_tokens, 3072);
        assert_eq!(a.prefix_evicted_blocks, 7);
        assert_eq!(a.prefill_chunks, 5);
        assert!((a.prefix_hit_rate() - 0.5).abs() < 1e-12);
        assert!(a.report().contains("prefix_hit_rate=0.50"));
        // No cache in play: rate 0, report stays terse.
        let fresh = ServeMetrics::new();
        assert_eq!(fresh.prefix_hit_rate(), 0.0);
        assert!(!fresh.report().contains("prefix_hit_rate"));
    }

    #[test]
    fn serve_metrics_report() {
        let mut m = ServeMetrics::new();
        m.requests_completed = 2;
        m.generated_tokens = 100;
        m.decode_steps = 50;
        m.decode_batch_sum = 100;
        assert_eq!(m.mean_decode_batch(), 2.0);
        assert!(m.report().contains("requests=2"));
        assert!(!m.report().contains("warning"), "no drops, no warning");
        m.trace_events_dropped = 12;
        assert!(
            m.report().contains("dropped 12 events"),
            "drops must warn, not stay silent: {}",
            m.report()
        );
    }

    #[test]
    fn reservoir_is_uniform_not_recency_biased() {
        // Record a long ascending stream: with Algorithm R the retained
        // sample is uniform over the whole stream, so p50 lands near the
        // stream midpoint. The old modulo overwrite kept only the newest
        // RESERVOIR window, which would put p50 near 48_000 here.
        let n = 50_000usize;
        let mut s = LatencyStat::new();
        for i in 0..n {
            s.record(i as f64);
        }
        let mid = n as f64 / 2.0;
        let p50 = s.p50_s();
        assert!(
            (p50 - mid).abs() < 0.05 * n as f64,
            "p50 {p50} not near midpoint {mid}: reservoir is biased"
        );
        // Tails from early and late in the stream both survive.
        assert!(s.percentile_s(0.05) < 0.15 * n as f64);
        assert!(s.percentile_s(0.95) > 0.85 * n as f64);
        // Exact moments are untouched by sampling.
        assert_eq!(s.count, n as u64);
        assert_eq!(s.min_s, 0.0);
        assert_eq!(s.max_s, (n - 1) as f64);
        // Deterministic: same stream, same percentiles.
        let mut s2 = LatencyStat::new();
        for i in 0..n {
            s2.record(i as f64);
        }
        assert_eq!(s.p50_s(), s2.p50_s());
    }

    #[test]
    fn merge_many_is_order_independent_past_the_cap() {
        // Three overfull stats with disjoint ranges: merged percentiles
        // must not depend on merge order.
        let mk = |lo: usize| {
            let mut s = LatencyStat::new();
            for i in 0..6000 {
                s.record((lo + i) as f64);
            }
            s
        };
        let (a, b, c) = (mk(0), mk(6000), mk(12000));
        let abc = LatencyStat::merge_many([&a, &b, &c]);
        let cba = LatencyStat::merge_many([&c, &b, &a]);
        for q in [0.1, 0.5, 0.9, 0.99] {
            assert_eq!(
                abc.percentile_s(q),
                cba.percentile_s(q),
                "merge order changed the q={q} percentile"
            );
        }
        assert_eq!(abc.count, 18_000);
    }

    #[test]
    fn serve_metrics_merge_folds_observability_fields() {
        let mut a = ServeMetrics::new();
        a.kv_bytes_read = 100;
        a.cow_block_copies = 2;
        a.trace_events_dropped = 5;
        a.pool_occupancy_peak = 0.7;
        a.mfu.record(0.4);
        a.pool_occupancy.record(0.5);
        let mut b = ServeMetrics::new();
        b.kv_bytes_read = 50;
        b.trace_events_dropped = 1;
        b.pool_occupancy_peak = 0.9;
        b.mfu.record(0.8);
        a.merge(&b);
        assert_eq!(a.kv_bytes_read, 150);
        assert_eq!(a.cow_block_copies, 2);
        assert_eq!(a.trace_events_dropped, 6);
        assert!((a.pool_occupancy_peak - 0.9).abs() < 1e-12);
        assert_eq!(a.mfu.count, 2);
        assert_eq!(a.pool_occupancy.count, 1);
    }

    #[test]
    fn json_row_parses_and_carries_new_fields() {
        use crate::util::json::Json;
        let mut m = ServeMetrics::new();
        m.requests_completed = 4;
        m.kv_bytes_read = 2048;
        m.trace_events_dropped = 3;
        m.pool_occupancy_peak = 0.5;
        m.mfu.record(0.6);
        let row = m.json_row("sim0");
        let j = Json::parse(&row).expect("json_row must parse");
        assert_eq!(j.get("label").and_then(Json::as_str), Some("sim0"));
        assert_eq!(j.get("requests_completed").and_then(Json::as_f64), Some(4.0));
        assert_eq!(j.get("kv_bytes_read").and_then(Json::as_f64), Some(2048.0));
        assert_eq!(j.get("trace_events_dropped").and_then(Json::as_f64), Some(3.0));
        assert_eq!(j.get("pool_occupancy_peak").and_then(Json::as_f64), Some(0.5));
        assert_eq!(j.get("mfu_mean").and_then(Json::as_f64), Some(0.6));
        assert_eq!(j.get("preemptions").and_then(Json::as_f64), Some(0.0));
        assert_eq!(j.get("host_swap_bytes").and_then(Json::as_f64), Some(0.0));
    }

    #[test]
    fn speculative_counters_merge_report_and_export() {
        use crate::util::json::Json;
        let mut a = ServeMetrics::new();
        a.spec_rounds = 4;
        a.spec_accepted_tokens = 12;
        a.spec_rejected_tokens = 4;
        a.spec_rollbacks = 3;
        a.beam_forks = 2;
        let mut b = ServeMetrics::new();
        b.spec_rounds = 1;
        b.spec_accepted_tokens = 4;
        b.beam_prunes = 1;
        a.merge(&b);
        assert_eq!(a.spec_rounds, 5);
        assert_eq!(a.spec_accepted_tokens, 16);
        assert_eq!(a.spec_rejected_tokens, 4);
        assert_eq!(a.spec_rollbacks, 3);
        assert_eq!(a.beam_forks, 2);
        assert_eq!(a.beam_prunes, 1);
        assert!((a.spec_acceptance_rate() - 0.8).abs() < 1e-12);
        assert!(a.report().contains("spec_rounds=5"));
        assert!(a.report().contains("spec_acceptance=0.80"));
        assert!(a.report().contains("beam_forks=2"));
        // Zero-valued keys still export (dashboards need the series).
        let fresh = ServeMetrics::new();
        assert!(!fresh.report().contains("spec_rounds"));
        assert!(!fresh.report().contains("beam_forks"));
        let j = Json::parse(&fresh.json_row("x")).unwrap();
        for key in [
            "spec_rounds",
            "spec_accepted_tokens",
            "spec_rejected_tokens",
            "spec_rollbacks",
            "beam_forks",
            "beam_prunes",
        ] {
            assert_eq!(j.get(key).and_then(Json::as_f64), Some(0.0), "{key}");
        }
    }

    #[test]
    fn preemption_counters_merge_and_report() {
        let mut a = ServeMetrics::new();
        a.preemptions = 2;
        a.swapped_out_blocks = 10;
        a.swapped_in_blocks = 6;
        a.host_swap_bytes = 4096;
        a.recompute_resumes = 1;
        let mut b = ServeMetrics::new();
        b.preemptions = 1;
        b.swapped_out_blocks = 3;
        b.host_swap_bytes = 512;
        a.merge(&b);
        assert_eq!(a.preemptions, 3);
        assert_eq!(a.swapped_out_blocks, 13);
        assert_eq!(a.swapped_in_blocks, 6);
        assert_eq!(a.host_swap_bytes, 4608);
        assert_eq!(a.recompute_resumes, 1);
        assert!(a.report().contains("preemptions=3"));
        // No preemptions: the report stays terse.
        assert!(!ServeMetrics::new().report().contains("preemptions"));
    }
}
