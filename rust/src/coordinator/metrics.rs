//! Serving metrics: TTFT / TPOT / throughput, in the units the paper's
//! e2e evaluation reports.

use std::time::Instant;

/// Streaming latency statistic (count / mean / min / max / p50-ish via
/// reservoir of recent values).
#[derive(Clone, Debug)]
pub struct LatencyStat {
    pub count: u64,
    pub sum_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    recent: Vec<f64>,
}

impl Default for LatencyStat {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyStat {
    pub fn new() -> Self {
        Self {
            count: 0,
            sum_s: 0.0,
            min_s: f64::INFINITY,
            max_s: 0.0,
            recent: Vec::new(),
        }
    }

    pub fn record(&mut self, seconds: f64) {
        self.count += 1;
        self.sum_s += seconds;
        self.min_s = self.min_s.min(seconds);
        self.max_s = self.max_s.max(seconds);
        if self.recent.len() < 4096 {
            self.recent.push(seconds);
        } else {
            let i = (self.count as usize) % 4096;
            self.recent[i] = seconds;
        }
    }

    pub fn mean_s(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_s / self.count as f64
        }
    }

    pub fn p50_s(&self) -> f64 {
        if self.recent.is_empty() {
            return 0.0;
        }
        let mut v = self.recent.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v[v.len() / 2]
    }

    pub fn p95_s(&self) -> f64 {
        if self.recent.is_empty() {
            return 0.0;
        }
        let mut v = self.recent.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v[(v.len() * 95 / 100).min(v.len() - 1)]
    }
}

/// Engine-level counters.
#[derive(Debug)]
pub struct ServeMetrics {
    pub started: Instant,
    pub requests_completed: u64,
    pub prompt_tokens: u64,
    pub generated_tokens: u64,
    pub prefill_steps: u64,
    pub decode_steps: u64,
    pub decode_batch_sum: u64,
    pub ttft: LatencyStat,
    pub tpot: LatencyStat,
    pub prefill_time: LatencyStat,
    pub decode_time: LatencyStat,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServeMetrics {
    pub fn new() -> Self {
        Self {
            started: Instant::now(),
            requests_completed: 0,
            prompt_tokens: 0,
            generated_tokens: 0,
            prefill_steps: 0,
            decode_steps: 0,
            decode_batch_sum: 0,
            ttft: LatencyStat::new(),
            tpot: LatencyStat::new(),
            prefill_time: LatencyStat::new(),
            decode_time: LatencyStat::new(),
        }
    }

    pub fn tokens_per_s(&self) -> f64 {
        let el = self.started.elapsed().as_secs_f64();
        if el > 0.0 {
            self.generated_tokens as f64 / el
        } else {
            0.0
        }
    }

    pub fn mean_decode_batch(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.decode_batch_sum as f64 / self.decode_steps as f64
        }
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} gen_tokens={} tok/s={:.1} ttft_mean={:.1}ms ttft_p95={:.1}ms \
             tpot_mean={:.2}ms decode_steps={} mean_batch={:.2}",
            self.requests_completed,
            self.generated_tokens,
            self.tokens_per_s(),
            self.ttft.mean_s() * 1e3,
            self.ttft.p95_s() * 1e3,
            self.tpot.mean_s() * 1e3,
            self.decode_steps,
            self.mean_decode_batch()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_stat_moments() {
        let mut s = LatencyStat::new();
        for v in [0.1, 0.2, 0.3] {
            s.record(v);
        }
        assert_eq!(s.count, 3);
        assert!((s.mean_s() - 0.2).abs() < 1e-12);
        assert_eq!(s.min_s, 0.1);
        assert_eq!(s.max_s, 0.3);
        assert!((s.p50_s() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn p95_on_many_samples() {
        let mut s = LatencyStat::new();
        for i in 0..100 {
            s.record(i as f64 / 100.0);
        }
        assert!(s.p95_s() >= 0.9);
    }

    #[test]
    fn serve_metrics_report() {
        let mut m = ServeMetrics::new();
        m.requests_completed = 2;
        m.generated_tokens = 100;
        m.decode_steps = 50;
        m.decode_batch_sum = 100;
        assert_eq!(m.mean_decode_batch(), 2.0);
        assert!(m.report().contains("requests=2"));
    }
}
