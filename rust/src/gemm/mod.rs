//! Scaled FP8 GEMM — the bit-exact software reference for Eq. 2.
//!
//! `X_{l+1} = S_x ( Q(S_x⁻¹·X·S_c⁻¹) ⊗ Q(S_c·Wᵀ·S_w⁻¹) ) S_w`
//!
//! The ⊗ multiply takes FP8 codes and accumulates in FP32 (the MME
//! accumulator), then the diagonal descale applies per-row (`s_x`) and
//! per-column (`s_w`) factors; the output is rounded to BF16 like the
//! hardware's GEMM output (Table 1: FP8 × FP8 → BF16).
//!
//! This module is the numeric oracle the Pallas kernel (L1) is tested
//! against, and the engine behind the Rust-side accuracy experiments.

mod qmatrix;
mod scaled;

pub use qmatrix::{quantize_matrix, QMatrix, QuantRounding};
pub use scaled::{scaled_gemm, scaled_gemm_ref, scaled_gemm_with_table, DiagScale};
