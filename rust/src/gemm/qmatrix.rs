//! Quantized matrices: FP8 codes + the scales that produced them.

use crate::fp8::{encode_rne, encode_stochastic, CastMode, DecodeTable, Fp8Format};
use crate::tensor::Tensor2;
use crate::util::rng::XorShiftRng;

/// A row-major matrix of FP8 codes.
#[derive(Clone, Debug, PartialEq)]
pub struct QMatrix {
    pub rows: usize,
    pub cols: usize,
    pub codes: Vec<u8>,
    pub format: Fp8Format,
}

impl QMatrix {
    #[inline]
    pub fn row(&self, r: usize) -> &[u8] {
        &self.codes[r * self.cols..(r + 1) * self.cols]
    }

    /// Dequantize to f32 (no descaling — raw representable values).
    pub fn dequantize(&self) -> Tensor2 {
        let t = DecodeTable::new(self.format);
        Tensor2 {
            rows: self.rows,
            cols: self.cols,
            data: self.codes.iter().map(|c| t.get(*c)).collect(),
        }
    }

    pub fn bytes(&self) -> usize {
        self.codes.len()
    }
}

/// How values are rounded during the cast.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantRounding {
    Nearest,
    Stochastic { seed: u64 },
}

/// Quantize `x` after applying inverse row scales (`s_row`, length rows or
/// 1) and inverse column scales (`s_col`, length cols or empty=unit):
/// `Q(S_row⁻¹ · X · S_col⁻¹)`.
///
/// Pass the *scales themselves*; the division happens here. This one
/// function covers activations (rows = samples) and transposed-weight
/// quantization (rows = output channels: `Q(S_c·Wᵀ·S_w⁻¹)` is
/// `quantize_matrix(W, s_row = s_w, s_col = 1/s_c)` since W is C'×C).
pub fn quantize_matrix(
    x: &Tensor2,
    s_row: &[f32],
    s_col: &[f32],
    format: Fp8Format,
    rounding: QuantRounding,
) -> QMatrix {
    assert!(
        s_row.len() == x.rows || s_row.len() == 1,
        "row scales: {} for {} rows",
        s_row.len(),
        x.rows
    );
    assert!(
        s_col.is_empty() || s_col.len() == x.cols,
        "col scales: {} for {} cols",
        s_col.len(),
        x.cols
    );
    let mut codes = Vec::with_capacity(x.rows * x.cols);
    let mut rng = match rounding {
        QuantRounding::Stochastic { seed } => Some(XorShiftRng::new(seed)),
        QuantRounding::Nearest => None,
    };
    let inv_col: Vec<f32> = s_col.iter().map(|s| 1.0 / s).collect();
    for r in 0..x.rows {
        let s = s_row[if s_row.len() == 1 { 0 } else { r }];
        let inv_r = 1.0 / s;
        for (c, &v) in x.row(r).iter().enumerate() {
            let scaled = if inv_col.is_empty() {
                v * inv_r
            } else {
                v * inv_r * inv_col[c]
            };
            let code = match &mut rng {
                None => encode_rne(scaled, format, CastMode::SatFinite),
                Some(g) => encode_stochastic(scaled, format, CastMode::SatFinite, g),
            };
            codes.push(code);
        }
    }
    QMatrix {
        rows: x.rows,
        cols: x.cols,
        codes,
        format,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_scale_quantization_roundtrips_representables() {
        let x = Tensor2::from_vec(2, 2, vec![1.5, -2.0, 0.0, 240.0]);
        let q = quantize_matrix(
            &x,
            &[1.0],
            &[],
            Fp8Format::E4M3Gaudi2,
            QuantRounding::Nearest,
        );
        assert_eq!(q.dequantize().data, x.data);
    }

    #[test]
    fn row_scales_divide_before_cast() {
        let x = Tensor2::from_vec(2, 1, vec![480.0, 480.0]);
        let q = quantize_matrix(
            &x,
            &[2.0, 4.0],
            &[],
            Fp8Format::E4M3Gaudi2,
            QuantRounding::Nearest,
        );
        let d = q.dequantize();
        assert_eq!(d.get(0, 0), 240.0); // 480/2
        assert_eq!(d.get(1, 0), 120.0); // 480/4
    }

    #[test]
    fn col_scales_divide_before_cast() {
        let x = Tensor2::from_vec(1, 2, vec![100.0, 100.0]);
        let q = quantize_matrix(
            &x,
            &[1.0],
            &[1.0, 100.0],
            Fp8Format::E4M3,
            QuantRounding::Nearest,
        );
        let d = q.dequantize();
        // Grid around 100 is {96, 104}; 100 is the exact midpoint and ties
        // to the even mantissa → 96.
        assert_eq!(d.get(0, 0), 96.0);
        assert_eq!(d.get(0, 1), 1.0);
    }

    #[test]
    fn out_of_range_saturates_not_infs() {
        let x = Tensor2::from_vec(1, 2, vec![1e9, -1e9]);
        let q = quantize_matrix(&x, &[1.0], &[], Fp8Format::E4M3, QuantRounding::Nearest);
        assert_eq!(q.dequantize().data, vec![448.0, -448.0]);
    }

    #[test]
    fn stochastic_rounding_is_seed_deterministic() {
        let mut rng = XorShiftRng::new(1);
        let x = Tensor2::randn(8, 8, 1.0, &mut rng);
        let a = quantize_matrix(
            &x,
            &[1.0],
            &[],
            Fp8Format::E4M3,
            QuantRounding::Stochastic { seed: 9 },
        );
        let b = quantize_matrix(
            &x,
            &[1.0],
            &[],
            Fp8Format::E4M3,
            QuantRounding::Stochastic { seed: 9 },
        );
        assert_eq!(a, b);
        let c = quantize_matrix(
            &x,
            &[1.0],
            &[],
            Fp8Format::E4M3,
            QuantRounding::Stochastic { seed: 10 },
        );
        assert_ne!(a.codes, c.codes);
    }

    #[test]
    fn quantization_error_shrinks_with_good_scale() {
        let mut rng = XorShiftRng::new(2);
        // Values of ~1e-3 sit at the bottom of E4M3's subnormal range where
        // unit-scale resolution (2^-9) is catastrophically coarse.
        let x = Tensor2::randn(32, 32, 0.001, &mut rng);
        let f = Fp8Format::E4M3Gaudi2;
        // Unit scale: resolution wasted, error relatively large.
        let q_unit = quantize_matrix(&x, &[1.0], &[], f, QuantRounding::Nearest);
        let err_unit = q_unit.dequantize().mse(&x);
        // Max-abs scale: error much smaller.
        let s = crate::quant::act_scale_per_tensor(crate::tensor::abs_max(&x), 1.0, f);
        let q_scaled = quantize_matrix(&x, &[s], &[], f, QuantRounding::Nearest);
        // Descale before comparing.
        let deq = q_scaled.dequantize().map(|v| v * s);
        let err_scaled = deq.mse(&x);
        assert!(
            err_scaled < err_unit / 20.0,
            "unit {err_unit} scaled {err_scaled}"
        );
    }

    #[test]
    #[should_panic(expected = "row scales")]
    fn wrong_scale_length_panics() {
        let x = Tensor2::zeros(3, 2);
        quantize_matrix(&x, &[1.0, 1.0], &[], Fp8Format::E4M3, QuantRounding::Nearest);
    }
}
