//! The ⊗ multiply + descale (Eq. 2, Fig. 3: "scaling factors are multiplied
//! with one another, then applied to the GEMM results").

use super::qmatrix::QMatrix;
use crate::fp8::bf16::round_slice_to_bf16;
use crate::fp8::{DecodeTable, Fp8Gemm8x8};
use crate::tensor::Tensor2;

/// A diagonal scale: one factor for everything, or one per row/column.
#[derive(Clone, Debug)]
pub enum DiagScale {
    Scalar(f32),
    Vector(Vec<f32>),
}

impl DiagScale {
    #[inline]
    pub fn at(&self, i: usize) -> f32 {
        match self {
            DiagScale::Scalar(s) => *s,
            DiagScale::Vector(v) => v[i],
        }
    }

    pub fn len_or_1(&self) -> usize {
        match self {
            DiagScale::Scalar(_) => 1,
            DiagScale::Vector(v) => v.len(),
        }
    }

    pub fn to_vec(&self, n: usize) -> Vec<f32> {
        match self {
            DiagScale::Scalar(s) => vec![*s; n],
            DiagScale::Vector(v) => {
                assert_eq!(v.len(), n);
                v.clone()
            }
        }
    }
}

/// Scaled FP8 GEMM: `out = S_x (X̂ ⊗ Ŵᵀ) S_w`, f32 accumulation, output
/// rounded to bf16 when `bf16_out`.
///
/// * `xq` — quantized activations, N×C;
/// * `wq` — quantized weights, C'×C (so ⊗ is an NT product, row·row);
/// * `s_x` — per-row descale (scalar or N-vector);
/// * `s_w` — per-output-channel descale (scalar or C'-vector).
///
/// Uses the 256×256 product table: the inner loop is one table load + add
/// per element pair.
pub fn scaled_gemm(
    xq: &QMatrix,
    wq: &QMatrix,
    s_x: &DiagScale,
    s_w: &DiagScale,
    bf16_out: bool,
) -> Tensor2 {
    assert_eq!(xq.cols, wq.cols, "inner dims");
    let table = Fp8Gemm8x8::new(xq.format, wq.format);
    scaled_gemm_with_table(xq, wq, s_x, s_w, bf16_out, &table)
}

/// Like [`scaled_gemm`] but with a caller-provided product table (hot paths
/// build the 256 KiB table once).
pub fn scaled_gemm_with_table(
    xq: &QMatrix,
    wq: &QMatrix,
    s_x: &DiagScale,
    s_w: &DiagScale,
    bf16_out: bool,
    table: &Fp8Gemm8x8,
) -> Tensor2 {
    assert_eq!(xq.cols, wq.cols, "inner dims");
    let (n, c, k) = (xq.rows, xq.cols, wq.rows);
    let mut out = Tensor2::zeros(n, k);
    let kb = k / 4 * 4;
    for i in 0..n {
        let xr = xq.row(i);
        let sx = s_x.at(if s_x.len_or_1() == 1 { 0 } else { i });
        let orow = out.row_mut(i);
        let mut j = 0;
        while j < kb {
            let (w0, w1, w2, w3) = (wq.row(j), wq.row(j + 1), wq.row(j + 2), wq.row(j + 3));
            let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for t in 0..c {
                let xv = xr[t];
                a0 += table.mul(xv, w0[t]);
                a1 += table.mul(xv, w1[t]);
                a2 += table.mul(xv, w2[t]);
                a3 += table.mul(xv, w3[t]);
            }
            let sw = |jj: usize| s_w.at(if s_w.len_or_1() == 1 { 0 } else { jj });
            orow[j] = a0 * sx * sw(j);
            orow[j + 1] = a1 * sx * sw(j + 1);
            orow[j + 2] = a2 * sx * sw(j + 2);
            orow[j + 3] = a3 * sx * sw(j + 3);
            j += 4;
        }
        while j < k {
            let wr = wq.row(j);
            let mut acc = 0.0f32;
            for t in 0..c {
                acc += table.mul(xr[t], wr[t]);
            }
            orow[j] = acc * sx * s_w.at(if s_w.len_or_1() == 1 { 0 } else { j });
            j += 1;
        }
    }
    if bf16_out {
        round_slice_to_bf16(&mut out.data);
    }
    out
}

/// Plain-decode reference implementation (no product table, no blocking) —
/// the oracle the optimized path is tested against.
pub fn scaled_gemm_ref(
    xq: &QMatrix,
    wq: &QMatrix,
    s_x: &DiagScale,
    s_w: &DiagScale,
    bf16_out: bool,
) -> Tensor2 {
    assert_eq!(xq.cols, wq.cols, "inner dims");
    let tx = DecodeTable::new(xq.format);
    let tw = DecodeTable::new(wq.format);
    let mut out = Tensor2::zeros(xq.rows, wq.rows);
    for i in 0..xq.rows {
        for j in 0..wq.rows {
            let mut acc = 0.0f32;
            for t in 0..xq.cols {
                acc += tx.get(xq.row(i)[t]) * tw.get(wq.row(j)[t]);
            }
            let sx = s_x.at(if s_x.len_or_1() == 1 { 0 } else { i });
            let sw = s_w.at(if s_w.len_or_1() == 1 { 0 } else { j });
            out.set(i, j, acc * sx * sw);
        }
    }
    if bf16_out {
        round_slice_to_bf16(&mut out.data);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp8::Fp8Format;
    use crate::gemm::qmatrix::{quantize_matrix, QuantRounding};
    use crate::util::rng::XorShiftRng;

    fn q(x: &Tensor2, s: &[f32], f: Fp8Format) -> QMatrix {
        quantize_matrix(x, s, &[], f, QuantRounding::Nearest)
    }

    #[test]
    fn identity_on_representable_values() {
        // All values representable, unit scales → exact linear algebra.
        let x = Tensor2::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let w = Tensor2::from_vec(2, 2, vec![1.0, 1.0, 0.0, 2.0]);
        let f = Fp8Format::E4M3;
        let out = scaled_gemm(
            &q(&x, &[1.0], f),
            &q(&w, &[1.0], f),
            &DiagScale::Scalar(1.0),
            &DiagScale::Scalar(1.0),
            false,
        );
        assert_eq!(out.data, vec![3.0, 4.0, 7.0, 8.0]);
    }

    #[test]
    fn optimized_matches_reference_exactly() {
        let mut rng = XorShiftRng::new(21);
        for f in Fp8Format::ALL {
            let x = Tensor2::randn(9, 33, 1.0, &mut rng);
            let w = Tensor2::randn(7, 33, 0.2, &mut rng);
            let xq = q(&x, &[0.25], f);
            let wq = q(&w, &[0.5], f);
            let sx = DiagScale::Scalar(0.25);
            let sw = DiagScale::Vector((0..7).map(|i| 0.5 + i as f32 * 0.1).collect());
            let fast = scaled_gemm(&xq, &wq, &sx, &sw, true);
            let slow = scaled_gemm_ref(&xq, &wq, &sx, &sw, true);
            assert_eq!(fast.data, slow.data, "format {f:?}");
        }
    }

    #[test]
    fn scaled_quantized_gemm_close_to_f32_gemm() {
        // End-to-end Eq. 2 with sane scales must approximate Eq. 1 to FP8
        // accuracy (relative error ~ 2^-3 per element, averaged down by
        // accumulation).
        let mut rng = XorShiftRng::new(3);
        let x = Tensor2::randn(16, 128, 1.0, &mut rng);
        let w = Tensor2::randn(24, 128, 0.05, &mut rng);
        let f = Fp8Format::E4M3Gaudi2;
        let s_x = crate::quant::act_scale_per_tensor(crate::tensor::abs_max(&x), 1.0, f);
        let s_w = crate::quant::weight_scale_per_tensor(crate::tensor::abs_max(&w), f);
        let xq = q(&x, &[s_x], f);
        let wq = q(&w, &[s_w], f);
        let out = scaled_gemm(
            &xq,
            &wq,
            &DiagScale::Scalar(s_x),
            &DiagScale::Scalar(s_w),
            false,
        );
        let reference = crate::tensor::matmul_nt(&x, &w);
        // Relative Frobenius error.
        let err = (out.sub(&reference).fro_norm_sq() / reference.fro_norm_sq()).sqrt();
        assert!(err < 0.05, "relative error {err}");
        // And it is NOT bit-identical (it really quantized).
        assert_ne!(out.data, reference.data);
    }

    #[test]
    fn per_sample_descale_applied_per_row() {
        let x = Tensor2::from_vec(2, 1, vec![2.0, 2.0]);
        let w = Tensor2::from_vec(1, 1, vec![1.0]);
        let f = Fp8Format::E4M3;
        let xq = q(&x, &[1.0, 2.0], f); // second row quantized as 1.0
        let wq = q(&w, &[1.0], f);
        let out = scaled_gemm(
            &xq,
            &wq,
            &DiagScale::Vector(vec![1.0, 2.0]),
            &DiagScale::Scalar(1.0),
            false,
        );
        // Row 0: Q(2/1)*1 = 2; row 1: Q(2/2)*2 = 2 — descale restores.
        assert_eq!(out.data, vec![2.0, 2.0]);
    }

    #[test]
    fn bf16_output_rounding_applied() {
        let mut rng = XorShiftRng::new(5);
        let x = Tensor2::randn(4, 64, 1.0, &mut rng);
        let w = Tensor2::randn(4, 64, 1.0, &mut rng);
        let f = Fp8Format::E4M3;
        let xq = q(&x, &[1.0], f);
        let wq = q(&w, &[1.0], f);
        let s = DiagScale::Scalar(1.0);
        let out = scaled_gemm(&xq, &wq, &s, &s, true);
        for v in &out.data {
            // bf16 values have zero low 16 mantissa bits.
            assert_eq!(v.to_bits() & 0xFFFF, 0, "{v} not bf16");
        }
    }

    #[test]
    fn mixed_formats_e4m3_x_e5m2() {
        let mut rng = XorShiftRng::new(6);
        let x = Tensor2::randn(3, 16, 1.0, &mut rng);
        let w = Tensor2::randn(5, 16, 1.0, &mut rng);
        let xq = q(&x, &[1.0], Fp8Format::E4M3);
        let wq = q(&w, &[1.0], Fp8Format::E5M2);
        let s = DiagScale::Scalar(1.0);
        let fast = scaled_gemm(&xq, &wq, &s, &s, false);
        let slow = scaled_gemm_ref(&xq, &wq, &s, &s, false);
        assert_eq!(fast.data, slow.data);
    }
}
