//! `repro` CLI: serve / fleet / eval / simulate / bench subcommands.

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::coordinator::{Engine, EngineConfig, PreemptPolicy, SchedulePolicy};
use crate::eval::suite::{evaluate_model, paper_schemes, EvalConfig};
use crate::eval::tables::render_accuracy_table;
use crate::fp8::Fp8Format;
use crate::gaudisim::{decode_step_tflops, gemm_time_s, prefill_tflops, Device, E2eConfig, GemmConfig, ScalingKind};
use crate::model::config::{ModelConfig, ModelFamily};
use crate::obs::{chrome_trace_json, DEFAULT_TRACE_CAPACITY};
use crate::quant::KvDtype;
use crate::router::{
    FleetConfig, FleetRouter, ReplicaHandle, RoutePolicy, SimReplica, SimReplicaConfig,
};
use crate::server::workload::{ArrivalPattern, OpenLoopConfig, WorkloadConfig, WorkloadGen};
use crate::util::pool::Parallelism;

/// Parsed command line: subcommand + --key value flags.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub flags: HashMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        if argv.is_empty() {
            bail!("usage: repro <serve|fleet|trace|eval|simulate|gemm|info> [--flag value ...]");
        }
        let mut args = Args {
            command: argv[0].clone(),
            flags: HashMap::new(),
        };
        let mut i = 1;
        while i < argv.len() {
            let k = argv[i]
                .strip_prefix("--")
                .ok_or_else(|| anyhow::anyhow!("expected --flag, got {}", argv[i]))?;
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                args.flags.insert(k.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                args.flags.insert(k.to_string(), "true".to_string());
                i += 1;
            }
        }
        Ok(args)
    }

    pub fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

fn parse_kv_dtype(s: &str) -> Result<KvDtype> {
    KvDtype::parse(s).ok_or_else(|| {
        anyhow::anyhow!("unknown kv dtype {s:?} (f32|bf16|fp8|fp8_e4m3|fp8_e5m2|fp8_e4m3_gaudi2)")
    })
}

/// `--preempt-policy swap|recompute|auto` spellings.
fn parse_preempt_policy(s: &str) -> Result<PreemptPolicy> {
    PreemptPolicy::parse(s)
        .ok_or_else(|| anyhow::anyhow!("unknown --preempt-policy {s:?} (swap|recompute|auto)"))
}

/// `--spec-decode gamma=K` (or a bare `K`): draft tokens proposed per
/// speculative draft-verify round, 0 = off.
fn parse_spec_gamma(s: &str) -> Result<usize> {
    s.strip_prefix("gamma=")
        .unwrap_or(s)
        .parse()
        .map_err(|_| anyhow::anyhow!("unknown --spec-decode {s:?} (gamma=K, K >= 0)"))
}

/// Speculation and beam groups are mutually exclusive: the accept-prefix
/// verify rule is defined against greedy decode, not scored beams.
fn reject_spec_beam_combo(spec_gamma: usize, beam_width: usize) -> Result<()> {
    if spec_gamma > 0 && beam_width > 1 {
        bail!(
            "--spec-decode and --beam-width are mutually exclusive \
             (accept-prefix verification is defined for greedy decode)"
        );
    }
    Ok(())
}

/// `--prefix-cache on|off` spellings.
fn parse_on_off(flag: &str, s: &str) -> Result<bool> {
    match s {
        "on" | "true" | "1" => Ok(true),
        "off" | "false" | "0" => Ok(false),
        other => bail!("unknown --{flag} {other:?} (on|off)"),
    }
}

pub fn run_cli(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "serve" => cmd_serve(&args),
        "fleet" => cmd_fleet(&args),
        "trace" => cmd_trace(&args),
        "eval" => cmd_eval(&args),
        "simulate" => cmd_simulate(&args),
        "gemm" => cmd_gemm(&args),
        "info" => cmd_info(&args),
        other => bail!("unknown command {other:?} (serve|fleet|trace|eval|simulate|gemm|info)"),
    }
}

/// Serve a synthetic workload through the full stack and report metrics.
fn cmd_serve(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.get("artifacts", "artifacts"));
    let variant = args.get("variant", "fp8_pt");
    let mut cfg = EngineConfig::new(&dir, &variant);
    cfg.slots = args.get_usize("slots", 8);
    // Host KV store dtype; f32 is the exact-roundtrip default, fp8 serves
    // at 1/4 the KV bytes (the paper's configuration).
    cfg.kv_dtype = parse_kv_dtype(&args.get("kv-dtype", "f32"))?;
    // Shared-prefix KV cache + chunked prefill (off by default).
    if parse_on_off("prefix-cache", &args.get("prefix-cache", "off"))? {
        cfg.prefix_cache_bytes = Some(args.get_f64("prefix-cache-mb", 64.0) * 1e6);
    }
    cfg.prefill_chunk = args.get_usize("prefill-chunk", 0);
    // Host KV tier for slot preemption under overload (ISSUE 9);
    // 0 GB (the default) keeps the legacy reject-only admission.
    cfg.host_kv_bytes = args.get_f64("host-kv-gb", 0.0) * 1e9;
    cfg.preempt_policy = parse_preempt_policy(&args.get("preempt-policy", "auto"))?;
    // Draft-verify speculative decoding and width-k beam groups
    // (ISSUE 10). Speculation stays bit-identical to greedy decode; beam
    // groups fork the prompt KV and emit the best-scoring branch.
    cfg.spec_gamma = parse_spec_gamma(&args.get("spec-decode", "0"))?;
    cfg.beam_width = args.get_usize("beam-width", 1).max(1);
    reject_spec_beam_combo(cfg.spec_gamma, cfg.beam_width)?;
    // Scoped-pool workers for the host-side paged KV hot path;
    // 0 = auto (REPRO_NUM_THREADS or the machine's parallelism).
    cfg.kv_parallelism = match args.get_usize("kv-workers", 0) {
        0 => Parallelism::Auto,
        n => Parallelism::Fixed(n),
    };
    if args.get("policy", "prefill-first") == "decode-first" {
        cfg.policy = SchedulePolicy::DecodeFirst {
            min_decode: args.get_usize("min-decode", 2),
        };
    }
    let mut engine = Engine::new(cfg)?;
    let trace_out = args.get("trace-out", "");
    let metrics_out = args.get("metrics-out", "");
    if !trace_out.is_empty() {
        ReplicaHandle::enable_trace(
            &mut engine,
            0,
            args.get_usize("trace-capacity", DEFAULT_TRACE_CAPACITY),
        );
    }
    let wl = WorkloadConfig {
        requests: args.get_usize("requests", 16),
        ..Default::default()
    };
    println!("serving {} requests (variant={variant})", wl.requests);
    let reqs = WorkloadGen::new(wl).generate_all();
    for r in reqs {
        engine.submit(r);
    }
    let outs = engine.run_to_completion()?;
    for o in &outs {
        let text: String = o.tokens.iter().map(|t| *t as u8 as char).collect();
        println!(
            "  req {:>3}: prompt {:>3} + {:>3} tokens  ttft {:>6.1}ms  tpot {:>5.2}ms  {:?}",
            o.id,
            o.prompt_len,
            o.tokens.len(),
            o.ttft_s * 1e3,
            o.tpot_s * 1e3,
            text
        );
    }
    println!("{}", engine.metrics.report());
    if !trace_out.is_empty() {
        if let Some(tr) = ReplicaHandle::trace(&engine) {
            std::fs::write(&trace_out, chrome_trace_json(&[(engine.label(), tr)]))?;
            println!("wrote Chrome trace to {trace_out} (load in Perfetto / chrome://tracing)");
        }
    }
    if !metrics_out.is_empty() {
        std::fs::write(&metrics_out, engine.metrics.render_prometheus())?;
        println!("wrote Prometheus snapshot to {metrics_out}");
    }
    if engine.metrics.trace_events_dropped > 0 {
        eprintln!(
            "warning: trace ring buffer dropped {} events (raise --trace-capacity \
             for a complete timeline)",
            engine.metrics.trace_events_dropped
        );
    }
    Ok(())
}

/// Multi-replica fleet simulation: N simulated Gaudi engines behind the
/// router, driven by an open-loop workload.
///
/// Flags: --replicas N, --policy rr|least|affinity, --requests N,
/// --pattern burst|uniform|poisson|bursty, --rate REQ_PER_S, --slots N,
/// --model tiny|small|base|llama31-70b, --kv-dtype f32|bf16|fp8,
/// --prefix-cache on|off (radix shared-prefix KV cache per replica),
/// --prefill-chunk TOK (chunked-prefill tail granularity, 0 = one chunk),
/// --host-kv-gb GB (host KV tier for preemption swap-outs, 0 = off),
/// --preempt-policy swap|recompute|auto (how preempted sequences resume),
/// --spec-decode gamma=K (draft-verify speculative decoding, 0 = off),
/// --spec-acceptance A (modeled draft acceptance rate, default 0.8),
/// --beam-width K (width-k beam groups per request, 1 = off),
/// --prompt-min/--prompt-max TOK, --max-new TOK, --seed N,
/// --fleet-queue N, --json,
/// --trace-out PATH (per-request Chrome trace-event timeline, Perfetto-
/// loadable), --metrics-out PATH (Prometheus text snapshot),
/// --trace-capacity N (per-replica event ring size).
fn cmd_fleet(args: &Args) -> Result<()> {
    let replicas = args.get_usize("replicas", 4).max(1);
    let policy = RoutePolicy::parse(&args.get("policy", "least"))
        .ok_or_else(|| anyhow::anyhow!("unknown policy (rr|least|affinity)"))?;
    let requests = args.get_usize("requests", 64);
    let rate = args.get_f64("rate", 64.0);
    let pattern = ArrivalPattern::parse(&args.get("pattern", "poisson"), rate)
        .ok_or_else(|| anyhow::anyhow!("unknown pattern (burst|uniform|poisson|bursty)"))?;

    let mut sim_cfg = match args.get("model", "tiny").as_str() {
        "tiny" => SimReplicaConfig::synthetic_tiny(),
        "small" => {
            let mut c = SimReplicaConfig::synthetic_tiny();
            c.e2e.model = ModelConfig::synthetic_small(ModelFamily::Llama3);
            c
        }
        "base" => {
            let mut c = SimReplicaConfig::synthetic_tiny();
            c.e2e.model = ModelConfig::synthetic_base(ModelFamily::Llama3);
            c
        }
        "llama31-70b" => SimReplicaConfig::gaudi2_llama31_70b(),
        m => bail!("unknown model {m} (tiny|small|base|llama31-70b)"),
    };
    sim_cfg.slots = args.get_usize("slots", sim_cfg.slots).max(1);
    // KV storage dtype per replica; fp8 (the paper's serving config) is
    // the default the SimReplicaConfig constructors already carry.
    sim_cfg.kv_dtype = parse_kv_dtype(&args.get("kv-dtype", sim_cfg.kv_dtype.name()))?;
    // Shared-prefix KV cache + chunked prefill per replica. The affinity
    // policy's 16-token hash span equals the cache's block size, so sticky
    // routing and radix lookups agree on what "same prefix" means.
    sim_cfg.prefix_cache = parse_on_off("prefix-cache", &args.get("prefix-cache", "off"))?;
    sim_cfg.prefill_chunk = args.get_usize("prefill-chunk", 0);
    // Host KV tier per replica: under overload the replica preempts and
    // swaps instead of rejecting with KvExhausted (0 GB = legacy off).
    sim_cfg.host_kv_bytes = args.get_f64("host-kv-gb", 0.0) * 1e9;
    sim_cfg.preempt_policy = parse_preempt_policy(&args.get("preempt-policy", "auto"))?;
    // Draft-verify speculative decoding (single-stream fast path) and
    // width-k beam groups per replica (ISSUE 10).
    sim_cfg.spec_gamma = parse_spec_gamma(&args.get("spec-decode", "0"))?;
    sim_cfg.spec_acceptance = args.get_f64("spec-acceptance", 0.8).clamp(0.0, 1.0);
    sim_cfg.beam_width = args.get_usize("beam-width", 1).max(1);
    reject_spec_beam_combo(sim_cfg.spec_gamma, sim_cfg.beam_width)?;

    let mut router = FleetRouter::new(FleetConfig {
        policy,
        queue_capacity: args.get_usize("fleet-queue", 1024),
    });
    for i in 0..replicas {
        router.add_replica(Box::new(SimReplica::new(
            &format!("gaudi2-sim{i}"),
            sim_cfg.clone(),
        )?));
    }

    let max_new = args.get_usize("max-new", 16).max(1);
    let prompt_min = args.get_usize("prompt-min", 16).max(1);
    // Guard against --prompt-min > --prompt-max (WorkloadGen would
    // underflow the range width).
    let prompt_max = args.get_usize("prompt-max", 256).max(prompt_min);
    let open = OpenLoopConfig {
        workload: WorkloadConfig {
            requests,
            prompt_len_min: prompt_min,
            prompt_len_max: prompt_max,
            max_new_min: max_new,
            max_new_max: max_new,
            seed: args.get_usize("seed", 7) as u64,
        },
        pattern,
    };
    let json = args.get("json", "false") == "true";
    let trace_out = args.get("trace-out", "");
    let metrics_out = args.get("metrics-out", "");
    if !trace_out.is_empty() {
        router.enable_tracing(args.get_usize("trace-capacity", DEFAULT_TRACE_CAPACITY));
    }
    if !json {
        println!(
            "fleet: {replicas} replicas, policy={}, {requests} requests ({})",
            policy.label(),
            args.get("pattern", "poisson")
        );
    }
    let report = router.run_open_loop(open.generate())?;
    if json {
        // Machine-readable mode: exactly one JSON object on stdout (the
        // row already carries the rejected count).
        println!("{}", report.metrics.json_row(replicas, policy.label(), requests));
    } else {
        println!("{}", report.metrics.report());
        for r in &report.rejected {
            println!("  rejected req {}: {:?}", r.id, r.reason);
        }
    }
    if !trace_out.is_empty() {
        std::fs::write(&trace_out, router.chrome_trace())?;
        if !json {
            println!("wrote Chrome trace to {trace_out} (load in Perfetto / chrome://tracing)");
        }
    }
    if !metrics_out.is_empty() {
        std::fs::write(&metrics_out, report.metrics.render_prometheus())?;
        if !json {
            println!("wrote Prometheus snapshot to {metrics_out}");
        }
    }
    // Never silent on an incomplete timeline — and never on stdout, which
    // --json reserves for the single machine-readable row.
    if report.metrics.merged.trace_events_dropped > 0 {
        eprintln!(
            "warning: trace ring buffer dropped {} events (raise --trace-capacity \
             for a complete timeline)",
            report.metrics.merged.trace_events_dropped
        );
    }
    Ok(())
}

/// `repro trace` — a fleet run with tracing forced on. Identical flags to
/// `fleet`; `--trace-out` defaults to `trace.json` instead of off.
fn cmd_trace(args: &Args) -> Result<()> {
    let mut forced = args.clone();
    forced
        .flags
        .entry("trace-out".to_string())
        .or_insert_with(|| "trace.json".to_string());
    cmd_fleet(&forced)
}

/// Accuracy tables (Tables 2–4 analogues) on synthetic-statistics models.
fn cmd_eval(args: &Args) -> Result<()> {
    let family = match args.get("family", "llama2").as_str() {
        "llama2" => ModelFamily::Llama2,
        "llama3" => ModelFamily::Llama3,
        "mistral" => ModelFamily::Mistral,
        "mixtral" => ModelFamily::Mixtral,
        f => bail!("unknown family {f}"),
    };
    let ec = EvalConfig {
        eval_samples: args.get_usize("samples", 512),
        ..Default::default()
    };
    let schemes = paper_schemes(Fp8Format::E4M3Gaudi2);
    for scale in ["tiny", "small", "base"] {
        let cfg = match scale {
            "tiny" => ModelConfig::synthetic_tiny(family),
            "small" => ModelConfig::synthetic_small(family),
            _ => ModelConfig::synthetic_base(family),
        };
        let rows = evaluate_model(&cfg, &schemes, &ec);
        println!("{}", render_accuracy_table(&cfg.name, &rows));
    }
    Ok(())
}

/// Gaudi performance model queries (Tables 5–6 analogues).
fn cmd_simulate(args: &Args) -> Result<()> {
    let cfg = E2eConfig::llama31_70b_paper();
    match args.get("phase", "prefill").as_str() {
        "prefill" => {
            let seq = args.get_usize("seq", 2048);
            let r = prefill_tflops(&cfg, seq);
            println!(
                "prefill seq={seq}: {:.1} TFLOPS, MFU {:.1}%, {:.1} ms",
                r.tflops,
                r.mfu * 100.0,
                r.time_s * 1e3
            );
        }
        "decode" => {
            let b = args.get_usize("batch", 32);
            let s = args.get_usize("seq", 2048);
            let r = decode_step_tflops(&cfg, b, s);
            println!(
                "decode batch={b} seq={s}: {:.1} TFLOPS, {:.2} ms/step",
                r.tflops,
                r.time_s * 1e3
            );
        }
        p => bail!("unknown phase {p}"),
    }
    Ok(())
}

/// Single-GEMM roofline query (Table 1 analogue).
fn cmd_gemm(args: &Args) -> Result<()> {
    let m = args.get_usize("m", 4096);
    let k = args.get_usize("k", m);
    let n = args.get_usize("n", m);
    let dev = match args.get("device", "gaudi2").as_str() {
        "gaudi2" => Device::gaudi2(),
        "gaudi3" => Device::gaudi3(),
        d => bail!("unknown device {d}"),
    };
    for scaling in [
        ScalingKind::PerTensorHwPow2,
        ScalingKind::PerTensorSw,
        ScalingKind::PerChannel,
        ScalingKind::Bf16,
    ] {
        let r = gemm_time_s(&GemmConfig { m, k, n, scaling }, &dev);
        println!(
            "{:>28}: {:>7.1} TFLOPS  MFU {:>5.1}%  {}",
            scaling.label(),
            r.tflops,
            r.mfu * 100.0,
            if r.compute_bound { "compute-bound" } else { "memory-bound" }
        );
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.get("artifacts", "artifacts"));
    println!("gaudi-fp8 — FP8 inference reproduction (Intel Gaudi paper)");
    match crate::coordinator::engine::ModelMeta::load(&dir) {
        Ok(meta) => {
            println!(
                "model: vocab={} hidden={} layers={} heads={} kv_heads={} cache_t={}",
                meta.vocab, meta.hidden, meta.layers, meta.heads, meta.kv_heads, meta.cache_t
            );
            println!("prefill variants: {:?}", meta.prefill_variants);
            println!("decode  variants: {:?}", meta.decode_variants);
        }
        Err(e) => println!("artifacts not built: {e}"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_subcommand_and_flags() {
        let a = Args::parse(&[
            "serve".into(),
            "--variant".into(),
            "bf16".into(),
            "--requests".into(),
            "4".into(),
            "--fast".into(),
        ])
        .unwrap();
        assert_eq!(a.command, "serve");
        assert_eq!(a.get("variant", "x"), "bf16");
        assert_eq!(a.get_usize("requests", 0), 4);
        assert_eq!(a.get("fast", "false"), "true");
        assert_eq!(a.get("missing", "dflt"), "dflt");
    }

    #[test]
    fn parse_rejects_bare_words() {
        assert!(Args::parse(&["serve".into(), "oops".into()]).is_err());
        assert!(Args::parse(&[]).is_err());
    }

    #[test]
    fn simulate_and_gemm_run() {
        cmd_simulate(&Args::parse(&["simulate".into(), "--phase".into(), "prefill".into()]).unwrap())
            .unwrap();
        cmd_simulate(&Args::parse(&["simulate".into(), "--phase".into(), "decode".into()]).unwrap())
            .unwrap();
        cmd_gemm(&Args::parse(&["gemm".into(), "--m".into(), "1024".into()]).unwrap()).unwrap();
    }

    #[test]
    fn fleet_quick_runs() {
        // Small fleet run through the CLI path, every policy.
        for policy in ["rr", "least", "affinity"] {
            let args = Args::parse(&[
                "fleet".into(),
                "--replicas".into(),
                "2".into(),
                "--policy".into(),
                policy.into(),
                "--requests".into(),
                "8".into(),
                "--pattern".into(),
                "burst".into(),
                "--json".into(),
            ])
            .unwrap();
            cmd_fleet(&args).unwrap();
        }
    }

    #[test]
    fn kv_dtype_flag_parses_and_rejects() {
        assert_eq!(parse_kv_dtype("f32").unwrap(), KvDtype::F32);
        assert_eq!(parse_kv_dtype("fp8").unwrap(), KvDtype::FP8_DEFAULT);
        assert!(parse_kv_dtype("int8").is_err());
        // Through the fleet path end to end.
        let args = Args::parse(&[
            "fleet".into(),
            "--replicas".into(),
            "1".into(),
            "--requests".into(),
            "4".into(),
            "--pattern".into(),
            "burst".into(),
            "--kv-dtype".into(),
            "f32".into(),
            "--json".into(),
        ])
        .unwrap();
        cmd_fleet(&args).unwrap();
        let bad = Args::parse(&["fleet".into(), "--kv-dtype".into(), "int8".into()]).unwrap();
        assert!(cmd_fleet(&bad).is_err());
    }

    #[test]
    fn prefix_cache_flags_parse_and_run() {
        assert!(parse_on_off("prefix-cache", "on").unwrap());
        assert!(!parse_on_off("prefix-cache", "off").unwrap());
        assert!(parse_on_off("prefix-cache", "sideways").is_err());
        // Through the fleet path end to end, chunked.
        let args = Args::parse(&[
            "fleet".into(),
            "--replicas".into(),
            "2".into(),
            "--requests".into(),
            "8".into(),
            "--pattern".into(),
            "burst".into(),
            "--prefix-cache".into(),
            "on".into(),
            "--prefill-chunk".into(),
            "32".into(),
            "--json".into(),
        ])
        .unwrap();
        cmd_fleet(&args).unwrap();
        let bad = Args::parse(&["fleet".into(), "--prefix-cache".into(), "maybe".into()]).unwrap();
        assert!(cmd_fleet(&bad).is_err());
    }

    #[test]
    fn preempt_flags_parse_and_run() {
        assert_eq!(parse_preempt_policy("swap").unwrap(), PreemptPolicy::Swap);
        assert_eq!(
            parse_preempt_policy("recompute").unwrap(),
            PreemptPolicy::Recompute
        );
        assert_eq!(parse_preempt_policy("auto").unwrap(), PreemptPolicy::Auto);
        assert!(parse_preempt_policy("drop").is_err());
        // Through the fleet path end to end with the host tier enabled.
        let args = Args::parse(&[
            "fleet".into(),
            "--replicas".into(),
            "1".into(),
            "--requests".into(),
            "8".into(),
            "--pattern".into(),
            "burst".into(),
            "--host-kv-gb".into(),
            "1".into(),
            "--preempt-policy".into(),
            "auto".into(),
            "--json".into(),
        ])
        .unwrap();
        cmd_fleet(&args).unwrap();
        let bad =
            Args::parse(&["fleet".into(), "--preempt-policy".into(), "drop".into()]).unwrap();
        assert!(cmd_fleet(&bad).is_err());
    }

    #[test]
    fn spec_and_beam_flags_parse_and_run() {
        assert_eq!(parse_spec_gamma("gamma=4").unwrap(), 4);
        assert_eq!(parse_spec_gamma("2").unwrap(), 2);
        assert_eq!(parse_spec_gamma("0").unwrap(), 0);
        assert!(parse_spec_gamma("gamma=lots").is_err());
        // Speculation through the fleet path end to end.
        let spec = Args::parse(&[
            "fleet".into(),
            "--replicas".into(),
            "1".into(),
            "--requests".into(),
            "4".into(),
            "--pattern".into(),
            "burst".into(),
            "--spec-decode".into(),
            "gamma=2".into(),
            "--spec-acceptance".into(),
            "0.7".into(),
            "--json".into(),
        ])
        .unwrap();
        cmd_fleet(&spec).unwrap();
        // Beam groups through the fleet path end to end.
        let beam = Args::parse(&[
            "fleet".into(),
            "--replicas".into(),
            "1".into(),
            "--requests".into(),
            "4".into(),
            "--pattern".into(),
            "burst".into(),
            "--beam-width".into(),
            "2".into(),
            "--json".into(),
        ])
        .unwrap();
        cmd_fleet(&beam).unwrap();
        // Mutually exclusive: accept-prefix verification assumes greedy.
        let both = Args::parse(&[
            "fleet".into(),
            "--spec-decode".into(),
            "2".into(),
            "--beam-width".into(),
            "2".into(),
        ])
        .unwrap();
        assert!(cmd_fleet(&both).is_err());
    }

    #[test]
    fn fleet_rejects_unknown_policy_and_pattern() {
        let bad_policy =
            Args::parse(&["fleet".into(), "--policy".into(), "zigzag".into()]).unwrap();
        assert!(cmd_fleet(&bad_policy).is_err());
        let bad_pattern =
            Args::parse(&["fleet".into(), "--pattern".into(), "sawtooth".into()]).unwrap();
        assert!(cmd_fleet(&bad_pattern).is_err());
    }

    #[test]
    fn fleet_trace_and_metrics_outputs_are_written_and_parse() {
        let dir = std::env::temp_dir();
        let trace = dir.join("repro_cli_test_trace.json");
        let prom = dir.join("repro_cli_test_metrics.prom");
        let args = Args::parse(&[
            "trace".into(),
            "--replicas".into(),
            "2".into(),
            "--requests".into(),
            "8".into(),
            "--pattern".into(),
            "burst".into(),
            "--trace-out".into(),
            trace.to_str().unwrap().into(),
            "--metrics-out".into(),
            prom.to_str().unwrap().into(),
            "--json".into(),
        ])
        .unwrap();
        cmd_trace(&args).unwrap();
        let text = std::fs::read_to_string(&trace).unwrap();
        let j = crate::util::json::Json::parse(&text).expect("trace must be valid JSON");
        let events = j
            .get("traceEvents")
            .and_then(crate::util::json::Json::as_arr)
            .expect("traceEvents array");
        assert!(!events.is_empty());
        let pm = std::fs::read_to_string(&prom).unwrap();
        assert!(pm.contains("repro_fleet_replicas 2"), "{pm}");
        assert!(pm.contains("repro_mfu"), "{pm}");
        let _ = std::fs::remove_file(&trace);
        let _ = std::fs::remove_file(&prom);
    }

    #[test]
    fn eval_quick_runs() {
        let args = Args::parse(&[
            "eval".into(),
            "--family".into(),
            "llama2".into(),
            "--samples".into(),
            "32".into(),
        ])
        .unwrap();
        cmd_eval(&args).unwrap();
    }
}
