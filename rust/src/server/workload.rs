//! Synthetic serving workloads: request streams with configurable prompt
//! lengths, generation budgets, and arrival pattern — the driver for the
//! e2e serving experiments.

use crate::coordinator::Request;
use crate::util::rng::XorShiftRng;

#[derive(Clone, Debug)]
pub struct WorkloadConfig {
    pub requests: usize,
    pub prompt_len_min: usize,
    pub prompt_len_max: usize,
    pub max_new_min: usize,
    pub max_new_max: usize,
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        Self {
            requests: 16,
            prompt_len_min: 8,
            prompt_len_max: 48,
            max_new_min: 8,
            max_new_max: 32,
            seed: 7,
        }
    }
}

/// Generates byte-level prompts that look like the training corpus
/// (lowercase words + spaces), so the served byte-LM sees in-distribution
/// inputs.
pub struct WorkloadGen {
    cfg: WorkloadConfig,
    rng: XorShiftRng,
    next_id: u64,
}

impl WorkloadGen {
    pub fn new(cfg: WorkloadConfig) -> Self {
        Self {
            rng: XorShiftRng::new(cfg.seed),
            cfg,
            next_id: 0,
        }
    }

    fn word(&mut self, out: &mut Vec<i32>) {
        let len = 2 + self.rng.below(7);
        for _ in 0..len {
            out.push((b'a' + self.rng.below(26) as u8) as i32);
        }
    }

    pub fn next_request(&mut self) -> Request {
        let target =
            self.cfg.prompt_len_min + self.rng.below(self.cfg.prompt_len_max - self.cfg.prompt_len_min + 1);
        let mut prompt = Vec::with_capacity(target + 8);
        while prompt.len() < target {
            self.word(&mut prompt);
            prompt.push(b' ' as i32);
        }
        prompt.truncate(target.max(1));
        let max_new = self.cfg.max_new_min
            + self.rng.below(self.cfg.max_new_max - self.cfg.max_new_min + 1);
        let id = self.next_id;
        self.next_id += 1;
        Request::new(id, prompt, max_new)
    }

    pub fn generate_all(&mut self) -> Vec<Request> {
        (0..self.cfg.requests).map(|_| self.next_request()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_count_with_bounds() {
        let cfg = WorkloadConfig {
            requests: 10,
            prompt_len_min: 5,
            prompt_len_max: 12,
            max_new_min: 3,
            max_new_max: 6,
            seed: 1,
        };
        let reqs = WorkloadGen::new(cfg).generate_all();
        assert_eq!(reqs.len(), 10);
        for r in &reqs {
            assert!((5..=12).contains(&r.prompt.len()), "{}", r.prompt.len());
            assert!((3..=6).contains(&r.max_new_tokens));
            assert!(r
                .prompt
                .iter()
                .all(|t| (*t as u8 as char).is_ascii_lowercase() || *t == b' ' as i32));
        }
        // ids unique and ascending
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = WorkloadConfig::default();
        let a = WorkloadGen::new(cfg.clone()).generate_all();
        let b = WorkloadGen::new(cfg).generate_all();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
        }
    }
}
