//! Synthetic serving workloads: request streams with configurable prompt
//! lengths, generation budgets, and arrival pattern — the driver for the
//! e2e serving experiments.

use crate::coordinator::Request;
use crate::util::rng::XorShiftRng;

#[derive(Clone, Debug)]
pub struct WorkloadConfig {
    pub requests: usize,
    pub prompt_len_min: usize,
    pub prompt_len_max: usize,
    pub max_new_min: usize,
    pub max_new_max: usize,
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        Self {
            requests: 16,
            prompt_len_min: 8,
            prompt_len_max: 48,
            max_new_min: 8,
            max_new_max: 32,
            seed: 7,
        }
    }
}

/// Generates byte-level prompts that look like the training corpus
/// (lowercase words + spaces), so the served byte-LM sees in-distribution
/// inputs.
pub struct WorkloadGen {
    cfg: WorkloadConfig,
    rng: XorShiftRng,
    next_id: u64,
}

impl WorkloadGen {
    pub fn new(cfg: WorkloadConfig) -> Self {
        Self {
            rng: XorShiftRng::new(cfg.seed),
            cfg,
            next_id: 0,
        }
    }

    fn word(&mut self, out: &mut Vec<i32>) {
        let len = 2 + self.rng.below(7);
        for _ in 0..len {
            out.push((b'a' + self.rng.below(26) as u8) as i32);
        }
    }

    pub fn next_request(&mut self) -> Request {
        let target =
            self.cfg.prompt_len_min + self.rng.below(self.cfg.prompt_len_max - self.cfg.prompt_len_min + 1);
        let mut prompt = Vec::with_capacity(target + 8);
        while prompt.len() < target {
            self.word(&mut prompt);
            prompt.push(b' ' as i32);
        }
        prompt.truncate(target.max(1));
        let max_new = self.cfg.max_new_min
            + self.rng.below(self.cfg.max_new_max - self.cfg.max_new_min + 1);
        let id = self.next_id;
        self.next_id += 1;
        Request::new(id, prompt, max_new)
    }

    pub fn generate_all(&mut self) -> Vec<Request> {
        (0..self.cfg.requests).map(|_| self.next_request()).collect()
    }
}

/// Arrival process for open-loop (rate-driven) workloads: the client issues
/// requests on its own schedule regardless of server progress, which is
/// what exposes queueing (closed-loop drivers never build a backlog).
#[derive(Clone, Debug)]
pub enum ArrivalPattern {
    /// Everything at t = 0 (saturation / makespan experiments).
    Burst,
    /// Constant inter-arrival gap of 1/rate seconds.
    Uniform { rate_per_s: f64 },
    /// Poisson process: exponential inter-arrival times at `rate_per_s`.
    Poisson { rate_per_s: f64 },
    /// `size` back-to-back arrivals, then a `gap_s` pause (bursty traffic).
    Bursty { size: usize, gap_s: f64 },
}

impl ArrivalPattern {
    pub fn parse(s: &str, rate_per_s: f64) -> Option<ArrivalPattern> {
        match s {
            "burst" => Some(ArrivalPattern::Burst),
            "uniform" => Some(ArrivalPattern::Uniform { rate_per_s }),
            "poisson" => Some(ArrivalPattern::Poisson { rate_per_s }),
            "bursty" => Some(ArrivalPattern::Bursty {
                size: 8,
                gap_s: if rate_per_s > 0.0 { 8.0 / rate_per_s } else { 1.0 },
            }),
            _ => None,
        }
    }
}

/// Open-loop workload: request content from [`WorkloadGen`], arrival times
/// from an [`ArrivalPattern`]. Deterministic per seed.
#[derive(Clone, Debug)]
pub struct OpenLoopConfig {
    pub workload: WorkloadConfig,
    pub pattern: ArrivalPattern,
}

impl OpenLoopConfig {
    pub fn generate(&self) -> Vec<crate::router::TimedRequest> {
        let reqs = WorkloadGen::new(self.workload.clone()).generate_all();
        let mut rng = XorShiftRng::new(self.workload.seed ^ 0x9e37_79b9_7f4a_7c15);
        let mut t = 0.0f64;
        let mut out = Vec::with_capacity(reqs.len());
        for (i, req) in reqs.into_iter().enumerate() {
            let arrival_s = match self.pattern {
                ArrivalPattern::Burst => 0.0,
                ArrivalPattern::Uniform { rate_per_s } => {
                    if i > 0 && rate_per_s > 0.0 {
                        t += 1.0 / rate_per_s;
                    }
                    t
                }
                ArrivalPattern::Poisson { rate_per_s } => {
                    if i > 0 && rate_per_s > 0.0 {
                        // Inverse-CDF exponential; clamp away from ln(0).
                        let u = (1.0 - rng.next_f64()).max(1e-12);
                        t += -u.ln() / rate_per_s;
                    }
                    t
                }
                ArrivalPattern::Bursty { size, gap_s } => {
                    let burst = i / size.max(1);
                    burst as f64 * gap_s
                }
            };
            out.push(crate::router::TimedRequest::new(req, arrival_s));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_count_with_bounds() {
        let cfg = WorkloadConfig {
            requests: 10,
            prompt_len_min: 5,
            prompt_len_max: 12,
            max_new_min: 3,
            max_new_max: 6,
            seed: 1,
        };
        let reqs = WorkloadGen::new(cfg).generate_all();
        assert_eq!(reqs.len(), 10);
        for r in &reqs {
            assert!((5..=12).contains(&r.prompt.len()), "{}", r.prompt.len());
            assert!((3..=6).contains(&r.max_new_tokens));
            assert!(r
                .prompt
                .iter()
                .all(|t| (*t as u8 as char).is_ascii_lowercase() || *t == b' ' as i32));
        }
        // ids unique and ascending
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = WorkloadConfig::default();
        let a = WorkloadGen::new(cfg.clone()).generate_all();
        let b = WorkloadGen::new(cfg).generate_all();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
        }
    }

    #[test]
    fn open_loop_patterns_are_monotone_and_deterministic() {
        let wl = WorkloadConfig {
            requests: 24,
            ..Default::default()
        };
        for pattern in [
            ArrivalPattern::Burst,
            ArrivalPattern::Uniform { rate_per_s: 10.0 },
            ArrivalPattern::Poisson { rate_per_s: 10.0 },
            ArrivalPattern::Bursty { size: 8, gap_s: 2.0 },
        ] {
            let cfg = OpenLoopConfig {
                workload: wl.clone(),
                pattern: pattern.clone(),
            };
            let a = cfg.generate();
            let b = cfg.generate();
            assert_eq!(a.len(), 24);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.arrival_s, y.arrival_s, "{pattern:?} not deterministic");
                assert_eq!(x.req.prompt, y.req.prompt);
            }
            for w in a.windows(2) {
                assert!(
                    w[1].arrival_s >= w[0].arrival_s,
                    "{pattern:?} arrivals must be non-decreasing"
                );
            }
        }
    }

    #[test]
    fn bursty_pattern_groups_arrivals() {
        let cfg = OpenLoopConfig {
            workload: WorkloadConfig {
                requests: 16,
                ..Default::default()
            },
            pattern: ArrivalPattern::Bursty { size: 8, gap_s: 3.0 },
        };
        let reqs = cfg.generate();
        assert!(reqs[..8].iter().all(|r| r.arrival_s == 0.0));
        assert!(reqs[8..].iter().all(|r| r.arrival_s == 3.0));
    }

    #[test]
    fn uniform_rate_spacing() {
        let cfg = OpenLoopConfig {
            workload: WorkloadConfig {
                requests: 4,
                ..Default::default()
            },
            pattern: ArrivalPattern::Uniform { rate_per_s: 4.0 },
        };
        let reqs = cfg.generate();
        let times: Vec<f64> = reqs.iter().map(|r| r.arrival_s).collect();
        assert_eq!(times, vec![0.0, 0.25, 0.5, 0.75]);
    }
}
