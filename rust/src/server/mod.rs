//! CLI plumbing for the `repro` binary (clap is unreachable offline; a
//! small hand-rolled parser covers the subcommand surface).

pub mod cli;
pub mod workload;

pub use cli::{run_cli, Args};
pub use workload::{WorkloadConfig, WorkloadGen};
