//! `repro` — leader entrypoint for the gaudi-fp8 reproduction.
//!
//! Subcommands:
//!   serve     — run the serving engine on a synthetic workload (artifacts
//!               required: `make artifacts`)
//!   fleet     — multi-replica fleet simulation: N simulated Gaudi engines
//!               behind the load-balancing router (no artifacts needed)
//!   eval      — Tables 2–4 accuracy analogues on synthetic-statistics models
//!   simulate  — Gaudi performance model queries (Tables 5–6)
//!   gemm      — single-GEMM roofline query (Table 1)
//!   info      — artifact/manifest inspection

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = gaudi_fp8::server::run_cli(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
