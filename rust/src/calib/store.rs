//! Persisted measurement files: calibration stats keyed by layer name,
//! serialized as JSON (consumed by `aot.py` to bake static scales into the
//! HLO artifacts, and by the Rust eval harness).

use std::collections::BTreeMap;
use std::path::Path;

use super::collector::ActStats;
use crate::util::json::Json;

/// A named collection of per-site activation statistics.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MeasurementStore {
    pub entries: BTreeMap<String, ActStats>,
}

impl MeasurementStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, site: &str, stats: ActStats) {
        self.entries.insert(site.to_string(), stats);
    }

    pub fn get(&self, site: &str) -> Option<&ActStats> {
        self.entries.get(site)
    }

    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        for (k, s) in &self.entries {
            obj.insert(
                k.clone(),
                Json::obj(vec![
                    ("r_x", Json::Num(s.r_x as f64)),
                    ("r_x_cols", Json::arr_f32(&s.r_x_cols)),
                    ("min", Json::Num(s.min as f64)),
                    ("max", Json::Num(s.max as f64)),
                    ("abs_mean", Json::Num(s.abs_mean as f64)),
                    ("samples", Json::Num(s.samples as f64)),
                ]),
            );
        }
        Json::Obj(obj)
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        let Json::Obj(map) = j else {
            return Err("expected object".into());
        };
        let mut out = Self::new();
        for (k, v) in map {
            let stats = ActStats {
                r_x: v
                    .get("r_x")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| format!("{k}: missing r_x"))? as f32,
                r_x_cols: v
                    .get("r_x_cols")
                    .and_then(Json::as_f32_vec)
                    .ok_or_else(|| format!("{k}: missing r_x_cols"))?,
                min: v.get("min").and_then(Json::as_f64).unwrap_or(0.0) as f32,
                max: v.get("max").and_then(Json::as_f64).unwrap_or(0.0) as f32,
                abs_mean: v.get("abs_mean").and_then(Json::as_f64).unwrap_or(0.0) as f32,
                samples: v.get("samples").and_then(Json::as_f64).unwrap_or(0.0) as usize,
                histogram: None,
            };
            out.entries.insert(k.clone(), stats);
        }
        Ok(out)
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
    }

    pub fn load(path: &Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        Self::from_json(&Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats() -> ActStats {
        ActStats {
            r_x: 3.5,
            r_x_cols: vec![1.0, 3.5, 0.25],
            min: -3.5,
            max: 2.0,
            abs_mean: 0.8,
            samples: 128,
            histogram: None,
        }
    }

    #[test]
    fn json_roundtrip() {
        let mut st = MeasurementStore::new();
        st.insert("layers.0.QProj", stats());
        st.insert("layers.1.Down", stats());
        let back = MeasurementStore::from_json(&st.to_json()).unwrap();
        assert_eq!(st, back);
    }

    #[test]
    fn file_roundtrip() {
        let mut st = MeasurementStore::new();
        st.insert("site", stats());
        let dir = std::env::temp_dir().join("gaudi_fp8_store_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("meas.json");
        st.save(&p).unwrap();
        let back = MeasurementStore::load(&p).unwrap();
        assert_eq!(st, back);
    }

    #[test]
    fn malformed_rejected() {
        assert!(MeasurementStore::from_json(&Json::Num(1.0)).is_err());
        let j = Json::parse(r#"{"site": {"min": 0}}"#).unwrap();
        assert!(MeasurementStore::from_json(&j).is_err()); // missing r_x
    }
}
