//! |x| histogram — the calibration statistic §3.1 mentions for
//! percentile-style scale selection.

/// Fixed-range linear histogram over [0, max_abs); the last bin also counts
/// overflow.
#[derive(Clone, Debug, PartialEq)]
pub struct Histogram {
    pub counts: Vec<u64>,
    pub max_abs: f32,
}

impl Histogram {
    pub fn new(bins: usize, max_abs: f32) -> Self {
        assert!(bins > 0 && max_abs > 0.0);
        Self {
            counts: vec![0; bins],
            max_abs,
        }
    }

    pub fn record(&mut self, abs_value: f32) {
        let bins = self.counts.len();
        let idx = ((abs_value / self.max_abs) * bins as f32) as usize;
        self.counts[idx.min(bins - 1)] += 1;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Smallest |x| bound such that at least `q` (0..=1) of mass is below it
    /// — used for percentile-clipping scales.
    pub fn quantile(&self, q: f64) -> f32 {
        let total = self.total();
        if total == 0 {
            return 0.0;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return (i + 1) as f32 / self.counts.len() as f32 * self.max_abs;
            }
        }
        self.max_abs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_quantile() {
        let mut h = Histogram::new(100, 1.0);
        for i in 0..100 {
            h.record(i as f32 / 100.0);
        }
        assert_eq!(h.total(), 100);
        let q50 = h.quantile(0.5);
        assert!((q50 - 0.5).abs() < 0.02, "{q50}");
        let q99 = h.quantile(0.99);
        assert!(q99 >= 0.98, "{q99}");
    }

    #[test]
    fn overflow_lands_in_last_bin() {
        let mut h = Histogram::new(4, 1.0);
        h.record(123.0);
        assert_eq!(h.counts[3], 1);
    }

    #[test]
    fn empty_quantile_zero() {
        let h = Histogram::new(4, 1.0);
        assert_eq!(h.quantile(0.9), 0.0);
    }
}
