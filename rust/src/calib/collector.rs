//! Streaming activation-statistics collectors.

use super::histogram::Histogram;
use crate::tensor::Tensor2;

/// Final calibration statistics for one tensor site (the measurements §3.1
/// enumerates).
#[derive(Clone, Debug, PartialEq)]
pub struct ActStats {
    /// Eq. 8a: per-tensor max-abs over all calibration batches.
    pub r_x: f32,
    /// Eq. 8b: per-channel max-abs (length C).
    pub r_x_cols: Vec<f32>,
    /// min / max over everything.
    pub min: f32,
    pub max: f32,
    /// Mean absolute value (running).
    pub abs_mean: f32,
    /// Number of samples (rows) observed.
    pub samples: usize,
    /// Optional histogram of |x|.
    pub histogram: Option<Histogram>,
}

/// Accumulates statistics across calibration batches for one site.
#[derive(Clone, Debug)]
pub struct ActObserver {
    channels: usize,
    r_x: f32,
    r_x_cols: Vec<f32>,
    min: f32,
    max: f32,
    abs_sum: f64,
    count: usize,
    samples: usize,
    histogram: Option<Histogram>,
}

impl ActObserver {
    pub fn new(channels: usize) -> Self {
        Self {
            channels,
            r_x: 0.0,
            r_x_cols: vec![0.0; channels],
            min: f32::INFINITY,
            max: f32::NEG_INFINITY,
            abs_sum: 0.0,
            count: 0,
            samples: 0,
            histogram: None,
        }
    }

    pub fn with_histogram(mut self, bins: usize, max_abs: f32) -> Self {
        self.histogram = Some(Histogram::new(bins, max_abs));
        self
    }

    /// Observe one batch of activations (N×C).
    pub fn observe(&mut self, x: &Tensor2) {
        assert_eq!(x.cols, self.channels, "channel mismatch");
        self.samples += x.rows;
        for r in 0..x.rows {
            for (c, &v) in x.row(r).iter().enumerate() {
                let a = v.abs();
                if a > self.r_x {
                    self.r_x = a;
                }
                if a > self.r_x_cols[c] {
                    self.r_x_cols[c] = a;
                }
                if v < self.min {
                    self.min = v;
                }
                if v > self.max {
                    self.max = v;
                }
                self.abs_sum += a as f64;
                self.count += 1;
                if let Some(h) = &mut self.histogram {
                    h.record(a);
                }
            }
        }
    }

    pub fn finalize(&self) -> ActStats {
        ActStats {
            r_x: self.r_x,
            r_x_cols: self.r_x_cols.clone(),
            min: if self.min.is_finite() { self.min } else { 0.0 },
            max: if self.max.is_finite() { self.max } else { 0.0 },
            abs_mean: if self.count > 0 {
                (self.abs_sum / self.count as f64) as f32
            } else {
                0.0
            },
            samples: self.samples,
            histogram: self.histogram.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShiftRng;

    #[test]
    fn single_batch_matches_direct_reductions() {
        let mut rng = XorShiftRng::new(1);
        let x = Tensor2::randn(32, 16, 2.0, &mut rng);
        let mut obs = ActObserver::new(16);
        obs.observe(&x);
        let s = obs.finalize();
        assert_eq!(s.r_x, crate::tensor::abs_max(&x));
        assert_eq!(s.r_x_cols, crate::tensor::col_abs_max(&x));
        let (lo, hi) = crate::tensor::stats::min_max(&x);
        assert_eq!((s.min, s.max), (lo, hi));
        assert_eq!(s.samples, 32);
    }

    #[test]
    fn multi_batch_accumulates_max() {
        let mut obs = ActObserver::new(2);
        obs.observe(&Tensor2::from_vec(1, 2, vec![1.0, -3.0]));
        obs.observe(&Tensor2::from_vec(2, 2, vec![5.0, 0.5, -0.1, 2.0]));
        let s = obs.finalize();
        assert_eq!(s.r_x, 5.0);
        assert_eq!(s.r_x_cols, vec![5.0, 3.0]);
        assert_eq!(s.samples, 3);
        assert_eq!(s.min, -3.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn abs_mean_running_average() {
        let mut obs = ActObserver::new(1);
        obs.observe(&Tensor2::from_vec(2, 1, vec![2.0, -4.0]));
        obs.observe(&Tensor2::from_vec(2, 1, vec![0.0, 6.0]));
        assert_eq!(obs.finalize().abs_mean, 3.0);
    }

    #[test]
    fn empty_observer_finalizes_safely() {
        let s = ActObserver::new(4).finalize();
        assert_eq!(s.r_x, 0.0);
        assert_eq!(s.abs_mean, 0.0);
        assert_eq!(s.samples, 0);
    }

    #[test]
    fn histogram_populated() {
        let mut obs = ActObserver::new(1).with_histogram(10, 10.0);
        obs.observe(&Tensor2::from_vec(3, 1, vec![0.5, 5.5, 9.9]));
        let s = obs.finalize();
        let h = s.histogram.unwrap();
        assert_eq!(h.total(), 3);
        assert_eq!(h.counts[0], 1);
        assert_eq!(h.counts[5], 1);
        assert_eq!(h.counts[9], 1);
    }
}
