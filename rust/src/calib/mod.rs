//! Calibration (paper §3.1): run typical inputs through the model and
//! measure activation statistics — per-tensor, per-channel, or per-sample
//! max-abs, min/max, mean-abs, or a histogram.
//!
//! The static scaling methods (§2.3.1) consume these offline statistics;
//! dynamic (JiT) scaling measures Eq. 9 at runtime instead.

pub mod collector;
pub mod histogram;
pub mod store;

pub use collector::{ActObserver, ActStats};
pub use histogram::Histogram;
pub use store::MeasurementStore;
