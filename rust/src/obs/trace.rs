//! Bounded per-replica trace recorder + Chrome trace-event JSON export.
//!
//! Every replica (wall-clock engine or virtual-clock simulation) owns one
//! [`TraceRecorder`]: a bounded buffer of typed lifecycle events stamped
//! by a [`Clock`]. The exporter renders a fleet of recorders as Chrome
//! trace-event JSON — loadable in Perfetto / `chrome://tracing` — with
//! one *process* per replica and one *thread* (track) per request, plus a
//! `steps` track carrying the device-level prefill/decode spans.
//!
//! The buffer drops the **newest** events once full (and counts them in
//! [`TraceRecorder::dropped`], per event kind in
//! [`TraceRecorder::dropped_by_kind`]) rather than overwriting the oldest:
//! a truncated tail loses recent detail but never tears an
//! already-recorded span in half. **Terminal events are exempt**: `Retire`
//! and `Reject` are always retained even past capacity — they are the sole
//! source of the synthesized per-request spans, and overload (the very
//! condition that fills the ring) is exactly when their latency payloads
//! matter most. The memory bound stays firm: capacity + one terminal
//! event per request.

use super::clock::Clock;
use crate::coordinator::RequestId;

/// Default per-replica event capacity (~64k events ≈ a few MB).
pub const DEFAULT_TRACE_CAPACITY: usize = 65_536;

/// Typed lifecycle event payloads — the event taxonomy.
#[derive(Clone, Debug, PartialEq)]
pub enum TraceEventKind {
    /// Request left the queue and was admitted for prefill.
    Admit {
        /// Seconds it waited in the queue before admission.
        queued_s: f64,
    },
    /// One prefill chunk (or a whole cold prefill) executed.
    PrefillChunk { tokens: usize, mfu: f64 },
    /// One decode step over a compiled group.
    DecodeStep {
        batch: usize,
        mfu: f64,
        kv_bytes: u64,
        /// Block-pool occupancy in [0, 1] right after the step.
        pool_occupancy: f64,
    },
    /// Admission found `tokens` of the prompt resident in the prefix cache.
    PrefixHit { tokens: usize },
    /// Copy-on-write block clones performed (shared block went private).
    CowCopy { blocks: u64 },
    /// Prefix-cache blocks reclaimed under admission pressure.
    Evict { blocks: u64 },
    /// Request finished; carries the latency summary used to synthesize
    /// its whole-request span in the export.
    Retire {
        generated: usize,
        ttft_s: f64,
        tpot_s: f64,
        total_s: f64,
    },
    /// Request completed unservable / rejected at the replica.
    Reject { reason: String },
    /// A running sequence was preempted under pool pressure; `swap` says
    /// whether its blocks moved to the host tier (vs dropped for
    /// re-prefill on resume).
    Preempt { blocks: u64, swap: bool },
    /// Blocks (codes + scales together) written out to the host KV tier.
    SwapOut { blocks: u64, bytes: u64 },
    /// Blocks restored from the host KV tier into the pool.
    SwapIn { blocks: u64, bytes: u64 },
    /// The draft model proposed `gamma` tokens for a speculative round.
    DraftPropose { gamma: usize },
    /// A speculative verify round finished: `accepted` of the proposals
    /// matched the target's greedy choice, `emitted` tokens entered the
    /// stream (accepted prefix + the correction/bonus token).
    VerifyAccept { accepted: usize, emitted: usize },
    /// Rejected speculative tokens were rolled back by block truncation;
    /// `tokens` rejected positions dropped, `blocks` now-dead tail
    /// blocks released.
    Rollback { tokens: usize, blocks: u64 },
}

impl TraceEventKind {
    pub fn name(&self) -> &'static str {
        match self {
            TraceEventKind::Admit { .. } => "admit",
            TraceEventKind::PrefillChunk { .. } => "prefill_chunk",
            TraceEventKind::DecodeStep { .. } => "decode_step",
            TraceEventKind::PrefixHit { .. } => "prefix_hit",
            TraceEventKind::CowCopy { .. } => "cow_copy",
            TraceEventKind::Evict { .. } => "evict",
            TraceEventKind::Retire { .. } => "retire",
            TraceEventKind::Reject { .. } => "reject",
            TraceEventKind::Preempt { .. } => "preempt",
            TraceEventKind::SwapOut { .. } => "swap_out",
            TraceEventKind::SwapIn { .. } => "swap_in",
            TraceEventKind::DraftPropose { .. } => "draft_propose",
            TraceEventKind::VerifyAccept { .. } => "verify_accept",
            TraceEventKind::Rollback { .. } => "rollback",
        }
    }

    /// Terminal events survive a full ring: they carry the only copy of
    /// the per-request latency summary the exporter synthesizes spans
    /// from, and there is at most one per request (bounded growth).
    fn always_retained(&self) -> bool {
        matches!(
            self,
            TraceEventKind::Retire { .. } | TraceEventKind::Reject { .. }
        )
    }
}

/// One recorded event: a timestamp (+ optional duration for spans) on the
/// replica's clock, an optional request id, and the typed payload.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub ts_s: f64,
    /// 0.0 for instants; > 0 for complete spans.
    pub dur_s: f64,
    pub request: Option<RequestId>,
    pub kind: TraceEventKind,
}

/// Bounded event buffer owned by one replica.
#[derive(Debug)]
pub struct TraceRecorder {
    replica: usize,
    clock: Clock,
    capacity: usize,
    events: Vec<TraceEvent>,
    dropped: u64,
    dropped_by_kind: std::collections::BTreeMap<&'static str, u64>,
}

impl TraceRecorder {
    pub fn new(replica: usize, clock: Clock) -> Self {
        Self::with_capacity(replica, clock, DEFAULT_TRACE_CAPACITY)
    }

    pub fn with_capacity(replica: usize, clock: Clock, capacity: usize) -> Self {
        Self {
            replica,
            clock,
            capacity: capacity.max(1),
            events: Vec::new(),
            dropped: 0,
            dropped_by_kind: std::collections::BTreeMap::new(),
        }
    }

    pub fn replica(&self) -> usize {
        self.replica
    }

    /// Current time on this recorder's clock.
    pub fn now_s(&self) -> f64 {
        self.clock.now_s()
    }

    /// Advance the underlying virtual clock (no-op on wall clocks).
    pub fn set_virtual_now(&mut self, now_s: f64) {
        self.clock.set_virtual(now_s);
    }

    /// Record an instant event stamped "now".
    pub fn record(&mut self, request: Option<RequestId>, kind: TraceEventKind) {
        let ts = self.now_s();
        self.record_at(ts, request, kind);
    }

    /// Record an instant event at an explicit timestamp (virtual-clock
    /// replicas stamp events at the modeled time, not the call time).
    pub fn record_at(&mut self, ts_s: f64, request: Option<RequestId>, kind: TraceEventKind) {
        self.push(TraceEvent {
            ts_s,
            dur_s: 0.0,
            request,
            kind,
        });
    }

    /// Record a complete span `[start_s, start_s + dur_s]`.
    pub fn record_span(
        &mut self,
        request: Option<RequestId>,
        start_s: f64,
        dur_s: f64,
        kind: TraceEventKind,
    ) {
        self.push(TraceEvent {
            ts_s: start_s,
            dur_s: dur_s.max(0.0),
            request,
            kind,
        });
    }

    fn push(&mut self, ev: TraceEvent) {
        if self.events.len() < self.capacity || ev.kind.always_retained() {
            self.events.push(ev);
        } else {
            self.dropped += 1;
            *self.dropped_by_kind.entry(ev.kind.name()).or_insert(0) += 1;
        }
    }

    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    /// Events refused because the buffer was full.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Drop counts broken down by event kind (`name()` → count). Terminal
    /// kinds (`retire`, `reject`) never appear here — they are always
    /// retained.
    pub fn dropped_by_kind(&self) -> &std::collections::BTreeMap<&'static str, u64> {
        &self.dropped_by_kind
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

/// Minimal JSON string escape (quotes, backslashes, control chars).
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Track id for a request's thread row (0 is the replica's `steps` track).
fn request_tid(id: RequestId) -> u64 {
    id + 1
}

fn complete_event(pid: usize, tid: u64, name: &str, ts_us: f64, dur_us: f64, args: &str) -> String {
    format!(
        "{{\"name\":\"{name}\",\"ph\":\"X\",\"pid\":{pid},\"tid\":{tid},\
         \"ts\":{ts_us:.3},\"dur\":{dur_us:.3},\"args\":{{{args}}}}}"
    )
}

fn instant_event(pid: usize, tid: u64, name: &str, ts_us: f64, args: &str) -> String {
    format!(
        "{{\"name\":\"{name}\",\"ph\":\"i\",\"s\":\"t\",\"pid\":{pid},\"tid\":{tid},\
         \"ts\":{ts_us:.3},\"args\":{{{args}}}}}"
    )
}

/// Render a fleet of recorders as Chrome trace-event JSON.
///
/// Layout: one process per replica (`pid` = replica id, named by its
/// label); inside it, `tid 0` is the `steps` track (prefill/decode spans,
/// CoW/evict instants) and each request gets its own thread whose
/// whole-request / ttft / decode spans are synthesized from the `Retire`
/// payload. Every track's events are sorted by timestamp, so per-track
/// timestamps are monotonic by construction.
pub fn chrome_trace_json(tracks: &[(String, &TraceRecorder)]) -> String {
    let mut parts: Vec<String> = Vec::new();
    for (label, rec) in tracks {
        let pid = rec.replica();
        parts.push(format!(
            "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{pid},\"tid\":0,\
             \"args\":{{\"name\":\"{}\"}}}}",
            esc(label)
        ));
        parts.push(format!(
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{pid},\"tid\":0,\
             \"args\":{{\"name\":\"steps\"}}}}"
        ));
        // Bucket events per track, then sort each track by timestamp.
        let mut per_tid: std::collections::BTreeMap<u64, Vec<(f64, String)>> =
            std::collections::BTreeMap::new();
        let mut named_tids: std::collections::BTreeSet<u64> = std::collections::BTreeSet::new();
        for ev in rec.events() {
            let ts_us = ev.ts_s.max(0.0) * 1e6;
            let dur_us = ev.dur_s * 1e6;
            match &ev.kind {
                TraceEventKind::PrefillChunk { tokens, mfu } => {
                    let args = format!("\"tokens\":{tokens},\"mfu\":{mfu:.6}");
                    per_tid.entry(0).or_default().push((
                        ts_us,
                        complete_event(pid, 0, "prefill_chunk", ts_us, dur_us, &args),
                    ));
                }
                TraceEventKind::DecodeStep {
                    batch,
                    mfu,
                    kv_bytes,
                    pool_occupancy,
                } => {
                    let args = format!(
                        "\"batch\":{batch},\"mfu\":{mfu:.6},\"kv_bytes\":{kv_bytes},\
                         \"pool_occupancy\":{pool_occupancy:.6}"
                    );
                    per_tid.entry(0).or_default().push((
                        ts_us,
                        complete_event(pid, 0, "decode_step", ts_us, dur_us, &args),
                    ));
                }
                TraceEventKind::CowCopy { blocks } => {
                    per_tid.entry(0).or_default().push((
                        ts_us,
                        instant_event(pid, 0, "cow_copy", ts_us, &format!("\"blocks\":{blocks}")),
                    ));
                }
                TraceEventKind::Evict { blocks } => {
                    per_tid.entry(0).or_default().push((
                        ts_us,
                        instant_event(pid, 0, "evict", ts_us, &format!("\"blocks\":{blocks}")),
                    ));
                }
                TraceEventKind::Admit { queued_s } => {
                    let tid = request_tid(ev.request.unwrap_or(0));
                    named_tids.insert(tid);
                    per_tid.entry(tid).or_default().push((
                        ts_us,
                        instant_event(pid, tid, "admit", ts_us, &format!("\"queued_s\":{queued_s:.6}")),
                    ));
                }
                TraceEventKind::PrefixHit { tokens } => {
                    let tid = request_tid(ev.request.unwrap_or(0));
                    named_tids.insert(tid);
                    per_tid.entry(tid).or_default().push((
                        ts_us,
                        instant_event(pid, tid, "prefix_hit", ts_us, &format!("\"tokens\":{tokens}")),
                    ));
                }
                TraceEventKind::Preempt { blocks, swap } => {
                    let tid = request_tid(ev.request.unwrap_or(0));
                    named_tids.insert(tid);
                    per_tid.entry(tid).or_default().push((
                        ts_us,
                        instant_event(
                            pid,
                            tid,
                            "preempt",
                            ts_us,
                            &format!("\"blocks\":{blocks},\"swap\":{swap}"),
                        ),
                    ));
                }
                TraceEventKind::SwapOut { blocks, bytes } => {
                    let tid = request_tid(ev.request.unwrap_or(0));
                    named_tids.insert(tid);
                    per_tid.entry(tid).or_default().push((
                        ts_us,
                        complete_event(
                            pid,
                            tid,
                            "swap_out",
                            ts_us,
                            dur_us,
                            &format!("\"blocks\":{blocks},\"bytes\":{bytes}"),
                        ),
                    ));
                }
                TraceEventKind::SwapIn { blocks, bytes } => {
                    let tid = request_tid(ev.request.unwrap_or(0));
                    named_tids.insert(tid);
                    per_tid.entry(tid).or_default().push((
                        ts_us,
                        complete_event(
                            pid,
                            tid,
                            "swap_in",
                            ts_us,
                            dur_us,
                            &format!("\"blocks\":{blocks},\"bytes\":{bytes}"),
                        ),
                    ));
                }
                TraceEventKind::DraftPropose { gamma } => {
                    let tid = request_tid(ev.request.unwrap_or(0));
                    named_tids.insert(tid);
                    per_tid.entry(tid).or_default().push((
                        ts_us,
                        instant_event(pid, tid, "draft_propose", ts_us, &format!("\"gamma\":{gamma}")),
                    ));
                }
                TraceEventKind::VerifyAccept { accepted, emitted } => {
                    let tid = request_tid(ev.request.unwrap_or(0));
                    named_tids.insert(tid);
                    per_tid.entry(tid).or_default().push((
                        ts_us,
                        complete_event(
                            pid,
                            tid,
                            "verify_accept",
                            ts_us,
                            dur_us,
                            &format!("\"accepted\":{accepted},\"emitted\":{emitted}"),
                        ),
                    ));
                }
                TraceEventKind::Rollback { tokens, blocks } => {
                    let tid = request_tid(ev.request.unwrap_or(0));
                    named_tids.insert(tid);
                    per_tid.entry(tid).or_default().push((
                        ts_us,
                        instant_event(
                            pid,
                            tid,
                            "rollback",
                            ts_us,
                            &format!("\"tokens\":{tokens},\"blocks\":{blocks}"),
                        ),
                    ));
                }
                TraceEventKind::Reject { reason } => {
                    let tid = request_tid(ev.request.unwrap_or(0));
                    named_tids.insert(tid);
                    per_tid.entry(tid).or_default().push((
                        ts_us,
                        instant_event(pid, tid, "reject", ts_us, &format!("\"reason\":\"{}\"", esc(reason))),
                    ));
                }
                TraceEventKind::Retire {
                    generated,
                    ttft_s,
                    tpot_s,
                    total_s,
                } => {
                    // The retire payload carries the whole request's
                    // latency summary: synthesize its request / ttft /
                    // decode spans on its own track.
                    let tid = request_tid(ev.request.unwrap_or(0));
                    named_tids.insert(tid);
                    let start_us = (ev.ts_s - total_s).max(0.0) * 1e6;
                    let ttft_us = ttft_s.max(0.0) * 1e6;
                    let total_us = total_s.max(0.0) * 1e6;
                    let bucket = per_tid.entry(tid).or_default();
                    bucket.push((
                        start_us,
                        complete_event(
                            pid,
                            tid,
                            "request",
                            start_us,
                            total_us,
                            &format!(
                                "\"generated\":{generated},\"ttft_s\":{ttft_s:.6},\
                                 \"tpot_s\":{tpot_s:.6},\"total_s\":{total_s:.6}"
                            ),
                        ),
                    ));
                    bucket.push((
                        start_us,
                        complete_event(pid, tid, "ttft", start_us, ttft_us, ""),
                    ));
                    let decode_start_us = start_us + ttft_us;
                    bucket.push((
                        decode_start_us,
                        complete_event(
                            pid,
                            tid,
                            "decode",
                            decode_start_us,
                            (total_us - ttft_us).max(0.0),
                            "",
                        ),
                    ));
                }
            }
        }
        for tid in named_tids {
            parts.push(format!(
                "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{pid},\"tid\":{tid},\
                 \"args\":{{\"name\":\"req {}\"}}}}",
                tid - 1
            ));
        }
        for (_, mut evs) in per_tid {
            evs.sort_by(|a, b| a.0.total_cmp(&b.0));
            parts.extend(evs.into_iter().map(|(_, s)| s));
        }
    }
    format!(
        "{{\"traceEvents\":[{}],\"displayTimeUnit\":\"ms\"}}",
        parts.join(",")
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn recorder() -> TraceRecorder {
        TraceRecorder::with_capacity(0, Clock::virtual_at(0.0), 16)
    }

    #[test]
    fn records_and_stamps_virtual_time() {
        let mut r = recorder();
        r.set_virtual_now(1.5);
        r.record(Some(7), TraceEventKind::Admit { queued_s: 0.5 });
        r.record_span(
            None,
            1.0,
            0.5,
            TraceEventKind::PrefillChunk {
                tokens: 128,
                mfu: 0.4,
            },
        );
        assert_eq!(r.len(), 2);
        assert_eq!(r.events()[0].ts_s, 1.5);
        assert_eq!(r.events()[1].dur_s, 0.5);
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn full_buffer_drops_newest_and_counts() {
        let mut r = TraceRecorder::with_capacity(0, Clock::virtual_at(0.0), 2);
        for i in 0..5 {
            r.record_at(i as f64, None, TraceEventKind::CowCopy { blocks: 1 });
        }
        assert_eq!(r.len(), 2);
        assert_eq!(r.dropped(), 3);
        // The *oldest* events survive.
        assert_eq!(r.events()[0].ts_s, 0.0);
        assert_eq!(r.events()[1].ts_s, 1.0);
        // Drops are attributed per kind.
        assert_eq!(r.dropped_by_kind().get("cow_copy"), Some(&3));
        r.record_at(9.0, None, TraceEventKind::PrefixHit { tokens: 16 });
        assert_eq!(r.dropped(), 4);
        assert_eq!(r.dropped_by_kind().get("prefix_hit"), Some(&1));
        assert_eq!(r.dropped_by_kind().get("cow_copy"), Some(&3));
    }

    #[test]
    fn terminal_events_survive_a_full_ring() {
        let mut r = TraceRecorder::with_capacity(0, Clock::virtual_at(0.0), 2);
        for i in 0..4 {
            r.record_at(i as f64, None, TraceEventKind::CowCopy { blocks: 1 });
        }
        r.record_at(
            4.0,
            Some(1),
            TraceEventKind::Retire {
                generated: 2,
                ttft_s: 0.1,
                tpot_s: 0.05,
                total_s: 0.5,
            },
        );
        r.record_at(
            4.5,
            Some(2),
            TraceEventKind::Reject {
                reason: "queue_full".to_string(),
            },
        );
        // The ring held 2 events; both terminal events were still retained.
        assert_eq!(r.len(), 4);
        assert_eq!(r.dropped(), 2);
        assert!(r.dropped_by_kind().get("retire").is_none());
        assert!(r.dropped_by_kind().get("reject").is_none());
    }

    #[test]
    fn span_reconstruction_survives_an_undersized_ring_under_overload() {
        // An overloaded replica floods the ring with step-level events; the
        // per-request spans are synthesized solely from Retire payloads, so
        // every completed request must still reconstruct even when the ring
        // is far too small for the step traffic.
        let requests = 16u64;
        let mut r = TraceRecorder::with_capacity(0, Clock::virtual_at(0.0), 4);
        for id in 0..requests {
            let t = id as f64;
            r.record_at(t, Some(id), TraceEventKind::Admit { queued_s: 0.5 });
            for s in 0..8 {
                r.record_span(
                    None,
                    t + 0.01 * s as f64,
                    0.01,
                    TraceEventKind::DecodeStep {
                        batch: 4,
                        mfu: 0.5,
                        kv_bytes: 4096,
                        pool_occupancy: 0.9,
                    },
                );
            }
            r.record_at(
                t + 0.9,
                Some(id),
                TraceEventKind::Retire {
                    generated: 8,
                    ttft_s: 0.2,
                    tpot_s: 0.1,
                    total_s: 0.9,
                },
            );
        }
        assert!(r.dropped() > 0, "the undersized ring must have overflowed");
        let out = chrome_trace_json(&[("overloaded".to_string(), &r)]);
        let j = Json::parse(&out).expect("chrome trace must be valid JSON");
        let events = j.get("traceEvents").and_then(Json::as_arr).unwrap();
        for id in 0..requests {
            let tid = (id + 1) as f64;
            assert!(
                events.iter().any(|e| {
                    e.get("name").and_then(Json::as_str) == Some("request")
                        && e.get("tid").and_then(Json::as_f64) == Some(tid)
                }),
                "request {id} span lost to the ring"
            );
        }
    }

    #[test]
    fn chrome_export_parses_and_is_monotonic_per_track() {
        let mut r = recorder();
        // Deliberately out of order: the exporter must sort per track.
        r.record_span(
            None,
            2.0,
            0.1,
            TraceEventKind::DecodeStep {
                batch: 2,
                mfu: 0.1,
                kv_bytes: 1024,
                pool_occupancy: 0.5,
            },
        );
        r.record_span(
            None,
            1.0,
            0.5,
            TraceEventKind::PrefillChunk {
                tokens: 64,
                mfu: 0.3,
            },
        );
        r.record_at(3.0, Some(1), TraceEventKind::PrefixHit { tokens: 32 });
        r.record_at(
            5.0,
            Some(1),
            TraceEventKind::Retire {
                generated: 8,
                ttft_s: 1.5,
                tpot_s: 0.5,
                total_s: 5.0,
            },
        );
        let out = chrome_trace_json(&[("sim0".to_string(), &r)]);
        let j = Json::parse(&out).expect("chrome trace must be valid JSON");
        let events = j.get("traceEvents").and_then(Json::as_arr).unwrap();
        assert!(events.len() >= 7, "events + metadata expected");
        // Per-(pid, tid) monotonic timestamps over non-metadata events.
        let mut last: std::collections::HashMap<(u64, u64), f64> = std::collections::HashMap::new();
        for e in events {
            if e.get("ph").and_then(Json::as_str) == Some("M") {
                continue;
            }
            let pid = e.get("pid").and_then(Json::as_f64).unwrap() as u64;
            let tid = e.get("tid").and_then(Json::as_f64).unwrap() as u64;
            let ts = e.get("ts").and_then(Json::as_f64).unwrap();
            let prev = last.entry((pid, tid)).or_insert(f64::NEG_INFINITY);
            assert!(ts >= *prev, "track ({pid},{tid}) went backwards");
            *prev = ts;
        }
        // The retire synthesized a whole-request span whose duration is
        // total_s and a ttft sub-span of ttft_s.
        let req_span = events
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("request"))
            .expect("request span synthesized");
        let dur = req_span.get("dur").and_then(Json::as_f64).unwrap();
        assert!((dur - 5.0e6).abs() < 1.0, "dur {dur} != 5s in us");
        let ttft_span = events
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("ttft"))
            .unwrap();
        assert!((ttft_span.get("dur").and_then(Json::as_f64).unwrap() - 1.5e6).abs() < 1.0);
    }

    #[test]
    fn speculative_events_export_on_the_request_track() {
        let mut r = recorder();
        r.record_at(1.0, Some(3), TraceEventKind::DraftPropose { gamma: 4 });
        r.record_span(
            Some(3),
            1.0,
            0.05,
            TraceEventKind::VerifyAccept {
                accepted: 3,
                emitted: 4,
            },
        );
        r.record_at(1.05, Some(3), TraceEventKind::Rollback { tokens: 1, blocks: 1 });
        let out = chrome_trace_json(&[("spec".to_string(), &r)]);
        let j = Json::parse(&out).expect("valid JSON");
        let events = j.get("traceEvents").and_then(Json::as_arr).unwrap();
        for name in ["draft_propose", "verify_accept", "rollback"] {
            let e = events
                .iter()
                .find(|e| e.get("name").and_then(Json::as_str) == Some(name))
                .unwrap_or_else(|| panic!("{name} missing from export"));
            // All three ride the request's own track, not `steps`.
            assert_eq!(e.get("tid").and_then(Json::as_f64), Some(4.0), "{name}");
        }
        let va = events
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("verify_accept"))
            .unwrap();
        let arg = |k: &str| va.get("args").and_then(|a| a.get(k)).and_then(Json::as_f64);
        assert_eq!(arg("accepted"), Some(3.0));
        assert_eq!(arg("emitted"), Some(4.0));
    }

    #[test]
    fn labels_are_escaped() {
        let r = TraceRecorder::with_capacity(3, Clock::virtual_at(0.0), 4);
        let out = chrome_trace_json(&[("we\"ird\\label".to_string(), &r)]);
        assert!(Json::parse(&out).is_ok(), "escaping broke: {out}");
    }
}
