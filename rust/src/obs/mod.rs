//! Observability: per-request trace timelines, step-level utilization
//! accounting, and metrics exposition.
//!
//! The paper's headline claim is operational ("frequently exceeding 90%
//! MFU") — this layer makes the repro report what the serving stack
//! *actually did* during a run, not just what isolated gaudisim calls
//! predict:
//!
//! * [`trace`] — a bounded per-replica [`TraceRecorder`] of typed
//!   lifecycle events (admit / prefill chunk / decode step / prefix hit /
//!   CoW copy / evict / retire / reject), exported as Chrome trace-event
//!   JSON (Perfetto-loadable): one process per replica, one track per
//!   request.
//! * [`clock`] — the [`Clock`] abstraction that lets the wall-clock
//!   engine and the discrete-event simulation stamp comparable timelines.
//! * [`step`] — [`StepStats`]: per-step modeled time / model FLOPs / KV
//!   bytes / pool occupancy, folded into the windowed `mfu` and
//!   `pool_occupancy` gauges on [`crate::coordinator::ServeMetrics`].
//! * [`prom`] — Prometheus text-format exposition
//!   (`ServeMetrics::render_prometheus`), the schema shared by `repro
//!   serve --metrics-out`, `repro fleet --metrics-out`, and the benches.

pub mod clock;
pub mod prom;
pub mod step;
pub mod trace;

pub use clock::Clock;
pub use step::StepStats;
pub use trace::{
    chrome_trace_json, TraceEvent, TraceEventKind, TraceRecorder, DEFAULT_TRACE_CAPACITY,
};
