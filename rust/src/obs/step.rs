//! Step-level utilization accounting: one record per prefill chunk or
//! decode step, priced against the device roofline.
//!
//! Both replica kinds produce these — the simulation from the gaudisim
//! model's own time/FLOPs, the engine from wall-clock step times — and
//! fold them into [`crate::coordinator::ServeMetrics`] windowed gauges
//! (`mfu`, `pool_occupancy`, `kv_bytes_read`). MFU follows the paper's
//! convention: Kim-et-al model FLOPs over modeled time, divided by
//! `Device::peak_fp8_tflops`.

use crate::coordinator::ServeMetrics;

/// One prefill-chunk or decode-step utilization sample.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepStats {
    /// Modeled (or measured) step time.
    pub time_s: f64,
    /// Kim-et-al model FLOPs the step performed (0 when no model applies,
    /// e.g. the tiny artifact engine — MFU then records as 0).
    pub model_flops: f64,
    /// Physical KV bytes the step read.
    pub kv_bytes_read: u64,
    /// Block-pool occupancy in [0, 1] right after the step.
    pub pool_occupancy: f64,
}

impl StepStats {
    /// Achieved TFLOPS: model FLOPs over step time.
    pub fn achieved_tflops(&self) -> f64 {
        if self.time_s > 0.0 {
            self.model_flops / self.time_s / 1e12
        } else {
            0.0
        }
    }

    /// Model FLOPs utilization against the device's FP8 peak.
    pub fn mfu(&self, peak_fp8_tflops: f64) -> f64 {
        if peak_fp8_tflops > 0.0 {
            self.achieved_tflops() / peak_fp8_tflops
        } else {
            0.0
        }
    }

    /// Fold this sample into the serving metrics' windowed gauges and
    /// return the MFU it recorded (so the caller can stamp it on the
    /// trace event too).
    pub fn apply(&self, m: &mut ServeMetrics, peak_fp8_tflops: f64) -> f64 {
        let mfu = self.mfu(peak_fp8_tflops);
        m.mfu.record(mfu);
        m.pool_occupancy.record(self.pool_occupancy);
        m.pool_occupancy_peak = m.pool_occupancy_peak.max(self.pool_occupancy);
        m.kv_bytes_read += self.kv_bytes_read;
        mfu
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mfu_is_flops_over_time_over_peak() {
        let s = StepStats {
            time_s: 0.01,
            model_flops: 4.0e12,
            kv_bytes_read: 1024,
            pool_occupancy: 0.25,
        };
        assert!((s.achieved_tflops() - 400.0).abs() < 1e-9);
        assert!((s.mfu(800.0) - 0.5).abs() < 1e-12);
        assert_eq!(StepStats::default().mfu(800.0), 0.0);
        assert_eq!(s.mfu(0.0), 0.0);
    }

    #[test]
    fn apply_updates_gauges_and_peak() {
        let mut m = ServeMetrics::new();
        let a = StepStats {
            time_s: 0.01,
            model_flops: 4.0e12,
            kv_bytes_read: 100,
            pool_occupancy: 0.5,
        };
        let b = StepStats {
            time_s: 0.01,
            model_flops: 2.0e12,
            kv_bytes_read: 50,
            pool_occupancy: 0.3,
        };
        a.apply(&mut m, 800.0);
        b.apply(&mut m, 800.0);
        assert_eq!(m.mfu.count, 2);
        assert!((m.mfu.max_s - 0.5).abs() < 1e-12);
        assert!((m.pool_occupancy_peak - 0.5).abs() < 1e-12);
        assert_eq!(m.kv_bytes_read, 150);
    }
}
