//! Clock abstraction for trace timestamps.
//!
//! The wall-clock [`crate::coordinator::Engine`] and the virtual-clock
//! [`crate::router::SimReplica`] must emit *comparable* timelines: both
//! report seconds since their own time zero, so a Chrome trace merging
//! replicas of either kind lines up on one axis.

use std::time::Instant;

/// Seconds-since-start time source behind a [`super::TraceRecorder`].
#[derive(Clone, Debug)]
pub enum Clock {
    /// Real time, measured from an anchor instant (the engine path).
    Wall(Instant),
    /// Discrete-event virtual time in seconds (`SimReplica::now_s`).
    Virtual(f64),
}

impl Clock {
    /// A wall clock anchored at the call site.
    pub fn wall() -> Self {
        Clock::Wall(Instant::now())
    }

    /// A virtual clock starting at `now_s` (usually 0.0).
    pub fn virtual_at(now_s: f64) -> Self {
        Clock::Virtual(now_s)
    }

    /// Current time in seconds since this clock's zero.
    pub fn now_s(&self) -> f64 {
        match self {
            Clock::Wall(anchor) => anchor.elapsed().as_secs_f64(),
            Clock::Virtual(t) => *t,
        }
    }

    /// Advance a virtual clock (monotonic: never backwards). Wall clocks
    /// advance themselves and ignore this.
    pub fn set_virtual(&mut self, now_s: f64) {
        if let Clock::Virtual(t) = self {
            *t = t.max(now_s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_is_monotonic() {
        let mut c = Clock::virtual_at(1.0);
        assert_eq!(c.now_s(), 1.0);
        c.set_virtual(3.5);
        assert_eq!(c.now_s(), 3.5);
        c.set_virtual(2.0);
        assert_eq!(c.now_s(), 3.5, "clock never goes backwards");
    }

    #[test]
    fn wall_clock_advances_by_itself() {
        let mut c = Clock::wall();
        let t0 = c.now_s();
        c.set_virtual(1e9); // ignored
        assert!(c.now_s() < 1e6);
        assert!(c.now_s() >= t0);
    }
}
