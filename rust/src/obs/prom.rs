//! Prometheus text-format exposition for the serving metrics.
//!
//! One schema shared by `repro serve`, `repro fleet` (fleet-merged), and
//! any future HTTP front end: counters for work done, summaries (with
//! `quantile` labels) for the latency and utilization distributions, and
//! gauges for pool occupancy / hit rates. Rendered on demand from a
//! [`ServeMetrics`] snapshot — there is no background collector thread.

use std::fmt::Write as _;

use crate::coordinator::{LatencyStat, ServeMetrics};

fn counter(out: &mut String, name: &str, help: &str, v: u64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} counter");
    let _ = writeln!(out, "{name} {v}");
}

fn gauge(out: &mut String, name: &str, help: &str, v: f64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} gauge");
    let _ = writeln!(out, "{name} {v}");
}

/// Prometheus `summary`: quantiles over the stat's reservoir plus exact
/// `_sum` / `_count`.
fn summary(out: &mut String, name: &str, help: &str, s: &LatencyStat) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} summary");
    for (label, q) in [("0.5", 0.5), ("0.95", 0.95), ("0.99", 0.99)] {
        let _ = writeln!(out, "{name}{{quantile=\"{label}\"}} {}", s.percentile_s(q));
    }
    let _ = writeln!(out, "{name}_sum {}", s.sum_s);
    let _ = writeln!(out, "{name}_count {}", s.count);
}

impl ServeMetrics {
    /// Render this snapshot in the Prometheus text exposition format.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        counter(
            &mut out,
            "repro_requests_completed",
            "Requests completed (including unservable empties).",
            self.requests_completed,
        );
        counter(
            &mut out,
            "repro_prompt_tokens",
            "Prompt tokens admitted.",
            self.prompt_tokens,
        );
        counter(
            &mut out,
            "repro_generated_tokens",
            "Tokens generated.",
            self.generated_tokens,
        );
        counter(
            &mut out,
            "repro_prefill_steps",
            "Prefill steps executed.",
            self.prefill_steps,
        );
        counter(
            &mut out,
            "repro_prefill_chunks",
            "Chunked-prefill tail chunks executed.",
            self.prefill_chunks,
        );
        counter(
            &mut out,
            "repro_decode_steps",
            "Decode steps executed.",
            self.decode_steps,
        );
        counter(
            &mut out,
            "repro_prefix_hit_tokens",
            "Prompt tokens served from the prefix cache.",
            self.prefix_hit_tokens,
        );
        counter(
            &mut out,
            "repro_prefix_evicted_blocks",
            "KV blocks reclaimed from the prefix cache by eviction.",
            self.prefix_evicted_blocks,
        );
        counter(
            &mut out,
            "repro_kv_bytes_read",
            "Physical KV bytes read by decode steps.",
            self.kv_bytes_read,
        );
        counter(
            &mut out,
            "repro_cow_block_copies",
            "Copy-on-write block clones (shared block went private).",
            self.cow_block_copies,
        );
        counter(
            &mut out,
            "repro_trace_events_dropped",
            "Trace events dropped by the bounded ring buffer.",
            self.trace_events_dropped,
        );
        counter(
            &mut out,
            "repro_preemptions",
            "Sequences preempted off the device under KV pool pressure.",
            self.preemptions,
        );
        counter(
            &mut out,
            "repro_swapped_out_blocks",
            "KV blocks moved device to host tier by preemption swap-outs.",
            self.swapped_out_blocks,
        );
        counter(
            &mut out,
            "repro_swapped_in_blocks",
            "KV blocks moved host tier to device by swap-in resumes.",
            self.swapped_in_blocks,
        );
        counter(
            &mut out,
            "repro_host_swap_bytes",
            "Bytes crossing the host link (KvLayout block rate, both directions).",
            self.host_swap_bytes,
        );
        counter(
            &mut out,
            "repro_recompute_resumes",
            "Preempted sequences resumed by chunked re-prefill.",
            self.recompute_resumes,
        );
        counter(
            &mut out,
            "repro_spec_rounds",
            "Speculative draft-verify rounds executed.",
            self.spec_rounds,
        );
        counter(
            &mut out,
            "repro_spec_accepted_tokens",
            "Draft tokens accepted by the target's greedy verify.",
            self.spec_accepted_tokens,
        );
        counter(
            &mut out,
            "repro_spec_rejected_tokens",
            "Draft tokens rejected and rolled back by block truncation.",
            self.spec_rejected_tokens,
        );
        counter(
            &mut out,
            "repro_spec_rollbacks",
            "Verify rounds that ended in a truncation rollback.",
            self.spec_rollbacks,
        );
        counter(
            &mut out,
            "repro_beam_forks",
            "Beam branches forked off live sequences.",
            self.beam_forks,
        );
        counter(
            &mut out,
            "repro_beam_prunes",
            "Beam branches pruned before winning their beam.",
            self.beam_prunes,
        );
        gauge(
            &mut out,
            "repro_spec_acceptance_rate",
            "Fraction of draft tokens accepted (0 with no spec rounds).",
            self.spec_acceptance_rate(),
        );
        gauge(
            &mut out,
            "repro_prefix_hit_rate",
            "Fraction of cache-attached admissions that hit.",
            self.prefix_hit_rate(),
        );
        gauge(
            &mut out,
            "repro_mean_decode_batch",
            "Mean decode group size.",
            self.mean_decode_batch(),
        );
        gauge(
            &mut out,
            "repro_pool_occupancy_peak",
            "Peak KV block-pool occupancy observed (0-1).",
            self.pool_occupancy_peak,
        );
        summary(
            &mut out,
            "repro_ttft_seconds",
            "Time to first token.",
            &self.ttft,
        );
        summary(
            &mut out,
            "repro_tpot_seconds",
            "Time per output token.",
            &self.tpot,
        );
        summary(
            &mut out,
            "repro_mfu",
            "Per-step model FLOPs utilization vs device FP8 peak (0-1).",
            &self.mfu,
        );
        summary(
            &mut out,
            "repro_pool_occupancy",
            "Per-step KV block-pool occupancy (0-1).",
            &self.pool_occupancy,
        );
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_families_and_values() {
        let mut m = ServeMetrics::new();
        m.requests_completed = 3;
        m.generated_tokens = 42;
        m.kv_bytes_read = 4096;
        m.trace_events_dropped = 7;
        m.pool_occupancy_peak = 0.75;
        m.preemptions = 2;
        m.swapped_out_blocks = 9;
        m.swapped_in_blocks = 5;
        m.host_swap_bytes = 8192;
        m.recompute_resumes = 1;
        m.spec_rounds = 6;
        m.spec_accepted_tokens = 20;
        m.spec_rejected_tokens = 4;
        m.spec_rollbacks = 3;
        m.beam_forks = 4;
        m.beam_prunes = 3;
        m.ttft.record(0.5);
        m.mfu.record(0.9);
        let text = m.render_prometheus();
        for needle in [
            "# TYPE repro_spec_rounds counter",
            "repro_spec_rounds 6",
            "repro_spec_accepted_tokens 20",
            "repro_spec_rejected_tokens 4",
            "repro_spec_rollbacks 3",
            "repro_beam_forks 4",
            "repro_beam_prunes 3",
            "# TYPE repro_requests_completed counter",
            "repro_requests_completed 3",
            "repro_generated_tokens 42",
            "repro_kv_bytes_read 4096",
            "repro_trace_events_dropped 7",
            "# TYPE repro_preemptions counter",
            "repro_preemptions 2",
            "repro_swapped_out_blocks 9",
            "repro_swapped_in_blocks 5",
            "repro_host_swap_bytes 8192",
            "repro_recompute_resumes 1",
            "repro_pool_occupancy_peak 0.75",
            "# TYPE repro_ttft_seconds summary",
            "repro_ttft_seconds{quantile=\"0.5\"} 0.5",
            "repro_ttft_seconds_count 1",
            "repro_mfu{quantile=\"0.99\"} 0.9",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
        // Every non-comment line is `name[{labels}] value`.
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let mut it = line.rsplitn(2, ' ');
            let v = it.next().unwrap();
            assert!(v.parse::<f64>().is_ok(), "unparseable value in {line:?}");
        }
    }
}
