//! Gaudi device descriptors: the published constants the analytical
//! performance model is built from.
//!
//! Sources: the paper (§2.4, Table 1 caption: "peak scaled FP8 dense GEMM
//! throughput is 865 TFLOPS" on Gaudi 2; §4.2.4: 96 GB HBM implied by
//! Llama-70B-FP8 fitting on one card) and Intel's published Gaudi 2/3 specs.

/// Gaudi accelerator generation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Generation {
    Gaudi2,
    Gaudi3,
}

/// Device model: peak rates and capacities used by the roofline.
#[derive(Clone, Copy, Debug)]
pub struct Device {
    pub generation: Generation,
    /// Peak dense FP8 GEMM throughput (TFLOP/s). Paper: 865 for Gaudi 2.
    pub peak_fp8_tflops: f64,
    /// Peak dense BF16 GEMM throughput (TFLOP/s). Gaudi 2: ~432 (half of FP8).
    pub peak_bf16_tflops: f64,
    /// HBM bandwidth (TB/s). Gaudi 2: 2.46, Gaudi 3: 3.7.
    pub hbm_bandwidth_tbps: f64,
    /// HBM capacity in *marketed decimal gigabytes* (1 GB = 1e9 B), the
    /// convention the paper and vendor specs use. Gaudi 2: 96, Gaudi 3:
    /// 128. (Formerly misnamed `hbm_capacity_gib` while every consumer
    /// multiplied by 1e9.)
    pub hbm_capacity_gb: f64,
    /// On-chip SRAM (MiB) — the analogue of VMEM for tiling decisions.
    pub sram_mib: f64,
    /// MME systolic-array tile (square side, elements) per engine.
    pub mme_tile: usize,
    /// Number of MME engines.
    pub mme_engines: usize,
    /// Vector-engine (TPC) elementwise throughput in Gelem/s for f32 —
    /// bounds descale/quantize side ops.
    pub tpc_gelems_per_s: f64,
    /// Host-link bandwidth (decimal GB/s) for device ↔ host DRAM
    /// transfers — the PCIe path KV swap-outs ride (ISSUE 9). Gaudi 2:
    /// PCIe Gen4 x16 ≈ 32 GB/s; Gaudi 3: Gen5 x16 ≈ 64 GB/s. Orders of
    /// magnitude below HBM bandwidth, which is exactly why the
    /// swap-vs-recompute policy has a real decision to make.
    pub host_link_gb_s: f64,
}

impl Device {
    pub fn gaudi2() -> Self {
        Device {
            generation: Generation::Gaudi2,
            peak_fp8_tflops: 865.0,
            peak_bf16_tflops: 432.0,
            hbm_bandwidth_tbps: 2.46,
            hbm_capacity_gb: 96.0,
            sram_mib: 48.0,
            mme_tile: 256,
            mme_engines: 2,
            tpc_gelems_per_s: 600.0,
            host_link_gb_s: 32.0,
        }
    }

    pub fn gaudi3() -> Self {
        Device {
            generation: Generation::Gaudi3,
            peak_fp8_tflops: 1835.0,
            peak_bf16_tflops: 1835.0, // Gaudi 3 MME runs BF16 at FP8 rate
            hbm_bandwidth_tbps: 3.7,
            hbm_capacity_gb: 128.0,
            sram_mib: 96.0,
            mme_tile: 256,
            mme_engines: 8,
            tpc_gelems_per_s: 1200.0,
            host_link_gb_s: 64.0,
        }
    }

    pub fn new(generation: Generation) -> Self {
        match generation {
            Generation::Gaudi2 => Self::gaudi2(),
            Generation::Gaudi3 => Self::gaudi3(),
        }
    }

    /// Usable capacity in bytes, decimal-GB semantics matching the field.
    pub fn hbm_capacity_bytes(&self) -> f64 {
        self.hbm_capacity_gb * 1e9
    }

    /// Seconds to move `bytes` across the host link in one direction —
    /// the transfer cost a KV swap-out (or swap-in) pays, priced against
    /// chunked re-prefill by the preemption policy.
    pub fn host_transfer_time_s(&self, bytes: f64) -> f64 {
        bytes / (self.host_link_gb_s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gaudi2_constants_match_paper() {
        let d = Device::gaudi2();
        assert_eq!(d.peak_fp8_tflops, 865.0); // Table 1 caption
        assert_eq!(d.hbm_capacity_gb, 96.0);
        assert_eq!(d.hbm_capacity_bytes(), 96e9); // marketed decimal GB
        assert_eq!(d.generation, Generation::Gaudi2);
    }

    #[test]
    fn gaudi3_outclasses_gaudi2() {
        let (g2, g3) = (Device::gaudi2(), Device::gaudi3());
        assert!(g3.peak_fp8_tflops > g2.peak_fp8_tflops);
        assert!(g3.hbm_bandwidth_tbps > g2.hbm_bandwidth_tbps);
        assert!(g3.hbm_capacity_gb > g2.hbm_capacity_gb);
        assert!(g3.host_link_gb_s > g2.host_link_gb_s);
    }

    #[test]
    fn host_link_is_the_slow_tier() {
        let d = Device::gaudi2();
        assert_eq!(d.host_link_gb_s, 32.0); // PCIe Gen4 x16
        assert_eq!(d.host_transfer_time_s(32e9), 1.0);
        assert_eq!(d.host_transfer_time_s(0.0), 0.0);
        // The link sits ~2 orders of magnitude below HBM: moving a byte
        // to host must never be mistaken for an HBM-priced operation.
        let hbm_s = 32e9 / (d.hbm_bandwidth_tbps * 1e12);
        assert!(d.host_transfer_time_s(32e9) > 50.0 * hbm_s);
    }
}
