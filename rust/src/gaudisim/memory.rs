//! HBM capacity model — predicts the OOM frontier of Table 6.
//!
//! Accounting (single Gaudi 2, 96 GB):
//! * linear weights in FP8 (1 B/param) — the paper quantizes all linears;
//! * embedding + LM head kept in BF16 (2 B/param) — excluded from FP8
//!   (§3.3 step 5, Table 5 caption);
//! * KV cache at the shared [`KvLayout`] rate — FP8 (1 B/elem) by default,
//!   required for the Table 6 batch grid to fit (e.g. batch 16 × seq 8192
//!   works on 96 GB only with FP8 KV);
//! * a fixed activation/workspace reserve.
//!
//! The KV rate is the same `KvLayout::bytes_per_token()` the coordinator's
//! `BlockAllocator` and the fleet's `SimReplica` charge, so the capacity
//! model, admission control, and the host store can no longer disagree
//! about what a token costs.
//!
//! The paper notes: "thanks to the memory gain, we can measure Llama 70B on
//! a single Gaudi 2, which would not be possible with BF16" — reproduced by
//! `fits_bf16` below.

use super::device::Device;
use crate::model::config::ModelConfig;
use crate::quant::{KvDtype, KvLayout, KV_BLOCK_TOKENS};

/// Fixed workspace reserve (bytes): activations, cos/sin tables, comms.
/// FP8 KV scale metadata (per-sequence, `KvLayout::scale_bytes_per_seq`)
/// is charged here rather than to the per-token rate.
pub const WORKSPACE_BYTES: f64 = 0.5e9;

#[derive(Clone, Debug)]
pub struct MemoryModel {
    pub device: Device,
    pub cfg: ModelConfig,
    /// KV-cache storage dtype. Defaults to FP8 — the paper's serving
    /// configuration, required for the Table 6 grid to fit in 96 GB.
    pub kv_dtype: KvDtype,
}

impl MemoryModel {
    pub fn new(device: Device, cfg: ModelConfig) -> Self {
        Self {
            device,
            cfg,
            kv_dtype: KvDtype::FP8_DEFAULT,
        }
    }

    /// Same model/device, different KV storage dtype.
    pub fn with_kv_dtype(mut self, kv_dtype: KvDtype) -> Self {
        self.kv_dtype = kv_dtype;
        self
    }

    /// The shared KV accounting contract for this (model, dtype).
    pub fn kv_layout(&self) -> KvLayout {
        self.cfg.kv_layout(self.kv_dtype)
    }

    /// Marketed capacity uses decimal GB (96 GB = 96e9 bytes).
    pub fn capacity_bytes(&self) -> f64 {
        self.device.hbm_capacity_bytes()
    }

    /// Model weights resident in HBM under FP8 linear quantization.
    pub fn weight_bytes_fp8(&self) -> f64 {
        let linear = self.cfg.linear_params() as f64; // 1 B/param
        let edges = (self.cfg.total_params() - self.cfg.linear_params()) as f64 * 2.0;
        linear + edges
    }

    /// Model weights fully in BF16.
    pub fn weight_bytes_bf16(&self) -> f64 {
        self.cfg.total_params() as f64 * 2.0
    }

    /// KV cache bytes for `batch` sequences of length `seq`, at the
    /// layout's bytes/token rate (FP8 KV by default).
    pub fn kv_bytes(&self, batch: usize, seq: usize) -> f64 {
        (batch * seq) as f64 * self.kv_layout().bytes_per_token() as f64
    }

    /// KV bytes when the `batch` sequences share a common `shared_prefix`
    /// stored once in the paged pool — **physical** block accounting, not
    /// logical tokens: the shareable prefix is floored to whole
    /// [`KV_BLOCK_TOKENS`]-token blocks (exactly what the radix cache can
    /// map) and charged a single time; each sequence's private tail is
    /// rounded *up* to the blocks it actually occupies — all at the same
    /// `KvLayout` rate.
    pub fn kv_bytes_shared(&self, batch: usize, seq: usize, shared_prefix: usize) -> f64 {
        let bt = KV_BLOCK_TOKENS;
        let p_blocks = shared_prefix.min(seq) / bt;
        let tail_blocks = (seq - p_blocks * bt).div_ceil(bt);
        let block_bytes = (bt * self.kv_layout().bytes_per_token()) as f64;
        (p_blocks + batch * tail_blocks) as f64 * block_bytes
    }

    /// Physical bytes one paged decode step reads for `batch` sequences at
    /// context `seq` — block-quantized per sequence (whole 16-token blocks
    /// stream; the kernel masks inside the tail block), at this model's KV
    /// dtype rate. The capacity-model twin of
    /// `gaudisim::kv_read_bytes_paged` (which charges the paper's fixed
    /// FP8 serving rate).
    pub fn kv_read_bytes_per_step(&self, batch: usize, seq: usize) -> f64 {
        let bt = KV_BLOCK_TOKENS;
        (batch * seq.div_ceil(bt) * bt) as f64 * self.kv_layout().bytes_per_token() as f64
    }

    pub fn total_bytes_fp8(&self, batch: usize, seq: usize) -> f64 {
        self.weight_bytes_fp8() + self.kv_bytes(batch, seq) + WORKSPACE_BYTES
    }

    /// Does the FP8-quantized model with this KV footprint fit?
    pub fn fits(&self, batch: usize, seq: usize) -> bool {
        self.total_bytes_fp8(batch, seq) <= self.capacity_bytes()
    }

    /// Would the BF16 model fit (without quantization)? BF16 weights and a
    /// BF16 KV cache, both rates from the shared layout contract.
    pub fn fits_bf16(&self, batch: usize, seq: usize) -> bool {
        let bf16_kv = self.cfg.kv_layout(KvDtype::Bf16);
        let kv = (batch * seq) as f64 * bf16_kv.bytes_per_token() as f64;
        self.weight_bytes_bf16() + kv + WORKSPACE_BYTES <= self.capacity_bytes()
    }

    /// Does the FP8 model fit when the batch shares a `shared_prefix`-token
    /// prompt stored once? Extends the Table 6 frontier along the axis the
    /// prefix cache opens.
    pub fn fits_shared(&self, batch: usize, seq: usize, shared_prefix: usize) -> bool {
        self.weight_bytes_fp8() + self.kv_bytes_shared(batch, seq, shared_prefix) + WORKSPACE_BYTES
            <= self.capacity_bytes()
    }

    /// Largest power-of-two batch that fits at sequence length `seq`.
    pub fn max_batch_pow2(&self, seq: usize) -> Option<usize> {
        let mut best = None;
        let mut b = 1usize;
        while b <= 1024 {
            if self.fits(b, seq) {
                best = Some(b);
            }
            b *= 2;
        }
        best
    }

    /// Largest power-of-two batch that fits at `seq` with a shared prefix.
    pub fn max_batch_pow2_shared(&self, seq: usize, shared_prefix: usize) -> Option<usize> {
        let mut best = None;
        let mut b = 1usize;
        while b <= 1024 {
            if self.fits_shared(b, seq, shared_prefix) {
                best = Some(b);
            }
            b *= 2;
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gaudisim::device::Device;

    fn mm() -> MemoryModel {
        MemoryModel::new(Device::gaudi2(), ModelConfig::llama31_70b())
    }

    /// Table 6's exact OOM pattern (true = runs, false = OOM in the paper).
    const TABLE6_FITS: &[(usize, usize, bool)] = &[
        (8, 512, true),
        (8, 1024, true),
        (8, 2048, true),
        (8, 4096, true),
        (8, 8192, true),
        (16, 512, true),
        (16, 1024, true),
        (16, 2048, true),
        (16, 4096, true),
        (16, 8192, true),
        (32, 512, true),
        (32, 1024, true),
        (32, 2048, true),
        (32, 4096, true),
        (32, 8192, false),
        (64, 512, true),
        (64, 1024, true),
        (64, 2048, true),
        (64, 4096, false),
        (64, 8192, false),
        (128, 512, true),
        (128, 1024, true),
        (128, 2048, false),
        (128, 4096, false),
        (128, 8192, false),
    ];

    #[test]
    fn table6_oom_frontier_matches_exactly() {
        let m = mm();
        for &(b, s, fits) in TABLE6_FITS {
            assert_eq!(
                m.fits(b, s),
                fits,
                "batch {b} seq {s}: modelled {:.1} GB vs capacity {:.1} GB",
                m.total_bytes_fp8(b, s) / 1e9,
                m.capacity_bytes() / 1e9
            );
        }
    }

    #[test]
    fn bf16_llama70b_does_not_fit_single_gaudi2() {
        // Paper §4.2.4: impossible without FP8.
        let m = mm();
        assert!(!m.fits_bf16(1, 512));
        assert!(m.fits(1, 512));
    }

    #[test]
    fn weights_dominate() {
        let m = mm();
        assert!(m.weight_bytes_fp8() > 65e9 && m.weight_bytes_fp8() < 78e9);
        assert!(m.weight_bytes_bf16() > 135e9);
    }

    #[test]
    fn kv_scaling_linear() {
        let m = mm();
        assert_eq!(m.kv_bytes(16, 1024), 2.0 * m.kv_bytes(8, 1024));
        assert_eq!(m.kv_bytes(8, 2048), m.kv_bytes(16, 1024));
    }

    #[test]
    fn step_read_bytes_are_block_quantized() {
        let m = mm();
        // Block-aligned contexts read exactly their resident bytes…
        assert_eq!(m.kv_read_bytes_per_step(8, 512), m.kv_bytes(8, 512));
        // …and a mid-block context rounds up to whole streamed blocks.
        assert_eq!(m.kv_read_bytes_per_step(2, 100), m.kv_bytes(2, 112));
    }

    #[test]
    fn max_batch_matches_frontier() {
        let m = mm();
        assert_eq!(m.max_batch_pow2(8192), Some(16));
        assert_eq!(m.max_batch_pow2(4096), Some(32));
        assert_eq!(m.max_batch_pow2(2048), Some(64));
        assert_eq!(m.max_batch_pow2(1024), Some(128));
    }

    #[test]
    fn kv_dtype_drives_the_frontier() {
        let fp8 = mm();
        let f32m = mm().with_kv_dtype(KvDtype::F32);
        assert_eq!(
            f32m.kv_layout().bytes_per_token(),
            4 * fp8.kv_layout().bytes_per_token()
        );
        // The paper's headline cell (batch 16 × seq 8192) fits only with
        // FP8 KV — with f32 KV the same workload blows the 96 GB budget.
        assert!(fp8.fits(16, 8192));
        assert!(!f32m.fits(16, 8192), "f32 KV must not fit Table 6's 16×8192");
    }

    #[test]
    fn shared_prefix_extends_the_oom_frontier() {
        let m = mm();
        // No sharing: identical to the per-sequence accounting.
        assert_eq!(m.kv_bytes_shared(16, 8192, 0), m.kv_bytes(16, 8192));
        // Bytes saved are (batch − 1) × prefix × rate, exactly.
        let saved = m.kv_bytes(16, 8192) - m.kv_bytes_shared(16, 8192, 1024);
        assert_eq!(saved, 15.0 * 1024.0 * m.kv_layout().bytes_per_token() as f64);
        // Table 6's OOM cell (32, 8192) becomes feasible once the batch
        // shares a long prompt stored once.
        assert!(!m.fits(32, 8192));
        assert!(m.fits_shared(32, 8192, 6144));
        assert!(m.max_batch_pow2_shared(8192, 6144) >= Some(32));
        // A prefix longer than the sequence clamps.
        assert_eq!(
            m.kv_bytes_shared(4, 512, 9999),
            512.0 * m.kv_layout().bytes_per_token() as f64
        );
        // Physical, not logical: a mid-block prefix shares only its
        // block-aligned part, and private tails round up to whole blocks —
        // the same arithmetic the paged pool actually performs.
        let rate = m.kv_layout().bytes_per_token() as f64;
        let bt = crate::quant::KV_BLOCK_TOKENS as f64;
        assert_eq!(
            m.kv_bytes_shared(2, 100, 30),
            (1.0 + 2.0 * 6.0) * bt * rate,
            "30-token prefix shares 1 block; 84-token tails occupy 6 blocks each"
        );
    }

    #[test]
    fn gaudi3_fits_more() {
        let m3 = MemoryModel::new(Device::gaudi3(), ModelConfig::llama31_70b());
        assert!(m3.fits(32, 8192)); // OOM on Gaudi 2
    }

    #[test]
    fn small_models_fit_in_bf16() {
        let m = MemoryModel::new(Device::gaudi2(), ModelConfig::llama3_8b());
        assert!(m.fits_bf16(32, 4096));
    }
}
