//! Analytical performance model of the Gaudi 2/3 accelerators.
//!
//! The paper's throughput numbers (Tables 1, 5, 6) were measured on real
//! hardware; this module reproduces their *shape* from first principles:
//! a roofline over the MME systolic array and HBM, plus the §2.4 scaling
//! fast-path and the §4.2.4 end-to-end prefill/decode FLOPs model.

pub mod device;
pub mod e2e;
pub mod memory;
pub mod mme;

pub use device::{Device, Generation};
pub use e2e::{
    attn_time_s_dense_copy, attn_time_s_paged, chunked_prefill_model_flops,
    chunked_prefill_report, chunked_prefill_time_s, decode_group_model_flops,
    decode_group_report_paged, decode_group_time_s_paged, decode_step_tflops,
    decode_step_tflops_dense, kv_read_bytes_dense, kv_read_bytes_paged, prefill_tflops,
    speculative_expected_tokens_per_round, speculative_round_time_s, speculative_tpot_s,
    E2eConfig, E2eReport, KV_PAGED_STREAM_INEFFICIENCY,
};
pub use memory::MemoryModel;
pub use mme::{
    gemm_time_s, GemmConfig, GemmReport, ScalingKind, GEMM_LAUNCH_OVERHEAD_S,
    PAGED_BLOCK_LAUNCH_OVERHEAD_S,
};
