//! Analytical performance model of the Gaudi 2/3 accelerators.
//!
//! The paper's throughput numbers (Tables 1, 5, 6) were measured on real
//! hardware; this module reproduces their *shape* from first principles:
//! a roofline over the MME systolic array and HBM, plus the §2.4 scaling
//! fast-path and the §4.2.4 end-to-end prefill/decode FLOPs model.

pub mod device;
pub mod e2e;
pub mod memory;
pub mod mme;

pub use device::{Device, Generation};
pub use e2e::{chunked_prefill_time_s, decode_step_tflops, prefill_tflops, E2eConfig};
pub use memory::MemoryModel;
pub use mme::{gemm_time_s, GemmConfig, GemmReport, ScalingKind, GEMM_LAUNCH_OVERHEAD_S};
