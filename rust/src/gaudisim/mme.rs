//! MME GEMM roofline model — regenerates Table 1.
//!
//! Time model for a scaled FP8 GEMM `(M×K)·(K×N) → BF16 (M×N)`:
//!
//! ```text
//! t_total = max(t_mme, t_hbm) + t_scale_exposed + t_fixed
//! t_mme   = 2·M·N·K / (peak · tile_eff)
//! t_hbm   = (M·K + K·N + 2·M·N) / BW            (fp8 in, bf16 out)
//! ```
//!
//! `t_scale_exposed` models the §2.4 scaling fast path: with hardware
//! power-of-two per-tensor scales on *both* inputs the scaling folds into
//! the exponent bias (zero cost). Software scales require a descale pass
//! over the output whose cache-miss fraction grows as the working set
//! exceeds on-chip SRAM; per-channel scales pay a larger coefficient
//! (scale-vector gathers on the TPC). One-sided pow2 halves the software
//! cost (paper: "if only one of the input tensors uses a power-of-two
//! scaling factor, the throughput improvement is reduced").

use super::device::Device;

/// Scaling configuration of a GEMM, in Table 1's terms.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScalingKind {
    /// Per-tensor pow2 scales on both inputs, in the HW-accelerated set.
    PerTensorHwPow2,
    /// Per-tensor pow2 on one input only.
    PerTensorHalfHw,
    /// Per-tensor arbitrary (software) scales.
    PerTensorSw,
    /// Per-output-channel weight scales (+ per-tensor activation).
    PerChannel,
    /// No FP8 — BF16 GEMM baseline.
    Bf16,
}

impl ScalingKind {
    pub fn label(self) -> &'static str {
        match self {
            ScalingKind::PerTensorHwPow2 => "per-tensor (HW pow2)",
            ScalingKind::PerTensorHalfHw => "per-tensor (one-sided pow2)",
            ScalingKind::PerTensorSw => "per-tensor (SW)",
            ScalingKind::PerChannel => "per-channel",
            ScalingKind::Bf16 => "bf16",
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct GemmConfig {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub scaling: ScalingKind,
}

/// Modelled outcome for one GEMM.
#[derive(Clone, Copy, Debug)]
pub struct GemmReport {
    pub time_s: f64,
    pub tflops: f64,
    pub mfu: f64,
    pub compute_bound: bool,
}

/// Fixed per-GEMM launch/pipeline-fill cost (seconds).
const T_FIXED: f64 = 8.0e-6;
/// The launch cost, exported for the chunked-prefill model: a chunked
/// prefill pays this once per linear per chunk, which — together with the
/// small-M weight-reload penalty (`M_HALF` below) — is the floor on how
/// small prefill chunks can usefully get.
pub const GEMM_LAUNCH_OVERHEAD_S: f64 = T_FIXED;
/// Fixed per-block program cost of the paged-attention read path: the
/// block-table walk, descriptor setup, and partial-softmax bookkeeping a
/// kernel pays for every 16-token KV block it streams. Together with
/// `e2e::KV_PAGED_STREAM_INEFFICIENCY` this decomposes the old flat
/// KV-read inefficiency factor into streaming + a per-block launch floor
/// (the two agree at the paper's block-aligned geometries).
pub const PAGED_BLOCK_LAUNCH_OVERHEAD_S: f64 = 5.0e-8;
/// Descale-pass exposure coefficients (fraction of a full output
/// read+write pass that escapes overlap, times spill³).
const SW_SCALE_COEFF: f64 = 1.0;
const PER_CHANNEL_COEFF: f64 = 1.5;
/// Saturating M-dimension efficiency: eff_m = M/(M + M_HALF). Models the
/// weight-reload cost per M-tile column of the output-stationary MME
/// schedule — small-M GEMMs re-stream the stationary operand more often.
const M_HALF: f64 = 192.0;

fn tile_eff(dim: usize, tile: usize) -> f64 {
    let tiles = dim.div_ceil(tile);
    dim as f64 / (tiles * tile) as f64
}

/// Model one GEMM on `dev`.
pub fn gemm_time_s(cfg: &GemmConfig, dev: &Device) -> GemmReport {
    let (m, k, n) = (cfg.m as f64, cfg.k as f64, cfg.n as f64);
    let flops = 2.0 * m * k * n;
    let peak = match cfg.scaling {
        ScalingKind::Bf16 => dev.peak_bf16_tflops,
        _ => dev.peak_fp8_tflops,
    } * 1e12;

    // Tile quantization: partial tiles waste systolic-array slots.
    let eff_tiles = tile_eff(cfg.m, dev.mme_tile)
        * tile_eff(cfg.n, dev.mme_tile)
        * tile_eff(cfg.k, dev.mme_tile).max(0.25);
    let eff_m = m / (m + M_HALF);
    let t_mme = flops / (peak * eff_tiles * eff_m);

    let in_bytes_per_elem = match cfg.scaling {
        ScalingKind::Bf16 => 2.0,
        _ => 1.0,
    };
    let bytes = (m * k + k * n) * in_bytes_per_elem + 2.0 * m * n;
    let bw = dev.hbm_bandwidth_tbps * 1e12;
    let t_hbm = bytes / bw;

    // Working set vs SRAM → spill fraction for the descale pass.
    let sram = dev.sram_mib * 1024.0 * 1024.0;
    let spill = (1.0 - sram / bytes).clamp(0.0, 1.0);
    let descale_pass = 4.0 * m * n / bw; // read+write the bf16 output once
    let spill3 = spill * spill * spill;
    let t_scale = match cfg.scaling {
        ScalingKind::PerTensorHwPow2 | ScalingKind::Bf16 => 0.0,
        ScalingKind::PerTensorHalfHw => 0.5 * SW_SCALE_COEFF * descale_pass * spill3,
        ScalingKind::PerTensorSw => SW_SCALE_COEFF * descale_pass * spill3,
        ScalingKind::PerChannel => PER_CHANNEL_COEFF * descale_pass * spill3,
    };

    let t_total = t_mme.max(t_hbm) + t_scale + T_FIXED;
    let tflops = flops / t_total / 1e12;
    GemmReport {
        time_s: t_total,
        tflops,
        mfu: tflops * 1e12 / (dev.peak_fp8_tflops * 1e12),
        // Compute-bound in the roofline sense: ideal MME time exceeds the
        // HBM streaming time (reload inefficiency at small M is an
        // *efficiency* loss, not arithmetic intensity).
        compute_bound: flops / peak >= t_hbm,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(m: usize, scaling: ScalingKind) -> GemmReport {
        gemm_time_s(
            &GemmConfig {
                m,
                k: m,
                n: m,
                scaling,
            },
            &Device::gaudi2(),
        )
    }

    /// The paper's Table 1, Gaudi 2 (TFLOPS).
    const TABLE1: &[(usize, ScalingKind, f64)] = &[
        (4096, ScalingKind::PerTensorHwPow2, 803.8),
        (4096, ScalingKind::PerTensorSw, 771.4),
        (4096, ScalingKind::PerChannel, 746.5),
        (6144, ScalingKind::PerTensorHwPow2, 849.1),
        (6144, ScalingKind::PerTensorSw, 837.5),
        (6144, ScalingKind::PerChannel, 831.5),
        (8192, ScalingKind::PerTensorHwPow2, 851.2),
        (8192, ScalingKind::PerTensorSw, 800.8),
        (8192, ScalingKind::PerChannel, 760.4),
    ];

    #[test]
    fn table1_within_tolerance() {
        // Absolute MFU within 6 points of every Table-1 cell.
        for &(m, s, paper_tflops) in TABLE1 {
            let got = run(m, s);
            let paper_mfu = paper_tflops / 865.0;
            assert!(
                (got.mfu - paper_mfu).abs() < 0.06,
                "{m} {s:?}: model {:.1}% vs paper {:.1}%",
                got.mfu * 100.0,
                paper_mfu * 100.0
            );
        }
    }

    #[test]
    fn table1_orderings_hold() {
        for m in [4096usize, 6144, 8192] {
            let hw = run(m, ScalingKind::PerTensorHwPow2).tflops;
            let half = run(m, ScalingKind::PerTensorHalfHw).tflops;
            let sw = run(m, ScalingKind::PerTensorSw).tflops;
            let pc = run(m, ScalingKind::PerChannel).tflops;
            assert!(hw >= half && half >= sw && sw >= pc, "m={m}: {hw} {half} {sw} {pc}");
        }
        // MFU improves from 4096 → 6144 (paper: "larger matrices reaching
        // over 98% MFU").
        assert!(run(6144, ScalingKind::PerTensorHwPow2).mfu > run(4096, ScalingKind::PerTensorHwPow2).mfu);
        assert!(run(6144, ScalingKind::PerTensorHwPow2).mfu > 0.94);
        assert!(run(8192, ScalingKind::PerTensorHwPow2).mfu > 0.95);
    }

    #[test]
    fn compute_bound_above_4096() {
        // Paper: "GEMM throughput is compute-bound for the product of
        // matrices larger than 4096×4096".
        for m in [4096usize, 6144, 8192] {
            assert!(run(m, ScalingKind::PerTensorHwPow2).compute_bound, "m={m}");
        }
    }

    #[test]
    fn small_gemms_memory_bound() {
        // Decode-phase shapes (M = batch) are bandwidth-bound.
        let r = gemm_time_s(
            &GemmConfig {
                m: 16,
                k: 8192,
                n: 8192,
                scaling: ScalingKind::PerTensorHwPow2,
            },
            &Device::gaudi2(),
        );
        assert!(!r.compute_bound);
        assert!(r.mfu < 0.1);
    }

    #[test]
    fn fp8_beats_bf16_when_compute_bound() {
        let f8 = run(8192, ScalingKind::PerTensorHwPow2);
        let bf = run(8192, ScalingKind::Bf16);
        let speedup = bf.time_s / f8.time_s;
        assert!(speedup > 1.6 && speedup < 2.2, "speedup={speedup}");
    }

    #[test]
    fn tile_quantization_penalizes_ragged_shapes() {
        let aligned = run(4096, ScalingKind::PerTensorHwPow2);
        let ragged = gemm_time_s(
            &GemmConfig {
                m: 4096 + 1,
                k: 4096,
                n: 4096,
                scaling: ScalingKind::PerTensorHwPow2,
            },
            &Device::gaudi2(),
        );
        assert!(ragged.mfu < aligned.mfu);
    }

    #[test]
    fn gaudi3_faster_than_gaudi2() {
        let cfg = GemmConfig {
            m: 8192,
            k: 8192,
            n: 8192,
            scaling: ScalingKind::PerTensorHwPow2,
        };
        let g2 = gemm_time_s(&cfg, &Device::gaudi2());
        let g3 = gemm_time_s(&cfg, &Device::gaudi3());
        assert!(g3.time_s < g2.time_s / 1.8);
    }
}
