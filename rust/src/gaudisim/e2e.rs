//! End-to-end prefill/decode throughput model — regenerates Tables 5 and 6.
//!
//! Prefill time = Σ per-layer linear GEMMs (FP8 via the MME model)
//!              + attention GEMMs in BF16 (excluded from FP8, Table 5 caption)
//!              + softmax/elementwise TPC passes
//!              + LM head in BF16.
//!
//! Decode time per step = FP8 weight streaming (memory-bound at batch
//! sizes ≤ 128) + BF16 LM-head streaming + KV-cache reads (with a paged-
//! attention inefficiency factor) + a fixed per-step overhead.
//!
//! Reported TFLOPS divide the Kim-et-al model FLOPs (attention-mask FLOPs
//! excluded) by the modelled time — exactly how the paper computes its
//! numbers, which is why Table 5's MFU is "understated".

use super::device::Device;
use super::mme::{gemm_time_s, GemmConfig, ScalingKind};
use crate::model::config::ModelConfig;
use crate::model::flops::{decode_step_model_flops, prefill_model_flops};
use crate::model::layers::{enumerate_linears, LayerKind};

/// Attention KV-read inefficiency in decode: paged/batched attention kernels
/// do not stream the KV cache at full HBM bandwidth.
const KV_READ_INEFFICIENCY: f64 = 3.25;
/// Fixed per-decode-step host+graph overhead (s): sampling, bookkeeping.
const DECODE_STEP_OVERHEAD_S: f64 = 4.5e-3;
/// Batched-attention BF16 GEMM efficiency during prefill.
const ATTN_BF16_EFF: f64 = 0.60;

#[derive(Clone, Debug)]
pub struct E2eConfig {
    pub model: ModelConfig,
    pub device: Device,
    /// Scaling used for the FP8 linears.
    pub scaling: ScalingKind,
    /// Include the LM head in time (it always runs, in BF16).
    pub lm_head_bf16: bool,
}

impl E2eConfig {
    pub fn llama31_70b_paper() -> Self {
        Self {
            model: ModelConfig::llama31_70b(),
            device: Device::gaudi2(),
            scaling: ScalingKind::PerTensorHwPow2,
            lm_head_bf16: true,
        }
    }
}

/// Report for one e2e measurement.
#[derive(Clone, Copy, Debug)]
pub struct E2eReport {
    pub time_s: f64,
    pub model_flops: f64,
    pub tflops: f64,
    pub mfu: f64,
}

/// Σ linear-GEMM time for one forward pass over `rows` tokens (FP8
/// linears via the MME model, BF16 LM head when configured) — shared by
/// the full-prefill and chunked-prefill paths.
fn linears_time_s(cfg: &E2eConfig, rows: usize) -> f64 {
    let dev = &cfg.device;
    let m = &cfg.model;
    let mut t = 0.0f64;
    for op in enumerate_linears(m) {
        match op.kind {
            LayerKind::Embedding => continue, // gather, negligible
            LayerKind::LmHead => {
                if cfg.lm_head_bf16 {
                    t += gemm_time_s(
                        &GemmConfig {
                            m: rows,
                            k: op.in_features,
                            n: op.out_features,
                            scaling: ScalingKind::Bf16,
                        },
                        dev,
                    )
                    .time_s;
                }
            }
            _ => {
                // MoE: only active experts run, each on a token subset.
                let share = if op.instances > 1 {
                    m.active_experts as f64 / op.instances as f64
                } else {
                    1.0
                };
                let r = ((rows as f64 * share) as usize).max(1);
                let inst = if op.instances > 1 { m.experts } else { 1 };
                // Router / expert GEMMs: instances that actually execute.
                let active_inst = if op.instances > 1 {
                    inst.min(m.active_experts.max(1))
                } else {
                    1
                };
                let one = gemm_time_s(
                    &GemmConfig {
                        m: r,
                        k: op.in_features,
                        n: op.out_features,
                        scaling: cfg.scaling,
                    },
                    dev,
                );
                t += one.time_s * active_inst as f64;
            }
        }
    }
    t
}

/// BF16 attention GEMMs + TPC softmax time for `rows` new tokens attending
/// over a `ctx`-key context, across all layers.
fn attn_time_s(cfg: &E2eConfig, rows: usize, ctx: usize) -> f64 {
    let dev = &cfg.device;
    let m = &cfg.model;
    // QKᵀ and PV in BF16: 4·rows·ctx·hidden FLOPs per layer.
    let attn_flops = 4.0 * (rows as f64) * (ctx as f64) * m.hidden as f64;
    let attn_rate = dev.peak_bf16_tflops * 1e12 * ATTN_BF16_EFF;
    // Softmax & masking on TPC: one pass over rows·ctx·heads elements.
    let softmax_elems = (rows as f64) * (ctx as f64) * m.heads as f64;
    m.layers as f64 * (attn_flops / attn_rate + softmax_elems / (dev.tpc_gelems_per_s * 1e9))
}

/// Prefill one sequence of `seq` tokens (batch 1), as in Table 5.
pub fn prefill_tflops(cfg: &E2eConfig, seq: usize) -> E2eReport {
    let dev = &cfg.device;
    let m = &cfg.model;
    let t = linears_time_s(cfg, seq) + attn_time_s(cfg, seq, seq);

    let model_flops = prefill_model_flops(m, seq, cfg.lm_head_bf16);
    let tflops = model_flops / t / 1e12;
    E2eReport {
        time_s: t,
        model_flops,
        tflops,
        mfu: tflops / dev.peak_fp8_tflops,
    }
}

/// One decode step for `batch` sequences at context `context` (Table 6
/// measures 256 such steps before the target length; steady-state per-step
/// numbers are equivalent).
pub fn decode_step_tflops(cfg: &E2eConfig, batch: usize, context: usize) -> E2eReport {
    let dev = &cfg.device;
    let m = &cfg.model;
    let bw = dev.hbm_bandwidth_tbps * 1e12;

    // Linear weights stream from HBM once per step (batch ≤ 128 keeps every
    // linear memory-bound). Active experts only for MoE.
    let linear_bytes = {
        let per_layer = m.attn_params_per_layer() as f64
            + m.active_experts as f64 * m.mlp_params_per_expert() as f64;
        m.layers as f64 * per_layer // FP8: 1 byte/param
    };
    let mut t = linear_bytes / bw;

    // LM head in BF16.
    if cfg.lm_head_bf16 {
        t += (m.vocab * m.hidden) as f64 * 2.0 / bw;
    }

    // KV reads: whole cache once per step, with paged-attention inefficiency.
    let kv_bytes = (batch * context) as f64 * m.kv_bytes_per_token(1) as f64;
    t += KV_READ_INEFFICIENCY * kv_bytes / bw;

    t += DECODE_STEP_OVERHEAD_S;

    let model_flops = decode_step_model_flops(m, batch, context, cfg.lm_head_bf16);
    let tflops = model_flops / t / 1e12;
    E2eReport {
        time_s: t,
        model_flops,
        tflops,
        mfu: tflops / dev.peak_fp8_tflops,
    }
}

/// Chunked prefill with a shared-prefix cache: `cached` prompt tokens are
/// skipped outright (their KV is already resident — the FLOP and HBM
/// saving the radix cache buys), and the uncached tail is computed in
/// `chunk_tokens`-sized pieces (0 = one chunk). Each chunk pays its linear
/// GEMMs at M = chunk — exposing the small-M weight-reload penalty and the
/// per-GEMM launch overhead (`mme::GEMM_LAUNCH_OVERHEAD_S`), which is why
/// tiny chunks cost more than one big one — plus attention over the full
/// context accumulated so far.
///
/// Attention is charged *causally* here (chunk rows attend only to the
/// keys accumulated so far), while the one-shot dense prefill above pays
/// the full masked square (`attn_time_s(S, S)`). Both are real: a dense
/// single-pass kernel computes the masked upper triangle anyway, chunked
/// execution never materializes it — so a many-chunk tail recovers up to
/// ~2× of the attention time, partially offsetting the launch/small-M
/// overheads. The single-chunk case degenerates to the same square as
/// `prefill_tflops` by construction.
///
/// A full hit (`cached ≥ prompt`) costs one batch-1 decode step: the last
/// prompt position is recomputed so its logits (the first-token sample)
/// exist.
pub fn chunked_prefill_time_s(
    cfg: &E2eConfig,
    prompt: usize,
    cached: usize,
    chunk_tokens: usize,
) -> f64 {
    let cached = cached.min(prompt);
    if cached >= prompt {
        return decode_step_tflops(cfg, 1, prompt.max(1)).time_s;
    }
    let step = if chunk_tokens == 0 {
        prompt - cached
    } else {
        chunk_tokens.max(1)
    };
    let mut t = 0.0f64;
    let mut pos = cached;
    while pos < prompt {
        let c = step.min(prompt - pos);
        t += linears_time_s(cfg, c) + attn_time_s(cfg, c, pos + c);
        pos += c;
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper Table 5: Llama v3.1 70B prefill on one Gaudi 2, HW-accelerated
    /// static per-tensor FP8 (attention + LM head excluded from FP8).
    const TABLE5: &[(usize, f64)] = &[
        (1024, 649.1),
        (2048, 671.0),
        (4096, 602.8),
        (8192, 513.7),
        (16384, 390.1),
    ];

    #[test]
    fn table5_prefill_within_tolerance() {
        let cfg = E2eConfig::llama31_70b_paper();
        for &(seq, paper) in TABLE5 {
            let got = prefill_tflops(&cfg, seq);
            let rel = (got.tflops - paper).abs() / paper;
            assert!(
                rel < 0.10,
                "seq {seq}: model {:.1} TF vs paper {paper} TF ({:.1}% off)",
                got.tflops,
                rel * 100.0
            );
        }
    }

    #[test]
    fn table5_shape_rise_then_decay() {
        let cfg = E2eConfig::llama31_70b_paper();
        let t: Vec<f64> = TABLE5
            .iter()
            .map(|(s, _)| prefill_tflops(&cfg, *s).tflops)
            .collect();
        assert!(t[1] > t[0], "2048 should beat 1024: {t:?}");
        assert!(t[1] > t[2] && t[2] > t[3] && t[3] > t[4], "decay: {t:?}");
    }

    #[test]
    fn prefill_beats_peak_bf16_even_at_8k() {
        // Paper: "even for 8096-long sequences, FP8 improves prefill
        // throughput to levels above the peak BF16 GEMM throughput" (432).
        let cfg = E2eConfig::llama31_70b_paper();
        assert!(prefill_tflops(&cfg, 8192).tflops > 432.0);
    }

    /// Paper Table 6 (decode TFLOPS), non-OOM cells.
    const TABLE6: &[(usize, usize, f64)] = &[
        (8, 512, 32.8),
        (8, 1024, 32.4),
        (8, 2048, 30.8),
        (8, 4096, 30.2),
        (8, 8192, 23.4),
        (16, 512, 63.2),
        (16, 1024, 61.5),
        (16, 2048, 55.8),
        (16, 4096, 51.4),
        (16, 8192, 39.6),
        (32, 512, 120.1),
        (32, 1024, 112.0),
        (32, 2048, 94.1),
        (32, 4096, 79.5),
        (64, 512, 224.1),
        (64, 1024, 198.8),
        (64, 2048, 152.3),
        (128, 512, 387.1),
        (128, 1024, 312.8),
    ];

    #[test]
    fn table6_decode_within_tolerance() {
        let cfg = E2eConfig::llama31_70b_paper();
        for &(b, s, paper) in TABLE6 {
            let got = decode_step_tflops(&cfg, b, s);
            let rel = (got.tflops - paper).abs() / paper;
            // 18%: the (8, 8192) cell is the paper's own outlier (it breaks
            // the otherwise smooth context-decay trend of its row); every
            // other cell lands within ~8%.
            assert!(
                rel < 0.18,
                "batch {b} seq {s}: model {:.1} vs paper {paper} ({:.1}% off)",
                got.tflops,
                rel * 100.0
            );
        }
    }

    #[test]
    fn table6_shape_properties() {
        let cfg = E2eConfig::llama31_70b_paper();
        // Throughput grows with batch (weights amortized)...
        for s in [512usize, 1024] {
            let t8 = decode_step_tflops(&cfg, 8, s).tflops;
            let t128 = decode_step_tflops(&cfg, 128, s).tflops;
            assert!(t128 > 5.0 * t8, "batch scaling at seq {s}");
        }
        // ...and decays with context length (KV reads dominate).
        for b in [8usize, 16, 32] {
            let short = decode_step_tflops(&cfg, b, 512).tflops;
            let long = decode_step_tflops(&cfg, b, 8192).tflops;
            assert!(short > long, "context decay at batch {b}");
        }
    }

    #[test]
    fn decode_far_below_prefill_mfu() {
        // Decode is memory-bound: MFU well under 50% of prefill's.
        let cfg = E2eConfig::llama31_70b_paper();
        let d = decode_step_tflops(&cfg, 32, 2048).mfu;
        let p = prefill_tflops(&cfg, 2048).mfu;
        assert!(d < 0.5 * p, "decode {d} prefill {p}");
    }

    #[test]
    fn chunked_prefill_single_cold_chunk_matches_full_prefill() {
        let cfg = E2eConfig::llama31_70b_paper();
        for seq in [1024usize, 4096] {
            let full = prefill_tflops(&cfg, seq).time_s;
            let chunked = chunked_prefill_time_s(&cfg, seq, 0, 0);
            assert!(
                (full - chunked).abs() / full < 1e-9,
                "seq {seq}: {full} vs {chunked}"
            );
        }
    }

    #[test]
    fn cached_prefix_cuts_prefill_time() {
        let cfg = E2eConfig::llama31_70b_paper();
        let cold = chunked_prefill_time_s(&cfg, 4096, 0, 512);
        let half = chunked_prefill_time_s(&cfg, 4096, 2048, 512);
        let full = chunked_prefill_time_s(&cfg, 4096, 4096, 512);
        assert!(half < cold, "half-cached must be cheaper: {half} vs {cold}");
        assert!(full < half, "full hit must be cheapest: {full} vs {half}");
        // The acceptance mechanism: a warm prompt reaches first-token ≥ 2×
        // faster than a cold one.
        assert!(cold / full >= 2.0, "TTFT gain {:.2}x < 2x", cold / full);
        // Full hit = one bootstrap decode step, exactly.
        let boot = decode_step_tflops(&cfg, 1, 4096).time_s;
        assert!((full - boot).abs() < 1e-12);
    }

    #[test]
    fn tiny_chunks_pay_launch_and_reload_overhead() {
        use super::super::mme::GEMM_LAUNCH_OVERHEAD_S;
        let cfg = E2eConfig::llama31_70b_paper();
        let big = chunked_prefill_time_s(&cfg, 4096, 0, 2048);
        let small = chunked_prefill_time_s(&cfg, 4096, 0, 128);
        assert!(small > big, "128-token chunks must cost more than 2048");
        // Floor: 32 chunks each pay at least one GEMM launch.
        assert!(small >= 32.0 * GEMM_LAUNCH_OVERHEAD_S);
    }

    #[test]
    fn moe_decode_streams_fewer_bytes() {
        // Mixtral's active-expert streaming beats a dense model of equal
        // total size.
        let dense = E2eConfig {
            model: ModelConfig::llama31_70b(),
            ..E2eConfig::llama31_70b_paper()
        };
        let moe = E2eConfig {
            model: ModelConfig::mixtral_8x7b(),
            ..E2eConfig::llama31_70b_paper()
        };
        let td = decode_step_tflops(&dense, 8, 512).time_s;
        let tm = decode_step_tflops(&moe, 8, 512).time_s;
        assert!(tm < td);
    }
}
