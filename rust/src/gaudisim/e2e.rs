//! End-to-end prefill/decode throughput model — regenerates Tables 5 and 6.
//!
//! Prefill time = Σ per-layer linear GEMMs (FP8 via the MME model)
//!              + attention GEMMs in BF16 (excluded from FP8, Table 5 caption)
//!              + softmax/elementwise TPC passes
//!              + LM head in BF16.
//!
//! Decode time per step = FP8 weight streaming (memory-bound at batch
//! sizes ≤ 128) + BF16 LM-head streaming + KV-cache reads + a fixed
//! per-step overhead.
//!
//! KV reads are priced with **two models** since ISSUE 5's block-table-
//! native decode:
//!
//! * [`attn_time_s_paged`] — the hot path: each slot streams exactly its
//!   live 16-token blocks (ceil-to-block, no batch-bucket rows, no window
//!   padding) at [`KV_PAGED_STREAM_INEFFICIENCY`], plus a fixed
//!   per-block program cost ([`PAGED_BLOCK_LAUNCH_OVERHEAD_S`]). At the
//!   paper's block-aligned uniform geometries this reproduces the old
//!   flat 3.25× factor (Table 6 asserts are unchanged), and for ragged
//!   groups it charges actual block bytes instead of the group max.
//! * [`attn_time_s_dense_copy`] — the pre-paged reference: every row of
//!   the compiled `bucket` padded to the full context window, the cost
//!   the old gather/scatter engine actually paid.
//!
//! Reported TFLOPS divide the Kim-et-al model FLOPs (attention-mask FLOPs
//! excluded) by the modelled time — exactly how the paper computes its
//! numbers, which is why Table 5's MFU is "understated".

use super::device::Device;
use super::mme::{gemm_time_s, GemmConfig, ScalingKind, PAGED_BLOCK_LAUNCH_OVERHEAD_S};
use crate::model::config::ModelConfig;
use crate::model::flops::{decode_step_model_flops, prefill_model_flops};
use crate::model::layers::{enumerate_linears, LayerKind};
use crate::quant::KV_BLOCK_TOKENS;

/// Attention KV-read inefficiency of the dense-copy reference path:
/// batched attention over a bucket-padded dense cache does not stream at
/// full HBM bandwidth.
const KV_READ_INEFFICIENCY: f64 = 3.25;
/// Streaming inefficiency of the paged read path proper. Slightly below
/// the flat dense factor because the per-block launch floor
/// ([`PAGED_BLOCK_LAUNCH_OVERHEAD_S`]) now carries the non-streaming share
/// explicitly; at 70B-geometry block sizes the two decompositions agree to
/// ~0.1%.
pub const KV_PAGED_STREAM_INEFFICIENCY: f64 = 3.2;
/// Fixed per-decode-step host+graph overhead (s): sampling, bookkeeping.
const DECODE_STEP_OVERHEAD_S: f64 = 4.5e-3;
/// Batched-attention BF16 GEMM efficiency during prefill.
const ATTN_BF16_EFF: f64 = 0.60;

#[derive(Clone, Debug)]
pub struct E2eConfig {
    pub model: ModelConfig,
    pub device: Device,
    /// Scaling used for the FP8 linears.
    pub scaling: ScalingKind,
    /// Include the LM head in time (it always runs, in BF16).
    pub lm_head_bf16: bool,
}

impl E2eConfig {
    pub fn llama31_70b_paper() -> Self {
        Self {
            model: ModelConfig::llama31_70b(),
            device: Device::gaudi2(),
            scaling: ScalingKind::PerTensorHwPow2,
            lm_head_bf16: true,
        }
    }

    /// The draft geometry speculative decoding prices its propose steps
    /// at: the tiny synthetic Llama-family stack (~8M params) on the same
    /// device — the "1%-of-target" draft the literature assumes. Its
    /// decode step is overhead-dominated (`DECODE_STEP_OVERHEAD_S`), the
    /// honest floor for a small model on a big accelerator.
    pub fn synthetic_tiny_draft() -> Self {
        Self {
            model: ModelConfig::synthetic_tiny(crate::model::config::ModelFamily::Llama3),
            device: Device::gaudi2(),
            scaling: ScalingKind::PerTensorHwPow2,
            lm_head_bf16: false,
        }
    }
}

/// Report for one e2e measurement.
#[derive(Clone, Copy, Debug)]
pub struct E2eReport {
    pub time_s: f64,
    pub model_flops: f64,
    pub tflops: f64,
    pub mfu: f64,
}

/// Σ linear-GEMM time for one forward pass over `rows` tokens (FP8
/// linears via the MME model, BF16 LM head when configured) — shared by
/// the full-prefill and chunked-prefill paths.
fn linears_time_s(cfg: &E2eConfig, rows: usize) -> f64 {
    let dev = &cfg.device;
    let m = &cfg.model;
    let mut t = 0.0f64;
    for op in enumerate_linears(m) {
        match op.kind {
            LayerKind::Embedding => continue, // gather, negligible
            LayerKind::LmHead => {
                if cfg.lm_head_bf16 {
                    t += gemm_time_s(
                        &GemmConfig {
                            m: rows,
                            k: op.in_features,
                            n: op.out_features,
                            scaling: ScalingKind::Bf16,
                        },
                        dev,
                    )
                    .time_s;
                }
            }
            _ => {
                // MoE: only active experts run, each on a token subset.
                let share = if op.instances > 1 {
                    m.active_experts as f64 / op.instances as f64
                } else {
                    1.0
                };
                let r = ((rows as f64 * share) as usize).max(1);
                let inst = if op.instances > 1 { m.experts } else { 1 };
                // Router / expert GEMMs: instances that actually execute.
                let active_inst = if op.instances > 1 {
                    inst.min(m.active_experts.max(1))
                } else {
                    1
                };
                let one = gemm_time_s(
                    &GemmConfig {
                        m: r,
                        k: op.in_features,
                        n: op.out_features,
                        scaling: cfg.scaling,
                    },
                    dev,
                );
                t += one.time_s * active_inst as f64;
            }
        }
    }
    t
}

/// BF16 attention GEMMs + TPC softmax time for `rows` new tokens attending
/// over a `ctx`-key context, across all layers.
fn attn_time_s(cfg: &E2eConfig, rows: usize, ctx: usize) -> f64 {
    let dev = &cfg.device;
    let m = &cfg.model;
    // QKᵀ and PV in BF16: 4·rows·ctx·hidden FLOPs per layer.
    let attn_flops = 4.0 * (rows as f64) * (ctx as f64) * m.hidden as f64;
    let attn_rate = dev.peak_bf16_tflops * 1e12 * ATTN_BF16_EFF;
    // Softmax & masking on TPC: one pass over rows·ctx·heads elements.
    let softmax_elems = (rows as f64) * (ctx as f64) * m.heads as f64;
    m.layers as f64 * (attn_flops / attn_rate + softmax_elems / (dev.tpc_gelems_per_s * 1e9))
}

/// Prefill one sequence of `seq` tokens (batch 1), as in Table 5.
pub fn prefill_tflops(cfg: &E2eConfig, seq: usize) -> E2eReport {
    let dev = &cfg.device;
    let m = &cfg.model;
    let t = linears_time_s(cfg, seq) + attn_time_s(cfg, seq, seq);

    let model_flops = prefill_model_flops(m, seq, cfg.lm_head_bf16);
    let tflops = model_flops / t / 1e12;
    E2eReport {
        time_s: t,
        model_flops,
        tflops,
        mfu: tflops / dev.peak_fp8_tflops,
    }
}

/// Weight streaming per decode step: FP8 linears (active experts only for
/// MoE) plus the BF16 LM head — the batch-independent, memory-bound core
/// shared by the paged and dense-copy decode models.
fn decode_weights_time_s(cfg: &E2eConfig) -> f64 {
    let m = &cfg.model;
    let bw = cfg.device.hbm_bandwidth_tbps * 1e12;
    let linear_bytes = {
        let per_layer = m.attn_params_per_layer() as f64
            + m.active_experts as f64 * m.mlp_params_per_expert() as f64;
        m.layers as f64 * per_layer // FP8: 1 byte/param
    };
    let mut t = linear_bytes / bw;
    if cfg.lm_head_bf16 {
        t += (m.vocab * m.hidden) as f64 * 2.0 / bw;
    }
    t
}

/// Physical KV bytes a paged decode step reads for per-slot contexts:
/// whole 16-token blocks (`ceil(ctx / bt) · bt` tokens each) at the FP8
/// rate — and nothing else. No batch-bucket rows, no window padding.
pub fn kv_read_bytes_paged(m: &ModelConfig, ctxs: &[usize]) -> f64 {
    let rate = m.kv_bytes_per_token(1) as f64;
    ctxs.iter()
        .map(|&c| (c.div_ceil(KV_BLOCK_TOKENS) * KV_BLOCK_TOKENS) as f64 * rate)
        .sum()
}

/// KV bytes the dense-copy reference moves per step: every row of the
/// compiled `bucket` padded to the full `window` — the (L, B, T, …)
/// staging the pre-paged engine gathered and scattered.
pub fn kv_read_bytes_dense(m: &ModelConfig, bucket: usize, window: usize) -> f64 {
    (bucket * window) as f64 * m.kv_bytes_per_token(1) as f64
}

/// Paged-attention KV read time for a decode group with per-slot contexts:
/// actual live block bytes at [`KV_PAGED_STREAM_INEFFICIENCY`], plus the
/// per-block program cost — the pricing of the block-table-native path.
pub fn attn_time_s_paged(cfg: &E2eConfig, ctxs: &[usize]) -> f64 {
    let bw = cfg.device.hbm_bandwidth_tbps * 1e12;
    let blocks: usize = ctxs.iter().map(|&c| c.div_ceil(KV_BLOCK_TOKENS)).sum();
    KV_PAGED_STREAM_INEFFICIENCY * kv_read_bytes_paged(&cfg.model, ctxs) / bw
        + blocks as f64 * PAGED_BLOCK_LAUNCH_OVERHEAD_S
}

/// Dense-copy KV read time: the whole bucket-padded window streams once
/// per step at the flat inefficiency — what the old gather/scatter decode
/// path paid regardless of live context.
pub fn attn_time_s_dense_copy(cfg: &E2eConfig, bucket: usize, window: usize) -> f64 {
    let bw = cfg.device.hbm_bandwidth_tbps * 1e12;
    KV_READ_INEFFICIENCY * kv_read_bytes_dense(&cfg.model, bucket, window) / bw
}

/// Full decode-step time for a (possibly ragged) group under the paged
/// model: weight streaming + per-slot paged KV reads + fixed overhead.
/// Padding rows of a compiled batch bucket cost nothing on the KV side —
/// they have no blocks to read.
pub fn decode_group_time_s_paged(cfg: &E2eConfig, ctxs: &[usize]) -> f64 {
    decode_weights_time_s(cfg) + attn_time_s_paged(cfg, ctxs) + DECODE_STEP_OVERHEAD_S
}

/// Kim-et-al model FLOPs of one decode step over a ragged group: the sum
/// of per-slot batch-1 decode FLOPs. The linear (and LM-head) term scales
/// with the group size and the attention term with each slot's own
/// context, so at uniform contexts this equals the batched
/// [`decode_step_model_flops`] exactly.
pub fn decode_group_model_flops(cfg: &E2eConfig, ctxs: &[usize]) -> f64 {
    ctxs.iter()
        .map(|&c| decode_step_model_flops(&cfg.model, 1, c.max(1), cfg.lm_head_bf16))
        .sum()
}

/// Time + FLOPs + achieved TFLOPS + MFU for one ragged paged decode group
/// — the per-step utilization sample the serving telemetry records.
pub fn decode_group_report_paged(cfg: &E2eConfig, ctxs: &[usize]) -> E2eReport {
    let time_s = decode_group_time_s_paged(cfg, ctxs);
    let model_flops = decode_group_model_flops(cfg, ctxs);
    let tflops = model_flops / time_s / 1e12;
    E2eReport {
        time_s,
        model_flops,
        tflops,
        mfu: tflops / cfg.device.peak_fp8_tflops,
    }
}

/// Model FLOPs matching [`chunked_prefill_time_s`]'s execution shape:
/// each chunk pays its linears (and LM head, when configured) at
/// M = chunk rows, plus *causal* attention over the context accumulated
/// so far — the chunks never materialize the masked square, so the FLOPs
/// model must not charge it either, or chunked MFU would be overstated.
/// A single cold chunk degenerates to [`prefill_model_flops`] exactly; a
/// full hit costs one batch-1 decode step, mirroring the time model.
pub fn chunked_prefill_model_flops(
    cfg: &E2eConfig,
    prompt: usize,
    cached: usize,
    chunk_tokens: usize,
) -> f64 {
    let m = &cfg.model;
    let cached = cached.min(prompt);
    if cached >= prompt {
        return decode_step_model_flops(m, 1, prompt.max(1), cfg.lm_head_bf16);
    }
    let step = if chunk_tokens == 0 {
        prompt - cached
    } else {
        chunk_tokens.max(1)
    };
    let per_layer_lin = m.attn_params_per_layer() as f64
        + m.active_experts as f64 * m.mlp_params_per_expert() as f64;
    let mut flops = 0.0f64;
    let mut pos = cached;
    while pos < prompt {
        let c = step.min(prompt - pos);
        let rows = c as f64;
        let ctx = (pos + c) as f64;
        flops += 2.0 * m.layers as f64 * per_layer_lin * rows;
        flops += 4.0 * m.layers as f64 * rows * ctx * m.hidden as f64;
        if cfg.lm_head_bf16 {
            flops += 2.0 * rows * m.hidden as f64 * m.vocab as f64;
        }
        pos += c;
    }
    flops
}

/// Time + FLOPs + achieved TFLOPS + MFU for a (possibly warm, possibly
/// chunked) prefill — the per-admission utilization sample.
pub fn chunked_prefill_report(
    cfg: &E2eConfig,
    prompt: usize,
    cached: usize,
    chunk_tokens: usize,
) -> E2eReport {
    let time_s = chunked_prefill_time_s(cfg, prompt, cached, chunk_tokens);
    let model_flops = chunked_prefill_model_flops(cfg, prompt, cached, chunk_tokens);
    let tflops = model_flops / time_s / 1e12;
    E2eReport {
        time_s,
        model_flops,
        tflops,
        mfu: tflops / cfg.device.peak_fp8_tflops,
    }
}

/// One decode step for `batch` sequences at context `context` (Table 6
/// measures 256 such steps before the target length; steady-state per-step
/// numbers are equivalent). Priced through the **paged** read model —
/// uniform block-aligned contexts reproduce the paper's flat-factor
/// numbers, so the Table 6 asserts below hold unchanged.
pub fn decode_step_tflops(cfg: &E2eConfig, batch: usize, context: usize) -> E2eReport {
    let m = &cfg.model;
    let ctxs = vec![context; batch];
    let t = decode_group_time_s_paged(cfg, &ctxs);
    let model_flops = decode_step_model_flops(m, batch, context, cfg.lm_head_bf16);
    let tflops = model_flops / t / 1e12;
    E2eReport {
        time_s: t,
        model_flops,
        tflops,
        mfu: tflops / cfg.device.peak_fp8_tflops,
    }
}

/// The dense-copy reference step: `bucket` rows all padded to `window`
/// context on the KV side. FLOPs are charged at the true `context` (the
/// padding is masked — it moves bytes, not useful arithmetic), so the
/// TFLOPS gap against [`decode_step_tflops`] is exactly the cost of the
/// per-step densify the paged path deleted.
pub fn decode_step_tflops_dense(
    cfg: &E2eConfig,
    bucket: usize,
    context: usize,
    window: usize,
) -> E2eReport {
    let m = &cfg.model;
    let t = decode_weights_time_s(cfg)
        + attn_time_s_dense_copy(cfg, bucket, window.max(context))
        + DECODE_STEP_OVERHEAD_S;
    let model_flops = decode_step_model_flops(m, bucket, context, cfg.lm_head_bf16);
    let tflops = model_flops / t / 1e12;
    E2eReport {
        time_s: t,
        model_flops,
        tflops,
        mfu: tflops / cfg.device.peak_fp8_tflops,
    }
}

/// Chunked prefill with a shared-prefix cache: `cached` prompt tokens are
/// skipped outright (their KV is already resident — the FLOP and HBM
/// saving the radix cache buys), and the uncached tail is computed in
/// `chunk_tokens`-sized pieces (0 = one chunk). Each chunk pays its linear
/// GEMMs at M = chunk — exposing the small-M weight-reload penalty and the
/// per-GEMM launch overhead (`mme::GEMM_LAUNCH_OVERHEAD_S`), which is why
/// tiny chunks cost more than one big one — plus attention over the full
/// context accumulated so far.
///
/// Attention is charged *causally* here (chunk rows attend only to the
/// keys accumulated so far), while the one-shot dense prefill above pays
/// the full masked square (`attn_time_s(S, S)`). Both are real: a dense
/// single-pass kernel computes the masked upper triangle anyway, chunked
/// execution never materializes it — so a many-chunk tail recovers up to
/// ~2× of the attention time, partially offsetting the launch/small-M
/// overheads. The single-chunk case degenerates to the same square as
/// `prefill_tflops` by construction.
///
/// A full hit (`cached ≥ prompt`) costs one batch-1 decode step: the last
/// prompt position is recomputed so its logits (the first-token sample)
/// exist.
pub fn chunked_prefill_time_s(
    cfg: &E2eConfig,
    prompt: usize,
    cached: usize,
    chunk_tokens: usize,
) -> f64 {
    let cached = cached.min(prompt);
    if cached >= prompt {
        return decode_step_tflops(cfg, 1, prompt.max(1)).time_s;
    }
    let step = if chunk_tokens == 0 {
        prompt - cached
    } else {
        chunk_tokens.max(1)
    };
    let mut t = 0.0f64;
    let mut pos = cached;
    while pos < prompt {
        let c = step.min(prompt - pos);
        t += linears_time_s(cfg, c) + attn_time_s(cfg, c, pos + c);
        pos += c;
    }
    t
}

/// One speculative draft-verify round (batch-1 latency mode), priced from
/// the same primitives as Tables 5/6 — nothing here touches the existing
/// prefill/decode pricing, so the paper anchors re-derive unchanged.
///
/// The round runs `gamma` *draft* decode steps (the draft geometry's
/// paged decode cost at the growing context) and then one *target*
/// chunked multi-token verify over the `gamma + 1` new positions (the
/// previous token plus the γ proposals — exactly a `chunked_prefill_time_s`
/// chunk with the context cached). This is the paper's Table 5 vs Table 6
/// gap turned into a latency win: the verify step runs the FP8 MME at
/// near-prefill utilization where token-by-token decode (Table 6, batch 1)
/// leaves it idle at ~33 ms/step of weight streaming.
pub fn speculative_round_time_s(
    target: &E2eConfig,
    draft: &E2eConfig,
    context: usize,
    gamma: usize,
) -> f64 {
    let context = context.max(1);
    let mut t = 0.0f64;
    for i in 0..gamma {
        t += decode_group_time_s_paged(draft, &[context + i]);
    }
    t + chunked_prefill_time_s(target, context + gamma + 1, context, gamma + 1)
}

/// Expected tokens emitted per draft-verify round under the greedy
/// accept-prefix rule with per-token acceptance probability `acceptance`
/// (i.i.d., the standard speculative-decoding analysis): the accepted
/// prefix plus the one token every round always yields (the correction
/// on reject, the bonus on full accept) —
/// `E = Σ_{i=0}^{γ} α^i = (1 − α^{γ+1}) / (1 − α)`, which is `γ + 1` at
/// `α = 1` and `1` at `α = 0`. Rounds never emit zero tokens, so
/// speculative decode never stalls; at `α → 0` it degrades to plain
/// decode plus the bounded draft + verify-overhead cost.
pub fn speculative_expected_tokens_per_round(gamma: usize, acceptance: f64) -> f64 {
    let a = acceptance.clamp(0.0, 1.0);
    if (1.0 - a).abs() < 1e-12 {
        return (gamma + 1) as f64;
    }
    (1.0 - a.powi(gamma as i32 + 1)) / (1.0 - a)
}

/// Expected single-stream TPOT under speculation: round cost amortized
/// over the expected emitted tokens. Compare against
/// `decode_group_time_s_paged(target, &[context])` — the token-by-token
/// baseline TPOT at the same context.
pub fn speculative_tpot_s(
    target: &E2eConfig,
    draft: &E2eConfig,
    context: usize,
    gamma: usize,
    acceptance: f64,
) -> f64 {
    speculative_round_time_s(target, draft, context, gamma)
        / speculative_expected_tokens_per_round(gamma, acceptance)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper Table 5: Llama v3.1 70B prefill on one Gaudi 2, HW-accelerated
    /// static per-tensor FP8 (attention + LM head excluded from FP8).
    const TABLE5: &[(usize, f64)] = &[
        (1024, 649.1),
        (2048, 671.0),
        (4096, 602.8),
        (8192, 513.7),
        (16384, 390.1),
    ];

    #[test]
    fn table5_prefill_within_tolerance() {
        let cfg = E2eConfig::llama31_70b_paper();
        for &(seq, paper) in TABLE5 {
            let got = prefill_tflops(&cfg, seq);
            let rel = (got.tflops - paper).abs() / paper;
            assert!(
                rel < 0.10,
                "seq {seq}: model {:.1} TF vs paper {paper} TF ({:.1}% off)",
                got.tflops,
                rel * 100.0
            );
        }
    }

    #[test]
    fn table5_shape_rise_then_decay() {
        let cfg = E2eConfig::llama31_70b_paper();
        let t: Vec<f64> = TABLE5
            .iter()
            .map(|(s, _)| prefill_tflops(&cfg, *s).tflops)
            .collect();
        assert!(t[1] > t[0], "2048 should beat 1024: {t:?}");
        assert!(t[1] > t[2] && t[2] > t[3] && t[3] > t[4], "decay: {t:?}");
    }

    #[test]
    fn prefill_beats_peak_bf16_even_at_8k() {
        // Paper: "even for 8096-long sequences, FP8 improves prefill
        // throughput to levels above the peak BF16 GEMM throughput" (432).
        let cfg = E2eConfig::llama31_70b_paper();
        assert!(prefill_tflops(&cfg, 8192).tflops > 432.0);
    }

    /// Paper Table 6 (decode TFLOPS), non-OOM cells.
    const TABLE6: &[(usize, usize, f64)] = &[
        (8, 512, 32.8),
        (8, 1024, 32.4),
        (8, 2048, 30.8),
        (8, 4096, 30.2),
        (8, 8192, 23.4),
        (16, 512, 63.2),
        (16, 1024, 61.5),
        (16, 2048, 55.8),
        (16, 4096, 51.4),
        (16, 8192, 39.6),
        (32, 512, 120.1),
        (32, 1024, 112.0),
        (32, 2048, 94.1),
        (32, 4096, 79.5),
        (64, 512, 224.1),
        (64, 1024, 198.8),
        (64, 2048, 152.3),
        (128, 512, 387.1),
        (128, 1024, 312.8),
    ];

    #[test]
    fn table6_decode_within_tolerance() {
        let cfg = E2eConfig::llama31_70b_paper();
        for &(b, s, paper) in TABLE6 {
            let got = decode_step_tflops(&cfg, b, s);
            let rel = (got.tflops - paper).abs() / paper;
            // 18%: the (8, 8192) cell is the paper's own outlier (it breaks
            // the otherwise smooth context-decay trend of its row); every
            // other cell lands within ~8%.
            assert!(
                rel < 0.18,
                "batch {b} seq {s}: model {:.1} vs paper {paper} ({:.1}% off)",
                got.tflops,
                rel * 100.0
            );
        }
    }

    #[test]
    fn table6_shape_properties() {
        let cfg = E2eConfig::llama31_70b_paper();
        // Throughput grows with batch (weights amortized)...
        for s in [512usize, 1024] {
            let t8 = decode_step_tflops(&cfg, 8, s).tflops;
            let t128 = decode_step_tflops(&cfg, 128, s).tflops;
            assert!(t128 > 5.0 * t8, "batch scaling at seq {s}");
        }
        // ...and decays with context length (KV reads dominate).
        for b in [8usize, 16, 32] {
            let short = decode_step_tflops(&cfg, b, 512).tflops;
            let long = decode_step_tflops(&cfg, b, 8192).tflops;
            assert!(short > long, "context decay at batch {b}");
        }
    }

    #[test]
    fn decode_far_below_prefill_mfu() {
        // Decode is memory-bound: MFU well under 50% of prefill's.
        let cfg = E2eConfig::llama31_70b_paper();
        let d = decode_step_tflops(&cfg, 32, 2048).mfu;
        let p = prefill_tflops(&cfg, 2048).mfu;
        assert!(d < 0.5 * p, "decode {d} prefill {p}");
    }

    #[test]
    fn chunked_prefill_single_cold_chunk_matches_full_prefill() {
        let cfg = E2eConfig::llama31_70b_paper();
        for seq in [1024usize, 4096] {
            let full = prefill_tflops(&cfg, seq).time_s;
            let chunked = chunked_prefill_time_s(&cfg, seq, 0, 0);
            assert!(
                (full - chunked).abs() / full < 1e-9,
                "seq {seq}: {full} vs {chunked}"
            );
        }
    }

    #[test]
    fn cached_prefix_cuts_prefill_time() {
        let cfg = E2eConfig::llama31_70b_paper();
        let cold = chunked_prefill_time_s(&cfg, 4096, 0, 512);
        let half = chunked_prefill_time_s(&cfg, 4096, 2048, 512);
        let full = chunked_prefill_time_s(&cfg, 4096, 4096, 512);
        assert!(half < cold, "half-cached must be cheaper: {half} vs {cold}");
        assert!(full < half, "full hit must be cheapest: {full} vs {half}");
        // The acceptance mechanism: a warm prompt reaches first-token ≥ 2×
        // faster than a cold one.
        assert!(cold / full >= 2.0, "TTFT gain {:.2}x < 2x", cold / full);
        // Full hit = one bootstrap decode step, exactly.
        let boot = decode_step_tflops(&cfg, 1, 4096).time_s;
        assert!((full - boot).abs() < 1e-12);
    }

    #[test]
    fn tiny_chunks_pay_launch_and_reload_overhead() {
        use super::super::mme::GEMM_LAUNCH_OVERHEAD_S;
        let cfg = E2eConfig::llama31_70b_paper();
        let big = chunked_prefill_time_s(&cfg, 4096, 0, 2048);
        let small = chunked_prefill_time_s(&cfg, 4096, 0, 128);
        assert!(small > big, "128-token chunks must cost more than 2048");
        // Floor: 32 chunks each pay at least one GEMM launch.
        assert!(small >= 32.0 * GEMM_LAUNCH_OVERHEAD_S);
    }

    #[test]
    fn paged_pricing_matches_dense_at_uniform_aligned_contexts() {
        // At the paper's block-aligned uniform geometries the paged
        // decomposition (stream factor + per-block launch) reproduces the
        // old flat-factor dense model — which is why the Table 6 asserts
        // above survive the repricing untouched.
        let cfg = E2eConfig::llama31_70b_paper();
        for &(b, s) in &[(8usize, 512usize), (16, 2048), (32, 4096), (128, 1024)] {
            let paged = decode_step_tflops(&cfg, b, s).time_s;
            let dense = decode_step_tflops_dense(&cfg, b, s, s).time_s;
            let rel = (paged - dense).abs() / dense;
            assert!(rel < 0.01, "({b},{s}): paged {paged} vs dense {dense}");
        }
    }

    #[test]
    fn paged_reads_charge_actual_blocks_not_the_window() {
        let cfg = E2eConfig::llama31_70b_paper();
        let m = &cfg.model;
        // Bytes: ceil-to-block per slot, nothing more.
        let rate = m.kv_bytes_per_token(1) as f64;
        assert_eq!(kv_read_bytes_paged(m, &[100]), 112.0 * rate); // ceil(100/16)=7 blocks
        assert_eq!(kv_read_bytes_paged(m, &[512, 16]), (512.0 + 16.0) * rate);
        assert_eq!(kv_read_bytes_dense(m, 4, 8192), 4.0 * 8192.0 * rate);
        // A ragged group under an 8192 window: the paged path reads its
        // live blocks; the dense copy pays the whole padded window.
        let ctxs = [512usize, 1024, 8192, 256];
        let paged = attn_time_s_paged(&cfg, &ctxs);
        let dense = attn_time_s_dense_copy(&cfg, 4, 8192);
        assert!(
            paged < 0.5 * dense,
            "ragged group must be ≥2x cheaper paged: {paged} vs {dense}"
        );
        // Bucket padding rows cost nothing on the paged side: pricing a
        // 3-slot group inside a compiled bucket of 8 charges 3 slots.
        let three = decode_group_time_s_paged(&cfg, &[1024, 1024, 1024]);
        let eight = decode_group_time_s_paged(&cfg, &[1024; 8]);
        assert!(three < eight);
    }

    #[test]
    fn paged_block_launch_is_a_floor() {
        use super::super::mme::PAGED_BLOCK_LAUNCH_OVERHEAD_S;
        let cfg = E2eConfig::llama31_70b_paper();
        // 128 one-token contexts: 128 blocks of launch cost at minimum.
        let t = attn_time_s_paged(&cfg, &[1usize; 128]);
        assert!(t >= 128.0 * PAGED_BLOCK_LAUNCH_OVERHEAD_S);
        // Equal token totals, equal blocks — block-aligned splitting is
        // free (the launch floor scales with blocks, not sequences).
        let one = attn_time_s_paged(&cfg, &[4096]);
        let four = attn_time_s_paged(&cfg, &[1024; 4]);
        assert!((one - four).abs() / one < 1e-9);
    }

    #[test]
    fn group_model_flops_sum_equals_batched_formula() {
        // Uniform contexts: the ragged sum must reproduce the batched
        // decode FLOPs exactly (linear term × batch, attention × context).
        let cfg = E2eConfig::llama31_70b_paper();
        for &(b, s) in &[(8usize, 512usize), (32, 2048), (128, 1024)] {
            let ragged = decode_group_model_flops(&cfg, &vec![s; b]);
            let batched = decode_step_model_flops(&cfg.model, b, s, cfg.lm_head_bf16);
            assert!(
                (ragged - batched).abs() / batched < 1e-12,
                "({b},{s}): {ragged} vs {batched}"
            );
        }
        // And the report wrapper agrees with decode_step_tflops at the
        // same geometry.
        let rep = decode_group_report_paged(&cfg, &[2048; 32]);
        let stp = decode_step_tflops(&cfg, 32, 2048);
        assert!((rep.tflops - stp.tflops).abs() / stp.tflops < 1e-12);
        assert!(rep.mfu > 0.0 && rep.mfu < 1.0);
    }

    #[test]
    fn chunked_prefill_flops_boundary_cases() {
        let cfg = E2eConfig::llama31_70b_paper();
        // Single cold chunk = the full prefill formula, exactly.
        for seq in [1024usize, 4096] {
            let chunked = chunked_prefill_model_flops(&cfg, seq, 0, 0);
            let full = prefill_model_flops(&cfg.model, seq, cfg.lm_head_bf16);
            assert!((chunked - full).abs() / full < 1e-12, "seq {seq}");
        }
        // Full hit = one bootstrap batch-1 decode step.
        let hit = chunked_prefill_model_flops(&cfg, 4096, 4096, 512);
        let boot = decode_step_model_flops(&cfg.model, 1, 4096, cfg.lm_head_bf16);
        assert!((hit - boot).abs() / boot < 1e-12);
        // Causal chunking computes *less* than the masked square, and a
        // warm tail less than a cold one.
        let cold_chunked = chunked_prefill_model_flops(&cfg, 4096, 0, 512);
        let cold_full = chunked_prefill_model_flops(&cfg, 4096, 0, 0);
        assert!(cold_chunked < cold_full);
        let warm = chunked_prefill_model_flops(&cfg, 4096, 2048, 512);
        assert!(warm < cold_chunked);
        // The report's MFU is finite and positive for a warm tail.
        let rep = chunked_prefill_report(&cfg, 4096, 2048, 512);
        assert!(rep.mfu > 0.0 && rep.mfu < 1.0, "mfu {}", rep.mfu);
    }

    #[test]
    fn speculative_expected_tokens_formula() {
        // Geometric-series endpoints and interior value.
        assert!((speculative_expected_tokens_per_round(4, 0.0) - 1.0).abs() < 1e-12);
        assert!((speculative_expected_tokens_per_round(4, 1.0) - 5.0).abs() < 1e-12);
        let e = speculative_expected_tokens_per_round(4, 0.8);
        let want = (1.0 - 0.8f64.powi(5)) / 0.2;
        assert!((e - want).abs() < 1e-12, "{e} vs {want}");
        // Monotone in both acceptance and gamma.
        assert!(speculative_expected_tokens_per_round(4, 0.9) > e);
        assert!(speculative_expected_tokens_per_round(8, 0.8) > e);
    }

    #[test]
    fn speculative_tpot_beats_token_by_token_at_realistic_acceptance() {
        // The ISSUE acceptance bar: γ=4 at 80% acceptance must be ≥1.5×
        // faster than token-by-token decode on the gaudisim pricing —
        // the 70B target's batch-1 decode step is ~33 ms of weight
        // streaming while the verify chunk re-uses prefill-grade MFU.
        let target = E2eConfig::llama31_70b_paper();
        let draft = E2eConfig::synthetic_tiny_draft();
        for ctx in [512usize, 2048, 8192] {
            let base = decode_group_time_s_paged(&target, &[ctx]);
            let spec = speculative_tpot_s(&target, &draft, ctx, 4, 0.8);
            assert!(
                base / spec >= 1.5,
                "ctx {ctx}: spec {spec:.5}s vs base {base:.5}s ({:.2}x)",
                base / spec
            );
        }
    }

    #[test]
    fn speculative_zero_acceptance_loss_is_bounded_by_draft_plus_verify_overhead() {
        // At α→0 every round still emits one token, so the worst case is
        // plain decode plus the draft steps plus the verify-vs-decode
        // gap — never an unbounded stall.
        let target = E2eConfig::llama31_70b_paper();
        let draft = E2eConfig::synthetic_tiny_draft();
        let (ctx, gamma) = (2048usize, 4usize);
        let base = decode_group_time_s_paged(&target, &[ctx]);
        let spec = speculative_tpot_s(&target, &draft, ctx, gamma, 0.0);
        let draft_cost: f64 = (0..gamma)
            .map(|i| decode_group_time_s_paged(&draft, &[ctx + i]))
            .sum();
        let verify = chunked_prefill_time_s(&target, ctx + gamma + 1, ctx, gamma + 1);
        assert!(spec >= base, "free lunch: spec cannot win at zero acceptance");
        assert!(
            spec - base <= draft_cost + (verify - base) + 1e-12,
            "spec {spec} base {base} draft {draft_cost} verify {verify}"
        );
        // TPOT is monotone non-increasing in acceptance.
        let mut prev = f64::INFINITY;
        for a in [0.0, 0.2, 0.4, 0.6, 0.8, 1.0] {
            let t = speculative_tpot_s(&target, &draft, ctx, gamma, a);
            assert!(t <= prev + 1e-15, "tpot not monotone at α={a}");
            prev = t;
        }
    }

    #[test]
    fn moe_decode_streams_fewer_bytes() {
        // Mixtral's active-expert streaming beats a dense model of equal
        // total size.
        let dense = E2eConfig {
            model: ModelConfig::llama31_70b(),
            ..E2eConfig::llama31_70b_paper()
        };
        let moe = E2eConfig {
            model: ModelConfig::mixtral_8x7b(),
            ..E2eConfig::llama31_70b_paper()
        };
        let td = decode_step_tflops(&dense, 8, 512).time_s;
        let tm = decode_step_tflops(&moe, 8, 512).time_s;
        assert!(tm < td);
    }
}
