//! Model architecture configs (the paper's §4 evaluation zoo).

use crate::quant::{KvDtype, KvLayout};

/// Model family — determines activation-outlier structure in the synthetic
/// analogues (Mistral-family models show strong outlier channels, which is
/// why unit scaling collapses on them in Table 4).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ModelFamily {
    Llama2,
    Llama3,
    Mistral,
    Mixtral,
    Synthetic,
}

/// Decoder-only transformer geometry. Enough to account parameters, FLOPs,
/// KV-cache bytes, and enumerate every linear op for quantization.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub family: ModelFamily,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub ffn_hidden: usize,
    pub vocab: usize,
    /// Mixture-of-experts: number of experts (1 = dense) and active experts.
    pub experts: usize,
    pub active_experts: usize,
    pub tied_embeddings: bool,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    /// Parameters in the attention block of one layer (Q,K,V,O projections).
    pub fn attn_params_per_layer(&self) -> usize {
        let hd = self.head_dim();
        let q = self.hidden * self.hidden;
        let kv = 2 * self.hidden * (self.kv_heads * hd);
        let o = self.hidden * self.hidden;
        q + kv + o
    }

    /// Parameters in the MLP of one layer (SwiGLU: gate, up, down), for one
    /// expert.
    pub fn mlp_params_per_expert(&self) -> usize {
        3 * self.hidden * self.ffn_hidden
    }

    /// Total parameters (weights of linears + embeddings; norms negligible
    /// but included at 2*hidden per layer + final).
    pub fn total_params(&self) -> usize {
        let per_layer = self.attn_params_per_layer()
            + self.experts * self.mlp_params_per_expert()
            + if self.experts > 1 {
                self.hidden * self.experts // router
            } else {
                0
            }
            + 2 * self.hidden; // norms
        let embed = self.vocab * self.hidden;
        let head = if self.tied_embeddings { 0 } else { self.vocab * self.hidden };
        self.layers * per_layer + embed + head + self.hidden
    }

    /// Parameters that participate in a decode step (active experts only).
    pub fn active_params(&self) -> usize {
        let per_layer = self.attn_params_per_layer()
            + self.active_experts * self.mlp_params_per_expert()
            + if self.experts > 1 { self.hidden * self.experts } else { 0 }
            + 2 * self.hidden;
        let embed = self.vocab * self.hidden;
        let head = if self.tied_embeddings { 0 } else { self.vocab * self.hidden };
        self.layers * per_layer + embed + head + self.hidden
    }

    /// Linear-layer parameters only (what FP8 quantization touches; the
    /// paper excludes embeddings and the LM head — §3.3 step 5, §4.2.4).
    pub fn linear_params(&self) -> usize {
        self.layers * (self.attn_params_per_layer() + self.experts * self.mlp_params_per_expert())
    }

    /// KV-cache bytes per token for the whole model.
    pub fn kv_bytes_per_token(&self, bytes_per_elem: usize) -> usize {
        2 * self.layers * self.kv_heads * self.head_dim() * bytes_per_elem
    }

    /// The shared KV accounting contract for this model under `dtype` —
    /// what `BlockAllocator`, `MemoryModel`, and `SimReplica` charge.
    pub fn kv_layout(&self, dtype: KvDtype) -> KvLayout {
        KvLayout::new(dtype, self.layers, self.kv_heads, self.head_dim())
    }

    // ----- the paper's zoo -------------------------------------------------

    pub fn llama2_7b() -> Self {
        Self {
            name: "Llama2-7B".into(),
            family: ModelFamily::Llama2,
            hidden: 4096,
            layers: 32,
            heads: 32,
            kv_heads: 32,
            ffn_hidden: 11008,
            vocab: 32000,
            experts: 1,
            active_experts: 1,
            tied_embeddings: false,
        }
    }

    pub fn llama2_13b() -> Self {
        Self {
            name: "Llama2-13B".into(),
            family: ModelFamily::Llama2,
            hidden: 5120,
            layers: 40,
            heads: 40,
            kv_heads: 40,
            ffn_hidden: 13824,
            vocab: 32000,
            experts: 1,
            active_experts: 1,
            tied_embeddings: false,
        }
    }

    pub fn llama2_70b() -> Self {
        Self {
            name: "Llama2-70B".into(),
            family: ModelFamily::Llama2,
            hidden: 8192,
            layers: 80,
            heads: 64,
            kv_heads: 8,
            ffn_hidden: 28672,
            vocab: 32000,
            experts: 1,
            active_experts: 1,
            tied_embeddings: false,
        }
    }

    pub fn llama3_8b() -> Self {
        Self {
            name: "Llama3-8B".into(),
            family: ModelFamily::Llama3,
            hidden: 4096,
            layers: 32,
            heads: 32,
            kv_heads: 8,
            ffn_hidden: 14336,
            vocab: 128256,
            experts: 1,
            active_experts: 1,
            tied_embeddings: false,
        }
    }

    pub fn llama3_70b() -> Self {
        Self {
            name: "Llama3-70B".into(),
            family: ModelFamily::Llama3,
            hidden: 8192,
            layers: 80,
            heads: 64,
            kv_heads: 8,
            ffn_hidden: 28672,
            vocab: 128256,
            experts: 1,
            active_experts: 1,
            tied_embeddings: false,
        }
    }

    /// Llama v3.1 70B — the Table 5/6 model. Same geometry as Llama3-70B.
    pub fn llama31_70b() -> Self {
        let mut c = Self::llama3_70b();
        c.name = "Llama3.1-70B".into();
        c
    }

    pub fn mistral_7b() -> Self {
        Self {
            name: "Mistral-7B".into(),
            family: ModelFamily::Mistral,
            hidden: 4096,
            layers: 32,
            heads: 32,
            kv_heads: 8,
            ffn_hidden: 14336,
            vocab: 32000,
            experts: 1,
            active_experts: 1,
            tied_embeddings: false,
        }
    }

    pub fn mixtral_8x7b() -> Self {
        Self {
            name: "Mixtral-8x7B".into(),
            family: ModelFamily::Mixtral,
            hidden: 4096,
            layers: 32,
            heads: 32,
            kv_heads: 8,
            ffn_hidden: 14336,
            vocab: 32000,
            experts: 8,
            active_experts: 2,
            tied_embeddings: false,
        }
    }

    // ----- synthetic reduced-scale analogues (accuracy experiments) --------

    /// ~8M-parameter analogue: the "7B-class" stand-in.
    pub fn synthetic_tiny(family: ModelFamily) -> Self {
        Self {
            name: format!("syn-tiny-{family:?}"),
            family,
            hidden: 256,
            layers: 4,
            heads: 8,
            kv_heads: if family == ModelFamily::Llama2 { 8 } else { 2 },
            ffn_hidden: 704,
            vocab: 512,
            experts: if family == ModelFamily::Mixtral { 4 } else { 1 },
            active_experts: if family == ModelFamily::Mixtral { 2 } else { 1 },
            tied_embeddings: false,
        }
    }

    /// ~25M-parameter analogue: the "13B-class" stand-in.
    pub fn synthetic_small(family: ModelFamily) -> Self {
        Self {
            name: format!("syn-small-{family:?}"),
            family,
            hidden: 448,
            layers: 6,
            heads: 8,
            kv_heads: if family == ModelFamily::Llama2 { 8 } else { 2 },
            ffn_hidden: 1216,
            vocab: 512,
            experts: if family == ModelFamily::Mixtral { 4 } else { 1 },
            active_experts: if family == ModelFamily::Mixtral { 2 } else { 1 },
            tied_embeddings: false,
        }
    }

    /// ~100M-parameter analogue: the "70B-class" stand-in; also the e2e
    /// serving model.
    pub fn synthetic_base(family: ModelFamily) -> Self {
        Self {
            name: format!("syn-base-{family:?}"),
            family,
            hidden: 768,
            layers: 12,
            heads: 12,
            kv_heads: if family == ModelFamily::Llama2 { 12 } else { 4 },
            ffn_hidden: 2048,
            vocab: 512,
            experts: if family == ModelFamily::Mixtral { 4 } else { 1 },
            active_experts: if family == ModelFamily::Mixtral { 2 } else { 1 },
            tied_embeddings: false,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        let all = [
            Self::llama2_7b(),
            Self::llama2_13b(),
            Self::llama2_70b(),
            Self::llama3_8b(),
            Self::llama3_70b(),
            Self::llama31_70b(),
            Self::mistral_7b(),
            Self::mixtral_8x7b(),
            Self::synthetic_tiny(ModelFamily::Llama2),
            Self::synthetic_small(ModelFamily::Llama2),
            Self::synthetic_base(ModelFamily::Llama2),
        ];
        all.iter()
            .find(|c| c.name.eq_ignore_ascii_case(name))
            .cloned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_published_sizes() {
        // Within 5% of the nominal sizes.
        let cases = [
            (ModelConfig::llama2_7b(), 6.7e9, 7.5e9),
            (ModelConfig::llama2_13b(), 12.5e9, 13.5e9),
            (ModelConfig::llama2_70b(), 66.0e9, 72.0e9),
            (ModelConfig::llama3_8b(), 7.5e9, 8.5e9),
            (ModelConfig::llama3_70b(), 68.0e9, 72.5e9),
            (ModelConfig::mistral_7b(), 6.9e9, 7.6e9),
            (ModelConfig::mixtral_8x7b(), 45.0e9, 48.0e9),
        ];
        for (c, lo, hi) in cases {
            let p = c.total_params() as f64;
            assert!(
                p > lo && p < hi,
                "{}: {p:.3e} not in [{lo:.1e}, {hi:.1e}]",
                c.name
            );
        }
    }

    #[test]
    fn mixtral_active_params_much_smaller_than_total() {
        let c = ModelConfig::mixtral_8x7b();
        let total = c.total_params() as f64;
        let active = c.active_params() as f64;
        assert!(active < 0.35 * total, "active {active:.2e} total {total:.2e}");
    }

    #[test]
    fn gqa_kv_cache_smaller_than_mha() {
        let l2 = ModelConfig::llama2_70b(); // GQA 8 kv heads
        let per_tok = l2.kv_bytes_per_token(1);
        // 2 * 80 layers * 8 heads * 128 dim = 163840 B/token in fp8.
        assert_eq!(per_tok, 163_840);
        let l27 = ModelConfig::llama2_7b(); // MHA
        assert_eq!(l27.kv_bytes_per_token(2), 2 * 32 * 4096 * 2);
    }

    #[test]
    fn synthetic_scales_ordered() {
        let t = ModelConfig::synthetic_tiny(ModelFamily::Llama2).total_params();
        let s = ModelConfig::synthetic_small(ModelFamily::Llama2).total_params();
        let b = ModelConfig::synthetic_base(ModelFamily::Llama2).total_params();
        assert!(t < s && s < b, "{t} {s} {b}");
        // tiny ≈ 3-12M, base ≈ 70-140M.
        assert!((2_500_000..14_000_000).contains(&t), "{t}");
        assert!((70_000_000..140_000_000).contains(&b), "{b}");
    }

    #[test]
    fn kv_layout_agrees_with_legacy_rate() {
        for c in [
            ModelConfig::llama2_7b(),
            ModelConfig::llama31_70b(),
            ModelConfig::synthetic_tiny(ModelFamily::Llama3),
        ] {
            for (dtype, elem) in [
                (KvDtype::F32, 4usize),
                (KvDtype::Bf16, 2),
                (KvDtype::FP8_DEFAULT, 1),
            ] {
                assert_eq!(
                    c.kv_layout(dtype).bytes_per_token(),
                    c.kv_bytes_per_token(elem),
                    "{} {dtype:?}",
                    c.name
                );
            }
        }
    }

    #[test]
    fn by_name_lookup() {
        assert!(ModelConfig::by_name("Llama2-7B").is_some());
        assert!(ModelConfig::by_name("llama3.1-70b").is_some());
        assert!(ModelConfig::by_name("nope").is_none());
    }

    #[test]
    fn linear_params_exclude_embeddings() {
        let c = ModelConfig::llama2_7b();
        assert!(c.linear_params() < c.total_params());
        let embed = 2 * c.vocab * c.hidden;
        assert!(c.total_params() - c.linear_params() >= embed);
    }
}
