//! LLM model descriptions: the architecture zoo the paper evaluates
//! (Llama2 7/13/70B, Llama3 8/70B, Llama3.1-70B, Mistral-7B, Mixtral-8x7B)
//! plus synthetic reduced-scale analogues used for accuracy experiments,
//! parameter / FLOPs / KV-cache accounting, and the per-layer linear-op
//! inventory that quantization recipes attach to.

pub mod config;
pub mod flops;
pub mod layers;
pub mod synthetic;

pub use config::{ModelConfig, ModelFamily};
pub use flops::{decode_step_model_flops, prefill_model_flops};
pub use layers::{LayerKind, LinearOp};
pub use synthetic::{DraftLm, SyntheticLm};
