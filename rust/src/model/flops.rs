//! Model-FLOPs accounting following Kim et al. (2025), the formula the paper
//! uses for Tables 5 and 6: linear-layer FLOPs plus attention score/value
//! FLOPs, *excluding* FLOPs from the attention mask (i.e. no causal
//! discount), and excluding nonlinearities/norms.

use super::config::ModelConfig;

/// FLOPs of one dense linear `M×K · K×N`: 2·M·K·N.
pub fn gemm_flops(m: usize, k: usize, n: usize) -> f64 {
    2.0 * m as f64 * k as f64 * n as f64
}

/// Total model FLOPs for a prefill of `seq` tokens (batch 1).
///
/// Linears: 2 · linear_params(active) · seq (+ lm_head if included).
/// Attention: per layer, QKᵀ and PV each cost 2·S²·(heads·head_dim) = 2·S²·H.
pub fn prefill_model_flops(cfg: &ModelConfig, seq: usize, include_lm_head: bool) -> f64 {
    let s = seq as f64;
    let lin = {
        let per_layer = cfg.attn_params_per_layer() as f64
            + cfg.active_experts as f64 * cfg.mlp_params_per_expert() as f64;
        2.0 * cfg.layers as f64 * per_layer * s
    };
    let attn = 4.0 * cfg.layers as f64 * s * s * cfg.hidden as f64;
    let head = if include_lm_head {
        2.0 * s * cfg.hidden as f64 * cfg.vocab as f64
    } else {
        0.0
    };
    lin + attn + head
}

/// Model FLOPs of a single decode step for a batch of `batch` sequences at
/// context length `context`.
pub fn decode_step_model_flops(
    cfg: &ModelConfig,
    batch: usize,
    context: usize,
    include_lm_head: bool,
) -> f64 {
    let b = batch as f64;
    let s = context as f64;
    let lin = {
        let per_layer = cfg.attn_params_per_layer() as f64
            + cfg.active_experts as f64 * cfg.mlp_params_per_expert() as f64;
        2.0 * cfg.layers as f64 * per_layer * b
    };
    // One query token attends to `context` keys: QKᵀ + PV = 4·S·H per layer
    // per sequence.
    let attn = 4.0 * cfg.layers as f64 * b * s * cfg.hidden as f64;
    let head = if include_lm_head {
        2.0 * b * cfg.hidden as f64 * cfg.vocab as f64
    } else {
        0.0
    };
    lin + attn + head
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_flops_formula() {
        assert_eq!(gemm_flops(2, 3, 4), 48.0);
    }

    #[test]
    fn prefill_scales_superlinearly() {
        let c = ModelConfig::llama31_70b();
        let f1 = prefill_model_flops(&c, 1024, false);
        let f2 = prefill_model_flops(&c, 2048, false);
        assert!(f2 > 2.0 * f1); // quadratic attention term
        assert!(f2 < 4.0 * f1);
    }

    #[test]
    fn prefill_magnitude_llama70b() {
        // ~2·70e9·S for linears at S=2048 → ≈ 2.8e14; attention adds ~5%.
        let c = ModelConfig::llama31_70b();
        let f = prefill_model_flops(&c, 2048, false);
        assert!(f > 2.5e14 && f < 3.5e14, "{f:.3e}");
    }

    #[test]
    fn decode_linear_in_batch() {
        let c = ModelConfig::llama31_70b();
        let f8 = decode_step_model_flops(&c, 8, 512, false);
        let f16 = decode_step_model_flops(&c, 16, 512, false);
        assert!((f16 / f8 - 2.0).abs() < 1e-6);
    }

    #[test]
    fn decode_grows_with_context() {
        let c = ModelConfig::llama31_70b();
        let a = decode_step_model_flops(&c, 8, 512, false);
        let b = decode_step_model_flops(&c, 8, 8192, false);
        assert!(b > a);
        // Linear part dominates at small context: ratio far below 16×.
        assert!(b / a < 2.0);
    }

    #[test]
    fn lm_head_inclusion_adds_vocab_term() {
        let c = ModelConfig::llama3_8b();
        let without = decode_step_model_flops(&c, 1, 128, false);
        let with = decode_step_model_flops(&c, 1, 128, true);
        assert!((with - without - 2.0 * 4096.0 * 128256.0).abs() < 1.0);
    }
}
