//! Per-model inventory of linear operations — what a quantization recipe
//! attaches scales to (§3.3: "Quantize all linear operations ... consider
//! omitting the first and last linear layers").

use super::config::ModelConfig;

/// Kind of linear op inside a transformer block (or at the edges).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LayerKind {
    Embedding,
    QProj,
    KProj,
    VProj,
    OProj,
    Gate,   // SwiGLU gate
    Up,     // SwiGLU up
    Down,   // SwiGLU down
    Router, // MoE router
    LmHead,
}

impl LayerKind {
    /// Is this an "edge" op the recipe skips by default (§3.3 step 5)?
    pub fn is_edge(self) -> bool {
        matches!(self, LayerKind::Embedding | LayerKind::LmHead)
    }
}

/// One concrete linear op: its position, kind, and GEMM geometry
/// (out_features × in_features weight; activations are N×in).
#[derive(Clone, Debug)]
pub struct LinearOp {
    pub layer_index: Option<usize>, // None for edge ops
    pub kind: LayerKind,
    pub in_features: usize,
    pub out_features: usize,
    /// Instances per layer (e.g. experts for MoE MLP projections).
    pub instances: usize,
}

impl LinearOp {
    pub fn weight_params(&self) -> usize {
        self.in_features * self.out_features * self.instances
    }

    pub fn qualified_name(&self) -> String {
        match self.layer_index {
            Some(i) => format!("layers.{i}.{:?}", self.kind),
            None => format!("{:?}", self.kind),
        }
    }
}

/// Enumerate every linear op in a model, in execution order.
pub fn enumerate_linears(cfg: &ModelConfig) -> Vec<LinearOp> {
    let hd = cfg.head_dim();
    let mut ops = Vec::new();
    ops.push(LinearOp {
        layer_index: None,
        kind: LayerKind::Embedding,
        in_features: cfg.vocab,
        out_features: cfg.hidden,
        instances: 1,
    });
    for l in 0..cfg.layers {
        let mk = |kind, inf, outf, inst| LinearOp {
            layer_index: Some(l),
            kind,
            in_features: inf,
            out_features: outf,
            instances: inst,
        };
        ops.push(mk(LayerKind::QProj, cfg.hidden, cfg.heads * hd, 1));
        ops.push(mk(LayerKind::KProj, cfg.hidden, cfg.kv_heads * hd, 1));
        ops.push(mk(LayerKind::VProj, cfg.hidden, cfg.kv_heads * hd, 1));
        ops.push(mk(LayerKind::OProj, cfg.heads * hd, cfg.hidden, 1));
        if cfg.experts > 1 {
            ops.push(mk(LayerKind::Router, cfg.hidden, cfg.experts, 1));
        }
        ops.push(mk(LayerKind::Gate, cfg.hidden, cfg.ffn_hidden, cfg.experts));
        ops.push(mk(LayerKind::Up, cfg.hidden, cfg.ffn_hidden, cfg.experts));
        ops.push(mk(LayerKind::Down, cfg.ffn_hidden, cfg.hidden, cfg.experts));
    }
    ops.push(LinearOp {
        layer_index: None,
        kind: LayerKind::LmHead,
        in_features: cfg.hidden,
        out_features: cfg.vocab,
        instances: 1,
    });
    ops
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_model_op_count() {
        let c = ModelConfig::llama2_7b();
        let ops = enumerate_linears(&c);
        // embed + 32 layers × 7 ops + lm_head
        assert_eq!(ops.len(), 2 + 32 * 7);
    }

    #[test]
    fn moe_model_has_router_and_experts() {
        let c = ModelConfig::mixtral_8x7b();
        let ops = enumerate_linears(&c);
        assert!(ops.iter().any(|o| o.kind == LayerKind::Router));
        let gate = ops.iter().find(|o| o.kind == LayerKind::Gate).unwrap();
        assert_eq!(gate.instances, 8);
    }

    #[test]
    fn weight_params_sum_matches_config_accounting() {
        for c in [
            ModelConfig::llama2_7b(),
            ModelConfig::llama3_70b(),
            ModelConfig::mixtral_8x7b(),
        ] {
            let ops = enumerate_linears(&c);
            let lin_sum: usize = ops
                .iter()
                .filter(|o| !o.kind.is_edge() && o.kind != LayerKind::Router)
                .map(|o| o.weight_params())
                .sum();
            assert_eq!(lin_sum, c.linear_params(), "{}", c.name);
        }
    }

    #[test]
    fn edge_detection() {
        assert!(LayerKind::Embedding.is_edge());
        assert!(LayerKind::LmHead.is_edge());
        assert!(!LayerKind::QProj.is_edge());
    }

    #[test]
    fn qualified_names_unique() {
        let c = ModelConfig::llama2_7b();
        let ops = enumerate_linears(&c);
        let mut names: Vec<String> = ops.iter().map(|o| o.qualified_name()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), ops.len());
    }

    #[test]
    fn gqa_kv_proj_narrower() {
        let c = ModelConfig::llama3_8b();
        let ops = enumerate_linears(&c);
        let k = ops.iter().find(|o| o.kind == LayerKind::KProj).unwrap();
        let q = ops.iter().find(|o| o.kind == LayerKind::QProj).unwrap();
        assert_eq!(q.out_features, 4096);
        assert_eq!(k.out_features, 8 * 128);
    }
}
