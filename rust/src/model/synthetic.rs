//! Synthetic-statistics LMs for the accuracy experiments (Tables 2–4).
//!
//! We cannot run Llama2-70B here; what transfers from the paper is the
//! *relationship* between quantization schemes and accuracy, which is a
//! function of weight/activation statistics. This module builds stacks of
//! quantizable linears whose statistics match the families the paper
//! evaluates:
//!
//! * Llama-family: well-behaved Gaussian activations — every scaled scheme
//!   works; unit scale degrades mildly (activations stay in E4M3 range).
//! * Mistral/Mixtral-family: strong activation *outlier channels* (values
//!   far beyond r_q) — unit scale clips catastrophically (the +136% / +725%
//!   PPL rows of Table 4), while calibrated scaling stays close.
//!
//! Scale robustness (§4.2.1) is reproduced through width: wider layers
//! average per-element FP8 noise over more terms (relative GEMM error
//! ∝ 1/√C), exactly the redundancy argument the paper gives.

use crate::calib::{ActObserver, ActStats};
use crate::model::config::{ModelConfig, ModelFamily};
use crate::quant::{QuantScheme, QuantizedLinear};
use crate::tensor::Tensor2;
use crate::util::rng::XorShiftRng;

/// A depth-`L` stack of linears + SiLU + RMS renorm, with a classification
/// head. All linears share one QuantScheme at eval time.
pub struct SyntheticLm {
    pub family: ModelFamily,
    pub hidden: usize,
    pub depth: usize,
    pub classes: usize,
    pub weights: Vec<Tensor2>, // depth × (hidden×hidden)
    pub head: Tensor2,         // classes×hidden
    /// Persistent outlier channel ids (empty for Llama-family).
    outlier_channels: Vec<usize>,
    outlier_scale: f32,
}

impl SyntheticLm {
    /// Family statistics knobs: (fraction of outlier channels, magnitude).
    /// Magnitudes are chosen so outlier activations land well beyond
    /// E4M3-Gaudi2's ±240 (real Mistral-family activations reach hundreds),
    /// with Mixtral worse than Mistral as in Table 4 (+725% vs +136% PPL).
    fn family_knobs(family: ModelFamily) -> (f64, f32) {
        match family {
            ModelFamily::Mistral => (0.04, 150.0),
            ModelFamily::Mixtral => (0.06, 320.0),
            _ => (0.0, 1.0),
        }
    }

    pub fn new(cfg: &ModelConfig, classes: usize, seed: u64) -> Self {
        let mut rng = XorShiftRng::new(seed);
        let hidden = cfg.hidden;
        // Fixed shallow depth: the paper's scale-robustness effect (§4.2.1)
        // is reproduced through WIDTH (per-element FP8 noise averages over
        // more GEMM terms); holding depth constant isolates that mechanism
        // and keeps the eval tractable.
        let depth = cfg.layers.min(4);
        let (p_out, s_out) = Self::family_knobs(cfg.family);
        let std = 1.0 / (hidden as f32).sqrt();
        let weights = (0..depth)
            .map(|_| Tensor2::randn(hidden, hidden, std, &mut rng))
            .collect();
        let head = Tensor2::randn(classes, hidden, std, &mut rng);
        let n_out = (hidden as f64 * p_out) as usize;
        let mut outlier_channels: Vec<usize> = Vec::new();
        while outlier_channels.len() < n_out {
            let c = rng.below(hidden);
            if !outlier_channels.contains(&c) {
                outlier_channels.push(c);
            }
        }
        Self {
            family: cfg.family,
            hidden,
            depth,
            classes,
            weights,
            head,
            outlier_channels,
            outlier_scale: s_out,
        }
    }

    /// Sample a batch of input activations with the family's statistics.
    pub fn sample_inputs(&self, n: usize, rng: &mut XorShiftRng) -> Tensor2 {
        let mut x = Tensor2::randn(n, self.hidden, 1.0, rng);
        self.inject_outliers(&mut x);
        x
    }

    fn inject_outliers(&self, x: &mut Tensor2) {
        for r in 0..x.rows {
            let row = x.row_mut(r);
            for &c in &self.outlier_channels {
                row[c] *= self.outlier_scale;
            }
        }
    }

    /// RMS-normalize rows by the *non-outlier* channels (so the persistent
    /// outlier channels keep their extreme absolute magnitude through every
    /// layer — how real Mistral-class activations behave), then re-inject
    /// the outlier pattern.
    fn renorm(&self, x: &mut Tensor2) {
        for r in 0..x.rows {
            let row = x.row_mut(r);
            let (mut sum, mut n) = (0.0f64, 0usize);
            for (c, v) in row.iter().enumerate() {
                if !self.outlier_channels.contains(&c) {
                    sum += (*v as f64) * (*v as f64);
                    n += 1;
                }
            }
            let ms = (sum / n.max(1) as f64) as f32;
            let inv = 1.0 / (ms + 1e-6).sqrt();
            for v in row.iter_mut() {
                *v *= inv;
            }
        }
        self.inject_outliers(x);
    }

    fn silu(x: &mut Tensor2) {
        for v in x.data.iter_mut() {
            *v = *v / (1.0 + (-*v).exp());
        }
    }

    /// High-precision forward → logits (N×classes).
    pub fn forward_reference(&self, x0: &Tensor2) -> Tensor2 {
        let mut x = x0.clone();
        for w in &self.weights {
            x = crate::tensor::matmul_nt(&x, w);
            Self::silu(&mut x);
            self.renorm(&mut x);
        }
        crate::tensor::matmul_nt(&x, &self.head)
    }

    /// Calibrate per-layer activation stats on a calibration batch (§3.1).
    pub fn calibrate(&self, x0: &Tensor2) -> Vec<ActStats> {
        let mut stats = Vec::with_capacity(self.depth + 1);
        let mut x = x0.clone();
        for w in &self.weights {
            let mut obs = ActObserver::new(self.hidden);
            obs.observe(&x);
            stats.push(obs.finalize());
            x = crate::tensor::matmul_nt(&x, w);
            Self::silu(&mut x);
            self.renorm(&mut x);
        }
        let mut obs = ActObserver::new(self.hidden);
        obs.observe(&x);
        stats.push(obs.finalize());
        stats
    }

    /// Quantized forward under `scheme`, with per-layer calibration stats.
    pub fn forward_quantized(
        &self,
        x0: &Tensor2,
        scheme: QuantScheme,
        stats: &[ActStats],
    ) -> Tensor2 {
        assert_eq!(stats.len(), self.depth + 1);
        let mut x = x0.clone();
        for (w, st) in self.weights.iter().zip(stats) {
            let q = QuantizedLinear::prepare(w, Some(st), scheme);
            x = q.forward(&x);
            Self::silu(&mut x);
            self.renorm(&mut x);
        }
        // Head stays high-precision (§3.3 step 5: skip the lm-head).
        crate::tensor::matmul_nt(&x, &self.head)
    }
}

/// The draft proposer for speculative decoding: a deterministic
/// prompt-lookup / n-gram model over the request's own token history.
///
/// The speculative contract ([`crate::coordinator::Engine`]) makes the
/// *output* independent of draft quality — the greedy accept-prefix rule
/// keeps the emitted stream bit-identical to plain greedy decode, and the
/// draft only moves the accept *rate* (how many target steps each verify
/// round amortizes). So the repro's draft does what real prompt-lookup
/// drafts (REST, vLLM's ngram speculator) do: propose the continuation
/// that followed the most recent earlier occurrence of the current token,
/// falling back to a seed-stable hash when the context has no match.
/// No second set of weights, no RNG — bit-stable across runs by
/// construction.
///
/// The attached [`ModelConfig`] geometry is what gaudisim prices a draft
/// *decode step* at ([`crate::gaudisim::speculative_round_time_s`]); the
/// default `synthetic_tiny` stands in for the ~1% -of-target-size draft
/// models the speculative-decoding literature assumes.
pub struct DraftLm {
    cfg: ModelConfig,
    vocab: usize,
}

impl DraftLm {
    /// Draft with an explicit geometry (and its vocab as token range).
    pub fn new(cfg: ModelConfig) -> Self {
        let vocab = cfg.vocab.max(2);
        Self { cfg, vocab }
    }

    /// The default draft: the tiny Llama-family synthetic geometry.
    pub fn tiny() -> Self {
        Self::new(ModelConfig::synthetic_tiny(ModelFamily::Llama3))
    }

    /// The geometry gaudisim prices this draft's decode steps at.
    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// Propose up to `gamma` continuation tokens for `context`
    /// (prompt + everything generated so far, last token included).
    /// Deterministic in `context` alone.
    pub fn propose(&self, context: &[i32], gamma: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(gamma);
        let mut ext: Vec<i32> = context.to_vec();
        for _ in 0..gamma {
            let next = self.lookup_next(&ext);
            out.push(next);
            ext.push(next);
        }
        out
    }

    /// Prompt-lookup step: find the most recent earlier occurrence of the
    /// final token and echo what followed it; otherwise a deterministic
    /// hash of the tail (a stand-in for "draft model free-runs").
    fn lookup_next(&self, context: &[i32]) -> i32 {
        let Some((&last, history)) = context.split_last() else {
            return 0;
        };
        if let Some(pos) = history.iter().rposition(|&t| t == last) {
            if pos + 1 < history.len() {
                return history[pos + 1];
            }
        }
        // FNV-1a over the last few tokens, folded into the vocab.
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for &t in context.iter().rev().take(4) {
            h ^= t as u32 as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        (h % self.vocab as u64) as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp8::Fp8Format;

    fn lm(family: ModelFamily) -> SyntheticLm {
        let cfg = ModelConfig::synthetic_tiny(family);
        SyntheticLm::new(&cfg, 64, 42)
    }

    #[test]
    fn mistral_has_outlier_channels_llama_does_not() {
        let mut rng = XorShiftRng::new(1);
        let m = lm(ModelFamily::Mistral);
        let l = lm(ModelFamily::Llama2);
        let xm = m.sample_inputs(64, &mut rng);
        let xl = l.sample_inputs(64, &mut rng);
        assert!(crate::tensor::abs_max(&xm) > 100.0);
        assert!(crate::tensor::abs_max(&xl) < 10.0);
    }

    #[test]
    fn reference_forward_finite_and_shaped() {
        let mut rng = XorShiftRng::new(2);
        let m = lm(ModelFamily::Mixtral);
        let x = m.sample_inputs(8, &mut rng);
        let z = m.forward_reference(&x);
        assert_eq!((z.rows, z.cols), (8, 64));
        assert!(z.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn quantized_tracks_reference_for_llama() {
        let mut rng = XorShiftRng::new(3);
        let m = lm(ModelFamily::Llama2);
        let xc = m.sample_inputs(64, &mut rng);
        let xe = m.sample_inputs(32, &mut rng);
        let stats = m.calibrate(&xc);
        let zr = m.forward_reference(&xe);
        let zq =
            m.forward_quantized(&xe, QuantScheme::per_tensor(Fp8Format::E4M3Gaudi2), &stats);
        let rel = (zq.sub(&zr).fro_norm_sq() / zr.fro_norm_sq()).sqrt();
        assert!(rel < 0.35, "rel err {rel}");
    }

    #[test]
    fn unit_scale_blows_up_on_mistral_but_not_llama() {
        // The Table 4 headline reproduced at the statistics level.
        let mut rng = XorShiftRng::new(4);
        let fmt = Fp8Format::E4M3Gaudi2;
        let mut rels = std::collections::HashMap::new();
        for fam in [ModelFamily::Llama2, ModelFamily::Mistral] {
            let m = lm(fam);
            let xc = m.sample_inputs(64, &mut rng);
            let xe = m.sample_inputs(32, &mut rng);
            let stats = m.calibrate(&xc);
            let zr = m.forward_reference(&xe);
            let unit = m.forward_quantized(&xe, QuantScheme::unit_scale(fmt), &stats);
            let pt = m.forward_quantized(&xe, QuantScheme::per_tensor(fmt), &stats);
            let rel_unit = (unit.sub(&zr).fro_norm_sq() / zr.fro_norm_sq()).sqrt();
            let rel_pt = (pt.sub(&zr).fro_norm_sq() / zr.fro_norm_sq()).sqrt();
            rels.insert(fam, (rel_unit, rel_pt));
        }
        let (lu, lp) = rels[&ModelFamily::Llama2];
        let (mu, mp) = rels[&ModelFamily::Mistral];
        assert!(mu > 3.0 * mp, "mistral unit {mu} vs pt {mp}");
        assert!(lu < 3.0 * lp.max(0.05), "llama unit {lu} vs pt {lp}");
    }

    #[test]
    fn wider_models_more_robust() {
        // §4.2.1 via the width mechanism.
        let mut rng = XorShiftRng::new(5);
        let fmt = Fp8Format::E4M3Gaudi2;
        let mut errs = Vec::new();
        for cfg in [
            ModelConfig::synthetic_tiny(ModelFamily::Llama2),
            ModelConfig::synthetic_base(ModelFamily::Llama2),
        ] {
            let m = SyntheticLm::new(&cfg, 64, 7);
            let xc = m.sample_inputs(64, &mut rng);
            let xe = m.sample_inputs(32, &mut rng);
            let stats = m.calibrate(&xc);
            let zr = m.forward_reference(&xe);
            let zq = m.forward_quantized(&xe, QuantScheme::per_tensor(fmt), &stats);
            errs.push((zq.sub(&zr).fro_norm_sq() / zr.fro_norm_sq()).sqrt());
        }
        assert!(errs[1] < errs[0], "base {} vs tiny {}", errs[1], errs[0]);
    }

    #[test]
    fn draft_is_deterministic_and_in_vocab() {
        let d = DraftLm::tiny();
        let ctx: Vec<i32> = vec![5, 9, 2, 5, 9, 2, 5];
        let a = d.propose(&ctx, 8);
        let b = d.propose(&ctx, 8);
        assert_eq!(a, b, "same context must draft the same tokens");
        assert_eq!(a.len(), 8);
        let v = d.config().vocab as i32;
        assert!(a.iter().all(|&t| (0..v).contains(&t)), "{a:?}");
    }

    #[test]
    fn draft_extends_a_repeating_pattern_exactly() {
        // Prompt-lookup on a periodic context: the most recent earlier
        // occurrence of the last token predicts the true continuation,
        // so the draft free-runs the whole period — the high-acceptance
        // regime speculative decode exploits.
        let d = DraftLm::tiny();
        let ctx: Vec<i32> = (0..20).map(|i| [3, 7, 11][i % 3]).collect();
        let got = d.propose(&ctx, 6);
        let want: Vec<i32> = (20..26).map(|i| [3, 7, 11][i % 3]).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn draft_falls_back_when_context_has_no_match() {
        let d = DraftLm::tiny();
        // No earlier occurrence of the last token: the hash fallback
        // still yields gamma in-vocab tokens, deterministically.
        let got = d.propose(&[1, 2, 3, 4], 4);
        assert_eq!(got, d.propose(&[1, 2, 3, 4], 4));
        assert_eq!(got.len(), 4);
        assert!(d.propose(&[], 2).len() == 2, "empty context must not panic");
    }
}
